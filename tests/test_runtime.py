"""repro.runtime — the fixed-capacity slot runtime.

Acceptance pins (ISSUE 3): the slot loop's jitted local step traces
exactly once (zero retraces) over a churn trace with >= 3 distinct
alive counts, while the re-stack loop traces once per distinct count;
and SlotTrainLoop losses match ChurnTrainLoop on the same scripted
trace to fp tolerance.  Plus coverage for SlotMap planning, schedule
padding, mask-aware mixing (vs the dense oracle, including the
shard_map path on 8 host devices), masked local steps, on-device
multirate participation, capacity-mode + double-buffered controllers,
and the Fig.-18 donor-copy / fresh-init joiner paths.

ISSUE 4 additions: the grouped (clients_per_device = G > 1) churn path —
OverlayController capacity mode at capacity C = G × devices driving a
SlotTrainLoop whose capacity axis is sharded over the real 8-device
mesh — pins 0 retraces across ≥ 3 distinct alive counts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mixing import (build_permute_schedule, masked_mixing_matrix,
                               multirate_participation, pad_schedule,
                               participation_mults, schedule_mixing_matrix)
from repro.core.ndmp import Simulator
from repro.overlay import (ChurnTrace, ChurnTrainLoop, OverlayController,
                           joiner_donors)
from repro.runtime import (SlotCapacityError, SlotMap, SlotTrainLoop,
                           counting_jit, masked_local_step, masked_mean,
                           pad_to_capacity, participation_mask)


def make_sim(n=6, L=2, seed=0):
    sim = Simulator(num_spaces=L, latency=0.05, heartbeat_period=0.5,
                    probe_period=1.0, seed=seed)
    sim.seed_network(list(range(n)))
    return sim


# --------------------------------------------------------------------------
# SlotMap
# --------------------------------------------------------------------------

def test_slot_map_allocates_lowest_free_slot():
    sm = SlotMap(4, initial=(10, 11))
    assert sm.slot_of == {10: 0, 11: 1}
    sm.free(10)
    assert 10 not in sm and len(sm) == 1
    assert sm.alloc(12) == 0            # freed slot reused, lowest first
    assert sm.alloc(13) == 2
    assert sm.nodes() == (12, 11, 13)   # slot order
    with pytest.raises(ValueError, match="already holds"):
        sm.alloc(13)
    sm.alloc(14)
    with pytest.raises(SlotCapacityError):
        sm.alloc(15)
    with pytest.raises(KeyError):
        sm.free(99)


def test_slot_map_plan_is_pure_and_identity_preserving():
    sm = SlotMap(6, initial=(1, 2, 3, 4))
    plan = sm.plan((2, 3, 5, 6))        # 1,4 leave; 5,6 join
    assert dict(plan.survivors) == {2: 1, 3: 2}
    assert dict(plan.leavers) == {1: 0, 4: 3}
    assert dict(plan.joiners) == {5: 0, 6: 3}   # lowest freed slots
    assert plan.changed
    # pure: nothing moved yet
    assert sm.slot_of == {1: 0, 2: 1, 3: 2, 4: 3}
    sm.apply(plan)
    assert sm.slot_of == {2: 1, 3: 2, 5: 0, 6: 3}
    np.testing.assert_array_equal(sm.alive_mask(),
                                  [1, 1, 1, 1, 0, 0])
    # no-op plan
    plan2 = sm.plan((2, 3, 5, 6))
    assert not plan2.changed and plan2.slot_of == sm.slot_of


def test_slot_map_plan_overflow_raises():
    sm = SlotMap(3, initial=(0, 1, 2))
    with pytest.raises(SlotCapacityError):
        sm.plan((0, 1, 2, 3))
    with pytest.raises(ValueError, match="duplicate"):
        sm.plan((0, 0, 1))


# --------------------------------------------------------------------------
# Capacity padding + mask-aware mixing
# --------------------------------------------------------------------------

def test_pad_schedule_dense_equivalence_and_dead_self_loops():
    sched = build_permute_schedule(5, 2)
    slots = (0, 2, 3, 5, 6)
    padded = pad_schedule(sched, slots, 8)
    assert padded.num_clients == 8
    Wp = schedule_mixing_matrix(padded)
    W = schedule_mixing_matrix(sched)
    idx = np.asarray(slots)
    np.testing.assert_allclose(Wp[np.ix_(idx, idx)], W, atol=1e-7)
    np.testing.assert_allclose(Wp.sum(axis=1), 1.0, atol=1e-6)
    for dead in (1, 4, 7):
        expect = np.zeros(8)
        expect[dead] = 1.0                  # self-loop with weight 1
        np.testing.assert_allclose(Wp[dead], expect)
        assert all(p[dead] == dead for p in padded.perms)


def test_pad_schedule_rejects_bad_assignments():
    sched = build_permute_schedule(4, 2)
    with pytest.raises(ValueError, match="one slot per"):
        pad_schedule(sched, (0, 1, 2), 8)
    with pytest.raises(ValueError, match="duplicate"):
        pad_schedule(sched, (0, 1, 1, 2), 8)
    with pytest.raises(ValueError, match="out of range"):
        pad_schedule(sched, (0, 1, 2, 8), 8)


def test_pad_to_capacity_uses_sorted_alive_order():
    sm = SlotMap(6, initial=(7, 3, 9))      # slots: 7->0, 3->1, 9->2
    sched = build_permute_schedule(3, 2)    # alive order sorted: 3,7,9
    padded = pad_to_capacity(sched, sm)
    W = schedule_mixing_matrix(sched)
    Wp = schedule_mixing_matrix(padded)
    idx = np.asarray([sm.slot_of[u] for u in (3, 7, 9)])
    np.testing.assert_allclose(Wp[np.ix_(idx, idx)], W, atol=1e-7)


def test_masked_global_mixer_matches_dense_oracle():
    from repro.dist.sync import global_mixer
    sched = build_permute_schedule(8, 2)
    mix = jax.jit(global_mixer("fedlay", sched, masked=True))
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(8, 17)).astype(np.float32))
    mask = np.asarray([1, 1, 0, 1, 0, 1, 1, 1], np.float32)
    out = np.asarray(mix(X, jnp.asarray(mask)))
    ref = masked_mixing_matrix(sched, mask) @ np.asarray(X)
    np.testing.assert_allclose(out, ref, atol=1e-5)
    # masked-out rows pass through untouched
    np.testing.assert_array_equal(out[2], np.asarray(X)[2])
    # all-ones mask degenerates to the unmasked mixer
    ones = jnp.ones((8,), jnp.float32)
    ref_plain = np.asarray(global_mixer("fedlay", sched)(X))
    np.testing.assert_allclose(np.asarray(mix(X, ones)), ref_plain,
                               atol=1e-6)


def test_masked_allreduce_mixer_means_live_rows_only():
    from repro.dist.sync import global_mixer
    mix = global_mixer("allreduce", masked=True)
    X = jnp.asarray(np.arange(12, dtype=np.float32).reshape(4, 3))
    mask = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    out = np.asarray(mix({"w": X}, mask)["w"])
    live_mean = np.asarray(X)[[0, 2]].mean(axis=0)
    np.testing.assert_allclose(out[0], live_mean, atol=1e-6)
    np.testing.assert_allclose(out[2], live_mean, atol=1e-6)
    np.testing.assert_array_equal(out[1], np.asarray(X)[1])  # untouched
    np.testing.assert_array_equal(out[3], np.asarray(X)[3])


@pytest.mark.multi_device
def test_masked_fedlay_mix_shard_map_matches_dense_oracle(multi_device):
    """Mask-aware ppermute mixing on 8 host devices ≡ the dense oracle —
    inline on the tier-1 forced host mesh (used to be a subprocess)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist.compat import make_client_mesh, shard_map
    from repro.dist.sync import fedlay_mix

    n, dim = 8, 24
    mesh = make_client_mesh(n, "data")
    sched = build_permute_schedule(n, 2)
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(n, dim)).astype(np.float32))
    mask = np.asarray([1, 0, 1, 1, 1, 0, 1, 1], np.float32)
    W = jnp.asarray(sched.weights)
    S = jnp.asarray(sched.self_weight)

    def body(x, w, s, m):
        return fedlay_mix({"m": x}, sched, w, s, "data", mask=m)["m"]

    f = jax.jit(shard_map(body, mesh=mesh,
                          in_specs=(P("data"), P("data"), P("data"),
                                    P("data")),
                          out_specs=P("data"), check_vma=False))
    shard = NamedSharding(mesh, P("data"))
    out = f(jax.device_put(X, shard), jax.device_put(W, shard),
            jax.device_put(S, shard),
            jax.device_put(jnp.asarray(mask), shard))
    ref = masked_mixing_matrix(sched, mask) @ np.asarray(X)
    assert float(np.abs(np.asarray(out) - ref).max()) < 1e-5


# --------------------------------------------------------------------------
# Masked local step + participation
# --------------------------------------------------------------------------

def test_masked_local_step_freezes_dead_rows_and_contains_nan():
    def step(params, opt_state, batch):
        w = params["w"] + batch["x"]
        loss = jnp.mean(w, axis=-1)
        return {"w": w}, opt_state, {"loss": loss}

    params = {"w": jnp.ones((4, 3))}
    batch = {"x": jnp.asarray(
        np.stack([np.full(3, 1.0), np.full(3, np.nan),
                  np.full(3, 2.0), np.full(3, np.nan)]),
        jnp.float32)}
    mask = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    mstep = jax.jit(masked_local_step(step))
    new_p, _, metrics = mstep(params, (), batch, mask)
    out = np.asarray(new_p["w"])
    np.testing.assert_allclose(out[0], 2.0)      # live: updated
    np.testing.assert_allclose(out[2], 3.0)
    np.testing.assert_allclose(out[1], 1.0)      # dead: frozen, NaN blocked
    np.testing.assert_allclose(out[3], 1.0)
    loss = float(np.asarray(metrics["loss"]))
    assert np.isfinite(loss)
    assert loss == pytest.approx((2.0 + 3.0) / 2)


def test_masked_mean_matches_numpy_oracle():
    v = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    m = jnp.asarray([1.0, 0.0, 1.0, 1.0])
    assert float(masked_mean(v, m)) == pytest.approx((1 + 3 + 4) / 3)
    assert float(masked_mean(v, jnp.zeros(4))) == 0.0   # guarded denom
    # 2-D metrics leaf: mean over live elements
    v2 = jnp.asarray(np.arange(8, dtype=np.float32).reshape(4, 2))
    want = np.asarray(v2)[[0, 2, 3]].mean()
    assert float(masked_mean(v2, m)) == pytest.approx(want)


def test_participation_mask_on_device_matches_host():
    periods = (1.0, 2.0, 4.0)
    mults = participation_mults(periods)
    np.testing.assert_array_equal(mults, [1, 2, 4])
    masker = jax.jit(lambda t: participation_mask(mults, t))
    for step in range(8):
        np.testing.assert_array_equal(
            np.asarray(masker(step)),
            multirate_participation(periods, step))


# --------------------------------------------------------------------------
# Capacity-mode + double-buffered controller
# --------------------------------------------------------------------------

def test_controller_capacity_mode_pads_and_masks():
    ctl = OverlayController(make_sim(n=6), capacity=8)
    assert ctl.schedule.num_clients == 8
    assert ctl.alive_schedule.num_clients == 6
    assert ctl.alive_mask().sum() == 6
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(8, 9)).astype(np.float32))
    out = np.asarray(ctl.mixer(X, jnp.asarray(ctl.alive_mask())))
    ref = masked_mixing_matrix(ctl.schedule, ctl.alive_mask()) @ np.asarray(X)
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_controller_capacity_fail_rejoin_is_cache_hit():
    """Same alive set + identity-preserving slots ⇒ same padded schedule
    digest ⇒ the swap back is a pure cache hit (zero retrace)."""
    sim = make_sim(n=6)
    ctl = OverlayController(sim, capacity=8)
    original = ctl.schedule
    misses0 = ctl.cache.misses
    for _ in range(20):
        ctl.step(1.0, trace=ChurnTrace.scripted(
            [(sim.now + 0.1, "fail", 2)]))
        if len(ctl.alive) == 5:
            break
    assert ctl.schedule != original
    assert ctl.alive_mask().sum() == 5
    trace = ChurnTrace.scripted([(sim.now + 0.1, "join", 2, 0)])
    for _ in range(20):
        ctl.step(1.0, trace=trace)
        trace = None
        if len(ctl.alive) == 6:
            break
    assert ctl.schedule == original     # node 2 reclaimed its old slot
    assert ctl.cache.misses == misses0 + 1


def test_controller_double_buffered_swaps_only_at_commit():
    sim = make_sim(n=6)
    ctl = OverlayController(sim, capacity=8, double_buffered=True)
    mixer0, sched0 = ctl.mixer, ctl.schedule
    trace = ChurnTrace.scripted([(sim.now + 0.1, "fail", 4)])
    swapped = False
    for _ in range(20):
        r = ctl.step(1.0, trace=trace)
        trace = None
        if r.swapped:
            swapped = True
            break
    assert swapped
    # staged, not live: the data plane still sees the old program
    assert ctl.mixer is mixer0 and ctl.schedule == sched0
    assert 4 in ctl.slots
    plan = ctl.commit()
    assert plan is not None and dict(plan.leavers)
    assert ctl.mixer is not mixer0 and ctl.schedule != sched0
    assert 4 not in ctl.slots
    # idempotent at quiescence
    ctl.step(1.0)
    assert ctl.commit() is None


def test_controller_capacity_requires_global_mixer_kind():
    with pytest.raises(ValueError, match="capacity mode"):
        OverlayController(make_sim(n=4), capacity=8,
                          mixer_kind="shard_map")


# --------------------------------------------------------------------------
# Joiner donors: the Fig.-18 catch-up selection (satellite coverage)
# --------------------------------------------------------------------------

def test_joiner_donors_all_joiner_cohort_falls_back_to_fresh_init():
    """A mass-join cohort with no surviving neighbors gets None for
    every joiner (fresh-init fallback)."""
    sched = build_permute_schedule(6, 2)
    alive = tuple(range(6))
    donors = joiner_donors(sched, alive, joiners=alive, survivors=())
    assert donors == {u: None for u in alive}


def test_joiner_donors_picks_highest_weight_survivor():
    sched = build_permute_schedule(6, 2)
    alive = tuple(range(6))
    donors = joiner_donors(sched, alive, joiners=(3,),
                           survivors=(0, 1, 2, 4, 5))
    donor = donors[3]
    weights = {}
    for k in range(sched.num_slots):
        src = alive[sched.perms[k][3]]
        if src != 3:
            weights[src] = max(weights.get(src, 0.0),
                               float(sched.weights[3, k]))
    assert donor is not None and weights[donor] == max(weights.values())


# --------------------------------------------------------------------------
# SlotTrainLoop: the ISSUE acceptance pins
# --------------------------------------------------------------------------

DIM = 32


def _make_params(u):
    w = np.random.default_rng(u).normal(size=DIM).astype(np.float32)
    return {"w": jnp.asarray(w)}


def _make_batch(node_ids, step):
    rows = [np.random.default_rng(abs(hash((u, step))) % 2**32)
            .normal(size=DIM).astype(np.float32) for u in node_ids]
    return {"x": jnp.asarray(np.stack(rows))}


def _base_step(lr=0.05):
    def step(params, opt_state, batch):
        w, x = params["w"], batch["x"]
        loss = jnp.mean((w - x) ** 2, axis=-1)
        grad = 2.0 * (w - x) / DIM
        return {"w": w - lr * grad}, opt_state, {"loss": loss}
    return step


def _restack_step(lr=0.05):
    base = _base_step(lr)

    def step(params, opt_state, batch):
        p, o, m = base(params, opt_state, batch)
        return p, o, {"loss": jnp.mean(m["loss"])}
    return step


def _churn():
    return ChurnTrace.scripted([
        (2.5, "fail", 1), (4.5, "fail", 3),
        (6.5, "join", 100, 0), (8.5, "join", 101, 0),
    ])


def test_slot_loop_matches_restack_loop_and_never_retraces():
    from repro.optim.optimizers import sgd
    opt = sgd(0.0)
    rjit, rcount = counting_jit(_restack_step())
    restack = ChurnTrainLoop(
        OverlayController(make_sim(n=6)), local_step=rjit,
        make_params=_make_params, optimizer=opt, make_batch=_make_batch,
        jit_local_step=False)
    recs_r = restack.run(12, trace=_churn())

    sjit, scount = counting_jit(masked_local_step(_base_step()))
    slot = SlotTrainLoop(
        OverlayController(make_sim(n=6), capacity=8), local_step=sjit,
        make_params=_make_params, optimizer=opt, make_batch=_make_batch,
        jit_local_step=False)
    recs_s = slot.run(12, trace=_churn())

    # identical churn observation
    assert [r.num_alive for r in recs_r] == [r.num_alive for r in recs_s]
    assert [r.joined for r in recs_r] == [r.joined for r in recs_s]
    assert [r.left for r in recs_r] == [r.left for r in recs_s]
    alive_counts = {r.num_alive for r in recs_s}
    assert len(alive_counts) >= 3
    # loss parity to fp tolerance
    np.testing.assert_allclose([r.loss for r in recs_r],
                               [r.loss for r in recs_s],
                               rtol=1e-5, atol=1e-5)
    # the acceptance pin: static shapes never retrace, re-stack pays one
    # trace per distinct alive count
    assert scount.traces == 1 and scount.retraces == 0
    assert rcount.traces == len(alive_counts)


def test_slot_loop_joiner_donor_copy_and_fresh_optimizer():
    """lr=0 + identity mixer ⇒ params are pure lineage markers: the
    joiner's row must equal its donor's init exactly (Fig.-18 catch-up),
    not its own fresh init."""
    from repro.optim.optimizers import sgd
    ctl = OverlayController(
        make_sim(n=4), capacity=6,
        mixer_factory=lambda sched: (lambda params, mask: params))
    loop = SlotTrainLoop(
        ctl, local_step=masked_local_step(_base_step(lr=0.0)),
        make_params=_make_params, optimizer=sgd(0.0),
        make_batch=_make_batch)
    loop.run(8, trace=ChurnTrace.scripted([(2.5, "join", 50, 0)]))
    assert 50 in ctl.slots
    joined = np.asarray(loop.client_params(50)["w"])
    donors = {u: np.asarray(_make_params(u)["w"]) for u in range(4)}
    fresh = np.asarray(_make_params(50)["w"])
    assert any(np.array_equal(joined, d) for d in donors.values())
    assert not np.array_equal(joined, fresh)


def test_slot_loop_multirate_participation_skips_mixing():
    """A slow client (period 4) trains locally every step but only mixes
    when step % 4 == 0; with lr=0 its params change exactly on
    participating steps."""
    from repro.optim.optimizers import sgd
    slow = 2
    ctl = OverlayController(make_sim(n=4), capacity=4)
    loop = SlotTrainLoop(
        ctl, local_step=masked_local_step(_base_step(lr=0.0)),
        make_params=_make_params, optimizer=sgd(0.0),
        make_batch=_make_batch,
        periods={u: (4.0 if u == slow else 1.0) for u in range(4)})
    snaps = []
    for _ in range(6):
        loop.run(1)
        snaps.append({u: np.asarray(loop.client_params(u)["w"])
                      for u in (0, slow)})
    assert [r.participating for r in loop.records] == [4, 3, 3, 3, 4, 3]
    for t in range(1, 6):
        fast_moved = not np.array_equal(snaps[t][0], snaps[t - 1][0])
        slow_moved = not np.array_equal(snaps[t][slow],
                                        snaps[t - 1][slow])
        assert fast_moved              # period-1 clients mix every step
        assert slow_moved == (t % 4 == 0)


def test_restack_loop_commits_double_buffered_controller():
    """Regression: ChurnTrainLoop must land staged swaps before using
    report.alive — otherwise it re-stacks to the staged membership but
    mixes with the stale uncommitted program.  With commit() in the
    loop, a double_buffered controller matches the immediate one."""
    from repro.optim.optimizers import sgd
    opt = sgd(0.0)
    runs = []
    for db in (False, True):
        loop = ChurnTrainLoop(
            OverlayController(make_sim(n=5), double_buffered=db),
            local_step=_restack_step(), make_params=_make_params,
            optimizer=opt, make_batch=_make_batch)
        runs.append(loop.run(10, trace=ChurnTrace.scripted(
            [(2.5, "fail", 1), (4.5, "join", 77, 0)])))
    immediate, buffered = runs
    assert [r.num_alive for r in immediate] == \
        [r.num_alive for r in buffered]
    np.testing.assert_allclose([r.loss for r in immediate],
                               [r.loss for r in buffered], rtol=1e-6)


def test_slot_loop_over_double_buffered_controller():
    """With double_buffered staging, the loop's commit() at the step
    boundary still lands every membership change exactly once."""
    from repro.optim.optimizers import sgd
    ctl = OverlayController(make_sim(n=5), capacity=8,
                            double_buffered=True)
    loop = SlotTrainLoop(
        ctl, local_step=masked_local_step(_base_step()),
        make_params=_make_params, optimizer=sgd(0.0),
        make_batch=_make_batch)
    recs = loop.run(10, trace=ChurnTrace.scripted(
        [(2.5, "fail", 1), (4.5, "join", 77, 0)]))
    assert [r.left for r in recs if r.left] == [(1,)]
    assert [r.joined for r in recs if r.joined] == [(77,)]
    assert recs[-1].num_alive == 5 and 77 in ctl.slots
    assert all(np.isfinite(r.loss) for r in recs)


@pytest.mark.multi_device
def test_grouped_slot_loop_capacity_2x_devices_zero_retrace(multi_device):
    """The ISSUE 4 acceptance pin: capacity C = 2 × devices (G = 2) on
    the real 8-device mesh — the slot loop's jitted local step and the
    controller's mask-aware mixers hold 0 retraces across a churn trace
    with ≥ 3 distinct alive counts, with every capacity-stacked row
    tree genuinely sharded over the mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist.compat import make_client_mesh
    from repro.optim.optimizers import sgd

    mesh = make_client_mesh(8, "data")
    ctl = OverlayController(make_sim(n=12), capacity=16,
                            clients_per_device=2)
    sjit, scount = counting_jit(masked_local_step(_base_step()))
    loop = SlotTrainLoop(
        ctl, local_step=sjit, make_params=_make_params, optimizer=sgd(0.0),
        make_batch=_make_batch, jit_local_step=False, mesh=mesh)
    # the capacity axis is genuinely distributed: 2 rows per device
    assert loop.params["w"].sharding == NamedSharding(mesh, P("data", None))
    recs = loop.run(12, trace=ChurnTrace.scripted([
        (2.5, "fail", 1), (4.5, "fail", 3),
        (6.5, "join", 100, 0), (8.5, "join", 101, 0),
    ]))
    assert len({r.num_alive for r in recs}) >= 3
    assert all(np.isfinite(r.loss) for r in recs)
    # zero retraces: one trace ever for the local step, and every
    # post-churn mixer program came out of the schedule-keyed cache on
    # revisit (fail -> rejoin restores the padded-schedule digest)
    assert scount.traces == 1 and scount.retraces == 0
    assert loop.params["w"].sharding == NamedSharding(mesh, P("data", None))


def test_grouped_slot_loop_rejects_mismatched_mesh():
    from repro.dist.compat import make_client_mesh
    from repro.optim.optimizers import sgd
    mesh = make_client_mesh(8, "data")
    ctl = OverlayController(make_sim(n=4), capacity=8)   # G=1, 8 = 1×8 ok
    SlotTrainLoop(ctl, local_step=masked_local_step(_base_step()),
                  make_params=_make_params, optimizer=sgd(0.0),
                  make_batch=_make_batch, mesh=mesh)
    ctl2 = OverlayController(make_sim(n=4), capacity=16)  # 16 != 1×8
    with pytest.raises(ValueError, match="capacity 16"):
        SlotTrainLoop(ctl2, local_step=masked_local_step(_base_step()),
                      make_params=_make_params, optimizer=sgd(0.0),
                      make_batch=_make_batch, mesh=mesh)


def test_controller_capacity_must_divide_into_groups():
    with pytest.raises(ValueError, match="multiple"):
        OverlayController(make_sim(n=4), capacity=9, clients_per_device=2)


def test_slot_loop_capacity_overflow_raises():
    from repro.optim.optimizers import sgd
    from repro.runtime import SlotCapacityError
    ctl = OverlayController(make_sim(n=4), capacity=4)
    loop = SlotTrainLoop(
        ctl, local_step=masked_local_step(_base_step()),
        make_params=_make_params, optimizer=sgd(0.0),
        make_batch=_make_batch)
    with pytest.raises(SlotCapacityError):
        loop.run(6, trace=ChurnTrace.scripted([(1.5, "join", 70, 0)]))


def test_slot_loop_requires_capacity_controller():
    from repro.optim.optimizers import sgd
    with pytest.raises(ValueError, match="capacity"):
        SlotTrainLoop(OverlayController(make_sim(n=4)),
                      local_step=masked_local_step(_base_step()),
                      make_params=_make_params, optimizer=sgd(0.0),
                      make_batch=_make_batch)


def test_slot_loop_drives_masked_dfl_train_bundle():
    """The real integration: dfl_train_bundle(masked=True) local step
    under the slot runtime (smoke-scale model, one join)."""
    import dataclasses
    from repro.configs import REGISTRY, reduce_for_smoke
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import dfl_train_bundle
    from repro.models import init_params
    from repro.models.config import INPUT_SHAPES
    from repro.optim.optimizers import adamw
    cfg = reduce_for_smoke(REGISTRY["qwen3-4b"])
    capacity = 3
    shape = dataclasses.replace(INPUT_SHAPES["train_4k"],
                                global_batch=capacity, seq_len=32)
    mesh = make_local_mesh(1, 1)
    opt = adamw(1e-3)
    bundle = dfl_train_bundle(cfg, shape, mesh, opt, dtype=jnp.float32,
                              sync="none", masked=True)
    assert len(bundle.arg_shapes) == 4
    stacked = jax.tree.leaves(bundle.arg_shapes[0])[0]
    assert bundle.arg_shapes[3].shape == (stacked.shape[0],)
    per_client = {k: v.shape[1:] for k, v in bundle.arg_shapes[2].items()}

    def make_params(node_id):
        return init_params(cfg, jax.random.PRNGKey(node_id),
                           dtype=jnp.float32)

    def make_batch(node_ids, step):
        out = {}
        for k, shp in per_client.items():
            rows = [np.random.default_rng(
                abs(hash((u, step, k))) % 2**32).integers(
                    0, cfg.vocab_size, shp) for u in node_ids]
            out[k] = jnp.asarray(np.stack(rows), jnp.int32)
        return out

    ctl = OverlayController(make_sim(n=2), capacity=capacity)
    loop = SlotTrainLoop(ctl, local_step=bundle.step,
                         make_params=make_params, optimizer=opt,
                         make_batch=make_batch)
    recs = loop.run(4, trace=ChurnTrace.scripted([(1.5, "join", 50, 0)]))
    assert all(np.isfinite(r.loss) for r in recs)
    assert recs[-1].num_alive == 3
    assert loop.controller.alive == (0, 1, 50)


def test_slot_loop_resident_flat_matches_tree_loop():
    """ISSUE 7 satellite: with OverlayController(flat_io=True) the loop
    keeps the population as the resident (capacity, N) flat buffer —
    ravel/unravel leaves the hot loop — yet observes identical churn,
    loss parity, zero retraces, and identity-preserving client_params."""
    from repro.optim.optimizers import sgd
    opt = sgd(0.0)
    tjit, _ = counting_jit(masked_local_step(_base_step()))
    tree_loop = SlotTrainLoop(
        OverlayController(make_sim(n=6), capacity=8, fuse="flat"),
        local_step=tjit, make_params=_make_params, optimizer=opt,
        make_batch=_make_batch, jit_local_step=False)
    recs_t = tree_loop.run(12, trace=_churn())

    fjit, fcount = counting_jit(masked_local_step(_base_step()))
    flat_loop = SlotTrainLoop(
        OverlayController(make_sim(n=6), capacity=8, fuse="flat",
                          flat_io=True),
        local_step=fjit, make_params=_make_params, optimizer=opt,
        make_batch=_make_batch, jit_local_step=False)
    assert flat_loop.flat_io
    recs_f = flat_loop.run(12, trace=_churn())

    assert [r.num_alive for r in recs_t] == [r.num_alive for r in recs_f]
    np.testing.assert_allclose([r.loss for r in recs_t],
                               [r.loss for r in recs_f],
                               rtol=1e-5, atol=1e-5)
    assert fcount.traces == 1 and fcount.retraces == 0
    # client_params unravels one row back to the tree contract
    for u in (0, 100):
        pt = np.asarray(tree_loop.client_params(u)["w"])
        pf = np.asarray(flat_loop.client_params(u)["w"])
        np.testing.assert_allclose(pf, pt, rtol=1e-6, atol=1e-6)


def test_slot_loop_checkpoint_roundtrip_bit_exact(tmp_path):
    """ISSUE 10 satellite: save/restore of the full slot-runtime state
    — resident flat rows, optimizer state, EF residual, step counter —
    is bit-exact, with slot occupancy validated against the checkpoint
    and wire-config mismatches rejected."""
    from repro.optim.optimizers import sgd
    opt = sgd(0.0)

    def build(n=6):
        ctl = OverlayController(make_sim(n=n), capacity=8, fuse="flat",
                                codec="int8-block", flat_io=True)
        return SlotTrainLoop(ctl, local_step=masked_local_step(_base_step()),
                             make_params=_make_params, optimizer=opt,
                             make_batch=_make_batch)

    loop = build()
    assert loop.ef and loop.flat_io
    loop.run(5)
    assert float(np.abs(np.asarray(loop.residual)).max()) > 0  # EF active
    path = str(tmp_path / "slot.npz")
    loop.save(path)

    # a brand-new stack: control plane replayed, then state restored
    fresh = build()
    for _ in range(5):
        fresh.controller.step(1.0)
        fresh.controller.commit()
    meta = fresh.restore(path)
    assert meta["step"] == 5 and fresh._step == 5
    np.testing.assert_array_equal(np.asarray(loop.params),
                                  np.asarray(fresh.params))
    np.testing.assert_array_equal(np.asarray(loop.residual),
                                  np.asarray(fresh.residual))
    for a, b in zip(jax.tree.leaves(loop.opt_state),
                    jax.tree.leaves(fresh.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # occupancy metadata survived: -1 for the two empty slots
    assert meta["slots"].count(-1) == 2
    # resumed run == uninterrupted run, bit for bit
    recs_a = loop.run(3)
    recs_b = fresh.run(3)
    np.testing.assert_array_equal(np.asarray(loop.params),
                                  np.asarray(fresh.params))
    assert [r.loss for r in recs_a[-3:]] == [r.loss for r in recs_b[-3:]]

    # a loop with a different wire config must refuse the checkpoint
    plain = SlotTrainLoop(
        OverlayController(make_sim(n=6), capacity=8),
        local_step=masked_local_step(_base_step()),
        make_params=_make_params, optimizer=opt, make_batch=_make_batch)
    with pytest.raises(ValueError, match="wire configuration"):
        plain.restore(path)
