"""Vectorized NDMP engine (repro.scale.ndmp_vec) vs the object
simulator: both are NDMP engines behind the same
:class:`repro.core.ndmp.SimulatorProtocol` seam, and on any churn trace
their **converged** states must be identical — neighbor tables,
exported flat arrays, Definition-1 correctness, and the schedules (and
hence confidence-weighted mixing weights) built from their alive sets.
Includes a hypothesis fuzz over batched event orderings (shimmed to
skip when hypothesis is not installed)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.coords import coordinates, coordinates_batch
from repro.core.mep import ClientProfile
from repro.core.mixing import schedule_from_addresses
from repro.core.ndmp import Simulator, SimulatorProtocol
from repro.scale import VectorSimulator

KW = dict(num_spaces=3, latency=0.05, heartbeat_period=0.5,
          probe_period=1.0)


def make_pair(n, seed=0):
    obj = Simulator(seed=seed, **KW)
    obj.seed_network(list(range(n)))
    vec = VectorSimulator(**KW)
    vec.seed_network(range(n))
    return obj, vec


# --------------------------------------------------------------------------
# Protocol seam
# --------------------------------------------------------------------------

def test_both_engines_satisfy_protocol():
    obj, vec = make_pair(10)
    assert isinstance(obj, SimulatorProtocol)
    assert isinstance(vec, SimulatorProtocol)


def test_tables_version_is_a_change_detector():
    _, vec = make_pair(20)
    v0 = vec.tables_version()
    vec.advance(5.0)
    assert vec.tables_version() == v0          # idle: no change
    vec.fail(3)
    vec.run_for(10.0)
    assert vec.tables_version() != v0


# --------------------------------------------------------------------------
# Batch coordinate hashing
# --------------------------------------------------------------------------

def test_coordinates_batch_bit_exact():
    ids = [0, 1, 7, 123, 10**12, 2**40 + 17]
    got = coordinates_batch(ids, 4, salt="s")
    for i, u in enumerate(ids):
        assert tuple(got[i]) == coordinates(u, 4, salt="s")


# --------------------------------------------------------------------------
# Converged-state parity on seeded traces (n <= 200)
# --------------------------------------------------------------------------

def assert_converged_equal(obj, vec):
    assert obj.correctness() == 1.0
    assert vec.correctness() == 1.0
    assert obj.alive_ids() == vec.alive_ids()
    assert obj.neighbor_tables() == vec.neighbor_tables()


@pytest.mark.parametrize("n", [30, 200])
def test_parity_join_leave_fail_trace(n):
    obj, vec = make_pair(n)
    assert_converged_equal(obj, vec)
    # interleaved churn: joins, abrupt failures, graceful leaves
    for j in range(n + 100, n + 100 + 5):
        obj.join(j, bootstrap=n // 2)
        vec.join(j)
    obj.run_for(8.0)
    vec.run_for(8.0)
    for f in (1, 4, 9):
        obj.fail(f)
        vec.fail(f)
    for v in (2, 6):
        obj.leave(v)
        vec.leave(v)
    obj.run_for(40.0)
    vec.run_for(40.0)
    assert_converged_equal(obj, vec)


def test_parity_export_state():
    obj, vec = make_pair(40)
    for f in (3, 8):
        obj.fail(f)
        vec.fail(f)
    obj.run_for(30.0)
    vec.run_for(30.0)
    a, b = obj.export_state(), vec.export_state()
    np.testing.assert_array_equal(a["ids"], b["ids"])
    np.testing.assert_array_equal(a["coords"], b["coords"])  # bit-exact
    np.testing.assert_array_equal(a["succ"], b["succ"])
    np.testing.assert_array_equal(a["pred"], b["pred"])


def test_parity_schedule_weights():
    """Identical alive sets + identical MEP profiles → bit-identical
    confidence-weighted mixing schedules from either engine."""
    obj, vec = make_pair(24)
    obj.fail(5)
    vec.fail(5)
    obj.run_for(30.0)
    vec.run_for(30.0)
    hist = np.ones(4)
    profiles = {u: ClientProfile(client_id=u, period=1.0 + (u % 3),
                                 label_histogram=hist * (1 + u % 5))
                for u in obj.alive_ids()}
    sa = schedule_from_addresses(obj.alive_addresses(), profiles=profiles)
    sb = schedule_from_addresses(vec.alive_addresses(), profiles=profiles)
    np.testing.assert_array_equal(sa.perms, sb.perms)
    np.testing.assert_array_equal(sa.weights, sb.weights)
    np.testing.assert_array_equal(sa.self_weight, sb.self_weight)


def test_from_simulator_adopts_membership():
    obj, _ = make_pair(25)
    obj.fail(7)
    obj.run_for(30.0)
    vec = VectorSimulator.from_simulator(obj)
    assert vec.alive_ids() == obj.alive_ids()
    assert vec.neighbor_tables() == obj.neighbor_tables()


# --------------------------------------------------------------------------
# Vectorized engine semantics
# --------------------------------------------------------------------------

def test_mid_repair_correctness_dips_then_recovers():
    """The engine models protocol *timing*, not just the fixed point:
    a failure is invisible until detection + repair completes."""
    _, vec = make_pair(50)
    vec.fail_batch([1, 2, 3])
    assert vec.correctness() < 1.0     # stale pointers during repair
    vec.run_for(30.0)
    assert vec.correctness() == 1.0


def test_batch_churn_rejects_bad_ops():
    _, vec = make_pair(10)
    with pytest.raises(ValueError):
        vec.join_batch([3])            # already alive
    with pytest.raises(KeyError):
        vec.fail_batch([99])           # not alive


def test_rejoin_after_failure():
    _, vec = make_pair(12)
    vec.fail(4)
    vec.run_for(30.0)
    vec.join(4)
    vec.run_for(30.0)
    assert 4 in vec.alive_ids()
    assert vec.correctness() == 1.0


def test_large_population_batch_churn_converges():
    """10^4 nodes: seed + 1% batched churn, exact repair — the fig20
    scale path in miniature (the full 10^5/10^6 budget is the
    benchmark's claim, not tier-1's)."""
    vec = VectorSimulator(**KW)
    vec.seed_network(range(10_000))
    vec.fail_batch(range(100))
    vec.join_batch(range(20_000, 20_100))
    vec.run_for(30.0)
    assert len(vec.alive_ids()) == 10_000
    assert vec.correctness() == 1.0


# --------------------------------------------------------------------------
# Property: any batched event ordering converges to the object fixpoint
# --------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["join", "fail", "leave"]),
                          st.integers(0, 10_000)),
                min_size=1, max_size=10),
       st.integers(0, 3))
def test_fuzz_batched_churn_parity(events, seed):
    """Property: the object engine applies events one by one, the
    vectorized engine in per-kind batches — same converged network."""
    n = 40
    obj, vec = make_pair(n, seed=seed)
    alive = set(range(n))
    next_id = 1000
    batch = {"join": [], "fail": [], "leave": []}
    for kind, jitter in events:
        if kind == "join":
            order = sorted(alive)
            obj.join(next_id, bootstrap=int(order[jitter % len(order)]))
            batch["join"].append(next_id)
            alive.add(next_id)
            next_id += 1
        elif len(alive) > 25:
            victim = sorted(alive)[jitter % len(alive)]
            if victim in batch["join"]:
                continue               # same-instant join+depart: skip
            getattr(obj, kind)(victim)
            batch[kind].append(victim)
            alive.discard(victim)
    if batch["fail"]:
        vec.fail_batch(batch["fail"])
    if batch["leave"]:
        vec.leave_batch(batch["leave"])
    if batch["join"]:
        vec.join_batch(batch["join"])
    obj.run_for(60.0)
    vec.run_for(60.0)
    assert_converged_equal(obj, vec)
