"""FedLay topology (Def. 1) and the correctness metric."""

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.coords import NodeAddress
from repro.core.topology import (correct_neighbor_sets, correctness,
                                 fedlay_topology, make_edge, ring_orders)


@given(st.integers(3, 80), st.integers(1, 5), st.integers(0, 3))
def test_degree_at_most_2L_and_connected(n, L, salt):
    addrs = [NodeAddress.create(i, L, salt=str(salt)) for i in range(n)]
    topo = fedlay_topology(addrs)
    assert topo.is_connected()
    degs = topo.degrees()
    assert max(degs.values()) <= 2 * L
    # every node has at least 2 neighbors (ring closure per space), n>=3
    assert min(degs.values()) >= 2 if L >= 1 else True


def test_correct_network_scores_one():
    addrs = [NodeAddress.create(i, 3) for i in range(40)]
    want = correct_neighbor_sets(addrs)
    assert correctness(want, addrs) == 1.0


def test_missing_and_stale_entries_reduce_correctness():
    addrs = [NodeAddress.create(i, 3) for i in range(40)]
    want = {u: set(v) for u, v in correct_neighbor_sets(addrs).items()}
    # remove one entry
    u = next(iter(want))
    want[u].pop()
    assert correctness(want, addrs) < 1.0
    # stale extra entry also penalized
    want2 = {u: set(v) for u, v in correct_neighbor_sets(addrs).items()}
    v = next(iter(want2))
    want2[v].add(10_000)
    assert correctness(want2, addrs) < 1.0


def test_make_edge_rejects_self_loop():
    with pytest.raises(ValueError):
        make_edge(3, 3)


def test_ring_orders_consistent_with_topology():
    addrs = [NodeAddress.create(i, 2) for i in range(25)]
    topo = fedlay_topology(addrs)
    orders = ring_orders(addrs)
    edges = set()
    for order in orders:
        n = len(order)
        for i in range(n):
            edges.add(make_edge(order[i], order[(i + 1) % n]))
    assert edges == set(topo.edges)
