"""Distribution layer: sharding rules, divisibility enforcement, and the
FedLay ppermute mixer — verified against the dense mixing matrix on the
8-device host mesh tier-1 runs on (forced by ``tests/conftest.py``)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import (dfl_client_count, enforce_divisibility,
                                 param_specs, spec_for_leaf)
from repro.dist.sync import sync_bytes_per_client
from repro.models import init_params
from repro.models.config import ArchConfig, MoEConfig
from repro.models.model import find_segments, layer_plan


def small_cfg():
    return ArchConfig(name="t", family="moe", num_layers=4, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=256, first_dense_layers=2,
                      moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                                    num_shared=1))


def test_param_specs_rules():
    cfg = small_cfg()
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(params, fsdp="data", tp="model")
    assert specs["embed"] == P("model", "data")
    seg1 = specs["seg1"]["sub0"]          # the MoE segment
    assert seg1["attn"]["wq"] == P(None, "data", "model")
    assert seg1["attn"]["wo"] == P(None, "model", "data")
    assert seg1["moe"]["w_gate"] == P(None, "model", "data", None)
    assert seg1["moe"]["w_down"] == P(None, "model", None, "data")
    # shared expert = dense rules, NOT expert-parallel
    assert seg1["moe"]["shared"]["w_gate"] == P(None, "data", "model")
    assert seg1["norm1"] == P(None, None)


def test_dfl_client_axis_layout():
    cfg = small_cfg()
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    stacked = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((8,) + l.shape, l.dtype), params)
    specs = param_specs(stacked, client_axis="clients", tp="model")
    assert specs["embed"] == P("clients", "model", None)   # no FSDP in DFL
    assert specs["seg0"]["sub0"]["attn"]["wq"] == P("clients", None, None, "model")


def test_enforce_divisibility():
    specs = {"a": P("model", None), "b": P("data", "model")}
    shapes = {"a": jax.ShapeDtypeStruct((8, 4), jnp.float32),     # 8 % 16 != 0
              "b": jax.ShapeDtypeStruct((32, 64), jnp.float32)}
    fixed = enforce_divisibility(specs, shapes, {"data": 16, "model": 16})
    assert fixed["a"] == P(None, None)
    assert fixed["b"] == P("data", "model")


def test_sync_bytes_model():
    mb = 1_000_000
    assert sync_bytes_per_client("fedlay", mb, 16, num_spaces=3) == 6 * mb
    assert sync_bytes_per_client("ring", mb, 16) == 2 * mb
    assert sync_bytes_per_client("complete", mb, 16) == 15 * mb
    ar = sync_bytes_per_client("allreduce", mb, 16)
    assert 1.8 * mb <= ar <= 2 * mb
    # the paper's claim: constant-degree fedlay beats complete graph and
    # stays within a small factor of ring all-reduce
    assert sync_bytes_per_client("fedlay", mb, 100, 3) < \
        sync_bytes_per_client("complete", mb, 100)


@pytest.mark.multi_device
def test_fedlay_ppermute_equals_dense_matrix(multi_device):
    """TPU-path mixing (shard_map + 2L ppermutes) ≡ W·X on 8 devices —
    inline on the tier-1 forced host mesh (used to be a subprocess)."""
    from repro.core.mixing import (build_permute_schedule,
                                   schedule_mixing_matrix)
    from repro.dist.compat import make_client_mesh, shard_map
    from repro.dist.sync import make_mixer

    n, dim = 8, 40
    mesh = make_client_mesh(n, "data")
    sched = build_permute_schedule(n, 3)
    mixer = make_mixer("fedlay", sched, "data", n)
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(n, dim)).astype(np.float32))
    W = jnp.asarray(sched.weights)
    S = jnp.asarray(sched.self_weight)

    def body(x, w, s):
        return mixer({"m": x}, w, s)["m"]

    f = jax.jit(shard_map(body, mesh=mesh,
                          in_specs=(P("data"), P("data"), P("data")),
                          out_specs=P("data"), check_vma=False))
    shard = NamedSharding(mesh, P("data"))
    out = f(jax.device_put(X, shard), jax.device_put(W, shard),
            jax.device_put(S, shard))
    ref = schedule_mixing_matrix(sched) @ np.asarray(X)
    assert float(np.abs(np.asarray(out) - ref).max()) < 1e-5


def test_dfl_client_count_grouped():
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(1, 1)
    assert dfl_client_count(mesh) == 1
    assert dfl_client_count(mesh, clients_per_device=4) == 4
    from repro.dist.compat import make_client_mesh
    mesh8 = make_client_mesh(8, "data")
    assert dfl_client_count(mesh8, clients_per_device=2) == 16
    with pytest.raises(ValueError, match=">= 1"):
        dfl_client_count(mesh8, clients_per_device=0)


def test_bundles_build_without_devices():
    """Step bundles (specs + eval_shape) build on 1 CPU device."""
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import serve_bundle, train_bundle
    from repro.models.config import INPUT_SHAPES, reduce_for_smoke
    from repro.configs import REGISTRY
    from repro.optim.optimizers import adamw
    import dataclasses
    cfg = reduce_for_smoke(REGISTRY["qwen3-4b"])
    mesh = make_local_mesh(1, 1)
    shp = dataclasses.replace(INPUT_SHAPES["train_4k"], global_batch=2,
                              seq_len=64)
    b = train_bundle(cfg, shp, mesh, adamw(1e-3), dtype=jnp.float32)
    assert len(b.arg_shapes) == 3
    shp2 = dataclasses.replace(INPUT_SHAPES["decode_32k"], global_batch=2,
                               seq_len=64)
    b2 = serve_bundle(cfg, shp2, mesh, dtype=jnp.float32)
    assert "token" in b2.arg_shapes[2]
