"""repro.faults — the deterministic fault-injection plane.

Acceptance pins (ISSUE 10): under a seeded :class:`FaultPlan` with 10%
NDMP message loss, one 2-way partition-and-heal, and stragglers, both
NDMP engines converge back to a valid near-regular topology with
table-identical state; degraded-round mixing (unreachable edges
dropped + renormalized through the runtime ``edge_mask``) equals the
dense renormalized oracle within 1e-6 at zero retraces on the same
MixerCache entry; and crash/resume through the checkpoint plane is
loss-parity <= 1e-6 against the uninterrupted run.  Plus unit coverage
for the plan vocabulary, the data-plane edge mask, decorrelated-jitter
backoff, the versioned suspect -> evict -> heal lifecycle, the
controller's bounded repair retry, and the swap-barrier abort hook.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mixing import masked_mixing_matrix
from repro.core.ndmp import Simulator
from repro.faults import (BackoffPolicy, ChaosEngine, DataFaults, FaultPlan,
                          HealthState, HealthTracker, LinkOutage, Partition,
                          RepairPolicy, Straggler, edge_mask_for)
from repro.obs import telemetry
from repro.obs.rounds import round_ledger
from repro.overlay import OverlayController
from repro.runtime import SlotTrainLoop, counting_jit, masked_local_step
from repro.scale import VectorSimulator

KW = dict(num_spaces=2, latency=0.05, heartbeat_period=0.5,
          probe_period=1.0)


def make_sim(n=6, seed=0):
    sim = Simulator(seed=seed, **KW)
    sim.seed_network(list(range(n)))
    return sim


# --------------------------------------------------------------------------
# Plan vocabulary
# --------------------------------------------------------------------------

def test_fault_plan_validation():
    with pytest.raises(ValueError, match="msg_loss"):
        FaultPlan(msg_loss=1.0)
    with pytest.raises(ValueError, match="msg_dup"):
        FaultPlan(msg_dup=-0.1)
    with pytest.raises(ValueError, match="after start"):
        Partition(5.0, 5.0, ((0,), (1,)))
    with pytest.raises(ValueError, match=">= 2 groups"):
        Partition(0.0, 1.0, ((0, 1),))
    with pytest.raises(ValueError, match="overlap"):
        Partition(0.0, 1.0, ((0, 1), (1, 2)))
    p = Partition(0.0, 1.0, ((0, 1), (2, 3)))
    assert p.group_of(2) == 1 and p.group_of(9) is None
    assert not FaultPlan().message_faults
    assert FaultPlan(msg_dup=0.1).message_faults


def test_delay_scale_closed_form():
    assert FaultPlan().delay_scale() == 1.0
    assert FaultPlan(msg_loss=0.5).delay_scale() == pytest.approx(2.0)
    # q=0.2 of messages take delay_factor=3 extra latencies
    assert FaultPlan(msg_delay=0.2, delay_factor=3.0).delay_scale() == \
        pytest.approx(1.6)


def test_data_faults_edge_down():
    df = DataFaults(down_pairs=frozenset({(1, 2)}),
                    slow_nodes=frozenset({5}),
                    groups=((0, 1), (3, 4)))
    assert not df.edge_down(7, 7)               # self never down
    assert df.edge_down(2, 1) and df.edge_down(1, 2)   # undirected pair
    assert df.edge_down(5, 0) and df.edge_down(0, 5)   # straggler
    assert df.edge_down(0, 3) and df.edge_down(4, 1)   # cross-partition
    assert not df.edge_down(0, 1)               # same side
    assert not df.edge_down(0, 7)               # 7 outside the partition
    assert not DataFaults()
    assert DataFaults(slow_nodes=frozenset({1}))


def test_edge_mask_for_stragglers_and_empty_slots():
    from repro.core.mixing import build_permute_schedule
    sched = build_permute_schedule(4, 2)
    slot_nodes = [10, 11, None, 13]
    em = edge_mask_for(sched, slot_nodes,
                       DataFaults(slow_nodes=frozenset({11})))
    perms = np.asarray(sched.perms)
    assert em.shape == (4, perms.shape[0])
    assert set(np.unique(em)) <= {0.0, 1.0}
    for i in range(4):
        for k in range(perms.shape[0]):
            src = slot_nodes[perms[k, i]]
            down = (slot_nodes[i] is not None and src is not None
                    and (slot_nodes[i] == 11 or src == 11)
                    and slot_nodes[i] != src)
            assert em[i, k] == (0.0 if down else 1.0), (i, k)
    # empty slot's own row untouched
    np.testing.assert_array_equal(em[2], 1.0)
    # no faults: the all-ones fast path
    np.testing.assert_array_equal(
        edge_mask_for(sched, slot_nodes, DataFaults()), 1.0)


# --------------------------------------------------------------------------
# Backoff / health / repair policies
# --------------------------------------------------------------------------

def test_backoff_deterministic_and_capped():
    a, b = BackoffPolicy(base=0.5, cap=8.0, seed=3), \
        BackoffPolicy(base=0.5, cap=8.0, seed=3)
    seq = [a.next_delay() for _ in range(12)]
    assert seq == [b.next_delay() for _ in range(12)]   # seeded replay
    assert all(0.5 <= d <= 8.0 for d in seq)
    assert max(seq) == 8.0 or max(seq) > 4.0            # grows toward cap
    a.reset()
    assert a.next_delay() == seq[0]                     # reset replays
    with pytest.raises(ValueError):
        BackoffPolicy(base=0.0)
    with pytest.raises(ValueError):
        BackoffPolicy(base=2.0, cap=1.0)


def test_health_tracker_versioned_lifecycle():
    h = HealthTracker(suspect_grace=2.0)
    assert h.state_of(7) is HealthState.HEALTHY
    v1 = h.suspect(7, now=10.0)
    assert h.state_of(7) is HealthState.SUSPECT
    assert h.suspect(7, now=10.5) == v1       # idempotent while suspect
    h.poll(11.0)                              # inside grace: still suspect
    assert h.state_of(7) is HealthState.SUSPECT
    h.poll(12.0)                              # grace expired: evicted
    assert h.state_of(7) is HealthState.EVICTED
    assert h.unhealthy() == frozenset({7}) == h.evicted()
    # a stale heal (observed at the suspect version) must NOT resurrect
    assert not h.heal(7, v1, now=12.5)
    assert h.state_of(7) is HealthState.EVICTED
    # a heal quoting the current version does
    assert h.heal(7, h.version_of(7), now=13.0)
    assert h.state_of(7) is HealthState.HEALTHY
    assert h.unhealthy() == frozenset()
    assert not h.heal(7, h.version_of(7))     # healthy: heal is a no-op


def test_controller_repair_retry_recovers_after_fail():
    sim = make_sim(6)
    ctl = OverlayController(sim, capacity=8, repair_policy=RepairPolicy())
    sim.fail(2)
    assert sim.correctness() < 1.0
    ctl.step(0.2)     # window too short for 3T detection: retries kick in
    assert sim.correctness() == 1.0
    assert ctl.repair_retries >= 1
    assert ctl.repair_recovered == 1 and ctl.repair_gave_up == 0


def test_controller_repair_retry_bounded_gives_up():
    ctl = OverlayController(make_sim(6), capacity=8,
                            repair_policy=RepairPolicy(max_retries=3))

    class _Stuck:
        now = 0.0

        def correctness(self):
            return 0.5

        def run_until(self, t):
            self.now = t

    ctl.sim = _Stuck()
    assert not ctl._repair_retry()
    assert ctl.repair_retries == 3
    assert ctl.repair_gave_up == 1 and ctl.repair_recovered == 0


def test_swap_barrier_abort_keeps_swap_staged():
    from repro.overlay import ChurnTrace
    calls = []
    armed = []

    def barrier():
        calls.append(1)
        if armed:
            armed.pop()
            raise RuntimeError("peer missed the boundary")

    sim = make_sim(6)
    ctl = OverlayController(sim, capacity=8, double_buffered=True,
                            swap_barrier=barrier)
    mixer0 = ctl.mixer
    trace = ChurnTrace.scripted([(sim.now + 0.1, "fail", 4)])
    for _ in range(20):
        r = ctl.step(1.0, trace=trace)
        trace = None
        if r.swapped:
            break
    assert r.swapped
    before = len(calls)
    armed.append(True)
    ctl.commit()                       # barrier raises -> abort
    assert ctl.swap_barrier_aborts == 1
    assert ctl.mixer is mixer0         # still serving the live program
    assert ctl.last_commit_ms == 0.0
    ctl.commit()                       # barrier passes -> swap lands
    assert len(calls) == before + 2
    assert ctl.mixer is not mixer0 and 4 not in ctl.slots


# --------------------------------------------------------------------------
# ChaosEngine event execution + transport filter
# --------------------------------------------------------------------------

def test_chaos_crash_guard_and_rejoin():
    plan = FaultPlan(crashes=((1.0, 3), (2.0, 3)),
                     rejoins=((5.0, 3, 0),))
    sim = ChaosEngine(make_sim(8), plan)
    sim.run_until(3.0)
    assert sim.counts["crashes"] == 1         # second crash: already dead
    assert 3 not in sim.alive_ids()
    sim.run_until(40.0)
    assert sim.counts["rejoins"] == 1         # dead node joins fresh
    assert 3 in sim.alive_ids()
    assert sim.correctness() == 1.0


def test_chaos_message_faults_counted_and_absorbed():
    plan = FaultPlan(seed=1, msg_loss=0.1, msg_delay=0.2, msg_dup=0.2)
    sim = ChaosEngine(make_sim(6), plan)
    sim.advance(10.0)
    for key in ("msg_dropped", "msg_delayed", "msg_duped"):
        assert sim.counts.get(key, 0) > 0, key
    # NDMP's monotone improve_pointer is idempotent under loss, delay,
    # and at-least-once duplication: the overlay stays correct
    assert sim.correctness() == 1.0


def test_chaos_asymmetric_partition_blocks_one_way():
    sim = ChaosEngine(make_sim(4), FaultPlan())
    p = Partition(1.0, 2.0, ((0, 1), (2, 3)), symmetric=False)
    sim._active.append(p)
    assert sim._blocked(0, 2) and sim._blocked(1, 3)   # from groups[0]
    assert not sim._blocked(2, 0) and not sim._blocked(3, 1)
    assert not sim._blocked(0, 1) and not sim._blocked(2, 3)
    sym = Partition(1.0, 2.0, ((0, 1), (2, 3)))
    sim._active = [sym]
    assert sim._blocked(0, 2) and sim._blocked(2, 0)


def test_chaos_data_faults_snapshot_windows():
    plan = FaultPlan(
        link_outages=(LinkOutage(1.0, 3.0, a=4, b=2),),
        stragglers=(Straggler(2.0, 5.0, node=1),),
        partitions=(Partition(6.0, 8.0, ((0, 1, 2), (3, 4, 5))),))
    sim = ChaosEngine(make_sim(6), plan)
    assert not sim.data_faults()                       # t=0: nothing yet
    sim.run_until(1.5)
    assert sim.data_faults().down_pairs == frozenset({(2, 4)})
    sim.run_until(2.5)
    df = sim.data_faults()
    assert df.slow_nodes == frozenset({1}) and df.edge_down(1, 0)
    sim.run_until(6.5)
    assert sim.data_faults().groups is not None        # partition active
    sim.run_until(20.0)
    assert not sim.data_faults()                       # all windows closed
    assert sim.counts["partition_heals"] == 1


# --------------------------------------------------------------------------
# The acceptance storm: both engines, table-identical
# --------------------------------------------------------------------------

def _storm_plan(n):
    half = tuple(range(n // 2)), tuple(range(n // 2, n))
    return FaultPlan(
        seed=5, msg_loss=0.10,
        partitions=(Partition(4.0, 10.0, half),),
        stragglers=(Straggler(2.0, 20.0, n - 1),
                    Straggler(2.0, 20.0, n - 2)))


@pytest.mark.chaos
def test_storm_parity_object_vs_vector():
    """10% NDMP loss + one 2-way partition-and-heal + 2 stragglers:
    after the storm both engines are at correctness 1.0 with identical
    neighbor tables and exported flat state — converged NDMP state is a
    pure function of visible membership, faults or not."""
    n = 12
    plan = _storm_plan(n)
    obj = ChaosEngine(make_sim(n), plan)
    vec = ChaosEngine(VectorSimulator(**KW), plan)
    vec.seed_network(range(n))
    obj.run_until(45.0)
    vec.run_until(45.0)
    assert obj.correctness() == 1.0 and vec.correctness() == 1.0
    assert obj.alive_ids() == vec.alive_ids()
    assert obj.neighbor_tables() == vec.neighbor_tables()
    a, b = obj.export_state(), vec.export_state()
    np.testing.assert_array_equal(a["ids"], b["ids"])
    np.testing.assert_array_equal(a["succ"], b["succ"])
    np.testing.assert_array_equal(a["pred"], b["pred"])
    # the object engine really injected transport faults; the partition
    # healed through the rejoin sweep on one side and a table rebuild on
    # the other — both counted
    assert obj.counts["msg_dropped"] > 0
    assert obj.counts["partition_starts"] == 1
    assert obj.counts["partition_heals"] == 1
    assert obj.counts["rejoins"] >= 1
    assert vec.counts["partition_heals"] == 1


# --------------------------------------------------------------------------
# Degraded-round mixing ≡ dense renormalized oracle, zero retraces
# --------------------------------------------------------------------------

DIM = 16


def _make_params(u):
    w = np.random.default_rng(u).normal(size=DIM).astype(np.float32)
    return {"w": jnp.asarray(w)}


def _make_batch(node_ids, step):
    return {"x": jnp.zeros((len(node_ids), 1), jnp.float32)}


def _identity_step(params, opt_state, batch):
    return params, opt_state, {"loss": jnp.mean(params["w"] ** 2, axis=-1)}


def _consensus_loop(sim, capacity, **kw):
    from repro.optim.optimizers import sgd
    sjit, scount = counting_jit(masked_local_step(_identity_step))
    ctl = OverlayController(sim, capacity=capacity)
    loop = SlotTrainLoop(ctl, local_step=sjit, make_params=_make_params,
                         optimizer=sgd(0.0), make_batch=_make_batch,
                         jit_local_step=False, **kw)
    return loop, scount


@pytest.mark.chaos
def test_degraded_mixing_matches_dense_oracle_zero_retraces():
    """With stragglers active, every round's mixed params equal the
    dense renormalized oracle (masked_mixing_matrix with edge_mask)
    within 1e-6 — and the degraded rounds ride the runtime-weights
    path: zero local-step retraces, zero new MixerCache entries."""
    slow = (4, 5)
    plan = FaultPlan(stragglers=tuple(
        Straggler(0.0, 1e9, u) for u in slow))
    chaos = ChaosEngine(make_sim(6), plan)
    loop, scount = _consensus_loop(chaos, capacity=8)
    ctl = loop.controller
    loop.run(1)                                # warmup trace
    misses = ctl.cache.misses
    for _ in range(3):
        X = np.asarray(loop.params["w"]).copy()
        mask = ctl.alive_mask()
        em = edge_mask_for(
            ctl.schedule,
            [ctl.slots.node_at(s) for s in range(8)],
            chaos.data_faults())
        assert (em == 0.0).any()               # faults actually active
        loop.run(1)
        W = masked_mixing_matrix(ctl.schedule, mask, em)
        np.testing.assert_allclose(np.asarray(loop.params["w"]),
                                   W @ X, atol=1e-6)
        # an isolated live row degenerates to its own model (total
        # weight = self weight > 0): the straggler keeps its params
        for u in slow:
            s = ctl.slots.slot_of[u]
            np.testing.assert_allclose(
                np.asarray(loop.params["w"])[s], X[s], atol=1e-6)
    assert scount.retraces == 0
    assert ctl.cache.misses == misses          # same MixerCache entry


@pytest.mark.chaos
@pytest.mark.multi_device
def test_grouped_storm_zero_retraces_and_oracle(multi_device):
    """The full acceptance storm on a G=2 grouped mesh (capacity 16 =
    2 x 8 devices): the slot loop converges through 10% loss + a 2-way
    partition-and-heal + 2 stragglers with 0 retraces, and the degraded
    round still equals the dense renormalized oracle."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.dist.compat import make_client_mesh
    from repro.optim.optimizers import sgd

    n = 12
    mesh = make_client_mesh(8, "data")
    chaos = ChaosEngine(make_sim(n), _storm_plan(n))
    ctl = OverlayController(chaos, capacity=16, clients_per_device=2)
    sjit, scount = counting_jit(masked_local_step(_identity_step))
    loop = SlotTrainLoop(ctl, local_step=sjit, make_params=_make_params,
                         optimizer=sgd(0.0), make_batch=_make_batch,
                         jit_local_step=False, mesh=mesh)
    assert loop.params["w"].sharding == NamedSharding(mesh, P("data", None))
    # 4 warmup rounds put sim.now at 4.0 — the partition has started,
    # so the oracle round (t in (4, 5]) sees a constant fault snapshot
    loop.run(4)
    X = np.asarray(loop.params["w"]).copy()
    mask = ctl.alive_mask()
    em = edge_mask_for(ctl.schedule,
                       [ctl.slots.node_at(s) for s in range(16)],
                       chaos.data_faults())
    assert (em == 0.0).any()
    loop.run(1)
    W = masked_mixing_matrix(ctl.schedule, mask, em)
    np.testing.assert_allclose(np.asarray(loop.params["w"]), W @ X,
                               atol=1e-6)
    loop.run(20)         # through the heal and out the other side
    assert scount.retraces == 0
    assert all(np.isfinite(r.loss) for r in loop.records)
    assert chaos.counts["partition_heals"] == 1
    # post-storm: the overlay healed and params stay row-sharded
    assert chaos.correctness() == 1.0
    assert loop.params["w"].sharding == NamedSharding(mesh, P("data", None))


# --------------------------------------------------------------------------
# Crash/resume: loss parity vs the uninterrupted run
# --------------------------------------------------------------------------

def _training_step(params, opt_state, batch):
    w, x = params["w"], batch["x"]
    loss = jnp.mean((w - x) ** 2, axis=-1)
    grad = 2.0 * (w - x) / DIM
    return {"w": w - 0.05 * grad}, opt_state, {"loss": loss}


def _training_batch(node_ids, step):
    rows = [np.random.default_rng(abs(hash((u, step))) % 2**32)
            .normal(size=DIM).astype(np.float32) for u in node_ids]
    return {"x": jnp.asarray(np.stack(rows))}


def _training_loop(sim):
    from repro.optim.optimizers import sgd
    ctl = OverlayController(sim, capacity=8)
    return SlotTrainLoop(ctl, local_step=masked_local_step(_training_step),
                         make_params=_make_params, optimizer=sgd(0.0),
                         make_batch=_training_batch)


@pytest.mark.chaos
def test_crash_resume_loss_parity(tmp_path):
    """Kill the loop at step 6, rebuild the whole stack from scratch
    (fresh simulator + controller, control plane replayed), restore the
    checkpoint: steps 6..11 match the uninterrupted run's losses within
    1e-6 and the final params bit-for-bit."""
    plan = FaultPlan(seed=3, msg_loss=0.10)

    # run A: uninterrupted
    loop_a = _training_loop(ChaosEngine(make_sim(6), plan))
    recs_a = loop_a.run(12)

    # run B: crash after 6 steps
    loop_b = _training_loop(ChaosEngine(make_sim(6), plan))
    loop_b.run(6)
    path = str(tmp_path / "crash.npz")
    loop_b.save(path)
    del loop_b                       # the crash

    # resume: replay the control plane (same seed, same windows), then
    # restore the training state into a brand-new loop
    sim_c = ChaosEngine(make_sim(6), plan)
    ctl_c = OverlayController(sim_c, capacity=8)
    for _ in range(6):
        ctl_c.step(1.0)
        ctl_c.commit()
    from repro.optim.optimizers import sgd
    loop_c = SlotTrainLoop(ctl_c,
                           local_step=masked_local_step(_training_step),
                           make_params=_make_params, optimizer=sgd(0.0),
                           make_batch=_training_batch)
    meta = loop_c.restore(path)
    assert meta["step"] == 6
    recs_c = loop_c.run(6)

    np.testing.assert_allclose([r.loss for r in recs_a[6:]],
                               [r.loss for r in recs_c],
                               rtol=0, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(loop_a.params["w"]),
                                  np.asarray(loop_c.params["w"]))


def test_restore_rejects_occupancy_mismatch(tmp_path):
    loop = _training_loop(make_sim(6))
    path = str(tmp_path / "s.npz")
    loop.save(path)
    other = _training_loop(make_sim(5))      # different membership
    with pytest.raises(ValueError, match="occupancy"):
        other.restore(path)


# --------------------------------------------------------------------------
# Telemetry: faults land on the bus and in the round ledger
# --------------------------------------------------------------------------

def test_fault_rounds_land_in_ledger_and_bus():
    plan = FaultPlan(seed=2, msg_loss=0.15,
                     stragglers=(Straggler(0.0, 1e9, 3),))
    with telemetry() as bus, round_ledger() as ledger:
        chaos = ChaosEngine(make_sim(6), plan)
        loop, _ = _consensus_loop(chaos, capacity=8)
        loop.run(4)
    assert bus.counters.get("faults.msg_dropped", 0) > 0
    rows = ledger.rows
    assert sum(r.faults_injected for r in rows) == \
        sum(chaos.counts.values())
    assert all(r.degraded_edges > 0 for r in rows)   # straggler always on


def test_health_tracker_feeds_loop_edge_mask():
    """A HealthTracker verdict degrades the round even without a chaos
    engine: evicting a node zeroes its edges in the loop's mask."""
    loop, _ = _consensus_loop(make_sim(6), capacity=8,
                              health=HealthTracker(suspect_grace=0.0))
    loop.health.suspect(2, now=0.0)
    X = None
    loop.run(1)
    recs = loop.records
    assert recs[-1].loss >= 0.0
    ctl = loop.controller
    em, degraded = loop._edge_mask(ctl.sim.now)
    assert degraded > 0
    s = ctl.slots.slot_of[2]
    perms = np.asarray(ctl.schedule.perms)
    live = [k for k in range(perms.shape[0])
            if ctl.slots.node_at(int(perms[k, s])) not in (None, 2)]
    assert all(em[s, k] == 0.0 for k in live)
