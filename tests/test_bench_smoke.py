"""Benchmark-harness smoke: the quick-mode front door must exit 0 so
benchmark-breaking API changes fail tier-1 instead of silently rotting
(fig3 exercises the topology-metrics path, churn_swap the overlay
control plane, slot_runtime the fixed-capacity runtime, and
sync_collectives the grouped clients-per-device HLO accounting — all
seconds-fast in quick mode)."""

import json
import os
import subprocess
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run(*args):
    env = dict(os.environ)
    src = os.path.join(REPO, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    # don't leak the conftest-forced 8-device flag: benchmarks must run
    # under the same device config here as in CI / standalone, or the
    # accumulated BENCH_<name>.json perf rows are not comparable
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", *args],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=600)


def test_benchmarks_quick_fig3():
    res = _run("--only", "fig3")
    assert res.returncode == 0, res.stderr[-2000:]
    assert "fig3" in res.stdout


def test_benchmarks_quick_churn_and_slot_runtime_json():
    """churn_swap + slot_runtime in quick mode through the --json path:
    exit 0, machine-readable BENCH_<name>.json rows at the repo root,
    and the slot runtime's zero-retrace claim visible in them."""
    res = _run("--only", "churn_swap,slot_runtime", "--json")
    assert res.returncode == 0, res.stderr[-2000:]
    by_name = {}
    for name in ("churn_swap", "slot_runtime"):
        path = os.path.join(REPO, f"BENCH_{name}.json")
        assert os.path.exists(path), name
        with open(path) as f:
            data = json.load(f)
        assert data["benchmark"] == name and data["quick"]
        assert not data["failed"] and data["rows"]
        by_name[name] = data
    by_loop = {r["loop"]: r for r in by_name["slot_runtime"]["rows"]
               if r["table"] == "slot_runtime"}
    assert by_loop["slot"]["retraces"] == 0
    assert by_loop["slot"]["distinct_alive"] >= 3
    assert by_loop["restack"]["retraces"] >= by_loop["restack"][
        "distinct_alive"] - 1


def test_benchmarks_quick_sync_collectives_grouped_json():
    """The grouped clients-per-device axis through the --json path:
    rows for G = 1 and G > 1, with the G > 1 fedlay schedule provably
    cheaper on the wire than the flat-layout paper bound."""
    res = _run("--only", "sync_collectives", "--json")
    assert res.returncode == 0, res.stderr[-2000:]
    path = os.path.join(REPO, "BENCH_sync_collectives.json")
    assert os.path.exists(path)
    with open(path) as f:
        data = json.load(f)
    assert not data["failed"] and data["rows"]
    fedlay = {r["clients_per_device"]: r for r in data["rows"]
              if r.get("strategy") == "fedlay"}
    assert 1 in fedlay and any(g > 1 for g in fedlay)
    for g, row in fedlay.items():
        assert row["clients"] == 8 * g
        assert row["wire_mb_per_dev"] > 0
        bound = 2 * 3 * row["model_mb"]          # flat 2L·model bytes
        assert row["exact_mb_per_client"] <= bound + 1e-6
        if g > 1:
            assert row["exact_mb_per_client"] < bound
