"""Benchmark-harness smoke: the quick-mode front door must exit 0 so
benchmark-breaking API changes fail tier-1 instead of silently rotting
(fig3 exercises the topology-metrics path end to end in seconds)."""

import os
import subprocess
import sys


def test_benchmarks_quick_fig3():
    repo = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    src = os.path.join(repo, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "fig3"],
        cwd=repo, env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "fig3" in res.stdout
