"""Benchmark-harness smoke: the quick-mode front door must exit 0 so
benchmark-breaking API changes fail tier-1 instead of silently rotting
(fig3 exercises the topology-metrics path, churn_swap the overlay
control plane, slot_runtime the fixed-capacity runtime,
sync_collectives the grouped clients-per-device HLO accounting, and
mix_fusion the flat-buffer fused mixing acceptance claims — all
seconds-fast in quick mode).  Plus the --json side artifacts: the
BENCH_history.jsonl append-log and the --baseline regression gate."""

import json
import os
import subprocess
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run(*args):
    env = dict(os.environ)
    src = os.path.join(REPO, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    # don't leak the conftest-forced 8-device flag: benchmarks must run
    # under the same device config here as in CI / standalone, or the
    # accumulated BENCH_<name>.json perf rows are not comparable
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", *args],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=600)


def test_benchmarks_quick_fig3():
    res = _run("--only", "fig3")
    assert res.returncode == 0, res.stderr[-2000:]
    assert "fig3" in res.stdout


def test_benchmarks_quick_churn_and_slot_runtime_json():
    """churn_swap + slot_runtime in quick mode through the --json path:
    exit 0, machine-readable BENCH_<name>.json rows at the repo root,
    and the slot runtime's zero-retrace claim visible in them."""
    res = _run("--only", "churn_swap,slot_runtime", "--json")
    assert res.returncode == 0, res.stderr[-2000:]
    by_name = {}
    for name in ("churn_swap", "slot_runtime"):
        path = os.path.join(REPO, f"BENCH_{name}.json")
        assert os.path.exists(path), name
        with open(path) as f:
            data = json.load(f)
        assert data["benchmark"] == name and data["quick"]
        assert not data["failed"] and data["rows"]
        by_name[name] = data
    by_loop = {r["loop"]: r for r in by_name["slot_runtime"]["rows"]
               if r["table"] == "slot_runtime"}
    assert by_loop["slot"]["retraces"] == 0
    assert by_loop["slot"]["distinct_alive"] >= 3
    assert by_loop["restack"]["retraces"] >= by_loop["restack"][
        "distinct_alive"] - 1


def test_benchmarks_quick_mix_fusion_json():
    """The ISSUE 5 acceptance pins through the --json path: fused ≡
    dense oracle ≤ 1e-6 for G ∈ {1,2,4} masked+unmasked; O(1) full-model
    temporaries per round at every L vs O(2L) for the tree walk; the
    shard_map round moves 2L flat-row ppermutes instead of T·2L
    per-leaf ones at identical wire bytes, and is no slower."""
    res = _run("--only", "mix_fusion", "--json")
    assert res.returncode == 0, res.stderr[-2000:]
    with open(os.path.join(REPO, "BENCH_mix_fusion.json")) as f:
        data = json.load(f)
    assert not data["failed"] and data["quick"]
    rows = data["rows"]
    parity = [r for r in rows if r["table"] == "mix_fusion_parity"]
    assert {(r["G"], r["masked"]) for r in parity} == \
        {(g, m) for g in (1, 2, 4) for m in (0, 1)}
    assert all(r["max_abs_err"] <= 1e-6 for r in parity), parity
    temps = {(r["path"], r["spaces"]): r["full_model_temps"]
             for r in rows if r["table"] == "mix_fusion_temps"}
    # fused: constant (O(1)) in the overlay degree; tree walk: O(2L)
    assert len({temps["flat", L] for L in (1, 2, 3)}) == 1
    assert temps["flat", 3] <= 4
    assert all(temps["tree", L] >= 2 * L for L in (1, 2, 3))
    rnd = {r["path"]: r for r in rows if r["table"] == "mix_fusion_round"}
    assert rnd["flat"]["ppermutes"] == 2 * rnd["flat"]["spaces"]
    assert rnd["tree"]["ppermutes"] == \
        rnd["tree"]["leaves"] * 2 * rnd["tree"]["spaces"]
    assert rnd["flat"]["wire_mb_per_dev"] == rnd["tree"]["wire_mb_per_dev"]
    # "no slower per round in quick mode" — the fused round eliminates
    # T·2L−2L collective dispatches, which dominates even on CPU
    assert rnd["flat"]["per_round_ms"] <= rnd["tree"]["per_round_ms"]
    # ISSUE 7: the wire-codec axis — HLO-measured reductions vs the
    # uncompressed flat round (int8 pays ~2 bf16 scale bytes per
    # 128-value block on the wire, hence >= 3.5x measured vs 4x payload)
    codec = {r["codec"]: r for r in rows
             if r["table"] == "mix_fusion_codec"}
    assert set(codec) >= {"uncompressed", "bf16", "int8-block",
                          "int4-block", "topk"}
    assert codec["uncompressed"]["wire_reduction"] == 1.0
    assert codec["bf16"]["wire_reduction"] >= 1.9
    assert codec["int8-block"]["wire_reduction"] >= 3.5
    assert codec["int8-block"]["payload_reduction"] >= 4.0
    assert codec["int4-block"]["wire_reduction"] >= 4.0
    assert codec["topk"]["wire_reduction"] >= 4.0
    for r in codec.values():
        # measured collective bytes agree with the codec closed form
        assert abs(r["wire_mb"] - r["predicted_wire_mb"]) <= \
            0.05 * r["predicted_wire_mb"] + 1e-4, r


def test_benchmarks_history_log_and_baseline_gate():
    """--json appends one record per run to BENCH_history.jsonl, and
    --baseline exits 0 against the just-committed artifact (a run is
    its own baseline within tolerance on the deterministic fields)."""
    hist = os.path.join(REPO, "BENCH_history.jsonl")
    before = sum(1 for _ in open(hist)) if os.path.exists(hist) else 0
    res = _run("--only", "fig3", "--json")
    assert res.returncode == 0, res.stderr[-2000:]
    with open(hist) as f:
        lines = f.read().splitlines()
    assert len(lines) == before + 1
    rec = json.loads(lines[-1])
    assert rec["benchmark"] == "fig3" and not rec["failed"] and rec["rows"]
    # baseline mode: fig3 is deterministic apart from its wall-time
    # rows, which compare within tolerance against the file just written
    res2 = _run("--only", "fig3", "--baseline")
    assert res2.returncode == 0, (res2.stdout[-500:], res2.stderr[-2000:])
    assert "baseline" in res2.stdout or "REGRESSION" not in res2.stderr


def test_baseline_compare_flags_regressions():
    """Unit-level: compare_rows matches rows by identity and gates both
    perf directions at the 25% tolerance."""
    sys.path.insert(0, REPO)
    try:
        from benchmarks.run import compare_rows, perf_direction
    finally:
        sys.path.remove(REPO)
    assert perf_direction("seconds") == -1
    assert perf_direction("per_round_ms") == -1
    assert perf_direction("steps_per_s") == +1
    assert perf_direction("final_loss") is None
    # ISSUE 7: bytes-on-the-wire fields gate lower-is-better, reduction
    # factors higher-is-better; identity-ish names stay ungated
    assert perf_direction("wire_mb") == -1
    assert perf_direction("payload_bytes") == -1
    assert perf_direction("wire_reduction") == +1
    assert perf_direction("wire_mb_per_dev") is None
    assert perf_direction("codec") is None
    base = [{"table": "t", "loop": "slot", "steps_per_s": 100.0,
             "seconds": 2.0, "final_loss": 0.5}]
    bad = [{"table": "t", "loop": "slot", "steps_per_s": 60.0,
            "seconds": 3.0, "final_loss": 9.9}]
    msgs = compare_rows(base, bad)
    assert len(msgs) == 2 and all("tolerance" in m for m in msgs)
    ok = [{"table": "t", "loop": "slot", "steps_per_s": 90.0,
           "seconds": 2.2, "final_loss": 0.5}]
    assert compare_rows(base, ok) == []
    # unmatched identities never regress
    assert compare_rows(base, [{"table": "t", "loop": "other",
                                "seconds": 99.0}]) == []


def test_benchmarks_quick_sync_collectives_grouped_json():
    """The grouped clients-per-device axis through the --json path:
    rows for G = 1 and G > 1, with the G > 1 fedlay schedule provably
    cheaper on the wire than the flat-layout paper bound."""
    res = _run("--only", "sync_collectives", "--json")
    assert res.returncode == 0, res.stderr[-2000:]
    path = os.path.join(REPO, "BENCH_sync_collectives.json")
    assert os.path.exists(path)
    with open(path) as f:
        data = json.load(f)
    assert not data["failed"] and data["rows"]
    fedlay = {r["clients_per_device"]: r for r in data["rows"]
              if r.get("strategy") == "fedlay"
              and r["table"] == "sync_collectives"}
    assert 1 in fedlay and any(g > 1 for g in fedlay)
    for g, row in fedlay.items():
        assert row["clients"] == 8 * g
        assert row["wire_mb_per_dev"] > 0
        bound = 2 * 3 * row["model_mb"]          # flat 2L·model bytes
        assert row["exact_mb_per_client"] <= bound + 1e-6
        if g > 1:
            assert row["exact_mb_per_client"] < bound
    # ISSUE 7: the codec axis pins sync_bytes_per_client(codec=)
    # against the HLO-measured compressed round (gap = lane padding)
    codec = {r["codec"]: r for r in data["rows"]
             if r["table"] == "sync_collectives_codec"}
    assert set(codec) >= {"uncompressed", "bf16", "int8-block",
                          "int4-block", "topk"}
    for r in codec.values():
        assert abs(r["wire_mb_per_dev"] - r["predicted_mb_per_client"]) \
            <= 0.05 * r["predicted_mb_per_client"] + 1e-3, r
    assert codec["int8-block"]["wire_reduction"] >= 3.5
    assert codec["int4-block"]["wire_reduction"] >= 4.0
    assert codec["topk"]["wire_reduction"] >= 4.0


def test_benchmarks_quick_fig20_json():
    """fig20 through the --json path: both engines at small n with the
    vec-vs-object parity row True, and comm rows including the cohort
    active_clients closed form."""
    res = _run("--only", "fig20", "--json")
    assert res.returncode == 0, res.stderr[-2000:]
    with open(os.path.join(REPO, "BENCH_fig20.json")) as f:
        data = json.load(f)
    assert not data["failed"] and data["quick"]
    rows = data["rows"]
    engines = {r["engine"] for r in rows if r["table"] == "fig20_protocol"}
    assert engines == {"object", "vec"}
    parity = [r for r in rows if r["table"] == "fig20_parity"]
    assert parity and all(r["tables_equal"] for r in parity)
    cohort = [r for r in rows if r["table"] == "fig20_comm"
              and r["strategy"] == "fedlay_cohort"]
    assert cohort and all(r["active_clients"] >= 1 for r in cohort)


def test_benchmarks_quick_cohort_stream_json():
    """The ISSUE 6 acceptance pins through the --json path: the device
    cohort round equals the dense oracle within 1e-6 across >= 3 cohort
    compositions with 0 retraces, and the K-sweep streaming rows also
    never retrace."""
    res = _run("--only", "cohort_stream", "--json")
    assert res.returncode == 0, res.stderr[-2000:]
    with open(os.path.join(REPO, "BENCH_cohort_stream.json")) as f:
        data = json.load(f)
    assert not data["failed"] and data["quick"]
    rows = data["rows"]
    oracle = [r for r in rows if r["table"] == "cohort_oracle"]
    assert len(oracle) >= 4           # 3 compositions + full-vs-dense pin
    assert all(r["within_1e6"] == 1 for r in oracle), oracle
    assert all(r["retraces"] == 0 for r in oracle)
    stream = [r for r in rows if r["table"] == "cohort_stream"]
    assert len({r["k"] for r in stream}) >= 3
    assert all(r["retraces"] == 0 for r in stream), stream
    assert all(r["streamed_in"] ==
               r["restored"] + r["donor_seeded"] + r["fresh"]
               for r in stream)


def test_benchmarks_quick_serve_load_json():
    """The ISSUE 9 acceptance pins through the --json path: per-slot-pos
    flash_decode equals the cache_attention oracle within 1e-5, empty
    slots return exactly zero, both admission policies replay the
    Poisson trace with 0 decode retraces after warmup across >= 3
    distinct batch occupancies, and continuous batching sustains at
    least static-batch throughput."""
    res = _run("--only", "serve_load", "--json")
    assert res.returncode == 0, res.stderr[-2000:]
    with open(os.path.join(REPO, "BENCH_serve_load.json")) as f:
        data = json.load(f)
    assert not data["failed"] and data["quick"]
    rows = data["rows"]
    parity = [r for r in rows if r["table"] == "serve_parity"]
    assert parity and all(r["within_1e5"] == 1 for r in parity), parity
    assert all(r["empty_slot_zero"] == 1 for r in parity)
    load = {r["policy"]: r for r in rows if r["table"] == "serve_load"}
    for policy in ("continuous", "static"):
        assert load[policy]["retraces"] == 0, load
        assert load[policy]["distinct_occupancies"] >= 3, load
        assert load[policy]["p99_ms"] >= load[policy]["p50_ms"] > 0
    assert load["continuous_vs_static"]["continuous_wins"] == 1, load


def test_baseline_malformed_artifact_warns_and_skips(capsys):
    """ISSUE 10 satellite: --baseline must degrade to "no comparison"
    (warn on stderr, return None) on a missing, truncated, non-object,
    or bad-rows BENCH artifact instead of crashing the gate."""
    import json as _json
    sys.path.insert(0, REPO)
    try:
        from benchmarks.run import _load_baseline
    finally:
        sys.path.remove(REPO)
    name = "zz_unit_malformed"          # never committed, never tracked
    path = os.path.join(REPO, f"BENCH_{name}.json")
    try:
        # missing artifact: clean None, no warning
        assert _load_baseline(name, quick=True) is None
        assert "WARNING" not in capsys.readouterr().err

        with open(path, "w") as f:      # truncated JSON
            f.write('{"rows": [')
        assert _load_baseline(name, quick=True) is None
        assert "skipping comparison" in capsys.readouterr().err

        with open(path, "w") as f:      # valid JSON, not an object
            _json.dump([1, 2, 3], f)
        assert _load_baseline(name, quick=True) is None
        err = capsys.readouterr().err
        assert "WARNING" in err and "expected a JSON object" in err

        with open(path, "w") as f:      # rows that aren't objects
            _json.dump({"quick": True, "failed": False,
                        "rows": [1, 2]}, f)
        assert _load_baseline(name, quick=True) is None
        assert "malformed rows" in capsys.readouterr().err

        with open(path, "w") as f:      # healthy artifact still loads
            _json.dump({"quick": True, "failed": False,
                        "rows": [{"table": "t", "x": 1}]}, f)
        assert _load_baseline(name, quick=True) == [{"table": "t", "x": 1}]
        # mode mismatch / failed runs stay silently incomparable
        assert _load_baseline(name, quick=False) is None
    finally:
        if os.path.exists(path):
            os.remove(path)
