"""The three DFL topology metrics (paper §II-B)."""

import numpy as np
import pytest

from repro.core.baselines import TOPOLOGY_REGISTRY
from repro.core.coords import NodeAddress
from repro.core.metrics import (convergence_factor, evaluate_topology,
                                metropolis_hastings_matrix, spectral_lambda,
                                uniform_mixing_matrix)
from repro.core.topology import fedlay_topology


def _mh(topo):
    return metropolis_hastings_matrix(topo.adjacency())


def test_mh_matrix_doubly_stochastic_symmetric():
    topo = fedlay_topology([NodeAddress.create(i, 3) for i in range(60)])
    M = _mh(topo)
    assert np.allclose(M.sum(0), 1.0) and np.allclose(M.sum(1), 1.0)
    assert np.allclose(M, M.T)
    assert (M >= -1e-12).all()


def test_complete_graph_lambda_near_zero():
    topo = TOPOLOGY_REGISTRY["complete"](20)
    lam = spectral_lambda(_mh(topo))
    # MH on K_n has second eigenvalue 1/n-ish
    assert lam < 0.1


def test_ring_mixes_slowly():
    ring = TOPOLOGY_REGISTRY["ring"](64)
    fed = fedlay_topology([NodeAddress.create(i, 3) for i in range(64)])
    lam_ring = spectral_lambda(_mh(ring))
    lam_fed = spectral_lambda(_mh(fed))
    assert lam_fed < lam_ring  # paper: FedLay converges faster than ring
    assert convergence_factor(fed) < convergence_factor(ring)


def test_diameter_and_aspl_small_world():
    rep = evaluate_topology(
        fedlay_topology([NodeAddress.create(i, 3) for i in range(300)]))
    # near-RRG with degree ~6 on 300 nodes: diameter stays logarithmic
    assert rep.diameter <= 6
    assert rep.avg_shortest_path <= 4.0
    assert rep.connected


def test_fedlay_close_to_best_random_regular():
    """Fig 3 claim: FedLay ≈ best of random d-regular graphs."""
    from repro.core.baselines import best_of_rrgs
    n, L = 100, 3
    fed = evaluate_topology(
        fedlay_topology([NodeAddress.create(i, L) for i in range(n)]))
    best = evaluate_topology(best_of_rrgs(n, 2 * L, trials=20))
    assert fed.convergence_factor < 1.5 * best.convergence_factor
    assert fed.diameter <= best.diameter + 1
    assert fed.avg_shortest_path <= best.avg_shortest_path * 1.3


def test_uniform_mixing_row_stochastic():
    topo = TOPOLOGY_REGISTRY["ring"](16)
    W = uniform_mixing_matrix(topo.adjacency())
    assert np.allclose(W.sum(1), 1.0)
