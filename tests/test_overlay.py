"""repro.overlay — the live churn control plane.

Acceptance pins (ISSUE 2): after a scripted fail+join trace the
controller's swapped-in mixer equals dense ``schedule_mixing_matrix``
mixing on the post-churn alive set, and an unchanged-topology control
step reports a compile-cache hit with no rebuild.  Plus coverage for the
delta tracker, churn traces, schedule hashing, and the churn train loop
(shard remap + joiner catch-up init) driving ``dfl_train_bundle``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mixing import (build_permute_schedule,
                               schedule_from_addresses,
                               schedule_mixing_matrix)
from repro.core.ndmp import Simulator
from repro.overlay import (ChurnEvent, ChurnTrace, ChurnTrainLoop,
                           DeltaTracker, OverlayController, joiner_donors)


def make_sim(n=12, L=3, seed=0):
    sim = Simulator(num_spaces=L, latency=0.05, heartbeat_period=0.5,
                    probe_period=1.0, seed=seed)
    sim.seed_network(list(range(n)))
    return sim


# --------------------------------------------------------------------------
# Schedule hashing / address-based compilation
# --------------------------------------------------------------------------

def test_permute_schedule_hash_eq():
    a = build_permute_schedule(8, 3)
    b = build_permute_schedule(8, 3)
    c = build_permute_schedule(8, 2)
    assert a == b
    assert hash(a) == hash(b)
    assert a != c
    assert len({a, b, c}) == 2          # usable as a dict/set key
    d = build_permute_schedule(8, 3, confidence_weighted=False)
    assert d == b                       # uniform profiles: weights agree


def test_schedule_from_addresses_matches_range_build():
    """Arbitrary-node-id compilation reduces to the static build when the
    ids are exactly the mesh positions."""
    sim = make_sim(n=10)
    sched = schedule_from_addresses(sim.alive_addresses())
    ref = build_permute_schedule(10, 3)
    assert sched == ref


def test_schedule_from_addresses_row_stochastic_after_churn():
    sim = make_sim(n=16)
    sim.fail(3)
    sim.leave(8)
    addrs = [a for a in sim.alive_addresses()]
    sched = schedule_from_addresses(sorted(addrs, key=lambda a: a.node_id))
    W = schedule_mixing_matrix(sched)
    assert W.shape == (14, 14)
    np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-6)


# --------------------------------------------------------------------------
# Delta tracker
# --------------------------------------------------------------------------

def test_delta_tracker_epochs_and_membership():
    sim = make_sim(n=10)
    tracker = DeltaTracker(sim)
    # quiescent poll: no epoch advance
    sim.run_for(0.01)
    d0 = tracker.poll()
    assert d0.empty and d0.epoch == 0
    # a failure changes membership immediately, repairs change tables
    sim.fail(4)
    sim.run_for(10.0)
    d1 = tracker.poll()
    assert not d1.empty
    assert d1.epoch == 1
    assert d1.left == frozenset({4})
    assert all(4 not in new for _, new in d1.changed.values())
    # a join shows up as membership + table changes
    sim.join(77, bootstrap=0)
    sim.run_for(10.0)
    d2 = tracker.poll()
    assert d2.epoch == 2
    assert d2.joined == frozenset({77})
    # back to quiescence
    d3 = tracker.poll()
    assert d3.empty and d3.epoch == 2


def test_tables_version_is_stable_when_quiescent():
    sim = make_sim(n=8)
    v0 = sim.tables_version()
    assert sim.tables_version() == v0
    sim.run_for(5.0)                 # heartbeats/probes, no churn
    assert sim.tables_version() == v0
    sim.fail(2)
    assert sim.tables_version() != v0


def test_tables_version_cannot_alias_fail_rejoin_in_one_window():
    """A fail→rejoin of the same node between two polls restores the
    alive set and resets the node's pointer versions — churn_ops still
    forces a stamp change, so the delta is never silently missed."""
    sim = make_sim(n=8)
    v0 = sim.tables_version()
    sim.fail(5)
    sim.join(5, bootstrap=0)         # same id, same coords, fresh state
    assert sim.tables_version() != v0
    tracker = DeltaTracker(make_sim(n=8))
    tracker.sim.fail(5)
    tracker.sim.join(5, bootstrap=0)
    assert not tracker.poll().empty  # the reset table is a real delta


def test_mixer_cache_lru_bound():
    from repro.overlay import MixerCache
    built = []
    cache = MixerCache(lambda s: built.append(s) or (lambda x: x),
                       maxsize=2)
    s = [build_permute_schedule(4, L) for L in (1, 2, 3)]
    for sched in s:
        cache.get(sched)
    assert len(cache) == 2 and cache.evictions == 1
    _, hit = cache.get(s[2])         # most recent: still cached
    assert hit
    _, hit = cache.get(s[0])         # evicted: recompiled
    assert not hit
    assert len(built) == 4


# --------------------------------------------------------------------------
# Churn traces
# --------------------------------------------------------------------------

def test_churn_trace_scripted_window_and_apply():
    trace = ChurnTrace.scripted([(2.0, "fail", 1), (1.0, "join", 50, 0),
                                 (3.0, "leave", 2)])
    assert [e.time for e in trace.events] == [1.0, 2.0, 3.0]  # sorted
    assert [e.kind for e in trace.between(0.0, 2.0)] == ["join", "fail"]
    assert trace.between(2.0, 2.5) == ()     # window is half-open (t0, t1]
    sim = make_sim(n=6)
    ChurnTrace.apply(sim, trace.events)
    sim.run_for(20.0)
    assert set(sim.alive_ids()) == {0, 3, 4, 5, 50}
    assert sim.correctness() == 1.0


def test_churn_trace_stochastic_deterministic_and_bounded():
    kw = dict(horizon=50.0, join_rate=0.2, fail_rate=0.1, leave_rate=0.1,
              initial_ids=range(10), min_alive=4, seed=7)
    a = ChurnTrace.stochastic(**kw)
    b = ChurnTrace.stochastic(**kw)
    assert a == b                         # same seed, same trace
    assert ChurnTrace.stochastic(**{**kw, "seed": 8}) != a
    alive = set(range(10))
    for ev in a.events:
        assert ev.time <= 50.0
        if ev.kind == "join":
            assert ev.node_id >= 10_000
            alive.add(ev.node_id)
        else:
            assert ev.node_id in alive
            alive.discard(ev.node_id)
            assert len(alive) >= 4


def test_churn_event_rejects_unknown_kind():
    with pytest.raises(ValueError):
        ChurnEvent(time=0.0, kind="explode", node_id=1)


# --------------------------------------------------------------------------
# Controller: the ISSUE acceptance pins
# --------------------------------------------------------------------------

def test_controller_swap_matches_dense_mixing_after_churn():
    """Scripted fail+join trace: the swapped-in compiled mixer must equal
    dense W@X of the post-churn alive set's schedule."""
    sim = make_sim(n=12)
    ctl = OverlayController(sim)
    trace = ChurnTrace.scripted([(0.5, "fail", 3), (0.7, "fail", 7),
                                 (1.2, "join", 100, 0)])
    swapped_any = False
    for _ in range(25):
        r = ctl.step(1.0, trace=trace)
        swapped_any = swapped_any or r.swapped
        if sim.correctness() == 1.0 and sim.now > trace.horizon + 5.0:
            break
    assert swapped_any
    assert sim.correctness() == 1.0
    want_alive = tuple(sorted((set(range(12)) - {3, 7}) | {100}))
    assert ctl.alive == want_alive
    assert ctl.schedule.num_clients == len(want_alive)

    m = len(ctl.alive)
    rng = np.random.default_rng(0)
    X = rng.normal(size=(m, 33)).astype(np.float32)
    out = np.asarray(ctl.mixer(jnp.asarray(X)))
    ref = schedule_mixing_matrix(ctl.schedule) @ X
    np.testing.assert_allclose(out, ref, atol=1e-5)


def test_controller_unchanged_topology_is_cache_hit_no_rebuild():
    sim = make_sim(n=10)
    ctl = OverlayController(sim)
    rebuilds_before = ctl.rebuilds
    r = ctl.step(1.0)                     # no churn scheduled
    assert r.cache_hit
    assert not r.rebuilt
    assert not r.swapped
    assert r.rebuild_ms == 0.0
    assert ctl.rebuilds == rebuilds_before
    # and the mixer object itself was not replaced
    mixer = ctl.mixer
    ctl.step(1.0)
    assert ctl.mixer is mixer


def test_controller_revisited_topology_hits_cache():
    """fail -> rejoin of the same node restores the alive set, so the
    rebuilt schedule hashes equal and the swap is a cache hit."""
    sim = make_sim(n=8)
    ctl = OverlayController(sim)
    original = ctl.schedule
    misses0 = ctl.cache.misses
    for _ in range(20):
        ctl.step(1.0, trace=ChurnTrace.scripted([(sim.now + 0.1, "fail", 5)]))
        if sim.correctness() == 1.0 and len(ctl.alive) == 7:
            break
    assert ctl.schedule != original
    assert ctl.cache.misses == misses0 + 1
    swap_back = None
    trace = ChurnTrace.scripted([(sim.now + 0.1, "join", 5, 0)])
    for _ in range(20):
        r = ctl.step(1.0, trace=trace)
        trace = None
        if r.swapped:
            swap_back = r
        if sim.correctness() == 1.0 and len(ctl.alive) == 8:
            break
    assert ctl.schedule == original       # node 5's coords are id-derived
    assert swap_back is not None and swap_back.cache_hit
    assert ctl.cache.misses == misses0 + 1   # no new compile on the way back


def test_controller_shard_map_kind_returns_cached_body():
    sim = make_sim(n=6, L=2)
    ctl = OverlayController(sim, mixer_kind="shard_map")
    body = ctl.mixer
    assert callable(body)
    r = ctl.step(1.0)
    assert r.cache_hit and ctl.mixer is body


def test_controller_confidence_profiles_shape_weights():
    from repro.core.mep import ClientProfile
    sim = make_sim(n=6, L=2)
    rng = np.random.default_rng(0)

    def profiles_fn(alive):
        return {u: ClientProfile(
            client_id=u, period=float(1.0 + (u % 3)),
            label_histogram=rng.dirichlet(np.ones(4)))
            for u in alive}

    ctl = OverlayController(sim, profiles_fn=profiles_fn)
    W = schedule_mixing_matrix(ctl.schedule)
    np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-6)
    # confidence weighting: rows are not the uniform simple average
    ctl_uniform = OverlayController(make_sim(n=6, L=2))
    assert ctl.schedule != ctl_uniform.schedule


# --------------------------------------------------------------------------
# Runtime: ChurnTrainLoop over dfl_train_bundle
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_bundle():
    from repro.configs import REGISTRY, reduce_for_smoke
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import dfl_train_bundle
    from repro.models.config import INPUT_SHAPES
    from repro.optim.optimizers import adamw
    cfg = reduce_for_smoke(REGISTRY["qwen3-4b"])
    shape = dataclasses.replace(INPUT_SHAPES["train_4k"], global_batch=2,
                                seq_len=32)
    mesh = make_local_mesh(1, 1)
    opt = adamw(1e-3)
    bundle = dfl_train_bundle(cfg, shape, mesh, opt, dtype=jnp.float32,
                              sync="none")
    return cfg, opt, bundle


def _loop_for(controller, cfg, opt, bundle):
    from repro.models import init_params
    per_client = {k: v.shape[1:] for k, v in bundle.arg_shapes[2].items()}

    def make_params(node_id):
        return init_params(cfg, jax.random.PRNGKey(node_id),
                           dtype=jnp.float32)

    def make_batch(node_ids, step):
        out = {}
        for k, shp in per_client.items():
            rows = [np.random.default_rng(
                abs(hash((u, step, k))) % 2**32).integers(
                    0, cfg.vocab_size, shp) for u in node_ids]
            out[k] = jnp.asarray(np.stack(rows), jnp.int32)
        return out

    return ChurnTrainLoop(controller, local_step=bundle.step,
                          make_params=make_params, optimizer=opt,
                          make_batch=make_batch, step_time=1.0)


def test_dfl_train_bundle_accepts_controller_schedule():
    """A controller's converged NDMP schedule can be baked into a static
    fedlay bundle (the no-churn deployment path for sched=)."""
    from repro.configs import REGISTRY, reduce_for_smoke
    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import dfl_train_bundle
    from repro.models.config import INPUT_SHAPES
    from repro.optim.optimizers import adamw
    cfg = reduce_for_smoke(REGISTRY["qwen3-4b"])
    shape = dataclasses.replace(INPUT_SHAPES["train_4k"], global_batch=2,
                                seq_len=32)
    mesh = make_local_mesh(1, 1)          # C = 1 on the CPU test mesh
    ctl = OverlayController(make_sim(n=1, L=2))
    b = dfl_train_bundle(cfg, shape, mesh, adamw(1e-3), dtype=jnp.float32,
                         sync="fedlay", sched=ctl.schedule)
    assert jax.tree.leaves(b.arg_shapes[0])[0].shape[0] == 1
    # schedule size must match the mesh's client count
    eight = OverlayController(make_sim(n=8, L=2)).schedule
    with pytest.raises(ValueError, match="8 clients"):
        dfl_train_bundle(cfg, shape, mesh, adamw(1e-3), dtype=jnp.float32,
                         sync="fedlay", sched=eight)
    # and only permute-based strategies accept one
    with pytest.raises(ValueError, match="fedlay/ring"):
        dfl_train_bundle(cfg, shape, mesh, adamw(1e-3), dtype=jnp.float32,
                         sync="allreduce", sched=ctl.schedule)


def test_churn_train_loop_remaps_and_catches_up(tiny_bundle):
    cfg, opt, bundle = tiny_bundle
    sim = make_sim(n=4, L=2)
    ctl = OverlayController(sim)
    loop = _loop_for(ctl, cfg, opt, bundle)
    trace = ChurnTrace.scripted([(2.5, "fail", 1), (4.5, "join", 50, 0)])
    recs = loop.run(8, trace=trace)
    assert len(recs) == 8
    assert all(np.isfinite(r.loss) for r in recs)
    fail_steps = [r for r in recs if r.left == (1,)]
    join_steps = [r for r in recs if 50 in r.joined]
    assert len(fail_steps) == 1 and fail_steps[0].num_alive == 3
    assert len(join_steps) == 1 and join_steps[0].num_alive == 4
    assert loop.assignment == (0, 2, 3, 50)
    # joiner catch-up: node 50 started from a live model, not from init
    from repro.models import init_params
    fresh = init_params(cfg, jax.random.PRNGKey(50), dtype=jnp.float32)
    joined = loop.client_params(50)
    diffs = [float(jnp.abs(a - b).max())
             for a, b in zip(jax.tree.leaves(fresh),
                             jax.tree.leaves(joined))]
    assert max(diffs) > 0.0


def test_joiner_donors_prefers_highest_confidence_survivor():
    sim = make_sim(n=8, L=2)
    sim.join(100, bootstrap=0)
    sim.run_for(20.0)
    assert sim.correctness() == 1.0
    alive = tuple(sim.alive_ids())
    sched = schedule_from_addresses(
        sorted(sim.alive_addresses(), key=lambda a: a.node_id))
    donors = joiner_donors(sched, alive, joiners=(100,),
                           survivors=tuple(range(8)))
    donor = donors[100]
    assert donor in set(range(8))
    # the donor is a neighbor with the max schedule weight for slot of 100
    i = alive.index(100)
    weights = {}
    for k in range(sched.num_slots):
        src = alive[sched.perms[k][i]]
        if src != 100:
            weights[src] = max(weights.get(src, 0.0),
                               float(sched.weights[i, k]))
    assert donor in weights
    assert weights[donor] == max(weights.values())
