"""Wire-compression subsystem (ISSUE 7): the :mod:`repro.wire.codec`
codecs and their plumbing through the mixing families.

Pinned here:

* the **coding contract** — ``decode ∘ encode`` is bit-exact for exact
  codecs, within the documented :meth:`WireCodec.tolerance` bound for
  lossy ones; ``wire_bytes(n)`` equals the actual bytes of the encoded
  parts; ``payload_bytes ≤ wire_bytes``; ``encode_ef`` returns exactly
  ``buf − decode(wire)`` (fixed cases, a seeded shapes×dtypes×blocks
  fuzz that always runs, and a hypothesis sibling);
* **compressed mixing ≡ dense oracle** — shard_map ``fedlay_mix`` and
  the global fused mixer with ``codec="int8-block"`` / ``"topk"``
  match ``schedule_mixing_matrix`` / ``masked_mixing_matrix`` within
  the per-element bound ``W_dense @ tolerance`` for G ∈ {1, 2, 4},
  masked and unmasked, on the real 8-device mesh;
* **error feedback** — a lossy-codec consensus loop with EF lands
  within ε of the exact consensus (the residual carries what each
  round drops); masked-out rows keep their residual, remapped slots
  get it zeroed (:func:`repro.runtime.slots.plan_reset_slots`);
* **control plane** — the MixerCache keys on (schedule, fuse, codec);
  the grouped capacity-mode churn loop holds zero retraces with
  ``codec="int8-block"``; ``sync_bytes_per_client(codec=)`` prices the
  fedlay/ring wire by ``wire_bytes`` and leaves allreduce alone.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.mixing import (build_permute_schedule, masked_mixing_matrix,
                               schedule_mixing_matrix)
from repro.dist.compat import make_client_mesh, shard_map
from repro.dist.flat import FlatSpec
from repro.dist.sync import (fedlay_mix, global_mixer, make_mixer,
                             resolve_wire, sync_bytes_per_client)
from repro.wire.codec import WIRE_CODECS, get_codec

CODEC_NAMES = tuple(WIRE_CODECS)
LOSSY = ("bf16", "int8-block", "int4-block", "topk")
EIGHT_DEVICES = jax.device_count() >= 8


def _buf(B=3, N=200, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((scale * rng.normal(size=(B, N))).astype(np.float32))


# --------------------------------------------------------------------------
# The coding contract
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", CODEC_NAMES)
def test_codec_round_trip_within_documented_tolerance(name):
    codec = get_codec(name)
    buf = _buf(B=4, N=300, seed=1)
    wire = codec.encode(buf)
    out = np.asarray(codec.decode(wire, buf.shape[1]))
    assert out.shape == buf.shape and out.dtype == np.float32
    tol = np.asarray(codec.tolerance(buf))
    err = np.abs(out - np.asarray(buf))
    assert (err <= tol + 1e-7).all(), float((err - tol).max())
    if codec.exact:
        np.testing.assert_array_equal(out, np.asarray(buf))


def test_none_codec_is_bit_exact_identity():
    codec = get_codec("none")
    buf = _buf(seed=2)
    np.testing.assert_array_equal(
        np.asarray(codec.decode(codec.encode(buf), buf.shape[1])),
        np.asarray(buf))


def test_bf16_codec_exact_on_representable_and_2byte_wire():
    codec = get_codec("bf16")
    # values already on the bf16 grid survive bit-exactly
    buf = jnp.asarray(np.asarray(
        _buf(seed=3).astype(jnp.bfloat16), np.float32))
    out = codec.decode(codec.encode(buf), buf.shape[1])
    np.testing.assert_array_equal(np.asarray(out), np.asarray(buf))
    # and the wire part is genuinely 2 bytes/element (u16 bits, so XLA
    # cannot cancel the f32->bf16->f32 round-trip across a collective)
    (part,) = codec.encode(buf)
    assert part.dtype == jnp.uint16
    assert part.nbytes == buf.shape[0] * codec.wire_bytes(buf.shape[1])


@pytest.mark.parametrize("name", CODEC_NAMES)
def test_wire_bytes_equals_actual_part_bytes(name):
    codec = get_codec(name)
    for N in (64, 127, 128, 300, 513):
        buf = _buf(B=2, N=N, seed=N)
        wire = codec.encode(buf)
        actual = sum(int(p.nbytes) for p in wire)
        assert actual == 2 * codec.wire_bytes(N), (name, N)
        assert codec.payload_bytes(N) <= codec.wire_bytes(N)


def test_int8_block_closed_forms():
    codec = get_codec("int8-block")
    b = codec.block
    # N=256: two blocks -> 256 payload bytes + 2 bf16 scales
    assert codec.wire_bytes(2 * b) == 2 * b + 4
    assert codec.payload_bytes(2 * b) == 2 * b
    # ragged tail pads to the block boundary
    assert codec.wire_bytes(b + 1) == 2 * b + 4


@pytest.mark.parametrize("name", ("int8-block", "int4-block", "topk"))
def test_encode_ef_residual_is_exact_compensation(name):
    codec = get_codec(name)
    assert codec.error_feedback
    buf = _buf(B=3, N=260, seed=7)
    wire, res = codec.encode_ef(buf)
    ref = np.asarray(buf) - np.asarray(codec.decode(wire, buf.shape[1]))
    np.testing.assert_allclose(np.asarray(res), ref, atol=1e-6)


def test_topk_keeps_largest_and_drops_rest():
    codec = get_codec("topk")
    N = 160
    k = max(1, round(codec.rate * N))
    buf = _buf(B=2, N=N, seed=9)
    out = np.asarray(codec.decode(codec.encode(buf), N))
    for r in range(buf.shape[0]):
        row = np.asarray(buf)[r]
        keep = np.argsort(np.abs(row))[-k:]
        np.testing.assert_array_equal(out[r, keep], row[keep])
        dropped = np.setdiff1d(np.arange(N), keep)
        assert (out[r, dropped] == 0).all()


def test_get_codec_registry_and_passthrough():
    assert get_codec(None) is None
    assert get_codec("none").name == "none"
    codec = get_codec("int8-block")
    assert get_codec(codec) is codec
    with pytest.raises(ValueError, match="codec"):
        get_codec("zstd")
    # frozen dataclasses: hashable, value-equal -> usable as cache keys
    assert get_codec("int8-block") == get_codec("int8-block")
    assert len({get_codec(n) for n in CODEC_NAMES}) == len(CODEC_NAMES)


def test_resolve_wire_implies_flat():
    codec, fuse = resolve_wire("int8-block", None)
    assert codec.name == "int8-block" and fuse == "flat"
    assert resolve_wire(None, None) == (None, None)
    assert resolve_wire(None, "tree")[1] in (None, "tree")  # tree walk
    # a codec always lands on the flat row buffer
    assert resolve_wire("bf16", "tree")[1] == "flat"
    with pytest.raises(ValueError):
        resolve_wire(None, "bogus")


# --------------------------------------------------------------------------
# Seeded fuzz: shapes × dtypes × block sizes (always runs; hypothesis
# sibling below adds minimized counterexamples where available)
# --------------------------------------------------------------------------

def _fuzz_tree(rng, batch):
    dtypes = [np.float32, jnp.bfloat16, np.float32]
    tree = {}
    for i in range(rng.integers(1, 4)):
        shape = (batch,) + tuple(
            int(rng.integers(1, 9)) for _ in range(rng.integers(1, 3)))
        arr = rng.normal(size=shape).astype(np.float32) * 10.0 ** \
            rng.integers(-2, 3)
        tree[f"l{i}"] = jnp.asarray(arr).astype(
            dtypes[rng.integers(0, len(dtypes))])
    return tree


@pytest.mark.parametrize("seed", range(12))
def test_fuzz_codec_round_trip_over_flat_specs(seed):
    """Random mixed-dtype trees raveled through FlatSpec, every codec:
    decode(encode) within tolerance, wire bytes exact, EF residual
    exact — across ragged widths and both int block layouts."""
    from repro.wire.codec import Int4BlockCodec, Int8BlockCodec
    rng = np.random.default_rng(seed)
    batch = int(rng.integers(1, 5))
    spec = FlatSpec.for_tree(_fuzz_tree(rng, batch))
    buf = jnp.asarray(rng.normal(size=(batch, spec.size))
                      .astype(np.float32))
    codecs = [get_codec(n) for n in CODEC_NAMES]
    codecs += [Int8BlockCodec(block=int(b)) for b in (32, 256)]
    codecs += [Int4BlockCodec(block=64)]
    for codec in codecs:
        wire = codec.encode(buf)
        assert all(int(p.shape[0]) == batch for p in wire)
        assert sum(int(p.nbytes) for p in wire) == \
            batch * codec.wire_bytes(spec.size)
        out = np.asarray(codec.decode(wire, spec.size))
        tol = np.asarray(codec.tolerance(buf))
        assert (np.abs(out - np.asarray(buf)) <= tol + 1e-6).all(), \
            codec.name
        if codec.error_feedback:
            wire2, res = codec.encode_ef(buf)
            ref = np.asarray(buf) - np.asarray(
                codec.decode(wire2, spec.size))
            np.testing.assert_allclose(np.asarray(res), ref, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6),
       batch=st.integers(min_value=1, max_value=4),
       width=st.integers(min_value=1, max_value=700),
       block=st.sampled_from((32, 64, 128, 256)))
def test_property_codec_round_trip(seed, batch, width, block):
    from repro.wire.codec import Int8BlockCodec
    rng = np.random.default_rng(seed)
    buf = jnp.asarray(rng.normal(size=(batch, width)).astype(np.float32))
    for codec in (get_codec("bf16"), Int8BlockCodec(block=block),
                  get_codec("topk")):
        out = np.asarray(codec.decode(codec.encode(buf), width))
        tol = np.asarray(codec.tolerance(buf))
        assert (np.abs(out - np.asarray(buf)) <= tol + 1e-6).all()


def test_flat_spec_over_shape_dtype_structs():
    """FlatSpec.for_tree accepts abstract trees (the launch-time sizing
    path that allocates EF residual buffers before params exist)."""
    tree = {"w": jax.ShapeDtypeStruct((4, 3, 5), jnp.float32),
            "b": jax.ShapeDtypeStruct((4, 7), jnp.bfloat16)}
    spec = FlatSpec.for_tree(tree)
    assert spec.batch == 4 and spec.size % 128 == 0
    concrete = {"w": jnp.zeros((4, 3, 5), jnp.float32),
                "b": jnp.zeros((4, 7), jnp.bfloat16)}
    assert FlatSpec.for_tree(concrete) == spec


# --------------------------------------------------------------------------
# Compressed mixing vs the dense oracle (the acceptance pin)
# --------------------------------------------------------------------------

def _mix_on_mesh(sched, X, codec, mask=None, num_devices=8):
    n = sched.num_clients
    mesh = make_client_mesh(num_devices, "data")
    shard = NamedSharding(mesh, P("data"))
    W, S = jnp.asarray(sched.weights), jnp.asarray(sched.self_weight)
    tree = {"m": jnp.asarray(X)}
    wire_codec = get_codec(codec)
    ef = wire_codec is not None and wire_codec.error_feedback
    nflat = FlatSpec.for_tree({"m": X[:1]}).size
    in_specs = [P("data"), P("data"), P("data")]
    args = [tree["m"], W, S]
    if mask is not None:
        in_specs.append(P("data"))
        args.append(jnp.asarray(mask, jnp.float32))
    if ef:
        in_specs.append(P("data", None))
        args.append(jnp.zeros((n, nflat), jnp.float32))

    def body(x, w, s, *rest):
        m = rest[0] if mask is not None else None
        r = rest[-1] if ef else None
        out = fedlay_mix({"m": x}, sched, w, s, "data", mask=m,
                         fuse="flat", codec=wire_codec, residual=r)
        if ef:
            out, res = out
            return out["m"], res
        return out["m"]

    out_specs = (P("data"), P("data", None)) if ef else P("data")
    f = jax.jit(shard_map(body, mesh=mesh, in_specs=tuple(in_specs),
                          out_specs=out_specs, check_vma=False))
    out = f(*[jax.device_put(a, shard) for a in args])
    return np.asarray(out[0] if ef else out)


def _oracle_bound(sched, X, codec, mask=None):
    """(ref, per-element bound): out row i mixes decode(encode(x_j)),
    so |out − W·X| ≤ W_dense @ tolerance(X) (self terms are sent
    uncompressed, making this an upper bound)."""
    Wd = (masked_mixing_matrix(sched, mask) if mask is not None
          else schedule_mixing_matrix(sched))
    tol = np.asarray(get_codec(codec).tolerance(jnp.asarray(X)))
    return Wd @ X, Wd @ tol + 1e-5


@pytest.mark.multi_device
@pytest.mark.parametrize("codec", ("int8-block", "topk"))
@pytest.mark.parametrize("G", (1, 2, 4))
@pytest.mark.parametrize("masked", (False, True))
def test_compressed_fedlay_mix_matches_dense_oracle(codec, G, masked,
                                                    multi_device):
    n = 8 * G
    sched = build_permute_schedule(n, 2, salt=f"wire{G}")
    rng = np.random.default_rng(G)
    X = rng.normal(size=(n, 150)).astype(np.float32)
    mask = None
    if masked:
        mask = (rng.random(n) > 0.4).astype(np.float32)
        mask[0] = 0.0
    out = _mix_on_mesh(sched, X, codec, mask=mask)
    ref, bound = _oracle_bound(sched, X, codec, mask=mask)
    assert (np.abs(out - ref) <= bound).all(), \
        float((np.abs(out - ref) - bound).max())
    if masked:
        dead = mask == 0
        np.testing.assert_array_equal(out[dead], X[dead])


@pytest.mark.multi_device
def test_none_codec_path_bit_equals_codec_free_flat(multi_device):
    """The exactness control arm: routing through the codec plumbing
    with codec="none" reproduces the codec-free flat path bit-for-bit."""
    n = 8
    sched = build_permute_schedule(n, 3, salt="ctrl")
    X = np.random.default_rng(0).normal(size=(n, 70)).astype(np.float32)
    with_codec = _mix_on_mesh(sched, X, "none")
    mesh = make_client_mesh(8, "data")
    shard = NamedSharding(mesh, P("data"))
    f = jax.jit(shard_map(
        lambda x, w, s: fedlay_mix({"m": x}, sched, w, s, "data",
                                   fuse="flat")["m"],
        mesh=mesh, in_specs=(P("data"),) * 3, out_specs=P("data"),
        check_vma=False))
    plain = np.asarray(f(*[jax.device_put(a, shard) for a in (
        jnp.asarray(X), jnp.asarray(sched.weights),
        jnp.asarray(sched.self_weight))]))
    np.testing.assert_array_equal(with_codec, plain)


@pytest.mark.parametrize("codec", ("bf16", "int8-block"))
@pytest.mark.parametrize("masked", (False, True))
def test_compressed_global_mixer_matches_dense_oracle(codec, masked):
    n = 8
    sched = build_permute_schedule(n, 2, salt="gwire")
    rng = np.random.default_rng(5)
    X = rng.normal(size=(n, 90)).astype(np.float32)
    tree = {"m": jnp.asarray(X)}
    wire_codec = get_codec(codec)
    ef = wire_codec.error_feedback
    nflat = FlatSpec.for_tree({"m": tree["m"][:1]}).size
    res0 = jnp.zeros((n, nflat), jnp.float32)
    mix = jax.jit(global_mixer("fedlay", sched, masked=masked,
                               codec=codec))
    mask = None
    args = [tree]
    if masked:
        mask = (rng.random(n) > 0.4).astype(np.float32)
        mask[0] = 0.0
        args.append(jnp.asarray(mask))
    if ef:
        args.append(res0)
    out = mix(*args)
    if ef:
        out, _ = out
    ref, bound = _oracle_bound(sched, X, codec, mask=mask)
    got = np.asarray(out["m"])
    assert (np.abs(got - ref) <= bound).all()


# --------------------------------------------------------------------------
# Error feedback: convergence parity + residual churn semantics
# --------------------------------------------------------------------------

def test_ef_consensus_tracks_exact_mixing():
    """40 gossip rounds toward consensus with int8-block + EF on the
    flat_io mixer: the lossy trajectory stays within ε of the exact
    one, and far closer than the same codec without compensation."""
    n, N = 8, 256
    sched = build_permute_schedule(n, 2, salt="efconv")
    rng = np.random.default_rng(0)
    buf0 = rng.normal(size=(n, N)).astype(np.float32)
    exact = jax.jit(global_mixer("fedlay", sched, fuse="flat",
                                 flat_io=True))
    ef_mix = jax.jit(global_mixer("fedlay", sched, codec="int8-block",
                                  flat_io=True))
    raw = get_codec("int8-block")

    b_exact = jnp.asarray(buf0)
    b_ef, res = jnp.asarray(buf0), jnp.zeros((n, N), jnp.float32)
    b_raw = jnp.asarray(buf0)
    for _ in range(40):
        b_exact = exact(b_exact)
        b_ef, res = ef_mix(b_ef, res)
        # no-EF arm: decode(encode(x)) each round, mixed exactly
        b_raw = exact(raw.decode(raw.encode(b_raw), N))
    err_ef = float(np.abs(np.asarray(b_ef - b_exact)).max())
    err_raw = float(np.abs(np.asarray(b_raw - b_exact)).max())
    spread = float(np.abs(buf0 - buf0.mean(0)).max())
    assert err_ef <= 0.02 * spread, (err_ef, spread)
    assert err_ef < err_raw


def test_ef_masked_rows_keep_residual_and_identity():
    """A masked-out row neither mixes nor consumes its residual: its
    buffer row passes through untouched and its residual is unchanged."""
    n, N = 6, 128
    sched = build_permute_schedule(n, 2, salt="efmask")
    rng = np.random.default_rng(3)
    buf = jnp.asarray(rng.normal(size=(n, N)).astype(np.float32))
    res0 = jnp.asarray(rng.normal(size=(n, N)).astype(np.float32))
    mask = np.ones(n, np.float32)
    mask[2] = 0.0
    mix = jax.jit(global_mixer("fedlay", sched, masked=True,
                               codec="int8-block", flat_io=True))
    out, res = mix(buf, jnp.asarray(mask), res0)
    np.testing.assert_array_equal(np.asarray(out)[2], np.asarray(buf)[2])
    np.testing.assert_array_equal(np.asarray(res)[2], np.asarray(res0)[2])
    alive = mask > 0
    assert not np.array_equal(np.asarray(res)[alive],
                              np.asarray(res0)[alive])


def test_plan_reset_slots_covers_joiners_and_leavers():
    from repro.runtime.slots import RemapPlan, plan_reset_slots
    plan = RemapPlan(capacity=8, survivors=((0, 0), (2, 2)),
                     joiners=((100, 3), (101, 5)), leavers=((7, 1),))
    assert plan_reset_slots(plan) == (1, 3, 5)
    assert plan_reset_slots(RemapPlan(capacity=8, survivors=(),
                                      joiners=(), leavers=())) == ()


# --------------------------------------------------------------------------
# Control plane: cache keys, churn zero-retrace, bytes accounting
# --------------------------------------------------------------------------

def test_mixer_cache_keys_on_codec():
    from repro.overlay.controller import MixerCache
    built = []

    def factory(sched):
        built.append(sched)
        return lambda p: p

    cache = MixerCache(factory)
    sched = build_permute_schedule(4, 1)
    _, hit0 = cache.get(sched, "flat")
    _, hit1 = cache.get(sched, "flat", get_codec("int8-block"))
    _, hit2 = cache.get(sched, "flat", get_codec("int8-block"))
    _, hit3 = cache.get(sched, "flat", get_codec("topk"))
    assert (hit0, hit1, hit2, hit3) == (False, False, True, False)
    assert len(built) == 3 and len(cache) == 3


def _make_sim(n=12, L=2, seed=0):
    from repro.core.ndmp import Simulator
    sim = Simulator(num_spaces=L, latency=0.05, heartbeat_period=0.5,
                    probe_period=1.0, seed=seed)
    sim.seed_network(list(range(n)))
    return sim


@pytest.mark.multi_device
@pytest.mark.parametrize("flat_io", (False, True))
def test_grouped_codec_slot_loop_zero_retrace(flat_io, multi_device):
    """The ISSUE 7 churn pin: the grouped capacity-mode loop (capacity
    2 × devices, G = 2) with codec="int8-block" — compressed mixers and
    the EF residual leaf hold zero retraces across ≥ 3 distinct alive
    counts, with or without the resident flat buffer."""
    from repro.optim.optimizers import sgd
    from repro.overlay import ChurnTrace, OverlayController
    from repro.runtime import SlotTrainLoop, counting_jit, masked_local_step

    dim = 24

    def make_params(u):
        w = np.random.default_rng(u).normal(size=dim).astype(np.float32)
        return {"w": jnp.asarray(w)}

    def make_batch(node_ids, step):
        rows = [np.random.default_rng(abs(hash((u, step))) % 2**32)
                .normal(size=dim).astype(np.float32) for u in node_ids]
        return {"x": jnp.asarray(np.stack(rows))}

    def base_step(params, opt_state, batch):
        w, x = params["w"], batch["x"]
        loss = jnp.mean((w - x) ** 2, axis=-1)
        return {"w": w - 0.05 * 2.0 * (w - x) / dim}, opt_state, \
            {"loss": loss}

    mesh = make_client_mesh(8, "data")
    ctl = OverlayController(_make_sim(n=12), capacity=16,
                            clients_per_device=2, codec="int8-block",
                            flat_io=flat_io)
    assert ctl.fuse == "flat"           # the codec implied it
    sjit, scount = counting_jit(masked_local_step(base_step))
    loop = SlotTrainLoop(
        ctl, local_step=sjit, make_params=make_params, optimizer=sgd(0.0),
        make_batch=make_batch, jit_local_step=False, mesh=mesh)
    recs = loop.run(12, trace=ChurnTrace.scripted([
        (2.5, "fail", 1), (4.5, "fail", 3),
        (6.5, "join", 100, 0), (8.5, "join", 101, 0),
    ]))
    assert len({r.num_alive for r in recs}) >= 3
    assert all(np.isfinite(r.loss) for r in recs)
    assert scount.traces == 1 and scount.retraces == 0
    assert ctl.cache.hits > 0
    # the EF residual leaf exists, matches the flat width, and holds
    # finite state after churn (remapped slots were zeroed, not stale)
    assert loop.residual is not None
    assert np.isfinite(np.asarray(loop.residual)).all()


def test_controller_flat_io_requires_global_flat():
    from repro.overlay import OverlayController
    with pytest.raises(ValueError, match="flat_io"):
        OverlayController(_make_sim(n=4), mixer_kind="shard_map",
                          flat_io=True)


def test_sync_bytes_codec_accounting():
    # N is a FlatSpec width: always a multiple of LANE=128, so the int
    # codecs' block padding never inflates the payload
    N, n, L = 10_240, 16, 3
    plain = sync_bytes_per_client("fedlay", 4 * N, n, L)
    for name in ("bf16", "int8-block", "int4-block", "topk"):
        codec = get_codec(name)
        got = sync_bytes_per_client("fedlay", 4 * N, n, L, codec=name)
        assert got == plain * codec.wire_bytes(N) // (4 * N) \
            or got == 2 * L * codec.wire_bytes(N)
    # int8-block: >= 3.5x on the wire incl. scales, 4x payload
    int8 = get_codec("int8-block")
    assert 4 * N / int8.wire_bytes(N) >= 3.5
    assert 4 * N / int8.payload_bytes(N) >= 4.0
    for name in ("int4-block", "topk"):
        assert 4 * N / get_codec(name).wire_bytes(N) >= 4.0
    # allreduce reduces in-network: codec ignored
    assert sync_bytes_per_client("allreduce", 4 * N, n, L,
                                 codec="int8-block") == \
        sync_bytes_per_client("allreduce", 4 * N, n, L)
