"""DFL-mode production bundle (the paper's technique as a train step)
and the §Perf sharding knobs — build/lower sanity on the local mesh."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import REGISTRY, reduce_for_smoke
from repro.dist import sharding as sharding_mod
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import dfl_train_bundle, serve_bundle
from repro.models.config import INPUT_SHAPES
from repro.optim.optimizers import adamw


@pytest.fixture
def small_shape():
    return dataclasses.replace(INPUT_SHAPES["train_4k"], global_batch=2,
                               seq_len=64)


def test_dfl_bundle_builds_and_runs(small_shape):
    """One-client DFL step on the local mesh: mixing degenerates to the
    identity (self-weight 1) and the step must still train."""
    cfg = reduce_for_smoke(REGISTRY["qwen3-4b"])
    mesh = make_local_mesh(1, 1)
    b = dfl_train_bundle(cfg, small_shape, mesh, adamw(1e-3),
                         dtype=jnp.float32, sync="fedlay")
    params_s, opt_s, batch_s = b.arg_shapes
    # leading client dim present on every param leaf
    for leaf in jax.tree.leaves(params_s):
        assert leaf.shape[0] == 1
    # run it for real (1 client, tiny batch)
    rng = np.random.default_rng(0)
    params = jax.tree.map(
        lambda l: jnp.asarray(rng.normal(size=l.shape, scale=0.02),
                              l.dtype), params_s)
    opt_state = jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype), opt_s)
    batch = {k: jnp.asarray(rng.integers(0, cfg.vocab_size, v.shape),
                            jnp.int32) for k, v in batch_s.items()}
    new_p, new_o, metrics = b.step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    moved = any(float(jnp.abs(a - c).max()) > 0
                for a, c in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_p)))
    assert moved


def test_dfl_bundle_allreduce_consensus(small_shape):
    """allreduce sync forces exact consensus across the client dim."""
    cfg = reduce_for_smoke(REGISTRY["llama3.2-3b"])
    mesh = make_local_mesh(1, 1)
    b = dfl_train_bundle(cfg, small_shape, mesh, adamw(1e-3),
                         dtype=jnp.float32, sync="allreduce")
    rng = np.random.default_rng(1)
    params_s, opt_s, batch_s = b.arg_shapes
    params = jax.tree.map(
        lambda l: jnp.asarray(rng.normal(size=l.shape, scale=0.02),
                              l.dtype), params_s)
    opt_state = jax.tree.map(lambda l: jnp.zeros(l.shape, l.dtype), opt_s)
    batch = {k: jnp.asarray(rng.integers(0, cfg.vocab_size, v.shape),
                            jnp.int32) for k, v in batch_s.items()}
    new_p, _, m = b.step(params, opt_state, batch)
    assert np.isfinite(float(m["loss"]))


def test_serve_weight_stationary_specs(small_shape):
    cfg = reduce_for_smoke(REGISTRY["qwen3-4b"])
    mesh = make_local_mesh(1, 1)
    shp = dataclasses.replace(INPUT_SHAPES["decode_32k"], global_batch=2,
                              seq_len=64)
    try:
        steps_mod.SERVE_WEIGHT_STATIONARY = True
        b = serve_bundle(cfg, shp, mesh, dtype=jnp.float32)
        # no data-axis FSDP anywhere in the param specs
        for spec in jax.tree.leaves(b.in_specs[0],
                                    is_leaf=lambda x: isinstance(x, P)):
            assert "data" not in [a for a in spec if a]
    finally:
        steps_mod.SERVE_WEIGHT_STATIONARY = False


def test_cache_len_tp_specs():
    from repro.dist.sharding import cache_specs
    cache = {"seg0": {"sub0": {"kv": {
        "k": jax.ShapeDtypeStruct((2, 4, 64, 8, 16), jnp.float32),
        "v": jax.ShapeDtypeStruct((2, 4, 64, 8, 16), jnp.float32)}}},
        "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    try:
        sharding_mod.CACHE_LEN_TP = True
        specs = cache_specs(cache, dp="data", tp="model", shard_batch=True)
        assert specs["seg0"]["sub0"]["kv"]["k"] == \
            P(None, "data", "model", None, None)
    finally:
        sharding_mod.CACHE_LEN_TP = False
    specs = cache_specs(cache, dp="data", tp="model", shard_batch=True)
    assert specs["seg0"]["sub0"]["kv"]["k"] == \
        P(None, "data", None, "model", None)


def test_bf16_cache_attention_knob_parity():
    """The bf16c serving path matches the f32 baseline within bf16 tol."""
    from repro.models import attention as att
    from repro.models import layers
    rng = np.random.default_rng(0)
    B, Hq, Hkv, hd, L = 2, 8, 2, 32, 128
    q = jnp.asarray(rng.normal(size=(B, 1, Hq, hd)).astype(np.float32))
    ck = jnp.asarray(rng.normal(size=(B, L, Hkv, hd))).astype(jnp.bfloat16)
    cv = jnp.asarray(rng.normal(size=(B, L, Hkv, hd))).astype(jnp.bfloat16)
    base = att.cache_attention(q, ck, cv, 100)
    try:
        layers.F32_DOT_OUTPUT = False
        fast = att.cache_attention(q, ck, cv, 100)
    finally:
        layers.F32_DOT_OUTPUT = True
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(fast, np.float32),
                               rtol=2e-2, atol=2e-2)
