import os
import sys
import types

# Tests see the real single CPU device; ONLY launch/dryrun.py forces 512
# host devices (per the dry-run contract).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # Offline container without hypothesis: install a shim so the
    # property-test modules still collect; every @given test is skipped.
    import pytest

    def _skip_given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    class _NoopSettings:
        """No-op stand-in for hypothesis.settings (decorator + profiles)."""

        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*args, **kwargs):
            pass

        @staticmethod
        def load_profile(*args, **kwargs):
            pass

    def _strategy(*_args, **_kwargs):
        return None

    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "lists", "tuples", "sampled_from",
                  "booleans", "just", "text", "one_of", "composite"):
        setattr(_st, _name, _strategy)

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _skip_given
    _hyp.settings = _NoopSettings
    _hyp.strategies = _st
    _hyp.assume = lambda *a, **k: True
    _hyp.example = _skip_given
    _hyp.HealthCheck = types.SimpleNamespace(all=staticmethod(lambda: []))
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
    settings = _NoopSettings

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")
