import os
import sys
import types

# Tier-1 runs on a forced 8-device CPU mesh so shard_map mixer paths
# (repro.dist.sync) execute as genuine multi-device programs instead of
# collapsing to 1 device.  Must happen before the first jax import —
# conftest loads before every test module.  Subprocess probes
# (tests/test_dist.py-style) pop the parent's XLA_FLAGS and force their
# own count, so they are unaffected; launch/dryrun.py still forces 512
# in its own process per the dry-run contract.
_flags = os.environ.get("XLA_FLAGS", "")
if ("xla_force_host_platform_device_count" not in _flags
        and "jax" not in sys.modules):
    # If jax is already imported (exotic plugin, sitecustomize) the flag
    # cannot take effect; leave it unset and let the multi_device
    # fixture skip rather than aborting the whole suite.
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # Offline container without hypothesis: install a shim so the
    # property-test modules still collect; every @given test is skipped.
    import pytest

    def _skip_given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    class _NoopSettings:
        """No-op stand-in for hypothesis.settings (decorator + profiles)."""

        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*args, **kwargs):
            pass

        @staticmethod
        def load_profile(*args, **kwargs):
            pass

    class _DummyStrategy:
        """Inert strategy stand-in: supports the combinator surface
        (map/filter/flatmap/|) so module-level strategy expressions in
        property-test files evaluate under collection."""

        def map(self, *_a, **_k):
            return self

        def filter(self, *_a, **_k):
            return self

        def flatmap(self, *_a, **_k):
            return self

        def example(self):
            return None

        def __or__(self, _other):
            return self

        def __call__(self, *_a, **_k):
            return self

    def _strategy(*_args, **_kwargs):
        return _DummyStrategy()

    def _composite(fn):
        # @st.composite functions must stay callable (they are invoked at
        # module level to build strategies); the result is inert.
        def build(*_a, **_k):
            return _DummyStrategy()
        return build

    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "lists", "tuples", "sampled_from",
                  "booleans", "just", "text", "one_of", "none", "data",
                  "dictionaries", "sets", "binary", "characters",
                  "permutations"):
        setattr(_st, _name, _strategy)
    _st.composite = _composite
    _st.SearchStrategy = _DummyStrategy
    # any strategy name we did not anticipate still resolves (PEP 562)
    _st.__getattr__ = lambda _name: _strategy

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _skip_given
    _hyp.settings = _NoopSettings
    _hyp.strategies = _st
    _hyp.assume = lambda *a, **k: True
    _hyp.example = _skip_given
    _hyp.HealthCheck = types.SimpleNamespace(all=staticmethod(lambda: []))
    # cover both import spellings: ``from hypothesis import strategies``
    # AND ``import hypothesis.strategies as st`` in property-test modules
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
    settings = _NoopSettings

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")

import pytest  # noqa: E402  (after the hypothesis shim)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multi_device: exercises real multi-device shard_map programs "
        "(needs the forced 8-device CPU mesh)")
    config.addinivalue_line(
        "markers",
        "chaos: fault-injection storms (repro.faults) — seeded chaos "
        "traces over the NDMP engines and the slot loop")


@pytest.fixture
def multi_device():
    """The 8-device CPU mesh tier-1 runs on.  Returns the device count;
    skips if the XLA force flag did not take (e.g. jax was pre-imported
    by an exotic plugin)."""
    import jax
    n = jax.device_count()
    if n < 8:
        pytest.skip(f"needs >= 8 host devices, have {n}")
    return n
