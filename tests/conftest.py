import os
import sys

# Tests see the real single CPU device; ONLY launch/dryrun.py forces 512
# host devices (per the dry-run contract).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from hypothesis import settings

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")
