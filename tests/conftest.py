import os
import sys
import types

# Tests see the real single CPU device; ONLY launch/dryrun.py forces 512
# host devices (per the dry-run contract).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # Offline container without hypothesis: install a shim so the
    # property-test modules still collect; every @given test is skipped.
    import pytest

    def _skip_given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    class _NoopSettings:
        """No-op stand-in for hypothesis.settings (decorator + profiles)."""

        def __init__(self, *args, **kwargs):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*args, **kwargs):
            pass

        @staticmethod
        def load_profile(*args, **kwargs):
            pass

    class _DummyStrategy:
        """Inert strategy stand-in: supports the combinator surface
        (map/filter/flatmap/|) so module-level strategy expressions in
        property-test files evaluate under collection."""

        def map(self, *_a, **_k):
            return self

        def filter(self, *_a, **_k):
            return self

        def flatmap(self, *_a, **_k):
            return self

        def example(self):
            return None

        def __or__(self, _other):
            return self

        def __call__(self, *_a, **_k):
            return self

    def _strategy(*_args, **_kwargs):
        return _DummyStrategy()

    def _composite(fn):
        # @st.composite functions must stay callable (they are invoked at
        # module level to build strategies); the result is inert.
        def build(*_a, **_k):
            return _DummyStrategy()
        return build

    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "lists", "tuples", "sampled_from",
                  "booleans", "just", "text", "one_of", "none", "data",
                  "dictionaries", "sets", "binary", "characters",
                  "permutations"):
        setattr(_st, _name, _strategy)
    _st.composite = _composite
    _st.SearchStrategy = _DummyStrategy
    # any strategy name we did not anticipate still resolves (PEP 562)
    _st.__getattr__ = lambda _name: _strategy

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _skip_given
    _hyp.settings = _NoopSettings
    _hyp.strategies = _st
    _hyp.assume = lambda *a, **k: True
    _hyp.example = _skip_given
    _hyp.HealthCheck = types.SimpleNamespace(all=staticmethod(lambda: []))
    # cover both import spellings: ``from hypothesis import strategies``
    # AND ``import hypothesis.strategies as st`` in property-test modules
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
    settings = _NoopSettings

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")
