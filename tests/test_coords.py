"""Virtual coordinates + circular distance (paper Def. 2) properties."""

import numpy as np
from hypothesis import given, strategies as st

from repro.core.coords import (NodeAddress, ccw_arc, circular_distance,
                               closer, coordinate, coordinates, cw_arc,
                               fnv1a_64, ring_order)

floats01 = st.floats(min_value=0.0, max_value=1.0, exclude_max=True,
                     allow_nan=False)


def test_hash_deterministic_and_distinct():
    assert coordinate(7, 0) == coordinate(7, 0)
    assert coordinate(7, 0) != coordinate(7, 1)
    assert coordinate(7, 0) != coordinate(8, 0)
    assert coordinate("10.0.0.1", 2) == coordinate("10.0.0.1", 2)


def test_coordinates_in_range_and_uniformish():
    xs = np.array([coordinate(i, 0) for i in range(4000)])
    assert (0 <= xs).all() and (xs < 1).all()
    # crude uniformity: decile occupancy within 30% of expected
    hist, _ = np.histogram(xs, bins=10, range=(0, 1))
    assert hist.min() > 0.7 * 400 and hist.max() < 1.3 * 400


@given(floats01, floats01)
def test_cd_symmetry_and_range(x, y):
    d = circular_distance(x, y)
    assert 0 <= d <= 0.5
    assert d == circular_distance(y, x)
    assert circular_distance(x, x) == 0.0


@given(floats01, floats01)
def test_cd_is_min_arc(x, y):
    assert abs(circular_distance(x, y)
               - min(cw_arc(x, y), ccw_arc(x, y))) < 1e-12


@given(floats01, floats01, floats01)
def test_cd_triangle_inequality_on_ring(x, y, z):
    assert circular_distance(x, z) <= (circular_distance(x, y)
                                       + circular_distance(y, z) + 1e-12)


@given(floats01, floats01, floats01)
def test_closer_total_order(x, y, t):
    # exactly one of closer(x,y), closer(y,x) unless identical node
    a = closer(x, y, t, tie_x=0, tie_y=1)
    b = closer(y, x, t, tie_x=1, tie_y=0)
    assert a != b


def test_ring_order_sorted_by_coord():
    addrs = [NodeAddress.create(i, 2) for i in range(50)]
    order = ring_order(addrs, 0)
    xs = {a.node_id: a.coords[0] for a in addrs}
    vals = [xs[u] for u in order]
    assert vals == sorted(vals)
