"""Substrate: optimizers, checkpointing, token pipeline, HLO stats."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager, load, save
from repro.data.tokens import TokenStream, input_specs
from repro.launch.hlo_stats import collective_stats
from repro.models.config import INPUT_SHAPES
from repro.configs import REGISTRY
from repro.optim.optimizers import (adamw, apply_updates,
                                    clip_by_global_norm, cosine_schedule,
                                    global_norm, sgd)


# --------------------------------------------------------------------------
# optimizers
# --------------------------------------------------------------------------

def _quadratic_converges(opt, steps=300):
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(5.0)}
    target = {"w": jnp.asarray([1.0, 1.0]), "b": jnp.asarray(-1.0)}
    state = opt.init(params)
    for _ in range(steps):
        grads = jax.tree.map(lambda p, t: p - t, params, target)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    err = max(float(jnp.abs(a - b).max())
              for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(target)))
    return err


def test_sgd_converges():
    assert _quadratic_converges(sgd(0.1)) < 1e-3


def test_sgd_momentum_converges():
    assert _quadratic_converges(sgd(0.05, momentum=0.9)) < 1e-3


def test_adamw_converges():
    assert _quadratic_converges(adamw(0.05, weight_decay=0.0)) < 1e-2


def test_clip_by_global_norm():
    grads = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_shape():
    sched = cosine_schedule(warmup=10, total=100, floor=0.1)
    assert float(sched(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, abs=1e-6)


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

def test_ckpt_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16) * 1.5,
                  "d": (jnp.asarray(3, jnp.int32), jnp.asarray(2.0))},
            "e": [jnp.zeros((2, 2))]}
    path = str(tmp_path / "ck")
    save(path, tree, {"step": 7})
    restored, meta = load(path)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_ckpt_manager_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.asarray(float(s))})
    assert mgr.steps() == [3, 4]
    tree, meta = mgr.restore()
    assert float(tree["x"]) == 4.0 and meta["step"] == 4


# --------------------------------------------------------------------------
# token pipeline
# --------------------------------------------------------------------------

def test_token_stream_deterministic_and_noniid():
    a1 = list(TokenStream(512, 2, 16, seed=0, client=0).batches(2))
    a2 = list(TokenStream(512, 2, 16, seed=0, client=0).batches(2))
    b = list(TokenStream(512, 2, 16, seed=0, client=1).batches(2))
    np.testing.assert_array_equal(a1[0][0], a2[0][0])
    assert not np.array_equal(a1[0][0], b[0][0])   # client shards differ
    x, y = a1[0]
    assert x.shape == (2, 16) and y.shape == (2, 16)
    assert x.min() >= 0 and x.max() < 512


def test_input_specs_all_pairs():
    for arch, cfg in REGISTRY.items():
        for shape in INPUT_SHAPES.values():
            specs = input_specs(cfg, shape)
            if shape.kind == "decode":
                assert specs["token"].shape == (shape.global_batch, 1)
            else:
                assert specs["tokens"].shape == (shape.global_batch,
                                                 shape.seq_len)
                if cfg.enc_dec:
                    assert "enc_embeds" in specs


# --------------------------------------------------------------------------
# HLO collective parsing
# --------------------------------------------------------------------------

FAKE_HLO = """
  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[256]{0} all-reduce(%y), replica_groups=[16,32]<=[512], to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%z), replica_groups={{0,1}}, dimensions={0}
  %cp = bf16[8,8]{1,0} collective-permute(%w), source_target_pairs={{0,1},{1,0}}
  %aa = f32[32]{0} all-to-all(%v), replica_groups={{0,1,2,3,4,5,6,7}}
"""


def test_collective_stats_parse():
    st = collective_stats(FAKE_HLO)
    assert st.counts == {"all-gather": 1, "all-reduce": 1,
                         "reduce-scatter": 1, "collective-permute": 1,
                         "all-to-all": 1}
    # all-gather result 16*1024*2 bytes, group 4 → wire (3/4)·32768
    assert st.result_bytes["all-gather"] == 32768
    assert st.wire_bytes_per_device > 0
    # collective-permute is point-to-point: exactly its bytes
    assert st.result_bytes["collective-permute"] == 128
