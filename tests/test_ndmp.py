"""NDMP protocols: join correctness (Thm 1), leave, failure repair
(Thm 2), and concurrent-churn convergence — including hypothesis-driven
random churn schedules."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.coords import NodeAddress, circular_distance, coordinates
from repro.core.ndmp import Simulator
from repro.core.topology import correct_neighbor_sets


def make_sim(n=30, L=3, seed=0, **kw):
    sim = Simulator(num_spaces=L, latency=0.05, heartbeat_period=0.5,
                    probe_period=1.0, seed=seed, **kw)
    sim.seed_network(list(range(n)))
    return sim


def test_seeded_network_is_correct():
    assert make_sim().correctness() == 1.0


def test_single_join_converges_to_correct():
    sim = make_sim(n=20)
    sim.join(100, bootstrap=3)
    sim.run_for(5.0)
    assert sim.correctness() == 1.0
    # Definition-1 check: the joiner's table is exactly its ring adjacency
    want = correct_neighbor_sets(sim.alive_addresses())
    assert sim.nodes[100].neighbor_set == want[100]


def test_join_is_recursive_from_two_nodes():
    """Paper: recursive construction from a 2-node network."""
    sim = Simulator(num_spaces=2, latency=0.05, heartbeat_period=0.5,
                    probe_period=1.0)
    sim.seed_network([0, 1])
    for j in range(2, 12):
        sim.join(j, bootstrap=int(j % 2))
        sim.run_for(4.0)
    assert sim.correctness() == 1.0


def test_leave_protocol():
    sim = make_sim(n=25)
    sim.leave(7)
    sim.run_for(3.0)
    assert sim.correctness() == 1.0
    assert 7 not in {a.node_id for a in sim.alive_addresses()}


def test_failure_repair_theorem2():
    """After one abrupt failure the two ring-adjacent nodes reconnect."""
    sim = make_sim(n=25)
    sim.fail(11)
    sim.run_for(10.0)   # detect (3T) + repair
    assert sim.correctness() == 1.0


def test_mass_concurrent_join():
    """Paper Fig 8a: 25 clients join a 100-client network at once."""
    sim = make_sim(n=100)
    for j in range(200, 225):
        sim.join(j, bootstrap=int(j % 100))
    sim.run_for(30.0)
    assert sim.correctness() == 1.0


def test_mass_concurrent_failure():
    """Paper Fig 8b: 25% of clients fail at the same instant."""
    sim = make_sim(n=80)
    for f in range(0, 20):
        sim.fail(f)
    sim.run_for(40.0)
    assert sim.correctness() == 1.0


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["join", "fail", "leave"]),
                          st.integers(0, 10_000)),
                min_size=1, max_size=12),
       st.integers(0, 5))
def test_random_churn_schedule_converges(events, seed):
    """Property: any interleaving of joins/leaves/failures converges back
    to a correct FedLay (the paper's core resilience claim)."""
    sim = make_sim(n=40, seed=seed)
    alive = set(range(40))
    next_id = 1000
    for kind, jitter in events:
        sim.run_for(0.01 * (jitter % 7))
        if kind == "join":
            order = sorted(alive)
            boot = int(order[jitter % len(alive)])
            # realistic deployment: joiner ships a 3-entry seed list, so
            # a bootstrap that dies mid-join doesn't strand it
            seeds = tuple(int(order[(jitter + k) % len(alive)])
                          for k in range(1, 4))
            sim.join(next_id, bootstrap=boot, seeds=seeds)
            alive.add(next_id)
            next_id += 1
        elif len(alive) > 25:
            victim = sorted(alive)[jitter % len(alive)]
            (sim.fail if kind == "fail" else sim.leave)(victim)
            alive.discard(victim)
    sim.run_for(60.0)
    assert sim.correctness() == 1.0


def test_construction_message_cost_scales():
    """Paper Fig 8c: ~30 join messages per client at n=500 — we assert the
    per-client join cost grows sub-linearly (greedy routing shortcuts)."""
    costs = {}
    for n in (50, 200):
        sim = Simulator(num_spaces=3, latency=0.01, heartbeat_period=50.0,
                        probe_period=100.0, seed=1)
        sim.seed_network(list(range(10)))
        for j in range(10, n):
            sim.join(j, bootstrap=int(j % 10))
            sim.run_for(1.0)
        sim.run_for(5.0)
        joins = [st_.join_messages for id_, st_ in sim.nodes.items() if id_ >= 10]
        costs[n] = float(np.mean(joins))
    assert costs[200] < costs[50] * 4.0   # ≈O(log n) growth, not O(n)
    assert costs[200] < 80.0
