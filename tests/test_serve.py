"""Serving-plane tests (ISSUE 9): the per-slot position vector through
the whole decode stack, batched prefill parity with the stepped decode
path, the three flash_decode/gqa_decode bugfixes, and the continuous-
batching ServeLoop's zero-retrace / isolation guarantees."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_decode import flash_decode, pick_block_l
from repro.kernels.ref import flash_decode_ref
from repro.launch.train import tiny_lm
from repro.models import decode_step, init_cache, init_params, prefill
from repro.models.attention import cache_attention, gqa_decode, gqa_init
from repro.obs.events import telemetry
from repro.obs.rounds import round_ledger
from repro.runtime.serving import ServeLoop


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


# --------------------------------------------------------------------------
# Per-slot position vector through the kernel and its oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("L", [64, 130, 160, 512, 700])
def test_flash_decode_pos_vector_parity(L):
    """flash_decode with a per-slot (B,) pos vector (mixed live, empty,
    boundary rows) equals both the pure-jnp cache_attention oracle and
    flash_decode_ref within 1e-5 — including odd/small L that exercise
    the lane-aligned block fix."""
    rng = np.random.default_rng(L)
    B, Hq, Hkv, hd = 5, 8, 2, 32
    q, k, v = (_rand(rng, B, Hq, hd), _rand(rng, B, L, Hkv, hd),
               _rand(rng, B, L, Hkv, hd))
    pos = jnp.asarray([0, L // 2, L - 1, -1, 3], jnp.int32)
    out = flash_decode(q, k, v, pos, interpret=True)
    ref = flash_decode_ref(q, k, v, pos)
    oracle = cache_attention(q[:, None], k, v, pos)[:, 0]
    assert float(jnp.abs(out - ref).max()) <= 1e-5
    assert float(jnp.abs(out - oracle).max()) <= 1e-5


def test_flash_decode_scalar_pos_still_works():
    rng = np.random.default_rng(0)
    B, Hq, Hkv, hd, L = 2, 4, 2, 16, 96
    q, k, v = (_rand(rng, B, Hq, hd), _rand(rng, B, L, Hkv, hd),
               _rand(rng, B, L, Hkv, hd))
    out = flash_decode(q, k, v, 7, interpret=True)
    ref = flash_decode_ref(q, k, v, 7)
    assert float(jnp.abs(out - ref).max()) <= 1e-5


def test_flash_decode_empty_slot_exactly_zero():
    """pos < 0 marks an empty serving slot: the output row must be
    EXACTLY zero (masked online softmax), not small-but-garbage — a
    bare exp(s - m) on an all-masked row would yield uniform weights."""
    rng = np.random.default_rng(1)
    B, Hq, Hkv, hd, L = 3, 4, 2, 16, 160
    q, k, v = (_rand(rng, B, Hq, hd), _rand(rng, B, L, Hkv, hd),
               _rand(rng, B, L, Hkv, hd))
    pos = jnp.asarray([-1, 5, -1], jnp.int32)
    out = flash_decode(q, k, v, pos, interpret=True)
    oracle = cache_attention(q[:, None], k, v, pos)[:, 0]
    assert float(jnp.abs(out[0]).max()) == 0.0
    assert float(jnp.abs(out[2]).max()) == 0.0
    assert float(jnp.abs(oracle[0]).max()) == 0.0
    assert float(jnp.abs(out[1]).max()) > 0.0


def test_pick_block_l_lane_aligned():
    """Regression for the bl = min(block_l, L) bug: every chosen block
    is a lane (128) multiple (a bare min() handed Pallas a lane-invalid
    block whenever 128 < L < block_l with L % 128 != 0)."""
    expected = {1: 128, 100: 128, 129: 256, 160: 256, 300: 384,
                511: 512, 512: 512, 513: 512, 4096: 512}
    for L, want in expected.items():
        bl = pick_block_l(L, 512)
        assert bl == want, (L, bl)
        assert bl % 128 == 0


def test_flash_decode_rejects_ragged_gqa():
    rng = np.random.default_rng(2)
    q, k, v = (_rand(rng, 2, 7, 16), _rand(rng, 2, 64, 2, 16),
               _rand(rng, 2, 64, 2, 16))
    with pytest.raises(ValueError, match="integer multiple"):
        flash_decode(q, k, v, 3, interpret=True)
    q8 = _rand(rng, 2, 8, 16)
    with pytest.raises(ValueError, match="per-slot vector"):
        flash_decode(q8, k, v, jnp.zeros((3,), jnp.int32), interpret=True)


# --------------------------------------------------------------------------
# gqa_decode overflow + per-slot writes
# --------------------------------------------------------------------------

def _gqa_setup(rng, B=2, L=8):
    p = gqa_init(jax.random.PRNGKey(0), 32, 4, 2, 8)
    x = _rand(rng, B, 1, 32)
    cache = {"k": jnp.zeros((B, L, 2, 8)), "v": jnp.zeros((B, L, 2, 8))}
    kw = dict(num_heads=4, num_kv_heads=2, head_dim=8, rope_theta=1e4)
    return p, x, cache, kw


def test_gqa_decode_overflow_raises():
    """Concrete pos >= cache_len with no window must raise instead of
    silently clamping onto the last slot (the old wrong-answer bug)."""
    rng = np.random.default_rng(3)
    p, x, cache, kw = _gqa_setup(rng, L=8)
    with pytest.raises(ValueError, match="overflows"):
        gqa_decode(p, x, cache, 8, **kw)
    with pytest.raises(ValueError, match="overflows"):
        gqa_decode(p, x, cache, jnp.asarray([3, 8]), **kw)
    # the windowed path is the ring buffer: same pos must NOT raise
    out, _ = gqa_decode(p, x, cache, 8, window=8, **kw)
    assert out.shape == (2, 1, 32)
    # in-range per-slot vector is fine; the empty row's output is zero
    out, new = gqa_decode(p, x, cache, jnp.asarray([3, -1]), **kw)
    assert float(jnp.abs(out[1]).max()) == 0.0
    assert new["k"].shape == cache["k"].shape


def test_gqa_decode_vector_matches_scalar():
    """A uniform (B,) pos vector must reproduce the scalar-pos path
    bit-for-bit (same writes, same validity)."""
    rng = np.random.default_rng(4)
    p, x, cache, kw = _gqa_setup(rng, L=8)
    o1, c1 = gqa_decode(p, x, cache, 2, **kw)
    o2, c2 = gqa_decode(p, x, cache, jnp.asarray([2, 2]), **kw)
    assert float(jnp.abs(o1 - o2).max()) <= 1e-6
    assert float(jnp.abs(c1["k"] - c2["k"]).max()) == 0.0


# --------------------------------------------------------------------------
# Batched prefill ≡ stepped decode
# --------------------------------------------------------------------------

def _stepped(cfg, params, cache, toks):
    logits = None
    for t in range(toks.shape[1]):
        logits, cache = decode_step(cfg, params, cache, toks[:, t:t + 1])
    return logits, cache


def _parity(cfg, B=2, P=8, cache_len=24, seed=0):
    rng = np.random.default_rng(seed)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, P)), jnp.int32)
    l1, c1 = _stepped(cfg, params, init_cache(cfg, params, B, cache_len), toks)
    l2, c2 = prefill(cfg, params, init_cache(cfg, params, B, cache_len), toks)
    scale = max(1.0, float(jnp.abs(l1).max()))
    assert float(jnp.abs(l1 - l2).max()) / scale < 2e-4
    nxt = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    g1, _ = decode_step(cfg, params, c1, nxt)
    g2, _ = decode_step(cfg, params, c2, nxt)
    assert float(jnp.abs(g1 - g2).max()) / scale < 2e-4
    assert int(c2["pos"]) == P if jnp.ndim(c2["pos"]) == 0 else True


def test_prefill_parity_dense():
    _parity(tiny_lm(layers=2))


def test_prefill_parity_sliding_window():
    cfg = dataclasses.replace(tiny_lm(layers=2), sliding_window=4)
    _parity(cfg, P=8)       # prompt longer than the window → ring prefill


def test_prefill_parity_ssm():
    from repro.configs import REGISTRY, reduce_for_smoke
    _parity(reduce_for_smoke(REGISTRY["mamba2-370m"]), P=8)


def test_prefill_ragged_lengths():
    """Padded ragged prefill: each row's last-valid-token logits and
    primed cache must equal a tight (unpadded) prefill of that row."""
    cfg = tiny_lm(layers=2)
    rng = np.random.default_rng(5)
    params = init_params(cfg, jax.random.PRNGKey(0))
    P, cache_len = 8, 24
    lens = [3, 8, 5]
    toks = np.zeros((3, P), np.int32)
    for b, ln in enumerate(lens):
        toks[b, :ln] = rng.integers(0, cfg.vocab_size, ln)
    cache = init_cache(cfg, params, 3, cache_len, per_slot_pos=True)
    logits, cache = prefill(cfg, params, cache, jnp.asarray(toks),
                            lengths=jnp.asarray(lens))
    assert list(np.asarray(cache["pos"])) == lens
    for b, ln in enumerate(lens):
        solo_cache = init_cache(cfg, params, 1, cache_len)
        solo, _ = prefill(cfg, params, solo_cache,
                          jnp.asarray(toks[b:b + 1, :ln]))
        scale = max(1.0, float(jnp.abs(solo).max()))
        assert float(jnp.abs(solo[0] - logits[b]).max()) / scale < 2e-4


def test_prefill_ragged_rejects_ssm_and_scalar_cache():
    from repro.configs import REGISTRY, reduce_for_smoke
    cfg = tiny_lm(layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.zeros((2, 4), jnp.int32)
    with pytest.raises(ValueError, match="per-slot pos"):
        prefill(cfg, params, init_cache(cfg, params, 2, 16), toks,
                lengths=jnp.asarray([2, 4]))
    ssm_cfg = reduce_for_smoke(REGISTRY["mamba2-370m"])
    ssm_params = init_params(ssm_cfg, jax.random.PRNGKey(0))
    ssm_cache = init_cache(ssm_cfg, ssm_params, 2, 16, per_slot_pos=True)
    with pytest.raises(ValueError, match="SSM"):
        prefill(ssm_cfg, ssm_params, ssm_cache, toks,
                lengths=jnp.asarray([2, 4]))


def test_prefill_overflow_raises():
    cfg = tiny_lm(layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, params, 1, 4)
    with pytest.raises(ValueError, match="overflows"):
        prefill(cfg, params, cache, jnp.zeros((1, 8), jnp.int32))


def test_decode_step_empty_slots_frozen():
    """Vector-pos decode: empty slots (pos = -1) never advance."""
    cfg = tiny_lm(layers=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, params, 3, 16, per_slot_pos=True)
    cache["pos"] = jnp.asarray([2, -1, 5], jnp.int32)
    _, new = decode_step(cfg, params, cache, jnp.zeros((3, 1), jnp.int32))
    assert list(np.asarray(new["pos"])) == [3, -1, 6]


# --------------------------------------------------------------------------
# The continuous-batching serving loop
# --------------------------------------------------------------------------

CFG = tiny_lm(layers=2)
PARAMS = init_params(CFG, jax.random.PRNGKey(0))


def _loop(policy="continuous", capacity=3):
    return ServeLoop(CFG, PARAMS, capacity=capacity, cache_len=24,
                     prompt_len=8, policy=policy)


def test_serve_churn_zero_retraces():
    """Request churn across >= 3 distinct occupancy counts compiles
    exactly one trace per step function — 0 retraces after warmup."""
    rng = np.random.default_rng(6)
    with telemetry() as bus, round_ledger() as ledger:
        loop = _loop()
        loop.submit(rng.integers(0, CFG.vocab_size, 4), max_new=2)
        loop.run()                      # warmup: all four steps traced
        warm = loop.traces
        occup = set()
        for _ in range(8):
            loop.submit(rng.integers(0, CFG.vocab_size,
                                     int(rng.integers(1, 9))),
                        max_new=int(rng.integers(2, 7)))
        while loop.pending or loop.active:
            loop.tick()
            occup.add(len(loop.slots))
        assert len(occup & {1, 2, 3}) >= 3 or len(occup) >= 3
        assert loop.traces == warm      # ZERO retraces across churn
        assert loop.retraces == 0
        assert bus.counters["serve.completed"] == 9
        assert "serve.tick.ms" in bus.histograms
        assert len(ledger.rows) > 0     # one RoundRecord per tick
        assert all(r.loop == "serve" for r in ledger.rows)
        assert all(r.retraces == 0 for r in ledger.rows)


def test_serve_continuous_matches_solo():
    """Batching must not change anyone's tokens: every request served
    in a churning continuous batch produces exactly the greedy tokens
    it gets when served alone — the per-slot pos correctness pin."""
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, CFG.vocab_size, int(rng.integers(2, 9)))
               for _ in range(6)]
    gens = [int(rng.integers(2, 7)) for _ in range(6)]

    loop = _loop()
    for p, g in zip(prompts, gens):
        loop.submit(p, max_new=g)
    loop.run()
    batched = {r.rid: r.tokens for r in loop.completed}

    solo_loop = _loop(capacity=1)
    for i, (p, g) in enumerate(zip(prompts, gens)):
        solo_loop.submit(p, max_new=g)
    solo_loop.run()
    solo = {r.rid: r.tokens for r in solo_loop.completed}
    assert batched == solo
    assert all(len(batched[i]) == gens[i] for i in range(6))


def test_serve_static_policy_never_mixes_batches():
    """Static policy: admissions only ever happen into an empty batch
    (the baseline semantics serve_load measures against), and outputs
    still match the solo run."""
    rng = np.random.default_rng(8)
    with round_ledger() as ledger:
        loop = _loop(policy="static")
        for _ in range(5):
            loop.submit(rng.integers(0, CFG.vocab_size, 4),
                        max_new=int(rng.integers(2, 6)))
        loop.run()
    for row in ledger.rows:
        admitted = row.extra.get("admitted", 0)
        # an admission tick started from an empty batch: alive after the
        # tick can only be what was admitted (minus same-tick retires)
        if admitted:
            assert row.num_alive <= admitted
    assert len(loop.completed) == 5


def test_serve_forced_retirement_on_cache_overflow():
    """A generation that would overflow cache_len is force-retired by
    the host-side guard instead of silently wrapping the prefix cache."""
    loop = ServeLoop(CFG, PARAMS, capacity=1, cache_len=10, prompt_len=8)
    req = loop.submit(np.arange(8) % CFG.vocab_size, max_new=50)
    loop.run()
    # prompt fills pos 0..7; decode may write pos 8 and 9 only
    assert req.done and len(req.tokens) <= 3


def test_serve_hot_reload_from_flat_buffer():
    """Model hot-swap straight from the training loop's FlatSpec flat
    buffer: same treedef in, zero retraces, and the identical row
    reproduces the exact pre-reload tokens."""
    from repro.dist.flat import FlatSpec
    prompt = np.arange(6) % CFG.vocab_size
    tree = jax.tree.map(lambda l: jnp.stack([l, l * 2.0]), PARAMS)
    spec = FlatSpec.for_tree(tree)
    buf = spec.ravel(tree)

    loop = _loop(capacity=2)
    loop.submit(prompt, max_new=4)
    loop.run()
    base = loop.completed[-1].tokens
    t0 = loop.traces
    loop.reload_from_flat(buf, spec, row=0)
    swapped = loop.params
    same_leaf = jax.tree.leaves(swapped)[0]
    assert float(jnp.abs(same_leaf - jax.tree.leaves(PARAMS)[0]).max()) == 0.0
    loop.submit(prompt, max_new=4)
    loop.run()
    assert loop.completed[-1].tokens == base
    loop.reload_from_flat(buf, spec, row=1)
    doubled_leaf = jax.tree.leaves(loop.params)[0]
    assert float(jnp.abs(doubled_leaf - 2.0 *
                         jax.tree.leaves(PARAMS)[0]).max()) == 0.0
    loop.submit(prompt, max_new=4)
    loop.run()
    assert loop.traces == t0            # reloads never retrace


def test_serve_rejects_bad_configs():
    with pytest.raises(ValueError, match="policy"):
        ServeLoop(CFG, PARAMS, capacity=2, cache_len=16, prompt_len=8,
                  policy="adaptive")
    with pytest.raises(ValueError, match="prompt_len"):
        ServeLoop(CFG, PARAMS, capacity=2, cache_len=8, prompt_len=16)
    from repro.configs import REGISTRY, reduce_for_smoke
    ssm_cfg = reduce_for_smoke(REGISTRY["mamba2-370m"])
    ssm_params = init_params(ssm_cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="SSM"):
        ServeLoop(ssm_cfg, ssm_params, capacity=2, cache_len=16,
                  prompt_len=8)
    loop = _loop()
    with pytest.raises(ValueError, match="prompt length"):
        loop.submit(np.zeros(9, np.int32))


def test_serve_deadline_eviction_frees_slot():
    """ISSUE 10 satellite: a request past its per-slot tick budget is
    force-retired with ``evicted=True`` and a ``serve.evictions``
    counter, and its slot frees the same tick — a stuck generation can
    never wedge the batch."""
    with telemetry() as bus, round_ledger() as ledger:
        loop = _loop(capacity=1)
        doomed = loop.submit(np.arange(4) % CFG.vocab_size, max_new=50,
                             max_ticks=2)
        ok = loop.submit(np.arange(4) % CFG.vocab_size, max_new=3)
        loop.run()
    assert doomed.evicted
    assert len(doomed.tokens) <= 3          # admit + 2 decode ticks max
    # the evicted slot was reclaimed: the queued request still completes
    assert not ok.evicted and len(ok.tokens) == 3
    assert bus.counters["serve.evictions"] == 1
    assert sum(r.extra.get("evicted", 0) for r in ledger.rows) == 1


def test_serve_wall_deadline_eviction():
    loop = _loop(capacity=2)
    req = loop.submit(np.arange(4) % CFG.vocab_size, max_new=50,
                      deadline_s=0.0)       # already expired on arrival
    loop.run()
    assert req.evicted and len(req.tokens) <= 2


def test_serve_rejects_bad_max_ticks():
    loop = _loop()
    with pytest.raises(ValueError, match="max_ticks"):
        loop.submit(np.arange(4) % CFG.vocab_size, max_ticks=0)
