"""Pallas kernel validation: interpret=True kernels vs pure-jnp oracles,
swept over shapes and dtypes (hypothesis for the shape space)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import flash_decode, ssd_scan, weighted_mix
from repro.kernels.ref import (flash_decode_ref, ssd_scan_ref,
                               weighted_mix_ref)

RNG = np.random.default_rng(0)
TOLS = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# --------------------------------------------------------------------------
# weighted_mix
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("K,N,bn", [(1, 128, 128), (3, 1000, 256),
                                    (7, 4096, 1024), (13, 65536, 65536),
                                    (5, 131, 128),
                                    # 128 < N < block_n with N % 128 != 0:
                                    # the lane-alignment regression (the
                                    # old min(block_n, N) block was
                                    # TPU-invalid here)
                                    (3, 200, 65536), (5, 300, 512)])
def test_weighted_mix_sweep(K, N, bn, dtype):
    m = jnp.asarray(RNG.normal(size=(K, N)), dtype)
    w = jnp.asarray(RNG.random(K).astype(np.float32))
    w = w / w.sum()
    out = weighted_mix(m, w, block_n=bn, interpret=True)
    ref = weighted_mix_ref(m, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOLS[dtype])


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 10), st.integers(1, 3000), st.integers(0, 4))
def test_weighted_mix_property(K, N, seed):
    rng = np.random.default_rng(seed)
    m = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    w = jnp.asarray(rng.random(K).astype(np.float32) + 0.01)
    out = weighted_mix(m, w, block_n=512, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(weighted_mix_ref(m, w)),
                               rtol=3e-5, atol=3e-5)


def test_weighted_mix_block_is_always_lane_aligned():
    """Regression: with 128 < N < block_n and N % 128 != 0 the old
    ``min(block_n, N)`` tile was not a lane multiple — TPU-invalid, and
    only passed in interpret mode.  The chosen block must always be a
    multiple of 128 and still tile the padded vector exactly."""
    from repro.kernels.weighted_mix import LANE, aligned_block_n
    for n, block_n in [(200, 65536), (131, 128), (129, 4096), (300, 512),
                       (1000, 300), (65536, 65536), (1, 128), (127, 64)]:
        bn = aligned_block_n(n, block_n)
        assert bn % LANE == 0, (n, block_n, bn)
        assert bn >= LANE
        padded = n + ((-n) % bn)
        assert padded % bn == 0
    # the exact regression shape: N=200 used to pick bn=200
    assert aligned_block_n(200, 65536) == 256


def test_weighted_mix_identity():
    """Self-weight 1, neighbors 0 ⇒ output == own model exactly."""
    m = jnp.asarray(RNG.normal(size=(4, 300)).astype(np.float32))
    w = jnp.asarray([1.0, 0.0, 0.0, 0.0], jnp.float32)
    out = weighted_mix(m, w, block_n=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(m[0]), atol=1e-6)


def test_weighted_mix_masked_renormalizes():
    """The masked variant drops masked-out models and renormalizes the
    surviving weights (≡ masked_mixing_matrix row semantics); an
    all-masked stack yields zeros."""
    m = jnp.asarray(RNG.normal(size=(5, 300)).astype(np.float32))
    w = jnp.asarray(RNG.random(5).astype(np.float32) + 0.1)
    mask = jnp.asarray([1, 0, 1, 1, 0], jnp.float32)
    out = weighted_mix(m, w, mask=mask, block_n=128, interpret=True)
    ref = weighted_mix_ref(m, w, mask=mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # surviving effective weights sum to 1: a constant stack is fixed
    const = jnp.ones((5, 256), jnp.float32) * 3.25
    out_c = weighted_mix(const, w, mask=mask, block_n=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out_c), 3.25, rtol=1e-6)
    out0 = weighted_mix(m, w, mask=jnp.zeros(5), block_n=128,
                        interpret=True)
    np.testing.assert_array_equal(np.asarray(out0), 0.0)


def test_mix_accumulate_incremental_equals_stacked():
    """Folding K models one at a time through the incremental entry ==
    the stacked weighted_mix == the jnp oracle."""
    from repro.kernels.ref import mix_accumulate_ref
    from repro.kernels.weighted_mix import mix_accumulate
    K, B, N = 5, 3, 515
    models = jnp.asarray(RNG.normal(size=(K, B, N)).astype(np.float32))
    w = jnp.asarray(RNG.random((K, B)).astype(np.float32))
    acc = mix_accumulate(None, models[0], w[0], block_n=256, interpret=True)
    ref = mix_accumulate_ref(None, models[0], w[0])
    for k in range(1, K):
        acc = mix_accumulate(acc, models[k], w[k], block_n=256,
                             interpret=True)
        ref = mix_accumulate_ref(ref, models[k], w[k])
    np.testing.assert_allclose(np.asarray(acc), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    # per-row parity with the stacked kernel (row 0 of each model)
    stacked = weighted_mix(models[:, 0, :], w[:, 0], block_n=256,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(acc[0]), np.asarray(stacked),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_mix_equals_dense_product(dtype):
    """The whole-round kernel: static source rows + runtime weights ≡
    the dense W·X it encodes."""
    from repro.kernels.ref import gather_mix_ref
    from repro.kernels.weighted_mix import gather_mix
    C, N, K1 = 8, 1000, 5
    rng = np.random.default_rng(3)
    buf = jnp.asarray(rng.normal(size=(C, N)), dtype)
    srcs = rng.integers(0, C, size=(C, K1))
    srcs[:, 0] = np.arange(C)                   # self column
    w = jnp.asarray(rng.random((C, K1)).astype(np.float32))
    out = gather_mix(buf, srcs, w, block_n=256, interpret=True)
    assert out.dtype == buf.dtype
    ref = gather_mix_ref(buf, srcs, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOLS[dtype])
    # dense-matrix cross-check: scatter the (srcs, w) table into (C, C)
    W = np.zeros((C, C))
    for i in range(C):
        for k in range(K1):
            W[i, srcs[i, k]] += float(w[i, k])
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               W @ np.asarray(buf, np.float32),
                               **TOLS[dtype])


def test_gather_mix_rejects_bad_tables():
    from repro.kernels.weighted_mix import gather_mix
    buf = jnp.ones((4, 256), jnp.float32)
    with pytest.raises(ValueError, match="match"):
        gather_mix(buf, np.zeros((3, 2), np.int64),
                   jnp.ones((3, 2)), interpret=True)
    with pytest.raises(ValueError, match="out of range"):
        gather_mix(buf, np.full((4, 2), 9), jnp.ones((4, 2)),
                   interpret=True)


def test_kernels_auto_interpret_on_cpu():
    """Regression (ISSUE 5): the raw kernel entries must run on CPU
    without callers passing interpret= — the old interpret=False
    default died with 'Only interpret mode is supported on CPU
    backend', so the fused mixing hot path could never reach them."""
    from repro.kernels.interpret import resolve_interpret
    from repro.kernels.weighted_mix import (gather_mix, mix_accumulate,
                                            weighted_mix as raw_mix)
    if jax.default_backend() == "tpu":
        pytest.skip("auto-interpret regression is about non-TPU backends")
    assert resolve_interpret(None) is True
    assert resolve_interpret(False) is False
    m = jnp.asarray(RNG.normal(size=(3, 256)).astype(np.float32))
    w = jnp.asarray([0.5, 0.25, 0.25], jnp.float32)
    # none of these pass interpret= — all must auto-interpret
    np.testing.assert_allclose(
        np.asarray(raw_mix(m, w)), np.asarray(weighted_mix_ref(m, w)),
        rtol=2e-5, atol=2e-5)
    mix_accumulate(None, m, w)
    gather_mix(m, np.zeros((3, 1), np.int64), jnp.ones((3, 1)))
    # and the jit front door still accepts the explicit override
    weighted_mix(m, w, interpret=True)


# --------------------------------------------------------------------------
# flash_decode
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,hd,L,bl,pos", [
    (1, 4, 1, 64, 256, 128, 255),
    (2, 8, 2, 64, 700, 128, 450),      # unaligned L → padding path
    (2, 16, 2, 128, 1024, 512, 100),   # pos masks most of the cache
    (1, 8, 8, 64, 512, 256, 511),      # MHA (G=1)
    (3, 8, 4, 32, 384, 128, 0),        # single valid slot
])
def test_flash_decode_sweep(B, Hq, Hkv, hd, L, bl, pos, dtype):
    q = jnp.asarray(RNG.normal(size=(B, Hq, hd)), dtype)
    kc = jnp.asarray(RNG.normal(size=(B, L, Hkv, hd)), dtype)
    vc = jnp.asarray(RNG.normal(size=(B, L, Hkv, hd)), dtype)
    out = flash_decode(q, kc, vc, pos, block_l=bl, interpret=True)
    ref = flash_decode_ref(q, kc, vc, pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **TOLS[dtype])


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([(4, 2), (8, 2), (4, 4)]),
       st.integers(10, 500), st.integers(0, 5))
def test_flash_decode_property(B, heads, L, seed):
    Hq, Hkv = heads
    hd = 32
    rng = np.random.default_rng(seed)
    pos = int(rng.integers(0, L))
    q = jnp.asarray(rng.normal(size=(B, Hq, hd)).astype(np.float32))
    kc = jnp.asarray(rng.normal(size=(B, L, Hkv, hd)).astype(np.float32))
    vc = jnp.asarray(rng.normal(size=(B, L, Hkv, hd)).astype(np.float32))
    out = flash_decode(q, kc, vc, pos, block_l=128, interpret=True)
    ref = flash_decode_ref(q, kc, vc, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_flash_decode_matches_model_cache_attention():
    """Kernel ≡ the model's cache_attention (the serving integration)."""
    from repro.models.attention import cache_attention
    B, Hq, Hkv, hd, L = 2, 8, 2, 64, 333
    q = jnp.asarray(RNG.normal(size=(B, 1, Hq, hd)).astype(np.float32))
    kc = jnp.asarray(RNG.normal(size=(B, L, Hkv, hd)).astype(np.float32))
    vc = jnp.asarray(RNG.normal(size=(B, L, Hkv, hd)).astype(np.float32))
    pos = 200
    ref = cache_attention(q, kc, vc, pos)
    out = flash_decode(q[:, 0], kc, vc, pos, block_l=128, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref[:, 0]),
                               rtol=3e-5, atol=3e-5)


# --------------------------------------------------------------------------
# ssd_scan
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 16, 16, 16),
    (2, 128, 3, 16, 32, 32),
    (2, 96, 2, 32, 16, 32),            # S not divisible by chunk → halves
    (1, 256, 4, 64, 128, 64),          # production-ish tile
])
def test_ssd_scan_sweep(B, S, H, P, N, chunk, dtype):
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)), dtype)
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, S, H))) * 0.2, jnp.float32)
    A = -jnp.asarray(np.abs(RNG.normal(size=(H,))).astype(np.float32))
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)), dtype)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)), dtype)
    out = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    ref = ssd_scan_ref(x.astype(jnp.float32), dt, A,
                       Bm.astype(jnp.float32), Cm.astype(jnp.float32))
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **tol)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 2), st.sampled_from([32, 64, 96]),
       st.integers(1, 3), st.integers(0, 5))
def test_ssd_scan_property(B, S, H, seed):
    rng = np.random.default_rng(seed)
    P, N = 8, 16
    x = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(np.abs(rng.normal(size=(B, S, H))).astype(np.float32) * 0.3)
    A = -jnp.asarray(np.abs(rng.normal(size=(H,))).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    out = ssd_scan(x, dt, A, Bm, Cm, chunk=32, interpret=True)
    ref = ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


def test_ssd_scan_chunk_invariance():
    """Different chunk sizes must give identical results."""
    B, S, H, P, N = 1, 128, 2, 16, 16
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, S, H))).astype(np.float32) * 0.2)
    A = -jnp.asarray(np.abs(RNG.normal(size=(H,))).astype(np.float32))
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)).astype(np.float32))
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)).astype(np.float32))
    o16 = ssd_scan(x, dt, A, Bm, Cm, chunk=16, interpret=True)
    o64 = ssd_scan(x, dt, A, Bm, Cm, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o16), np.asarray(o64),
                               rtol=1e-4, atol=1e-4)
