"""Method-registry front door: every registered method (plus the
ablation-suffix variants) runs end to end on a tiny synthetic task, the
traces are monotone in time and non-degenerate, and the method name
round-trips through RunResult."""

import numpy as np
import pytest

from repro.core.dfl import (METHOD_REGISTRY, Engine, MethodSpec, RunResult,
                            resolve_method, run_method)
from repro.data.noniid import shard_partition
from repro.data.synthetic import mnist_like
from repro.models.small import MLPTask

VARIANTS = ("fedlay-sync", "fedlay-noconf", "fedlay-noconf-sync",
            "fedlay-sync-noconf")
ALL_METHODS = tuple(sorted(METHOD_REGISTRY)) + VARIANTS


@pytest.fixture(scope="module")
def task():
    data = mnist_like(n_train=240, n_test=120, seed=0)
    part = shard_partition(data.y_train, num_clients=8, shards_per_client=3,
                           seed=0)
    return MLPTask(data, part, hidden=8, local_steps=1, batch=16)


@pytest.mark.parametrize("method", ALL_METHODS)
def test_every_registered_method_runs(task, method):
    res = Engine().run(task, method, total_time=6.0, model_bytes=1000,
                       seed=0)
    assert isinstance(res, RunResult)
    # the trace is monotone in time and non-degenerate
    assert len(res.trace) >= 2
    times = [row.time for row in res.trace]
    assert times == sorted(times)
    for row in res.trace:
        assert np.isfinite(row.mean_acc)
        assert 0.0 <= row.min_acc <= row.mean_acc <= row.max_acc <= 1.0
    assert res.local_steps_per_client > 0
    assert len(res.final_params) == task.num_clients
    # method name round-trips: RunResult.method is the canonical name and
    # resolves back to the very spec that ran
    spec = resolve_method(method)
    assert res.method == spec.name
    assert resolve_method(res.method) == spec


def test_suffix_order_is_irrelevant():
    a = resolve_method("fedlay-noconf-sync")
    b = resolve_method("fedlay-sync-noconf")
    assert a == b
    assert a.aggregation == "simple" and a.pacing == "sync"
    assert a.name == "fedlay-noconf-sync"       # canonical ordering


def test_single_suffixes():
    assert resolve_method("fedlay-sync").pacing == "sync"
    assert resolve_method("fedlay-sync").aggregation == "confidence"
    assert resolve_method("fedlay-noconf").aggregation == "simple"
    assert resolve_method("fedlay-noconf").pacing == "async"
    assert resolve_method("fedlay") == METHOD_REGISTRY["fedlay"]


def test_unknown_method_lists_known():
    with pytest.raises(ValueError) as exc:
        resolve_method("fedsky-sync")
    msg = str(exc.value)
    assert "fedsky" in msg
    assert "fedlay" in msg and "fedavg" in msg    # lists known methods


def test_ad_hoc_spec_runs(task):
    from repro.core.baselines import TOPOLOGY_REGISTRY
    spec = MethodSpec(name="fedlay-d4",
                      topology=TOPOLOGY_REGISTRY["fedlay"](task.num_clients, 2))
    res = Engine().run(task, spec, total_time=4.0, model_bytes=1000, seed=0)
    assert res.method == "fedlay-d4"
    assert np.isfinite(res.final_mean_acc)


def test_run_method_shim_deprecated(task):
    with pytest.deprecated_call():
        res = run_method("fedlay", task, total_time=4.0, model_bytes=1000,
                         seed=0)
    assert res.method == "fedlay"
    assert np.isfinite(res.final_mean_acc)


@pytest.mark.parametrize("method", ("fedlay", "fedavg", "fedlay-noconf-sync"))
def test_run_method_shim_parity_with_engine(task, method):
    """The shim must emit DeprecationWarning AND reproduce Engine.run
    bit-for-bit (same defaults, same seed => identical run)."""
    with pytest.warns(DeprecationWarning):
        old = run_method(method, task, total_time=4.0, model_bytes=1000,
                         seed=0)
    new = Engine().run(task, method, total_time=4.0, model_bytes=1000,
                       seed=0)
    assert old.method == new.method
    assert [r.time for r in old.trace] == [r.time for r in new.trace]
    assert [r.mean_acc for r in old.trace] == [r.mean_acc for r in new.trace]
    assert old.comm_bytes_per_client == new.comm_bytes_per_client
    assert old.messages_per_client == new.messages_per_client
    assert len(old.final_params) == len(new.final_params)
    for a, b in zip(old.final_params, new.final_params):
        np.testing.assert_array_equal(a, b)


def test_gossip_spec_requires_topology(task):
    with pytest.raises(ValueError):
        Engine().run(task, MethodSpec(name="bare"), total_time=2.0,
                     model_bytes=100)
