"""Model-zoo correctness: per-family forward/grad sanity, decode-vs-
prefill parity, SSD-vs-recurrence equivalence, MLA absorbed decode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (ArchConfig, HybridConfig, MLAConfig, MoEConfig,
                          SSMConfig, decode_step, forward, init_cache,
                          init_params, train_loss)
from repro.models.config import reduce_for_smoke

RNG = np.random.default_rng(0)


def dense_cfg(**kw):
    base = dict(name="dense-t", family="dense", num_layers=2, d_model=128,
                num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256,
                vocab_size=256, qk_norm=True, rope_theta=10_000.0)
    base.update(kw)
    return ArchConfig(**base)


def _loss_and_grad(cfg, B=2, S=32, enc=None):
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if enc is not None:
        batch["enc_embeds"] = enc
    loss = train_loss(cfg, params, batch, remat=False)
    g = jax.grad(lambda p: train_loss(cfg, p, batch, remat=True))(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    return float(loss), gn, params


@pytest.mark.parametrize("cfg", [
    dense_cfg(),
    dense_cfg(name="swa", sliding_window=16),
    dense_cfg(name="moe-t", family="moe", first_dense_layers=1,
              moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                            num_shared=1, capacity_factor=2.0)),
    dense_cfg(name="ssm-t", family="ssm", d_ff=0,
              ssm=SSMConfig(d_state=16, headdim=16, chunk=8)),
    dense_cfg(name="hyb-t", family="hybrid", num_layers=4,
              hybrid=HybridConfig(period=2, attn_index=0),
              ssm=SSMConfig(d_state=16, headdim=16, chunk=8),
              moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128,
                            moe_every=2, moe_offset=1, capacity_factor=2.0)),
    dense_cfg(name="mla-t", num_kv_heads=4,
              mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                            qk_nope_head_dim=16, qk_rope_head_dim=8,
                            v_head_dim=16), mtp_depth=1),
], ids=lambda c: c.name)
def test_family_loss_and_grads_finite(cfg):
    loss, gn, _ = _loss_and_grad(cfg)
    assert np.isfinite(loss) and loss > 0
    assert np.isfinite(gn) and gn > 0


def test_encdec_loss_and_grads():
    cfg = dense_cfg(name="ed-t", family="audio", enc_dec=True, enc_layers=2,
                    qk_norm=False)
    enc = jnp.asarray(RNG.normal(size=(2, 12, cfg.d_model)), jnp.float32)
    loss, gn, _ = _loss_and_grad(cfg, enc=enc)
    assert np.isfinite(loss) and np.isfinite(gn) and gn > 0


def _decode_parity(cfg, S=16, enc=None, atol=2e-4):
    params = init_params(cfg, jax.random.PRNGKey(1))
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, size=(1, S)), jnp.int32)
    lf, _ = forward(cfg, params, toks, enc_embeds=enc)
    cache = init_cache(cfg, params, 1, max(S, 32), enc_embeds=enc)
    errs = []
    for t in range(S):
        lg, cache = decode_step(cfg, params, cache, toks[:, t:t + 1])
        errs.append(float(jnp.abs(lg[0] - lf[0, t]).max()))
    assert max(errs) < atol, errs


def test_decode_parity_dense():
    _decode_parity(dense_cfg())


def test_decode_parity_sliding_window():
    cfg = dense_cfg(name="swa", sliding_window=8)
    _decode_parity(cfg)


def test_decode_parity_ssm():
    cfg = dense_cfg(name="ssm-t", family="ssm", d_ff=0,
                    ssm=SSMConfig(d_state=16, headdim=16, chunk=8))
    _decode_parity(cfg)


def test_decode_parity_hybrid():
    cfg = dense_cfg(name="hyb-t", family="hybrid", num_layers=4,
                    hybrid=HybridConfig(period=2, attn_index=0),
                    ssm=SSMConfig(d_state=16, headdim=16, chunk=8))
    _decode_parity(cfg)


def test_decode_parity_mla():
    cfg = dense_cfg(name="mla-t", num_kv_heads=4,
                    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                  qk_nope_head_dim=16, qk_rope_head_dim=8,
                                  v_head_dim=16))
    _decode_parity(cfg)


def test_decode_parity_encdec():
    cfg = dense_cfg(name="ed-t", family="audio", enc_dec=True, enc_layers=2,
                    qk_norm=False)
    enc = jnp.asarray(RNG.normal(size=(1, 12, cfg.d_model)), jnp.float32)
    _decode_parity(cfg, enc=enc)


def test_nested_remat_matches_plain():
    """Nested √L remat is a pure memory optimization — loss identical."""
    cfg = dense_cfg(num_layers=6)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    l1 = train_loss(cfg, params, batch, remat=False)
    l2 = train_loss(cfg, params, batch, remat=True)
    assert float(jnp.abs(l1 - l2)) < 1e-5
    g1 = jax.grad(lambda p: train_loss(cfg, p, batch, remat=False))(params)
    g2 = jax.grad(lambda p: train_loss(cfg, p, batch, remat=True))(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_padded_vocab_never_predicted():
    cfg = dense_cfg(vocab_size=250)   # pads to 256
    params = init_params(cfg, jax.random.PRNGKey(0))
    assert params["embed"].shape[0] == 256
    logits, _ = forward(cfg, params, jnp.zeros((2, 8), jnp.int32))
    assert logits.shape[-1] == 256
    assert float(logits[..., 250:].max()) <= -1e29


def test_ssd_matches_sequential_recurrence():
    from repro.models.ssm import ssd_chunked
    B, S, H, P, N = 2, 64, 3, 8, 16
    x = jnp.asarray(RNG.normal(size=(B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, S, H))).astype(np.float32) * 0.2)
    A = -jnp.asarray(np.abs(RNG.normal(size=(H,))).astype(np.float32))
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)).astype(np.float32))
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)).astype(np.float32))
    y, fin = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    from repro.kernels.ref import ssd_scan_ref
    ref = ssd_scan_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_blockwise_attention_matches_naive():
    from repro.models.attention import blockwise_attention
    B, S, Hq, Hkv, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(RNG.normal(size=(B, S, Hq, hd)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, hd)).astype(np.float32))

    def naive(q, k, v, window=None, causal=True):
        G = Hq // Hkv
        qg = q.reshape(B, S, Hkv, G, hd)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", q.reshape(B, S, Hkv, G, hd), k)
        s = s * hd ** -0.5
        i = jnp.arange(S)[:, None]
        j = jnp.arange(S)[None, :]
        mask = jnp.ones((S, S), bool) if not causal else (j <= i)
        if window is not None:
            mask &= (j > i - window)
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, -1)
        return jnp.einsum("bqhgk,bkhd->bqhgd", p, v).reshape(B, S, Hq, hd)

    for kw in ({}, {"window": 16}, {"causal": False}):
        out = blockwise_attention(q, k, v, chunk=16, **kw)
        ref = naive(q, k, v, **kw)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)


def test_segment_plan_factoring():
    from repro.models.model import find_segments, layer_plan
    jam = dense_cfg(name="j", family="hybrid", num_layers=8,
                    hybrid=HybridConfig(period=4, attn_index=0),
                    ssm=SSMConfig(d_state=16, headdim=16, chunk=8))
    segs = find_segments(layer_plan(jam))
    assert len(segs) == 1 and len(segs[0][0]) == 4 and segs[0][1] == 2
    ds = dense_cfg(name="d", family="moe", num_layers=6, first_dense_layers=2,
                   moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64))
    segs = find_segments(layer_plan(ds))
    assert [r for _, r in segs] == [2, 4]
