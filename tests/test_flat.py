"""The flat-buffer fused mixing hot path (ISSUE 5).

Three layers pinned here:

* :class:`repro.dist.flat.FlatSpec` — ``unravel ∘ ravel`` is the exact
  identity over mixed-dtype / mixed-shape trees (fixed cases plus
  hypothesis fuzz), offsets are lane-aligned, and lossy layouts are
  rejected loudly;
* the fused mixers — ``fedlay_mix(fuse="flat")`` under ``shard_map`` on
  the real 8-device tier-1 mesh and ``global_mixer(fuse="flat")`` both
  ≡ the tree walk ≡ the dense ``schedule_mixing_matrix`` /
  ``masked_mixing_matrix`` oracles for G ∈ {1, 2, 4}, masked and
  unmasked;
* the control plane — :class:`repro.overlay.OverlayController` with
  ``fuse="flat"``: the MixerCache keys on the fuse mode, and a grouped
  capacity-mode churn loop over the fused mixers holds **zero
  retraces** across ≥ 3 distinct alive counts (the ISSUE 4 pin, now on
  the fused path).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.mixing import (build_permute_schedule, masked_mixing_matrix,
                               schedule_mixing_matrix)
from repro.dist.compat import make_client_mesh, shard_map
from repro.dist.flat import FlatSpec
from repro.dist.sync import check_fuse, fedlay_mix, global_mixer, make_mixer
from repro.kernels.weighted_mix import LANE

GROUPS = (1, 2, 4)
EIGHT_DEVICES = jax.device_count() >= 8


# --------------------------------------------------------------------------
# FlatSpec: the flat-buffer contract
# --------------------------------------------------------------------------

def _mixed_tree(batch=4, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(batch, 3, 5)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(batch, 7)).astype(np.float32)
                         ).astype(jnp.bfloat16),
        "nest": {"s": jnp.asarray(
            rng.normal(size=(batch,)).astype(np.float16))},
    }


def test_flat_spec_round_trip_exact_mixed_dtypes():
    tree = _mixed_tree()
    spec = FlatSpec.for_tree(tree)
    back = spec.unravel(spec.ravel(tree))
    for got, want in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        assert got.dtype == want.dtype
        assert jnp.array_equal(got, want)        # bitwise, not allclose


def test_flat_spec_offsets_lane_aligned():
    tree = _mixed_tree()
    spec = FlatSpec.for_tree(tree)
    assert all(off % LANE == 0 for off in spec.offsets)
    assert spec.size % LANE == 0
    # segments don't overlap and cover in declaration order
    for off, size, nxt in zip(spec.offsets, spec.sizes,
                              spec.offsets[1:] + (spec.size,)):
        assert off + size <= nxt


def test_flat_spec_ravel_shape_and_padding_zeros():
    tree = {"a": jnp.ones((2, 3), jnp.float32)}
    spec = FlatSpec.for_tree(tree)
    buf = spec.ravel(tree)
    assert buf.shape == (2, LANE) and buf.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(buf[:, 3:]), 0.0)


def test_flat_spec_rejects_lossy_or_ragged_layouts():
    with pytest.raises(ValueError, match="losslessly"):
        FlatSpec.for_tree({"x": jnp.zeros((2, 3), jnp.int32)})
    with pytest.raises(ValueError, match="losslessly"):
        FlatSpec.for_tree({"x": jnp.zeros((2, 3), jnp.float32)},
                          dtype=jnp.float16)
    with pytest.raises(ValueError, match="leading batch"):
        FlatSpec.for_tree({"x": jnp.zeros((2, 3)), "y": jnp.zeros((4, 3))})
    with pytest.raises(ValueError, match="empty"):
        FlatSpec.for_tree({})
    spec = FlatSpec.for_tree({"x": jnp.zeros((2, 3))})
    with pytest.raises(ValueError, match="buffer shape"):
        spec.unravel(jnp.zeros((2, 2 * LANE)))


def test_check_fuse_validates():
    assert check_fuse(None) is None
    assert check_fuse("tree") is None
    assert check_fuse("flat") == "flat"
    with pytest.raises(ValueError, match="fuse"):
        check_fuse("nope")
    with pytest.raises(ValueError, match="fuse"):
        global_mixer("fedlay", build_permute_schedule(4, 1), fuse="bogus")


@settings(max_examples=25, deadline=None)
@given(data=st.data(),
       batch=st.integers(min_value=1, max_value=6),
       num_leaves=st.integers(min_value=1, max_value=5),
       seed=st.integers(min_value=0, max_value=10**6))
def test_property_flat_round_trip_identity(data, batch, num_leaves, seed):
    """The tentpole fuzz: ravel ∘ unravel is the exact identity over
    random mixed-dtype / mixed-shape trees."""
    rng = np.random.default_rng(seed)
    tree = {}
    for i in range(num_leaves):
        ndim = data.draw(st.integers(min_value=0, max_value=3),
                         label=f"ndim{i}")
        trailing = tuple(data.draw(st.integers(min_value=1, max_value=7),
                                   label=f"dim{i}_{d}") for d in range(ndim))
        dt = data.draw(st.sampled_from(
            [jnp.float32, jnp.bfloat16, jnp.float16]), label=f"dtype{i}")
        arr = rng.normal(size=(batch,) + trailing).astype(np.float32)
        tree[f"leaf{i}"] = jnp.asarray(arr).astype(dt)
    spec = FlatSpec.for_tree(tree)
    assert spec.size % LANE == 0
    back = spec.unravel(spec.ravel(tree))
    for got, want in zip(jax.tree.leaves(back), jax.tree.leaves(tree)):
        assert got.dtype == want.dtype and jnp.array_equal(got, want)


# --------------------------------------------------------------------------
# Fused mixing ≡ tree walk ≡ dense oracle
# --------------------------------------------------------------------------

def _tree_of(X, n):
    """Split (n, 17) rows into a two-leaf mixed-shape tree."""
    return {"a": jnp.asarray(X[:, :12]).reshape(n, 3, 4),
            "b": jnp.asarray(X[:, 12:])}


def _tree_rows(tree, n):
    return np.concatenate(
        [np.asarray(l, np.float32).reshape(n, -1)
         for l in jax.tree.leaves(tree)], axis=1)


def _mix_on_mesh(sched, X, mask=None, fuse=None, num_devices=8):
    n = sched.num_clients
    mesh = make_client_mesh(num_devices, "data")
    shard = NamedSharding(mesh, P("data"))
    W = jnp.asarray(sched.weights)
    S = jnp.asarray(sched.self_weight)
    tree = _tree_of(X, n)
    if mask is None:
        def body(t, w, s):
            return fedlay_mix(t, sched, w, s, "data", fuse=fuse)
        in_specs = (jax.tree.map(lambda _: P("data"), tree),
                    P("data"), P("data"))
        args = (tree, W, S)
    else:
        def body(t, w, s, m):
            return fedlay_mix(t, sched, w, s, "data", mask=m, fuse=fuse)
        in_specs = (jax.tree.map(lambda _: P("data"), tree),
                    P("data"), P("data"), P("data"))
        args = (tree, W, S, jnp.asarray(mask, jnp.float32))
    f = jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=jax.tree.map(lambda _: P("data"), tree),
                          check_vma=False))
    out = f(*jax.tree.map(lambda a: jax.device_put(a, shard), args))
    return _tree_rows(out, n)


@pytest.mark.multi_device
@pytest.mark.parametrize("G", GROUPS)
@pytest.mark.parametrize("masked", (False, True))
def test_fused_fedlay_mix_equals_tree_and_dense_oracle(G, masked,
                                                       multi_device):
    """The acceptance pin: shard_map fuse="flat" ≡ the tree walk ≡ W·X
    on the real 8-device mesh, G ∈ {1, 2, 4}, masked and unmasked."""
    n = 8 * G
    sched = build_permute_schedule(n, 2, salt=f"fused{G}")
    rng = np.random.default_rng(G)
    X = rng.normal(size=(n, 17)).astype(np.float32)
    mask = None
    Wd = schedule_mixing_matrix(sched)
    if masked:
        mask = (rng.random(n) > 0.4).astype(np.float32)
        mask[0] = 0.0
        Wd = masked_mixing_matrix(sched, mask)
    fused = _mix_on_mesh(sched, X, mask=mask, fuse="flat")
    tree = _mix_on_mesh(sched, X, mask=mask, fuse=None)
    ref = Wd @ X
    np.testing.assert_allclose(fused, ref, atol=1e-6)
    np.testing.assert_allclose(fused, tree, atol=1e-6)


@pytest.mark.parametrize("G", GROUPS)
@pytest.mark.parametrize("masked", (False, True))
def test_fused_global_mixer_equals_dense_oracle(G, masked):
    """Global-view fuse="flat" (one gather_mix kernel per round) ≡ the
    dense oracle, on a mixed-shape tree, G ∈ {1, 2, 4}."""
    n = 8 * G
    sched = build_permute_schedule(n, 2, salt=f"gflat{G}")
    rng = np.random.default_rng(G + 3)
    X = rng.normal(size=(n, 17)).astype(np.float32)
    tree = _tree_of(X, n)
    Wd = schedule_mixing_matrix(sched)
    if masked:
        mask = (rng.random(n) > 0.4).astype(np.float32)
        mask[0] = 0.0
        Wd = masked_mixing_matrix(sched, mask)
        mix = jax.jit(global_mixer("fedlay", sched, masked=True,
                                   fuse="flat", clients_per_device=G))
        out = mix(tree, jnp.asarray(mask))
    else:
        mix = jax.jit(global_mixer("fedlay", sched, fuse="flat",
                                   clients_per_device=G))
        out = mix(tree)
    np.testing.assert_allclose(_tree_rows(out, n), Wd @ X, atol=1e-6)
    # dtypes survive the flat round trip
    assert jax.tree.map(lambda l: l.dtype, out) == \
        jax.tree.map(lambda l: l.dtype, tree)


def test_fused_global_mixer_preserves_bf16_leaves():
    sched = build_permute_schedule(4, 1, salt="bf16")
    rng = np.random.default_rng(0)
    tree = {"w": jnp.asarray(rng.normal(size=(4, 9)).astype(np.float32)
                             ).astype(jnp.bfloat16)}
    out = jax.jit(global_mixer("fedlay", sched, fuse="flat"))(tree)
    assert out["w"].dtype == jnp.bfloat16
    ref = schedule_mixing_matrix(sched) @ np.asarray(
        tree["w"], np.float32)
    np.testing.assert_allclose(np.asarray(out["w"], np.float32), ref,
                               atol=2e-2)


@pytest.mark.multi_device
@pytest.mark.parametrize("strategy", ("fedlay", "ring"))
def test_fused_make_mixer_equals_unfused(strategy, multi_device):
    """make_mixer(fuse="flat") ≡ make_mixer(fuse=None) for both
    schedule-driven strategies on the real mesh (G = 2)."""
    G, n = 2, 16
    sched = build_permute_schedule(n, 2, salt="mm")
    mesh = make_client_mesh(8, "data")
    shard = NamedSharding(mesh, P("data"))
    rng = np.random.default_rng(7)
    X = jnp.asarray(rng.normal(size=(n, 11)).astype(np.float32))
    W = jnp.asarray(sched.weights)
    S = jnp.asarray(sched.self_weight)
    outs = []
    for fuse in (None, "flat"):
        mixer = make_mixer(strategy, sched, "data", n,
                           clients_per_device=G, fuse=fuse)

        def body(x, w, s):
            return mixer({"m": x}, w, s)["m"]

        f = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("data"),) * 3,
                              out_specs=P("data"), check_vma=False))
        outs.append(np.asarray(f(*[jax.device_put(a, shard)
                                   for a in (X, W, S)])))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-6)


@pytest.mark.multi_device
@pytest.mark.skipif(not EIGHT_DEVICES, reason="needs 8 host devices")
@settings(max_examples=8, deadline=None)
@given(G=st.sampled_from(GROUPS),
       salt=st.integers(min_value=0, max_value=10**6))
def test_property_fused_fedlay_mix_vs_dense(G, salt):
    """Fuzzed sibling of the fixed-seed fused parity pin."""
    n = 8 * G
    sched = build_permute_schedule(n, 2, salt=f"pf{salt}")
    rng = np.random.default_rng(salt)
    X = rng.normal(size=(n, 17)).astype(np.float32)
    mask = (rng.random(n) > 0.35).astype(np.float32)
    out = _mix_on_mesh(sched, X, mask=mask, fuse="flat")
    ref = masked_mixing_matrix(sched, mask) @ X
    np.testing.assert_allclose(out, ref, atol=1e-6)


# --------------------------------------------------------------------------
# Control plane: fuse-keyed cache + zero-retrace churn on the fused path
# --------------------------------------------------------------------------

def _make_sim(n=6, L=2, seed=0):
    from repro.core.ndmp import Simulator
    sim = Simulator(num_spaces=L, latency=0.05, heartbeat_period=0.5,
                    probe_period=1.0, seed=seed)
    sim.seed_network(list(range(n)))
    return sim


def test_mixer_cache_keys_on_fuse_mode():
    from repro.overlay.controller import MixerCache
    built = []

    def factory(sched):
        built.append(sched)
        return lambda p: p

    cache = MixerCache(factory)
    sched = build_permute_schedule(4, 1)
    _, hit0 = cache.get(sched, None)
    _, hit1 = cache.get(sched, "flat")      # same schedule, other mode
    _, hit2 = cache.get(sched, "flat")
    assert (hit0, hit1, hit2) == (False, False, True)
    assert len(built) == 2 and len(cache) == 2


def test_controller_fuse_flat_mixers_match_unfused():
    """Two controllers over the same seed network, fused vs unfused
    global mixers: identical mixed params."""
    from repro.overlay import OverlayController
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(size=(5, 2, 3)).astype(np.float32))
    outs = [np.asarray(OverlayController(_make_sim(n=5, seed=3),
                                         fuse=fuse).mixer({"w": X})["w"])
            for fuse in (None, "flat")]
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-6)


def test_controller_rejects_bad_fuse():
    from repro.overlay import OverlayController
    with pytest.raises(ValueError, match="fuse"):
        OverlayController(_make_sim(), fuse="bogus")


@pytest.mark.multi_device
def test_grouped_fused_slot_loop_zero_retrace(multi_device):
    """The ISSUE 5 churn pin: the ISSUE 4 zero-retrace loop (capacity =
    2 × devices, G = 2, rows sharded over the real 8-device mesh), now
    with fuse="flat" — the fused mask-aware gather_mix mixers hold 0
    retraces across ≥ 3 distinct alive counts."""
    from repro.optim.optimizers import sgd
    from repro.overlay import ChurnTrace, OverlayController
    from repro.runtime import SlotTrainLoop, counting_jit, masked_local_step

    dim = 24

    def make_params(u):
        w = np.random.default_rng(u).normal(size=dim).astype(np.float32)
        return {"w": jnp.asarray(w)}

    def make_batch(node_ids, step):
        rows = [np.random.default_rng(abs(hash((u, step))) % 2**32)
                .normal(size=dim).astype(np.float32) for u in node_ids]
        return {"x": jnp.asarray(np.stack(rows))}

    def base_step(params, opt_state, batch):
        w, x = params["w"], batch["x"]
        loss = jnp.mean((w - x) ** 2, axis=-1)
        return {"w": w - 0.05 * 2.0 * (w - x) / dim}, opt_state, \
            {"loss": loss}

    mesh = make_client_mesh(8, "data")
    ctl = OverlayController(_make_sim(n=12), capacity=16,
                            clients_per_device=2, fuse="flat")
    sjit, scount = counting_jit(masked_local_step(base_step))
    loop = SlotTrainLoop(
        ctl, local_step=sjit, make_params=make_params, optimizer=sgd(0.0),
        make_batch=make_batch, jit_local_step=False, mesh=mesh)
    recs = loop.run(12, trace=ChurnTrace.scripted([
        (2.5, "fail", 1), (4.5, "fail", 3),
        (6.5, "join", 100, 0), (8.5, "join", 101, 0),
    ]))
    assert len({r.num_alive for r in recs}) >= 3
    assert all(np.isfinite(r.loss) for r in recs)
    assert scount.traces == 1 and scount.retraces == 0
    # fail -> rejoin restored a previously-seen padded schedule: the
    # fused mixer came straight out of the fuse-keyed compile cache
    assert ctl.cache.hits > 0
