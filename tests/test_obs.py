"""The unified telemetry plane (ISSUE 8): :mod:`repro.obs`.

Pinned here:

* the **bus contract** — counters/gauges/histograms/spans/events on a
  monotonic clock; the :data:`~repro.obs.NULL` singleton is the
  process-global default, every method a no-op, and the
  enable/disable/scoped-context plumbing restores state exactly;
* the **round ledger** — field routing (unknown kwargs → ``extra``),
  per-record bus counter deltas, strict-JSON JSONL export, summary and
  terminal table;
* **counting_jit edge cases** — nested jit counts the inlined trace,
  ``static_argnums``/``donate_argnums`` forward to ``jax.jit`` with
  jax's own cache semantics, grouped ``G > 1`` masked mixers stay
  zero-retrace under mask changes;
* the **ISSUE 8 acceptance run** — a grouped capacity-mode churn loop
  (8-device mesh, G = 2, ``codec="int8-block"``) produces a ledger
  where every round records wire bytes, a zero retrace delta after
  warmup, cache hit/miss, and repair/commit latency — and writes valid
  JSONL;
* **zero impact when disabled** — the same loop under
  :func:`repro.obs.disabled` computes identical losses at zero
  retraces, and the instrumented loops add no trace when enabled.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core.mixing import build_permute_schedule
from repro.dist.compat import make_client_mesh
from repro.dist.sync import global_mixer
from repro.obs import (NULL, NullTelemetry, RoundLedger, Telemetry,
                       annotation, capture, disabled, get_round_ledger,
                       get_telemetry, round_ledger, scope, set_telemetry,
                       telemetry)
from repro.runtime.loop import TraceCount, counting_jit


# --------------------------------------------------------------------------
# The bus
# --------------------------------------------------------------------------

def test_bus_instruments():
    bus = Telemetry()
    bus.count("overlay.swaps")
    bus.count("overlay.swaps", 2)
    bus.gauge("slot.num_alive", 7)
    bus.gauge("slot.num_alive", 5)
    bus.observe("overlay.rebuild_ms", 2.0)
    bus.observe("overlay.rebuild_ms", 4.0)
    bus.event("churn", node=3)
    assert bus.counters == {"overlay.swaps": 3}
    assert bus.gauges == {"slot.num_alive": 5.0}
    h = bus.histograms["overlay.rebuild_ms"]
    assert (h.count, h.total, h.min, h.max, h.mean) == (2, 6.0, 2.0, 4.0, 3.0)
    assert bus.events[0].name == "churn" and bus.events[0].attrs == {"node": 3}
    s = bus.summary()
    assert s["counters"]["overlay.swaps"] == 3
    assert s["histograms"]["overlay.rebuild_ms"]["mean"] == 3.0
    assert s["num_events"] == 1


def test_bus_span_times_into_histogram():
    bus = Telemetry()
    with bus.span("overlay.commit"):
        pass
    h = bus.histograms["overlay.commit.ms"]
    assert h.count == 1 and h.min >= 0.0
    # attrs promote the span to an event too
    with bus.span("overlay.commit", slot=2):
        pass
    assert bus.events and bus.events[0].attrs["slot"] == 2


def test_bus_event_cap_drops_not_grows():
    bus = Telemetry(max_events=2)
    for i in range(5):
        bus.event("e", i=i)
    assert len(bus.events) == 2 and bus.dropped_events == 3
    assert bus.summary()["dropped_events"] == 3


def test_null_bus_is_inert_and_default():
    assert get_telemetry() is NULL
    assert not NULL.enabled and Telemetry().enabled
    NULL.count("x")
    NULL.gauge("x", 1)
    NULL.observe("x", 1)
    NULL.event("x", a=1)
    with NULL.span("x"):
        pass
    assert NULL.snapshot() == {} and NULL.summary() == {}
    assert isinstance(NULL, NullTelemetry)


def test_enable_disable_and_scoped_context_restore():
    assert get_telemetry() is NULL
    bus = obs.enable()
    try:
        assert get_telemetry() is bus and bus.enabled
    finally:
        obs.disable()
    assert get_telemetry() is NULL
    with telemetry() as scoped:
        assert get_telemetry() is scoped
        with telemetry(Telemetry()) as inner:
            assert get_telemetry() is inner
        assert get_telemetry() is scoped
    assert get_telemetry() is NULL
    # set_telemetry returns the previous bus; None restores NULL
    prev = set_telemetry(bus)
    assert prev is NULL and get_telemetry() is bus
    set_telemetry(None)
    assert get_telemetry() is NULL


# --------------------------------------------------------------------------
# The round ledger
# --------------------------------------------------------------------------

def test_ledger_field_routing_and_counter_deltas():
    bus = Telemetry()
    led = RoundLedger(bus=bus)
    bus.count("overlay.cache_misses")
    r0 = led.record(round=0, loop="t", loss=1.0, my_extra=42)
    assert r0.loss == 1.0 and r0.extra["my_extra"] == 42
    assert r0.extra["overlay.cache_misses"] == 1
    bus.count("overlay.cache_hits", 3)
    r1 = led.record(round=1, loop="t")
    # deltas, not totals: the miss from round 0 does not reappear
    assert r1.extra == {"overlay.cache_hits": 3}
    assert len(led) == 2


def test_ledger_jsonl_roundtrip_strict_json(tmp_path):
    led = RoundLedger(bus=NULL)
    led.record(round=0, loop="t", loss=float("nan"), joined=(5, 6),
               wire_bytes_per_client=128.0)
    led.record(round=1, loop="t", loss=0.25, left=(5,))
    path = tmp_path / "rounds.jsonl"
    assert led.to_jsonl(path) == 2
    rows = [json.loads(line) for line in path.read_text().splitlines()]
    assert rows[0]["loss"] is None          # NaN → null, strict JSON
    assert rows[0]["joined"] == [5, 6]
    assert rows[0]["wire_bytes_per_client"] == 128.0
    assert rows[1]["loss"] == 0.25 and rows[1]["left"] == [5]


def test_ledger_summary_and_table():
    led = RoundLedger(bus=NULL)
    for r in range(25):
        led.record(round=r, loop="slot", num_alive=6, participating=6,
                   loss=1.0 / (r + 1), wire_bytes_per_client=1000.0,
                   payload_bytes_per_client=4000.0, retraces=1,
                   swapped=(r == 3), rebuilt=(r == 3), cache_hit=(r == 9),
                   joined=(100,) if r == 3 else (), repair_ms=2.0)
    s = led.summary()
    assert s["rounds"] == 25 and s["loop"] == "slot"
    assert s["swaps"] == 1 and s["cache_hits"] == 1 and s["joins"] == 1
    assert s["wire_reduction"] == 4.0
    assert s["final_loss"] == 1.0 / 25
    table = led.summary_table()
    assert "earlier rounds elided" in table     # capped at last 20
    assert "wire_mb/client" in table
    assert table.count("\n") >= 22


def test_ledger_global_context_and_disabled():
    assert get_round_ledger() is None
    with round_ledger() as led:
        assert get_round_ledger() is led
        with telemetry(), disabled():
            assert get_round_ledger() is None
            assert get_telemetry() is NULL
        assert get_round_ledger() is led
    assert get_round_ledger() is None


# --------------------------------------------------------------------------
# Profiling wrappers
# --------------------------------------------------------------------------

def test_scope_annotation_capture_are_harmless():
    with scope("test.scope"), annotation("test.annotation", step=1):
        x = jnp.ones((4,)) + 1
    np.testing.assert_array_equal(np.asarray(x), 2.0)
    with capture(None):                   # falsy log_dir → no-op
        pass

    @jax.jit
    def f(v):
        with scope("test.inner"):
            return v * 2
    np.testing.assert_array_equal(np.asarray(f(x)), 4.0)


def test_capture_writes_profile(tmp_path):
    log_dir = tmp_path / "prof"
    with capture(log_dir):
        jax.block_until_ready(jnp.arange(8) * 2)
    assert log_dir.exists() and any(log_dir.rglob("*"))


# --------------------------------------------------------------------------
# counting_jit edge cases
# --------------------------------------------------------------------------

def test_counting_jit_nested_jit_counts_inlined_trace():
    inner_fn, inner = counting_jit(lambda x: x + 1)
    outer_fn, outer = counting_jit(lambda x: inner_fn(x) * 2)
    assert np.asarray(outer_fn(jnp.float32(3.0))) == 8.0
    outer_fn(jnp.float32(4.0))
    # one outer trace; the inner body traced once, inlined into it
    assert outer.traces == 1 and inner.traces == 1
    # standalone call with the same aval hits the shared jit cache
    inner_fn(jnp.float32(1.0))
    assert inner.traces == 1 and inner.retraces == 0
    # a new shape is a genuine retrace
    inner_fn(jnp.ones((2,), jnp.float32))
    assert inner.traces == 2 and inner.retraces == 1


def test_counting_jit_static_argnums_trace_per_value():
    fn, count = counting_jit(lambda x, k: x * k, static_argnums=(1,))
    fn(jnp.float32(1.0), 2)
    fn(jnp.float32(2.0), 2)     # same static value: cached
    assert count.traces == 1
    fn(jnp.float32(1.0), 3)     # new static value: its own trace
    assert count.traces == 2 and count.retraces == 1


def test_counting_jit_donated_args_single_trace():
    fn, count = counting_jit(lambda x: x + 1, donate_argnums=(0,))
    x = jnp.arange(4, dtype=jnp.float32)
    for _ in range(3):
        x = fn(x)               # donation reuses the buffer, no retrace
    np.testing.assert_array_equal(np.asarray(x), np.arange(4) + 3)
    assert count.traces == 1 and count.retraces == 0


def test_counting_jit_grouped_masked_mixer_zero_retrace():
    """The G > 1 global fused mixer under changing runtime masks: one
    trace, every mask a cache hit."""
    n, G = 8, 2
    sched = build_permute_schedule(n, 2)
    mixer = global_mixer("fedlay", sched, masked=True,
                         clients_per_device=G, fuse="flat")
    fn, count = counting_jit(mixer)
    buf = {"w": jnp.asarray(np.random.default_rng(0)
                            .normal(size=(n, 48)).astype(np.float32))}
    for alive in ([1] * 8, [1, 1, 0, 1, 1, 1, 1, 0], [0, 1] * 4):
        out = fn(buf, jnp.asarray(alive, jnp.float32))
        assert np.isfinite(np.asarray(out["w"])).all()
    assert count.traces == 1 and count.retraces == 0


# --------------------------------------------------------------------------
# Loop integration
# --------------------------------------------------------------------------

def _make_sim(n=12, L=2, seed=0):
    from repro.core.ndmp import Simulator
    sim = Simulator(num_spaces=L, latency=0.05, heartbeat_period=0.5,
                    probe_period=1.0, seed=seed)
    sim.seed_network(list(range(n)))
    return sim


def _toy_harness(dim=24):
    def make_params(u):
        w = np.random.default_rng(u).normal(size=dim).astype(np.float32)
        return {"w": jnp.asarray(w)}

    def make_batch(node_ids, step):
        rows = [np.random.default_rng(abs(hash((u, step))) % 2**32)
                .normal(size=dim).astype(np.float32) for u in node_ids]
        return {"x": jnp.asarray(np.stack(rows))}

    def base_step(params, opt_state, batch):
        w, x = params["w"], batch["x"]
        loss = jnp.mean((w - x) ** 2, axis=-1)
        return {"w": w - 0.05 * 2.0 * (w - x) / dim}, opt_state, \
            {"loss": loss}
    return make_params, make_batch, base_step


_CHURN = [(2.5, "fail", 1), (4.5, "fail", 3),
          (6.5, "join", 100, 0), (8.5, "join", 101, 0)]


@pytest.mark.multi_device
def test_acceptance_grouped_codec_churn_round_ledger(multi_device, tmp_path):
    """The ISSUE 8 acceptance pin: a churn run over the int8-block
    codec (G = 2, 8-device mesh) produces a round-ledger JSONL where
    every round records wire bytes, its retrace delta (0 after warmup),
    cache hit/miss, and repair/commit latency."""
    from repro.optim.optimizers import sgd
    from repro.overlay import ChurnTrace, OverlayController
    from repro.runtime import SlotTrainLoop, counting_jit, masked_local_step

    make_params, make_batch, base_step = _toy_harness()
    mesh = make_client_mesh(8, "data")
    ctl = OverlayController(_make_sim(n=12), capacity=16,
                            clients_per_device=2, codec="int8-block",
                            double_buffered=True)
    sjit, scount = counting_jit(masked_local_step(base_step))
    bus = Telemetry()
    led = RoundLedger(bus=bus)
    loop = SlotTrainLoop(
        ctl, local_step=sjit, make_params=make_params, optimizer=sgd(0.0),
        make_batch=make_batch, jit_local_step=False, mesh=mesh,
        telemetry=bus, ledger=led, trace_count=scount)
    recs = loop.run(12, trace=ChurnTrace.scripted(_CHURN))

    assert len(led) == len(recs) == 12
    rows = led.rows
    # data plane: every round prices the codec wire, and the payload
    # (uncompressed f32 image) shows the ~4x int8 wire reduction
    assert all(r.wire_bytes_per_client > 0 for r in rows)
    assert all(r.payload_bytes_per_client > 3.5 * r.wire_bytes_per_client
               for r in rows)
    # zero-retrace guarantee, observed live: one warmup trace, then 0
    assert rows[0].retrace_delta == 1
    assert all(r.retrace_delta == 0 for r in rows[1:])
    assert rows[-1].retraces == 0 and scount.traces == 1
    # control plane joined in: churn membership, swaps, cache traffic,
    # repair/commit latency on the rounds that rebuilt
    assert sum(len(r.joined) for r in rows) == 2
    assert sum(len(r.left) for r in rows) == 2
    swapped = [r for r in rows if r.swapped]
    assert swapped and any(r.cache_hit for r in rows)
    assert all(r.repair_ms > 0 for r in rows if r.rebuilt)
    assert all(r.repair_ms == 0 for r in rows if not r.rebuilt)
    assert all(r.commit_ms >= 0 for r in rows)
    assert any(r.commit_ms > 0 for r in swapped)
    # the bus counted the same control-plane events the ledger flagged
    assert bus.counters["slot.steps"] == 12
    assert bus.counters["overlay.churn_joins"] == 2
    assert bus.counters["overlay.churn_leaves"] == 2
    assert bus.counters["overlay.swaps"] == len(swapped)
    assert bus.counters.get("overlay.cache_hits", 0) == ctl.cache.hits > 0
    # and the JSONL export is strict JSON, row per round
    path = tmp_path / "ledger.jsonl"
    assert led.to_jsonl(path) == 12
    parsed = [json.loads(line) for line in path.read_text().splitlines()]
    assert [p["round"] for p in parsed] == [r.round for r in rows]
    assert all(p["wire_bytes_per_client"] > 0 for p in parsed)


@pytest.mark.multi_device
def test_disabled_telemetry_is_zero_impact(multi_device):
    """The same grouped codec churn run fully disabled vs fully on:
    identical losses, zero retraces both ways."""
    from repro.optim.optimizers import sgd
    from repro.overlay import ChurnTrace, OverlayController
    from repro.runtime import SlotTrainLoop, counting_jit, masked_local_step

    make_params, make_batch, base_step = _toy_harness()

    def run_arm(enable):
        mesh = make_client_mesh(8, "data")
        ctl = OverlayController(_make_sim(n=12), capacity=16,
                                clients_per_device=2, codec="int8-block")
        sjit, scount = counting_jit(masked_local_step(base_step))
        loop = SlotTrainLoop(
            ctl, local_step=sjit, make_params=make_params,
            optimizer=sgd(0.0), make_batch=make_batch,
            jit_local_step=False, mesh=mesh, trace_count=scount)
        if enable:
            with telemetry(), round_ledger() as led:
                recs = loop.run(10, trace=ChurnTrace.scripted(_CHURN))
            assert len(led) == 10
        else:
            with disabled():
                recs = loop.run(10, trace=ChurnTrace.scripted(_CHURN))
        assert scount.retraces == 0
        return [r.loss for r in recs]

    np.testing.assert_allclose(run_arm(False), run_arm(True), rtol=0, atol=0)


def test_churn_loop_ledger_shows_restack_retrace_tax():
    """ChurnTrainLoop re-stacks per alive count: its ledger's retrace
    deltas light up at every new alive count — the tax the slot loop's
    ledger shows as zero."""
    from repro.optim.optimizers import sgd
    from repro.overlay import ChurnTrace, ChurnTrainLoop, OverlayController

    make_params, make_batch, base_step = _toy_harness()

    def restack_step(params, opt_state, batch):
        p, o, m = base_step(params, opt_state, batch)
        return p, o, {"loss": jnp.mean(m["loss"])}

    bus = Telemetry()
    led = RoundLedger(bus=bus)
    loop = ChurnTrainLoop(
        OverlayController(_make_sim(n=6)), local_step=restack_step,
        make_params=make_params, optimizer=sgd(0.0), make_batch=make_batch,
        telemetry=bus, ledger=led)
    loop.run(10, trace=ChurnTrace.scripted(_CHURN))
    rows = led.rows
    assert len(rows) == 10 and all(r.loop == "churn" for r in rows)
    distinct_alive = len({r.num_alive for r in rows})
    assert distinct_alive >= 3
    # one fresh trace per distinct alive count, attributed to the round
    # where that count first appeared
    assert sum(r.retrace_delta for r in rows) == distinct_alive
    assert rows[-1].retraces == distinct_alive - 1
    assert all(r.wire_bytes_per_client > 0 for r in rows)
    assert bus.counters["churn.steps"] == 10
    assert bus.counters["churn.remaps"] == sum(
        1 for r in rows if r.joined or r.left)


def test_cohort_loop_reports_to_global_ledger():
    from repro.scale import CohortStreamLoop, VectorSimulator

    sim = VectorSimulator(num_spaces=2, latency=0.05, heartbeat_period=0.5,
                          probe_period=1.0)
    sim.seed_network(range(64))
    loop = CohortStreamLoop(
        sim, capacity=8, cohort_size=8,
        make_params=lambda u: np.random.default_rng(u)
        .random(16).astype(np.float32), seed=3)
    with telemetry() as bus, round_ledger() as led:
        loop.run(6)
    rows = led.rows
    assert len(rows) == 6 and all(r.loop == "cohort" for r in rows)
    assert all(r.wire_bytes_per_client > 0 for r in rows)
    assert all(r.retrace_delta == 0 for r in rows[1:])
    assert all(r.repair_ms > 0 for r in rows)       # remap cost, per round
    assert all(r.extra["restored"] + r.extra["donor_seeded"]
               + r.extra["fresh"] == len(r.joined) for r in rows)
    assert bus.counters["cohort.rounds"] == 6
    assert bus.histograms["cohort.remap_ms"].count == 6


def test_engine_run_scoped_telemetry_kwargs():
    from repro.core.dfl import Engine
    from repro.data.noniid import shard_partition
    from repro.data.synthetic import mnist_like
    from repro.models.small import MLPTask

    data = mnist_like(n_train=160, n_test=80, seed=0)
    part = shard_partition(data.y_train, num_clients=6, shards_per_client=3,
                           seed=0)
    task = MLPTask(data, part, hidden=8, local_steps=1, batch=16)
    bus = Telemetry()
    led = RoundLedger(bus=bus)
    res = Engine().run(task, "fedlay", total_time=6.0, model_bytes=1000,
                       telemetry=bus, ledger=led)
    assert res.final_mean_acc > 0
    # the scope was per-run: globals restored afterwards
    assert get_telemetry() is NULL and get_round_ledger() is None
    assert bus.counters["engine.evals"] == len(led)
    assert bus.counters["engine.msgs_sent"] == pytest.approx(
        res.messages_per_client * 6)
    assert all(r.loop == "engine" for r in led.rows)
    assert led.rows[-1].num_alive == 6
    # per-snapshot byte deltas sum to the run's per-client mean
    total = sum(r.wire_bytes_per_client for r in led.rows)
    assert total == pytest.approx(res.comm_bytes_per_client)
