"""Grouped multi-client-per-device mixing (ISSUE 4).

The grouped layout maps ``num_clients = G · num_devices`` onto the mesh
block-contiguously (client i → device i // G).  These tests pin the
whole chain on a **real 8-device CPU mesh** (the tier-1 forced host
platform, see ``tests/conftest.py``):

* host side: :func:`repro.core.mixing.grouped_routing` covers every
  weight>0 schedule edge exactly once with valid ppermute rounds, and
  the pure-numpy :func:`grouped_mix_reference` oracle equals the dense
  mixing matrix for any G;
* device side: :func:`repro.dist.sync.fedlay_mix` under ``shard_map``
  ≡ the dense ``schedule_mixing_matrix`` / ``masked_mixing_matrix``
  oracles for G ∈ {1, 2, 4}, masked and unmasked, and
  :func:`global_mixer` ≡ :func:`make_mixer` on the same mesh;
* accounting: grouped :func:`sync_bytes_per_client` (on-device edges
  cost zero network bytes) against closed forms and the exact
  per-schedule cross-edge counts.

Property-based variants (hypothesis, shimmed to skip when it is not
installed) fuzz schedules × masks × G over the same equivalences.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.mixing import (build_permute_schedule, grouped_mix_reference,
                               grouped_routing, masked_mixing_matrix,
                               pad_schedule, schedule_mixing_matrix)
from repro.dist.compat import make_client_mesh, shard_map
from repro.dist.sync import (fedlay_mix, global_mixer, make_mixer,
                             ring_schedule, sync_bytes_per_client)

GROUPS = (1, 2, 4)

# Property tests can't take the function-scoped multi_device fixture
# (hypothesis forbids fixtures under @given), so they gate on the
# device count at collection time instead.
EIGHT_DEVICES = jax.device_count() >= 8


# --------------------------------------------------------------------------
# Host side: routing decomposition + grouped dense oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("G", GROUPS)
def test_grouped_routing_covers_every_edge_once(G):
    n = 8 * G
    sched = build_permute_schedule(n, 3, salt=f"cov{G}")
    rt = grouped_routing(sched, G)
    assert rt.num_devices == 8 and rt.clients_per_device == G
    covered = set()
    for k in range(sched.num_slots):
        for d in range(8):
            for l in range(G):
                if rt.intra_on[k][d, l] > 0:
                    i = d * G + l
                    src = d * G + rt.intra_src[k][d, l]
                    assert src == sched.perms[k][i]
                    covered.add((i, k))
        for rnd in rt.rounds[k]:
            srcs = [p[0] for p in rnd.pairs]
            dsts = [p[1] for p in rnd.pairs]
            # a valid jax.lax.ppermute: unique sources, unique dests
            assert len(set(srcs)) == len(srcs)
            assert len(set(dsts)) == len(dsts)
            for sd, dd in rnd.pairs:
                i = dd * G + rnd.recv_slot[dd]
                src = sd * G + rnd.send_row[sd]
                assert src == sched.perms[k][i]
                assert src // G != dd          # genuinely cross-device
                assert (i, k) not in covered   # exactly-once coverage
                covered.add((i, k))
    want = {(i, k) for i in range(n) for k in range(sched.num_slots)
            if sched.weights[i, k] > 0}
    assert covered == want
    assert rt.cross_edges == sum(
        1 for (i, k) in want if sched.perms[k][i] // G != i // G)


def test_grouped_routing_one_device_is_all_intra():
    sched = build_permute_schedule(6, 2)
    rt = grouped_routing(sched, 6)       # D = 1: everything on-device
    assert rt.cross_edges == 0 and rt.max_rounds == 0


def test_grouped_routing_g1_single_round_per_slot():
    """G = 1 cross edges form a partial device permutation, so the
    coloring must use exactly one round per slot."""
    sched = build_permute_schedule(8, 3, salt="g1")
    rt = grouped_routing(sched, 1)
    assert rt.max_rounds <= 1


@pytest.mark.parametrize("G", (1, 2, 3, 4, 8))
def test_grouped_routing_koenig_rounds_at_most_G(G):
    """ISSUE 5: König edge coloring packs every slot's cross edges into
    exactly Δ ≤ G rounds (each client sends once and receives once per
    slot, so the bipartite degree is ≤ G) — the greedy coloring this
    replaced could need up to 2G − 1."""
    n = 8 * G
    for salt in range(6):
        sched = build_permute_schedule(n, 3, salt=f"koenig{salt}")
        rt = grouped_routing(sched, G)
        for k in range(sched.num_slots):
            # Δ for this slot: per-device cross in/out degree
            out_deg = np.zeros(rt.num_devices, np.int64)
            in_deg = np.zeros(rt.num_devices, np.int64)
            for rnd in rt.rounds[k]:
                for sd, dd in rnd.pairs:
                    out_deg[sd] += 1
                    in_deg[dd] += 1
            delta = max(out_deg.max(initial=0), in_deg.max(initial=0))
            assert len(rt.rounds[k]) == delta <= G


def test_bipartite_edge_coloring_is_proper_and_tight():
    """Direct coverage of the Kempe-chain colorer: proper (no color
    repeats a source or destination) and exactly Δ colors, including
    multigraph edges (the same device pair twice)."""
    from repro.core.mixing import _bipartite_edge_coloring
    rng = np.random.default_rng(0)
    for _ in range(50):
        D = int(rng.integers(2, 9))
        E = int(rng.integers(1, 3 * D))
        edges = [(int(rng.integers(D)), int(rng.integers(D)))
                 for _ in range(E)]
        colors = _bipartite_edge_coloring(edges, D)
        deg = {}
        for (s, d) in edges:
            deg[("s", s)] = deg.get(("s", s), 0) + 1
            deg[("d", d)] = deg.get(("d", d), 0) + 1
        delta = max(deg.values())
        assert max(colors) + 1 <= delta
        seen = set()
        for (s, d), c in zip(edges, colors):
            assert (c, "s", s) not in seen and (c, "d", d) not in seen
            seen.add((c, "s", s))
            seen.add((c, "d", d))
    assert _bipartite_edge_coloring([], 4) == []


def test_grouped_routing_rejects_bad_group():
    sched = build_permute_schedule(8, 2)
    with pytest.raises(ValueError, match="divide"):
        grouped_routing(sched, 3)
    with pytest.raises(ValueError, match=">= 1"):
        grouped_routing(sched, 0)


@pytest.mark.parametrize("G", GROUPS)
@pytest.mark.parametrize("masked", (False, True))
def test_grouped_reference_equals_dense_oracle(G, masked):
    n = 8 * G
    sched = build_permute_schedule(n, 2, salt=f"ref{G}")
    rng = np.random.default_rng(G)
    X = rng.normal(size=(n, 5))
    mask = ((rng.random(n) > 0.3).astype(np.float64) if masked
            else np.ones(n))
    ref = masked_mixing_matrix(sched, mask) @ X
    got = grouped_mix_reference(sched, X, G, mask=mask if masked else None)
    np.testing.assert_allclose(got, ref, atol=1e-6)


def test_grouped_reference_on_padded_schedule():
    """Dead capacity slots (weight-0 self-loops) never touch the wire
    and pass through the grouped decomposition untouched."""
    sched = build_permute_schedule(6, 2)
    padded = pad_schedule(sched, (0, 1, 2, 4, 5, 7), 8)
    mask = np.zeros(8)
    mask[[0, 1, 2, 4, 5, 7]] = 1
    rng = np.random.default_rng(0)
    X = rng.normal(size=(8, 4))
    for G in (1, 2, 4):
        got = grouped_mix_reference(padded, X, G, mask=mask)
        ref = masked_mixing_matrix(padded, mask) @ X
        np.testing.assert_allclose(got, ref, atol=1e-6)


# --------------------------------------------------------------------------
# Device side: shard_map grouped mixing on the real 8-device mesh
# --------------------------------------------------------------------------

def _mix_on_mesh(sched, G, X, mask=None, num_devices=8):
    """Run fedlay_mix under shard_map with the grouped (G, ...) layout
    and return the (n, dim) result."""
    mesh = make_client_mesh(num_devices, "data")
    shard = NamedSharding(mesh, P("data"))
    W = jnp.asarray(sched.weights)
    S = jnp.asarray(sched.self_weight)
    if mask is None:
        def body(x, w, s):
            return fedlay_mix({"m": x}, sched, w, s, "data")["m"]
        in_specs = (P("data"),) * 3
        args = (jnp.asarray(X), W, S)
    else:
        def body(x, w, s, m):
            return fedlay_mix({"m": x}, sched, w, s, "data", mask=m)["m"]
        in_specs = (P("data"),) * 4
        args = (jnp.asarray(X), W, S, jnp.asarray(mask, jnp.float32))
    f = jax.jit(shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=P("data"), check_vma=False))
    return np.asarray(f(*[jax.device_put(a, shard) for a in args]))


@pytest.mark.multi_device
@pytest.mark.parametrize("G", GROUPS)
def test_grouped_fedlay_mix_equals_dense_oracle(G, multi_device):
    """The acceptance pin: grouped shard_map mixing ≡ W·X on a real
    8-device mesh for G ∈ {1, 2, 4}."""
    n = 8 * G
    sched = build_permute_schedule(n, 2, salt=f"dev{G}")
    rng = np.random.default_rng(G)
    X = rng.normal(size=(n, 17)).astype(np.float32)
    out = _mix_on_mesh(sched, G, X)
    ref = schedule_mixing_matrix(sched) @ X
    np.testing.assert_allclose(out, ref, atol=1e-5)


@pytest.mark.multi_device
@pytest.mark.parametrize("G", GROUPS)
def test_grouped_masked_fedlay_mix_equals_dense_oracle(G, multi_device):
    n = 8 * G
    sched = build_permute_schedule(n, 2, salt=f"mdev{G}")
    rng = np.random.default_rng(G + 10)
    X = rng.normal(size=(n, 9)).astype(np.float32)
    mask = (rng.random(n) > 0.4).astype(np.float32)
    mask[0] = 0.0                       # at least one dead client
    out = _mix_on_mesh(sched, G, X, mask=mask)
    ref = masked_mixing_matrix(sched, mask) @ X
    np.testing.assert_allclose(out, ref, atol=1e-5)
    # masked-out rows pass through untouched
    np.testing.assert_array_equal(out[0], X[0])


@pytest.mark.multi_device
def test_grouped_mask_renormalizes_over_alive_local_clients(multi_device):
    """A fully dead device group: its rows pass through, and live
    clients on other devices renormalize over the surviving weights."""
    G, n = 2, 16
    sched = build_permute_schedule(n, 2, salt="deadgrp")
    mask = np.ones(n, np.float32)
    mask[4:6] = 0.0                     # device 2's whole group is dead
    rng = np.random.default_rng(0)
    X = rng.normal(size=(n, 7)).astype(np.float32)
    out = _mix_on_mesh(sched, G, X, mask=mask)
    ref = masked_mixing_matrix(sched, mask) @ X
    np.testing.assert_allclose(out, ref, atol=1e-5)
    np.testing.assert_array_equal(out[4:6], X[4:6])
    # the masked dense matrix is row-stochastic, so live rows actually
    # renormalized rather than losing the dead group's mass
    W = masked_mixing_matrix(sched, mask)
    np.testing.assert_allclose(W.sum(axis=1), 1.0, atol=1e-6)


@pytest.mark.multi_device
@pytest.mark.parametrize("G", GROUPS)
@pytest.mark.parametrize("strategy", ("fedlay", "ring", "allreduce"))
def test_make_mixer_equals_global_mixer_on_mesh(G, strategy, multi_device):
    """The two device paths agree under the grouped layout: the
    explicit shard_map program ≡ the auto-sharded global-view program,
    for every strategy."""
    n = 8 * G
    sched = build_permute_schedule(n, 2, salt=f"gg{G}")
    mesh = make_client_mesh(8, "data")
    shard = NamedSharding(mesh, P("data"))
    rng = np.random.default_rng(G)
    X = jnp.asarray(rng.normal(size=(n, 11)).astype(np.float32))
    W = jnp.asarray(sched.weights)
    S = jnp.asarray(sched.self_weight)
    mixer = make_mixer(strategy, sched, "data", n, clients_per_device=G)

    def body(x, w, s):
        return mixer({"m": x}, w, s)["m"]

    f = jax.jit(shard_map(body, mesh=mesh, in_specs=(P("data"),) * 3,
                          out_specs=P("data"), check_vma=False))
    out_shard = np.asarray(f(*[jax.device_put(a, shard)
                               for a in (X, W, S)]))
    gsched = ring_schedule(n) if strategy == "ring" else sched
    out_global = np.asarray(jax.jit(global_mixer(
        strategy, gsched if strategy != "allreduce" else None,
        clients_per_device=G))(X))
    np.testing.assert_allclose(out_shard, out_global, atol=1e-5)


# --------------------------------------------------------------------------
# Property-based equivalence (hypothesis; skips when not installed)
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(data=st.data(),
       G=st.sampled_from(GROUPS),
       L=st.integers(min_value=1, max_value=3),
       salt=st.integers(min_value=0, max_value=10**6))
def test_property_grouped_reference_vs_dense(data, G, L, salt):
    """Host-side fuzz: random schedules × masks × G — the grouped
    decomposition reconstructs the dense masked oracle exactly."""
    D = data.draw(st.integers(min_value=1, max_value=8), label="devices")
    n = D * G
    sched = build_permute_schedule(n, L, salt=f"h{salt}")
    bits = data.draw(st.lists(st.booleans(), min_size=n, max_size=n),
                     label="mask")
    mask = np.asarray(bits, np.float64)
    rng = np.random.default_rng(salt)
    X = rng.normal(size=(n, 3))
    ref = masked_mixing_matrix(sched, mask) @ X
    got = grouped_mix_reference(sched, X, G, mask=mask)
    np.testing.assert_allclose(got, ref, atol=1e-6)


@pytest.mark.multi_device
@pytest.mark.skipif(not EIGHT_DEVICES, reason="needs 8 host devices")
@settings(max_examples=8, deadline=None)
@given(data=st.data(),
       G=st.sampled_from(GROUPS),
       salt=st.integers(min_value=0, max_value=10**6))
def test_property_grouped_fedlay_mix_vs_dense(data, G, salt):
    """Device-side fuzz on the 8-device mesh: grouped fedlay_mix ≡
    masked_mixing_matrix for random schedules × masks × G."""
    n = 8 * G
    sched = build_permute_schedule(n, 2, salt=f"d{salt}")
    bits = data.draw(st.lists(st.booleans(), min_size=n, max_size=n),
                     label="mask")
    mask = np.asarray(bits, np.float32)
    rng = np.random.default_rng(salt)
    X = rng.normal(size=(n, 6)).astype(np.float32)
    out = _mix_on_mesh(sched, G, X, mask=mask)
    ref = masked_mixing_matrix(sched, mask) @ X
    np.testing.assert_allclose(out, ref, atol=1e-5)


@pytest.mark.multi_device
@pytest.mark.skipif(not EIGHT_DEVICES, reason="needs 8 host devices")
@settings(max_examples=8, deadline=None)
@given(G=st.sampled_from(GROUPS),
       salt=st.integers(min_value=0, max_value=10**6))
def test_property_global_mixer_equals_make_mixer(G, salt):
    """Fuzzed sibling of the fixed-seed two-path agreement test."""
    n = 8 * G
    sched = build_permute_schedule(n, 2, salt=f"p{salt}")
    rng = np.random.default_rng(salt)
    X = rng.normal(size=(n, 5)).astype(np.float32)
    out_shard = _mix_on_mesh(sched, G, X)
    out_global = np.asarray(jax.jit(global_mixer(
        "fedlay", sched, clients_per_device=G))(jnp.asarray(X)))
    np.testing.assert_allclose(out_shard, out_global, atol=1e-5)


# --------------------------------------------------------------------------
# Grouped communication accounting
# --------------------------------------------------------------------------

def test_sync_bytes_grouped_accounting():
    """Table-3-style pinning of the grouped network-bytes model:
    on-device edges are free, one device means zero wire bytes."""
    mb = 1_000_000
    # G = 1 keeps the paper's numbers bit-for-bit
    assert sync_bytes_per_client("fedlay", mb, 16, 3) == 6 * mb
    assert sync_bytes_per_client("ring", mb, 16) == 2 * mb
    assert sync_bytes_per_client("complete", mb, 16) == 15 * mb
    # grouped fedlay: expected cross fraction (n-G)/(n-1)
    got = sync_bytes_per_client("fedlay", mb, 16, 3, clients_per_device=2)
    assert got == pytest.approx(6 * mb * 14 / 15)
    # whole population on one device: every strategy costs 0 on the wire
    for strat in ("fedlay", "ring", "complete", "allreduce"):
        assert sync_bytes_per_client(strat, mb, 16, 3,
                                     clients_per_device=16) == 0.0
    # device-contiguous ring: only 2 of each group's 2G edges cross
    assert sync_bytes_per_client("ring", mb, 16, clients_per_device=4) \
        == pytest.approx(2 * mb / 4)
    # hierarchical allreduce: local reduce free, ring over D devices,
    # amortized over the G clients per device
    got = sync_bytes_per_client("allreduce", mb, 16, clients_per_device=2)
    assert got == pytest.approx(2 * (7 / 8) * mb / 2)
    assert sync_bytes_per_client("complete", mb, 16, clients_per_device=4) \
        == 12 * mb
    with pytest.raises(ValueError, match="divide"):
        sync_bytes_per_client("fedlay", mb, 16, 3, clients_per_device=3)


@pytest.mark.parametrize("G", GROUPS)
def test_sync_bytes_tracks_exact_cross_edges(G):
    """The closed form is the expectation of the exact per-schedule
    count: pin the exact counter, and the expectation within a loose
    band over schedule salts."""
    n, L, mb = 8 * G, 3, 1.0
    exact = []
    for salt in range(8):
        sched = build_permute_schedule(n, L, salt=f"b{salt}")
        rt = grouped_routing(sched, G)
        # per-client exact network bytes for this schedule
        exact.append(rt.cross_edges * mb / n)
        # never more than the flat-layout paper bound
        assert rt.cross_edges <= 2 * L * n
    model = sync_bytes_per_client("fedlay", mb, n, L, clients_per_device=G)
    assert model <= 2 * L * mb
    # the closed form is the paper's degree-bound expectation; the exact
    # count also prunes duplicate adjacencies (a peer adjacent in
    # several spaces is exchanged once), so it sits at or below the
    # model, within a loose band
    assert np.mean(exact) <= model + 1e-9
    assert np.mean(exact) >= 0.6 * model
    if G > 1:
        # grouping strictly saves wire bytes vs the flat layout
        flat = np.mean([grouped_routing(
            build_permute_schedule(n, L, salt=f"b{s}"), 1).cross_edges
            for s in range(8)]) / n
        assert np.mean(exact) < flat


@pytest.mark.parametrize("G", GROUPS)
def test_sync_bytes_cohort_active_clients_tracks_exact_edges(G):
    """Cohort streaming accounting: with only K of n clients active the
    fedlay closed form uses the cohort-induced degree min(2L, K-1) and
    the packed-slot cross fraction (K-G)/(K-1).  Pinned against exact
    cross-edge counts of capacity-padded cohort schedules (the SlotMap
    packs the cohort into the lowest slots, which is exactly
    ``pad_schedule(sched, range(K), K)``)."""
    from repro.core.mixing import schedule_from_addresses
    from repro.scale.cohort import cohort_addresses

    n, L, mb = 200, 3, 1.0
    K = 8 * G
    rng = np.random.default_rng(G)
    exact = []
    for _ in range(8):
        cohort = tuple(sorted(int(u) for u in
                              rng.choice(n, size=K, replace=False)))
        sched = schedule_from_addresses(cohort_addresses(cohort, L))
        padded = pad_schedule(sched, list(range(K)), K)
        rt = grouped_routing(padded, G)
        assert rt.cross_edges <= min(2 * L, K - 1) * K
        exact.append(rt.cross_edges * mb / K)
    model = sync_bytes_per_client("fedlay", mb, n, L, clients_per_device=G,
                                  active_clients=K)
    # the closed form is the expectation of the exact count (which also
    # dedups multi-space adjacencies), same band as full participation
    assert np.mean(exact) <= model + 1e-9
    assert np.mean(exact) >= 0.6 * model


def test_sync_bytes_cohort_reduces_to_full_participation():
    """active_clients=None and active_clients=n agree bit-for-bit on
    every strategy, and tiny cohorts cap the fedlay degree at K-1."""
    mb = 1_000_000
    for strat in ("fedlay", "ring", "complete", "allreduce"):
        for G in (1, 2, 4):
            assert sync_bytes_per_client(strat, mb, 16, 3,
                                         clients_per_device=G) == \
                sync_bytes_per_client(strat, mb, 16, 3,
                                      clients_per_device=G,
                                      active_clients=16)
    # K=4 cohort cannot realize 2L=6 distinct neighbors: degree = K-1
    assert sync_bytes_per_client("fedlay", mb, 200, 3,
                                 active_clients=4) == 3 * mb
    # single-member or single-device cohorts cost zero wire bytes
    assert sync_bytes_per_client("fedlay", mb, 200, 3,
                                 active_clients=1) == 0.0
    assert sync_bytes_per_client("fedlay", mb, 200, 3, clients_per_device=8,
                                 active_clients=8) == 0.0
    # K=8 cohort packed 2/device: ring over D_K=4 devices, 2·D_K/K·model
    assert sync_bytes_per_client("ring", mb, 200, clients_per_device=2,
                                 active_clients=8) == mb
    with pytest.raises(ValueError, match="active_clients"):
        sync_bytes_per_client("fedlay", mb, 16, 3, active_clients=17)
