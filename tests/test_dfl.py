"""DFL engine integration: the paper's method comparisons in miniature —
FedLay converges, beats isolated training, tracks FedAvg, fingerprints
suppress duplicate sends, async helps stragglers."""

import numpy as np
import pytest

from repro.core.dfl import capacity_periods, run_gossip, run_method
from repro.core.baselines import TOPOLOGY_REGISTRY
from repro.data.noniid import shard_partition
from repro.data.synthetic import mnist_like
from repro.models.small import MLPTask


@pytest.fixture(scope="module")
def task():
    data = mnist_like(n_train=1200, n_test=400, seed=0)
    part = shard_partition(data.y_train, num_clients=12, shards_per_client=3,
                           seed=0)
    return MLPTask(data, part, hidden=32, local_steps=2, batch=32)


def test_capacity_periods_tiers():
    p = capacity_periods(300, 10.0, seed=0)
    vals = sorted(set(np.round(p, 6)))
    assert np.allclose(vals, [10 * 2 / 3, 10.0, 20.0])


def test_fedlay_learns_and_beats_isolated(task):
    fed = run_method("fedlay", task, total_time=40.0, model_bytes=1000,
                     base_period=1.0, seed=0)
    iso_topo = TOPOLOGY_REGISTRY["ring"](task.num_clients)
    # isolated = no edges: simulate with gossip over an empty topology
    from repro.core.topology import Topology
    empty = Topology(nodes=tuple(range(task.num_clients)), edges=frozenset())
    iso = run_gossip(task, empty, capacity_periods(task.num_clients, 1.0),
                     total_time=40.0, model_bytes=1000, seed=0)
    assert fed.final_mean_acc > 0.5            # learns far above chance
    assert fed.final_mean_acc > iso.final_mean_acc + 0.05
    # convergence: accuracy increased over the run
    assert fed.trace[-1].mean_acc > fed.trace[0].mean_acc + 0.2


def test_fedavg_upper_bounds_fedlay(task):
    """Paper Table III compares accuracy AT CONVERGENCE — FedAvg is paced
    by the slowest client (rounds of max-period), so it gets a longer
    wall-clock budget to converge; FedLay must land within a few points
    of the centralized bound (and converge faster per unit time)."""
    fed = run_method("fedlay", task, total_time=160.0, model_bytes=1000, seed=0)
    avg = run_method("fedavg", task, total_time=160.0, model_bytes=1000, seed=0)
    assert avg.final_mean_acc >= 0.8            # centralized bound converged
    assert fed.final_mean_acc >= avg.final_mean_acc - 0.05
    # FedLay's *time-to-accuracy* beats synchronized FedAvg (async claim)
    avg_40 = run_method("fedavg", task, total_time=40.0, model_bytes=1000,
                        seed=0)
    assert fed.final_mean_acc >= avg_40.final_mean_acc - 0.02


def test_fingerprint_suppression_counts(task):
    res = run_method("fedlay", task, total_time=20.0, model_bytes=1000, seed=0)
    assert res.suppressed_sends >= 0
    assert res.messages_per_client > 0
    assert res.comm_bytes_per_client == pytest.approx(
        res.messages_per_client * 1000)


def test_methods_registry_coverage(task):
    for method in ("gaia", "dfl-dds", "chord", "ring", "fedlay-sync",
                   "fedlay-noconf"):
        res = run_method(method, task, total_time=10.0, model_bytes=1000,
                         seed=0)
        assert np.isfinite(res.final_mean_acc)


def test_async_beats_sync_in_time_budget(task):
    """Fig 12: per-client periods beat slowest-client pacing."""
    sync = run_method("fedlay-sync", task, total_time=30.0, model_bytes=1000,
                      seed=0)
    asyn = run_method("fedlay", task, total_time=30.0, model_bytes=1000,
                      seed=0)
    assert asyn.local_steps_per_client >= sync.local_steps_per_client
