"""MEP: confidence parameters, async periods, fingerprint dedup."""

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.mep import (ClientProfile, FingerprintTable,
                            aggregation_weights, data_confidence,
                            fine_grained_period, link_period,
                            model_fingerprint, tier_period)


def prof(cid, period, hist):
    return ClientProfile(client_id=cid, period=period,
                         label_histogram=np.asarray(hist, float))


def test_data_confidence_uniform_is_one():
    assert data_confidence(np.ones(10)) == pytest.approx(1.0)


def test_data_confidence_decreases_with_skew():
    c_uniform = data_confidence(np.ones(10))
    c_two = data_confidence(np.array([5.0, 5.0] + [0.0] * 8))
    c_one = data_confidence(np.array([10.0] + [0.0] * 9))
    assert c_uniform > c_two > c_one > 0.0


def test_link_period_is_max():
    assert link_period(3.0, 5.0) == 5.0


def test_fine_grained_period_requires_eta_gt_one():
    assert fine_grained_period(10.0, eta=1.2) == pytest.approx(12.0)
    with pytest.raises(ValueError):
        fine_grained_period(10.0, eta=0.9)


def test_tier_periods():
    assert tier_period(60.0, "high") == pytest.approx(40.0)
    assert tier_period(60.0, "medium") == 60.0
    assert tier_period(60.0, "low") == 120.0


@given(st.integers(1, 8), st.integers(0, 3))
def test_aggregation_weights_simplex(n_nbrs, seed):
    rng = np.random.default_rng(seed)
    me = prof(0, 1.0 + rng.random(), rng.random(10) + 0.01)
    nbrs = [prof(i + 1, 0.5 + rng.random() * 3, rng.random(10) + 0.01)
            for i in range(n_nbrs)]
    w = aggregation_weights(me, nbrs, 0.5, 0.5, True)
    assert len(w) == n_nbrs + 1
    assert np.all(np.asarray(w) >= 0)
    assert np.sum(w) == pytest.approx(1.0)


def test_confidence_weights_favor_rich_fast_clients():
    """Higher data richness + shorter period ⇒ larger weight."""
    me = prof(0, 1.0, np.ones(10))
    rich_fast = prof(1, 0.5, np.ones(10))              # uniform data, fast
    poor_slow = prof(2, 4.0, [10] + [0] * 9)           # skewed data, slow
    w = aggregation_weights(me, [rich_fast, poor_slow], 0.5, 0.5, True)
    assert w[1] > w[2]


def test_simple_average_when_unweighted():
    me = prof(0, 1.0, np.ones(4))
    nbrs = [prof(1, 9.0, [4, 0, 0, 0]), prof(2, 0.1, np.ones(4))]
    w = aggregation_weights(me, nbrs, 0.5, 0.5, False)
    assert np.allclose(w, 1.0 / 3.0)


def test_fingerprint_dedup():
    t = FingerprintTable()
    m1 = np.arange(10, dtype=np.float32)
    f1 = model_fingerprint(m1)
    assert t.should_send(5, f1)
    t.record(5, f1)
    assert not t.should_send(5, f1)          # duplicate suppressed
    assert t.suppressed == 1
    m2 = m1 + 1e-3
    assert t.should_send(5, model_fingerprint(m2))   # changed model resends
    assert t.should_send(6, f1)                      # other peer unaffected


def test_fingerprint_deterministic():
    m = np.random.default_rng(0).normal(size=100).astype(np.float32)
    assert model_fingerprint(m) == model_fingerprint(m.copy())
