"""Cohort streaming (repro.scale.cohort): the induced-FedLay cohort
round on the fixed-capacity buffer must equal the dense mixing-matrix
oracle, reduce to full participation when the whole population is
sampled, preserve node identity across stream-out/stream-in, seed cold
members by Fig-18 donor catch-up, and never retrace the jitted round
as cohort composition changes."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mixing import schedule_from_addresses, schedule_mixing_matrix
from repro.kernels.weighted_mix import gather_mix
from repro.runtime.loop import counting_jit
from repro.scale import CohortSampler, CohortStreamLoop, VectorSimulator
from repro.scale.cohort import (cohort_addresses, cohort_mixing_matrix,
                                cohort_schedule, schedule_tables)

L = 3


def make_sim(n):
    sim = VectorSimulator(num_spaces=L, latency=0.05, heartbeat_period=0.5,
                          probe_period=1.0)
    sim.seed_network(range(n))
    return sim


class FixedSampler:
    """Scripted cohorts — last entry repeats."""

    def __init__(self, cohorts):
        self.cohorts = [tuple(sorted(c)) for c in cohorts]

    def sample(self, round_index):
        return self.cohorts[min(round_index, len(self.cohorts) - 1)]


def make_params(u):
    return np.random.default_rng(u).random(16).astype(np.float32)


# --------------------------------------------------------------------------
# The mixing round vs the dense oracle
# --------------------------------------------------------------------------

def test_gather_mix_traced_srcs_equals_dense_oracle():
    """>= 3 cohort compositions through ONE jitted gather_mix: each
    equals M @ buf within 1e-6, with zero retraces (the source table is
    runtime data)."""
    capacity, dim, n = 16, 64, 20
    rng = np.random.default_rng(0)
    buf = rng.random((capacity, dim), dtype=np.float32)
    buf_j = jnp.asarray(buf)
    mix, count = counting_jit(lambda b, s, w: gather_mix(b, s, w))
    cohorts = [tuple(range(10)), tuple(range(5, 17)),
               tuple(2 * k for k in range(8))]
    for cohort in cohorts:
        slot_of = {u: i for i, u in enumerate(cohort)}
        _, padded = cohort_schedule(cohort, L, slot_of, capacity)
        srcs, weights = schedule_tables(padded)
        out = np.asarray(mix(buf_j, jnp.asarray(srcs), jnp.asarray(weights)))
        oracle = cohort_mixing_matrix(cohort, L, slot_of, capacity) \
            @ buf.astype(np.float64)
        assert float(np.abs(out - oracle).max()) <= 1e-6
    assert count.retraces == 0


def test_full_population_cohort_is_full_participation():
    """Sampling everyone gives exactly the dense full-participation
    mixing matrix (identity on the spare dead slots)."""
    n, capacity = 12, 16
    cohort = tuple(range(n))
    slot_of = {u: i for i, u in enumerate(cohort)}
    M = cohort_mixing_matrix(cohort, L, slot_of, capacity)
    dense = schedule_mixing_matrix(
        schedule_from_addresses(cohort_addresses(cohort, L)))
    np.testing.assert_array_equal(M[:n, :n], dense)
    np.testing.assert_array_equal(M[n:, n:], np.eye(capacity - n))
    np.testing.assert_array_equal(M[:n, n:], 0.0)
    # row-stochastic restriction: live rows renormalize over the cohort
    np.testing.assert_allclose(M.sum(axis=1), 1.0, atol=1e-12)


def test_cohort_matrix_is_cohort_supported_and_stochastic():
    cohort = (3, 8, 11, 25, 40, 41)
    slot_of = {u: i for i, u in enumerate(cohort)}
    M = cohort_mixing_matrix(cohort, L, slot_of, 8)
    np.testing.assert_allclose(M.sum(axis=1), 1.0, atol=1e-12)
    live = [slot_of[u] for u in cohort]
    dead = [s for s in range(8) if s not in live]
    np.testing.assert_array_equal(M[np.ix_(dead, dead)], np.eye(len(dead)))
    np.testing.assert_array_equal(M[np.ix_(live, dead)], 0.0)


# --------------------------------------------------------------------------
# Sampler
# --------------------------------------------------------------------------

def test_sampler_deterministic_and_bounded():
    sim = make_sim(100)
    a = CohortSampler(sim, 10, seed=5)
    b = CohortSampler(sim, 10, seed=5)
    assert a.sample(3) == b.sample(3)
    assert len(a.sample(0)) == 10
    assert a.sample(0) != a.sample(1)      # fresh draw per round
    small = CohortSampler(sim, 500, seed=5)
    assert small.sample(0) == tuple(sim.alive_ids())   # K > population
    with pytest.raises(ValueError):
        CohortSampler(sim, 0)


# --------------------------------------------------------------------------
# The streaming loop
# --------------------------------------------------------------------------

def test_stream_out_parks_and_stream_in_restores_identity():
    sim = make_sim(8)
    cohorts = [(0, 1, 2, 3), (2, 3, 4, 5), (0, 1, 2, 3)]
    loop = CohortStreamLoop(sim, capacity=4, cohort_size=4,
                            make_params=make_params,
                            sampler=FixedSampler(cohorts))
    loop.run(1)
    p0 = loop.client_params(0).copy()
    loop.run(1)                    # 0 streamed out -> parked
    assert 0 in loop.park
    np.testing.assert_array_equal(loop.client_params(0), p0)
    loop.run(1)                    # 0 streamed back in -> restored
    assert 0 not in loop.park
    r = loop.records[-1]
    assert r.restored == 2 and r.fresh == 0       # 0 and 1 resume
    assert loop.records[1].streamed_out == 2
    # the restored row re-entered mixing from its own parked state:
    # round 2's pre-mix value for node 0 was exactly p0
    assert loop.trace_count.retraces == 0


def test_cold_members_get_donor_catchup():
    """Round 0: everyone is cold (fresh init).  Round 1: new members
    joining a warm cohort are donor-seeded (Fig 18), not fresh."""
    sim = make_sim(16)
    cohorts = [(0, 1, 2, 3, 4, 5), (0, 1, 2, 3, 6, 7)]
    loop = CohortStreamLoop(sim, capacity=6, cohort_size=6,
                            make_params=make_params,
                            sampler=FixedSampler(cohorts))
    loop.run(2)
    r0, r1 = loop.records
    assert r0.fresh == 6 and r0.donor_seeded == 0
    assert r1.streamed_in == 2
    assert r1.donor_seeded == 2 and r1.fresh == 0
    # accounting identity holds every round
    for r in loop.records:
        assert r.streamed_in == r.restored + r.donor_seeded + r.fresh


def test_zero_retraces_across_compositions_and_churn():
    """>= 3 distinct cohort compositions, plus engine churn between
    rounds: still one compiled round program."""
    sim = make_sim(200)
    loop = CohortStreamLoop(sim, capacity=8, cohort_size=8,
                            make_params=make_params, seed=11)
    loop.run(2)
    sim.fail_batch(range(5))
    sim.join_batch(range(500, 505))
    sim.run_for(30.0)
    loop.run(2)
    assert len({r.round for r in loop.records}) == 4
    assert loop.records[-1].retraces == 0
    assert loop.trace_count.traces == 1


def test_loop_validates_capacity():
    sim = make_sim(8)
    with pytest.raises(ValueError, match="exceeds"):
        CohortStreamLoop(sim, capacity=4, cohort_size=8,
                         make_params=make_params)


def test_loop_matches_dense_oracle_round_by_round():
    """End-to-end: with a stable cohort (reconcile is a no-op after
    round 0) every device round is exactly buf ← M @ buf for the dense
    cohort mixing matrix M."""
    sim = make_sim(10)
    cohort = (0, 1, 2, 3, 4)
    loop = CohortStreamLoop(sim, capacity=5, cohort_size=5,
                            make_params=make_params,
                            sampler=FixedSampler([cohort]))
    loop.run(1)                    # seeds everyone, first mix
    M = cohort_mixing_matrix(cohort, L, dict(loop.slots.slot_of), 5)
    for _ in range(3):
        before = np.asarray(loop.buf, dtype=np.float64)
        loop.run(1)
        after = np.asarray(loop.buf, dtype=np.float64)
        assert float(np.abs(after - M @ before).max()) <= 1e-6
    assert loop.trace_count.retraces == 0


# --------------------------------------------------------------------------
# Bounded park: LRU eviction + snapshot/restore (ISSUE 7 satellite)
# --------------------------------------------------------------------------

def test_park_lru_eviction_is_bounded_and_counted():
    """Disjoint cohorts over a big population: the park never exceeds
    max_parked, evictions hit the oldest entries first, and the round
    records carry the eviction count."""
    sim = make_sim(40)
    cohorts = [tuple(range(8 * r, 8 * r + 8)) for r in range(4)]
    loop = CohortStreamLoop(sim, capacity=8, cohort_size=8,
                            make_params=make_params,
                            sampler=FixedSampler(cohorts),
                            max_parked=8)
    loop.run(4)
    assert len(loop.park) <= 8
    # round 1 parks cohort 0; rounds 2 and 3 each park 8 more and evict
    # the 8 oldest — only the most recently parked cohort survives
    assert loop.evictions == 16
    assert loop.records[-1].evicted == 8
    assert set(loop.park) == set(cohorts[2])


def test_park_unbounded_by_default():
    sim = make_sim(40)
    cohorts = [tuple(range(8 * r, 8 * r + 8)) for r in range(4)]
    loop = CohortStreamLoop(sim, capacity=8, cohort_size=8,
                            make_params=make_params,
                            sampler=FixedSampler(cohorts))
    loop.run(4)
    assert len(loop.park) == 24 and loop.evictions == 0
    assert all(r.evicted == 0 for r in loop.records)


def test_park_eviction_snapshot_restore_preserves_identity():
    """With a snapshot/restore policy the evicted row round-trips: the
    node re-enters with exactly the state it was evicted with (restored,
    not donor-seeded), so a bounded park stays identity-preserving."""
    store = {}
    sim = make_sim(20)
    cohorts = [(0, 1, 2, 3), (4, 5, 6, 7), (8, 9, 10, 11), (0, 1, 2, 3)]
    loop = CohortStreamLoop(
        sim, capacity=4, cohort_size=4, make_params=make_params,
        sampler=FixedSampler(cohorts), max_parked=4,
        snapshot_fn=lambda u, row: store.__setitem__(u, row.copy()),
        restore_fn=lambda u: store.get(u))
    loop.run(2)
    p0 = loop.client_params(0).copy()   # parked after round 1
    loop.run(1)                          # round 2 parks 4..7 -> 0..3 evicted
    assert set(store) == {0, 1, 2, 3}
    assert 0 not in loop.park
    # client_params falls through park -> restore_fn
    np.testing.assert_array_equal(loop.client_params(0), p0)
    loop.run(1)                          # 0..3 stream back in
    r = loop.records[-1]
    assert r.restored == 4 and r.donor_seeded == 0 and r.fresh == 0
    np.testing.assert_array_equal(
        np.asarray(loop.buf)[loop.slots.slot_of[0]], p0)


def test_park_eviction_without_restore_falls_back_to_donor():
    """Evicted with no snapshot policy = truly forgotten: on return the
    node is donor-seeded like any cold joiner (graceful degradation,
    not a crash)."""
    sim = make_sim(20)
    cohorts = [(0, 1, 2, 3), (4, 5, 6, 7), (8, 9, 10, 11), (0, 8, 9, 10)]
    loop = CohortStreamLoop(sim, capacity=4, cohort_size=4,
                            make_params=make_params,
                            sampler=FixedSampler(cohorts), max_parked=4)
    loop.run(3)
    with pytest.raises(KeyError):
        loop.client_params(0)            # evicted, no restore policy
    loop.run(1)                          # 0 rejoins a warm cohort
    r = loop.records[-1]
    assert r.streamed_in == 1
    assert r.restored == 0 and r.donor_seeded == 1 and r.fresh == 0


def test_park_validates_max_parked():
    sim = make_sim(8)
    with pytest.raises(ValueError, match="max_parked"):
        CohortStreamLoop(sim, capacity=4, cohort_size=4,
                         make_params=make_params, max_parked=0)
