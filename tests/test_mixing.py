"""Mixing schedules: the TPU ppermute path and the simulation path must
agree; multirate participation; spectral sanity of the mixing operator."""

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.coords import NodeAddress
from repro.core.mep import ClientProfile
from repro.core.mixing import (build_permute_schedule,
                               confidence_mixing_matrix, gossip_step,
                               multirate_participation,
                               schedule_mixing_matrix)
from repro.core.topology import fedlay_topology


def profiles(n, seed=0):
    rng = np.random.default_rng(seed)
    return {i: ClientProfile(client_id=i, period=0.5 + rng.random() * 2,
                             label_histogram=rng.random(10) + 0.01)
            for i in range(n)}


@given(st.integers(4, 32), st.integers(1, 4), st.integers(0, 3))
def test_schedule_matches_dense_mixing_matrix(n, L, seed):
    """ppermute-schedule ≡ confidence mixing matrix (TPU path = sim path)."""
    profs = profiles(n, seed)
    sched = build_permute_schedule(n, L, profiles=profs)
    W_sched = schedule_mixing_matrix(sched)
    addrs = [NodeAddress.create(i, L) for i in range(n)]
    topo = fedlay_topology(addrs)
    W_dense = confidence_mixing_matrix(topo, profs)
    assert np.allclose(W_sched, W_dense, atol=1e-6)


@given(st.integers(4, 40), st.integers(1, 4))
def test_schedule_row_stochastic_nonnegative(n, L):
    sched = build_permute_schedule(n, L)
    W = schedule_mixing_matrix(sched)
    assert np.allclose(W.sum(1), 1.0, atol=1e-6)
    assert (W >= -1e-9).all()


def test_gossip_contracts_disagreement():
    """Repeated mixing drives client models to consensus at rate λ."""
    n, L = 24, 3
    sched = build_permute_schedule(n, L)
    W = schedule_mixing_matrix(sched)
    rng = np.random.default_rng(0)
    X = rng.normal(size=(n, 50))
    spread0 = np.linalg.norm(X - X.mean(0), axis=0).max()
    for _ in range(20):
        X = gossip_step(X, W)
    spread = np.linalg.norm(X - X.mean(0), axis=0).max()
    assert spread < 0.05 * spread0


def test_duplicate_adjacency_masked():
    """A peer adjacent on two rings must be counted once (fingerprint
    dedup image) — every incoming source appears once per row."""
    n, L = 6, 3   # tiny n → duplicates guaranteed
    sched = build_permute_schedule(n, L)
    for i in range(n):
        srcs = [sched.perms[k][i] for k in range(sched.num_slots)
                if sched.weights[i, k] > 0]
        assert len(srcs) == len(set(srcs))
        assert i not in srcs


def test_pod_bias_cuts_cross_pod_edges():
    """Beyond-paper: pod-biased coordinates leave exactly P crossing
    edges per ring direction; full randomness crosses ~half."""
    from repro.core.mixing import cross_pod_messages
    n, L, P = 32, 3, 2
    rand = build_permute_schedule(n, L)
    bias = build_permute_schedule(n, L, pod_bias=P)
    cr, cb = cross_pod_messages(rand, P), cross_pod_messages(bias, P)
    assert cb == 2 * L * P * 2 // 2   # P crossings × 2 dirs × L spaces
    assert cb < cr / 4
    # still a valid row-stochastic mixing schedule
    W = schedule_mixing_matrix(bias)
    assert np.allclose(W.sum(1), 1.0, atol=1e-6)
    # partial bias interpolates
    half = build_permute_schedule(n, L, pod_bias=P, pod_bias_spaces=1)
    assert cb < cross_pod_messages(half, P) < cr


def test_multirate_participation():
    mask0 = multirate_participation([1.0, 2.0, 4.0], step=0)
    assert mask0.tolist() == [1, 1, 1]
    mask1 = multirate_participation([1.0, 2.0, 4.0], step=1)
    assert mask1.tolist() == [1, 0, 0]
    mask2 = multirate_participation([1.0, 2.0, 4.0], step=2)
    assert mask2.tolist() == [1, 1, 0]
