"""Per-architecture smoke tests (deliverable f): every assigned config
instantiates a REDUCED same-family variant (≤2 layers, d_model ≤ 512,
≤4 experts) and runs one forward + one train step + one decode step on
CPU, asserting output shapes and no NaNs.  Full configs are exercised
only via the dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, for_shape, reduce_for_smoke
from repro.models import (decode_step, forward, init_cache, init_params,
                          train_loss)
from repro.models.config import INPUT_SHAPES
from repro.optim.optimizers import apply_updates, sgd

ARCHS = sorted(REGISTRY)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduce_for_smoke(REGISTRY[arch])
    assert cfg.num_layers <= 2 or cfg.hybrid is not None
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 32
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    if cfg.enc_dec:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(size=(B, 16, cfg.d_model)), jnp.float32)

    logits, aux = forward(cfg, params, batch["tokens"],
                          enc_embeds=batch.get("enc_embeds"))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())

    # one SGD train step: loss finite, params move, still finite
    loss, grads = jax.value_and_grad(
        lambda p: train_loss(cfg, p, batch, remat=True))(params)
    assert np.isfinite(float(loss))
    opt = sgd(1e-2)
    updates, _ = opt.update(grads, opt.init(params), params)
    new_params = apply_updates(params, updates)
    moved = any(float(jnp.abs(a - b).max()) > 0
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert moved
    loss2 = train_loss(cfg, new_params, batch, remat=False)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = reduce_for_smoke(REGISTRY[arch])
    params = init_params(cfg, jax.random.PRNGKey(1))
    B = 2
    enc = None
    if cfg.enc_dec:
        enc = jnp.asarray(np.random.default_rng(0).normal(
            size=(B, 16, cfg.d_model)), jnp.float32)
    cache = init_cache(cfg, params, B, 64, enc_embeds=enc)
    tok = jnp.zeros((B, 1), jnp.int32)
    for _ in range(3):
        logits, cache = decode_step(cfg, params, cache, tok)
        assert logits.shape == (B, cfg.padded_vocab)
        assert not bool(jnp.isnan(logits).any())
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        assert int(tok.max()) < cfg.vocab_size
    assert int(cache["pos"]) == 3


@pytest.mark.parametrize("arch", ARCHS)
def test_long_context_variant_is_subquadratic(arch):
    """long_500k applicability: SSM/hybrid native; attention archs get
    the sliding-window variant (window 8192)."""
    cfg = for_shape(REGISTRY[arch], INPUT_SHAPES["long_500k"])
    if cfg.family == "ssm":
        assert cfg.sliding_window is None   # native O(1) state
    else:
        assert cfg.sliding_window == 8192


def test_param_counts_match_nameplates():
    expected = {
        "mamba2-370m": (0.37, 0.1), "qwen3-14b": (14.8, 1.0),
        "llama3-405b": (405.9, 8.0), "qwen3-4b": (4.0, 0.5),
        "llama3.2-3b": (3.2, 0.4), "chameleon-34b": (34.3, 2.0),
        "seamless-m4t-medium": (1.0, 0.4), "deepseek-v3-671b": (683.0, 15.0),
        "phi3.5-moe-42b-a6.6b": (41.9, 2.0), "jamba-1.5-large-398b": (398.0, 8.0),
    }
    for arch, (want, tol) in expected.items():
        got = REGISTRY[arch].param_count() / 1e9
        assert abs(got - want) < tol, (arch, got, want)


def test_moe_active_params():
    ds = REGISTRY["deepseek-v3-671b"]
    assert abs(ds.param_count(active_only=True) / 1e9 - 38.1) < 3.0
    phi = REGISTRY["phi3.5-moe-42b-a6.6b"]
    assert abs(phi.param_count(active_only=True) / 1e9 - 6.6) < 1.0
