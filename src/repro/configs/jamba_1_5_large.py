"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave with MoE
[arXiv:2403.19887].  72L, d_model=8192, 64 heads (GQA kv=8), d_ff=24576,
vocab=65536, 16 experts top-2 on every other layer.

Block structure: period-8 superblocks with the attention layer at index
0 and Mamba at 1..7 (the paper's 1:7 ratio); MoE FFN on odd layers,
dense on even.  Jamba-1/1.5 ship Mamba-1 mixers; we use the Mamba2 SSD
mixer (our kernelized scan) — recorded in DESIGN.md §deviations.
long_500k decodes natively on the Mamba state; attention layers keep a
sliding-window cache (Jamba's bounded-KV design goal)."""

from ..models.config import ArchConfig, HybridConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    rope_theta=10_000.0,
    hybrid=HybridConfig(period=8, attn_index=0),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=24576, num_shared=0,
                  moe_every=2, moe_offset=1),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, headdim=128, chunk=256),
    source="Jamba [arXiv:2403.19887]",
)
