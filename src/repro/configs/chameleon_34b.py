"""chameleon-34b — early-fusion VLM [arXiv:2405.09818].
48L, d_model=8192, 64 heads (GQA kv=8), d_ff=22016, vocab=65536.

Early fusion means image content enters as discrete VQ tokens inside the
same 65536-entry vocabulary — the backbone is an ordinary decoder-only
transformer (with qk-norm, which Chameleon introduced for training
stability).  The VQ-GAN image tokenizer is the stubbed modality
frontend per the carve-out: ``input_specs`` supplies token ids that are
an interleaved text/image stream."""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,            # Chameleon's qk-norm stability fix
    rope_theta=10_000.0,
    source="Chameleon early-fusion [arXiv:2405.09818]",
)
