"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE
[hf:microsoft/Phi-3.5-MoE-instruct].  32L, d_model=4096, 32 heads
(GQA kv=8), expert d_ff=6400, vocab=32064, every layer MoE."""

from ..models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    rope_theta=10_000.0,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=6400, num_shared=0),
    source="Phi-3.5-MoE [hf:microsoft/Phi-3.5-MoE-instruct]",
)
