"""deepseek-v3-671b — MoE with MLA + MTP [arXiv:2412.19437].
61L, d_model=7168, 128 heads (MLA latent attention), expert d_ff=2048,
vocab=129280, 1 shared + 256 routed experts top-8, first 3 layers dense
(dense d_ff=18432 per the tech report), multi-token-prediction depth 1.

MLA dims per the report: q_lora 1536, kv_lora 512, 128/64 nope/rope head
dims, v_head 128.  The sigmoid+bias-balanced router is simplified to
softmax top-k + aux loss (DESIGN.md §deviations)."""

from ..models.config import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,        # MLA: latent cache is shared; heads decompress
    d_ff=18432,              # dense d_ff for the first_dense_layers
    vocab_size=129280,
    rope_theta=10_000.0,
    first_dense_layers=3,
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048, num_shared=1),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    mtp_depth=1,
    source="DeepSeek-V3 [arXiv:2412.19437]",
)
