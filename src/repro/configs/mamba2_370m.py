"""mamba2-370m — attention-free SSM, SSD (state-space duality)
[arXiv:2405.21060].  48L, d_model=1024, ssm_state=128, vocab=50280.
d_inner = 2·d_model = 2048, headdim 64 → 32 SSD heads.  long_500k is
native: O(1) recurrent decode state."""

from ..models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=32,            # SSD heads (d_inner / headdim)
    num_kv_heads=32,
    d_ff=0,                  # attention-free, no separate FFN
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, headdim=64, chunk=256),
    source="SSD / Mamba2 [arXiv:2405.21060]",
)
