"""Assigned architecture configs (public-literature pool) + the paper's
own small client models.  ``get(name)`` / ``REGISTRY`` are the front
door; every config cites its source in ``CONFIG.source``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from ..models.config import ArchConfig, INPUT_SHAPES, InputShape, reduce_for_smoke

from .mamba2_370m import CONFIG as mamba2_370m
from .qwen3_14b import CONFIG as qwen3_14b
from .llama3_405b import CONFIG as llama3_405b
from .qwen3_4b import CONFIG as qwen3_4b
from .llama3_2_3b import CONFIG as llama3_2_3b
from .chameleon_34b import CONFIG as chameleon_34b
from .seamless_m4t_medium import CONFIG as seamless_m4t_medium
from .deepseek_v3_671b import CONFIG as deepseek_v3_671b
from .phi3_5_moe import CONFIG as phi3_5_moe
from .jamba_1_5_large import CONFIG as jamba_1_5_large

REGISTRY: Dict[str, ArchConfig] = {
    c.name: c for c in [
        mamba2_370m, qwen3_14b, llama3_405b, qwen3_4b, llama3_2_3b,
        chameleon_34b, seamless_m4t_medium, deepseek_v3_671b, phi3_5_moe,
        jamba_1_5_large,
    ]
}


def get(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def for_shape(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    """Shape-conditioned variant of an architecture.

    ``long_500k`` requires sub-quadratic attention: SSM/hybrid run
    natively; every attention architecture switches to the
    sliding-window variant (window 8192) so the 500k decode cache is
    O(window) — recorded as ``attn=sliding`` in the dry-run table.
    """
    if shape.name == "long_500k" and cfg.family != "ssm" and cfg.hybrid is None:
        return dataclasses.replace(cfg, sliding_window=8192)
    if shape.name == "long_500k" and cfg.hybrid is not None:
        # hybrid: mamba layers carry the long context; attention layers
        # use a window so their cache stays bounded (Jamba's design).
        return dataclasses.replace(cfg, sliding_window=8192)
    return cfg


__all__ = ["REGISTRY", "get", "for_shape", "INPUT_SHAPES", "reduce_for_smoke"]
