"""seamless-m4t-medium — enc-dec multimodal (speech/text) [arXiv:2308.11596].
12L decoder + 12L encoder, d_model=1024, 16 heads (kv=16 = MHA),
d_ff=4096, vocab=256206.

The speech frontend (mel spectrogram + conv feature extractor) is the
stubbed modality frontend per the carve-out: ``input_specs`` supplies
precomputed frame embeddings (B, frames, d_model); the implemented part
is the full transformer encoder + autoregressive text decoder with
cross-attention."""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    rope_theta=10_000.0,
    enc_dec=True,
    enc_layers=12,
    source="SeamlessM4T [arXiv:2308.11596]",
)
