"""Profiler hooks: labelled device timelines and opt-in trace capture.

Two kinds of label, matching where the cost lives:

* :func:`scope` — ``jax.named_scope``.  A **trace-time** label: it
  names the HLO ops emitted under it, so profiler timelines and HLO
  dumps show ``fedlay_mix/round0`` or ``codec/int8-block/encode``
  instead of anonymous fusions.  Zero runtime cost — it exists only
  while tracing, so it is safe on the hottest path and cannot disturb
  fusion or retrace behavior.
* :func:`annotation` — ``jax.profiler.TraceAnnotation``.  A **runtime**
  host-side label for the profiler timeline (host rows).  Used at
  step/swap boundaries only (controller rebuilds, loop steps), never
  inside jitted code.

:func:`capture` wraps ``jax.profiler.trace``: pass a directory to get a
TensorBoard-loadable profile of the ``with`` body, pass None to no-op —
the shape behind ``launch/train.py --profile-dir``.

Everything degrades to a null context when jax (or the specific
profiler API) is unavailable, so importing this module never introduces
a hard jax dependency at module scope.
"""

from __future__ import annotations

from contextlib import contextmanager, nullcontext
from typing import ContextManager, Iterator, Optional


def scope(name: str) -> ContextManager:
    """``jax.named_scope(name)`` — label HLO emitted while tracing the
    ``with`` body.  Null context if jax is missing."""
    try:
        import jax
        return jax.named_scope(name)
    except Exception:
        return nullcontext()


def annotation(name: str, **kwargs) -> ContextManager:
    """``jax.profiler.TraceAnnotation`` — label a host-side block on
    the profiler timeline.  Null context when no profiler backend."""
    try:
        from jax.profiler import TraceAnnotation
        return TraceAnnotation(name, **kwargs)
    except Exception:
        return nullcontext()


@contextmanager
def capture(log_dir: Optional[str]) -> Iterator[None]:
    """Profile the ``with`` body into ``log_dir`` (TensorBoard format)
    via ``jax.profiler.trace``; no-op when ``log_dir`` is None/empty."""
    if not log_dir:
        yield
        return
    import jax
    with jax.profiler.trace(str(log_dir)):
        yield
