"""The per-round ledger: one joined record per training round.

Control-plane signals (schedule rebuilds, mixer hot-swaps, MixerCache
hit/miss, churn membership, repair and commit latency) and data-plane
facts (wire/payload bytes per client from the
:func:`repro.dist.sync.sync_bytes_per_client` closed forms, retrace
deltas from :class:`repro.runtime.loop.TraceCount`, masked loss and
participation) land in a single :class:`RoundRecord` per round, emitted
by whichever loop is driving training (:class:`~repro.runtime.loop.
SlotTrainLoop`, :class:`~repro.overlay.runtime.ChurnTrainLoop`,
:class:`~repro.scale.cohort.CohortStreamLoop`, or
:class:`~repro.core.dfl.Engine`).

A ledger can additionally be bound to a :class:`~repro.obs.events.
Telemetry` bus, in which case every record also carries the bus's
counter *deltas* since the previous record — ad-hoc counters added
anywhere in the stack show up per round with no ledger changes.

Export: :meth:`RoundLedger.to_jsonl` (one JSON object per line, the
``--telemetry-out`` format of ``launch/train.py``) and
:meth:`RoundLedger.summary_table` (the terminal table
``examples/quickstart.py`` prints).
"""

from __future__ import annotations

import dataclasses
import json
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

from .events import Telemetry, get_telemetry


@dataclasses.dataclass
class RoundRecord:
    """One training round, control plane joined with data plane.

    ``wire_bytes_per_client`` is what actually crosses links under the
    active codec; ``payload_bytes_per_client`` is the same traffic in
    uncompressed model bytes (their ratio is the codec's wire
    reduction).  ``retrace_delta`` is the number of fresh XLA traces
    this round — 0 after warmup is the zero-retrace guarantee, observed
    live.  ``repair_ms`` is the host-side schedule rebuild triggered by
    NDMP repair/churn (0 on quiescent rounds); ``commit_ms`` times the
    staged-swap commit at the step boundary.

    ``faults_injected`` counts the :mod:`repro.faults` injections
    (drops/delays/dups/crashes/partition events) that landed during the
    round; ``degraded_edges`` is how many directed data-plane edges the
    round's unreachable-edge mask zeroed — together they show what was
    injected vs. what the round actually had to survive."""

    round: int
    loop: str
    time: float = 0.0
    num_alive: int = 0
    participating: int = 0
    loss: float = float("nan")
    wire_bytes_per_client: float = 0.0
    payload_bytes_per_client: float = 0.0
    retraces: int = 0
    retrace_delta: int = 0
    swapped: bool = False
    rebuilt: bool = False
    cache_hit: bool = False
    joined: Tuple[int, ...] = ()
    left: Tuple[int, ...] = ()
    repair_ms: float = 0.0
    commit_ms: float = 0.0
    faults_injected: int = 0
    degraded_edges: int = 0
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["joined"] = list(self.joined)
        d["left"] = list(self.left)
        extra = d.pop("extra")
        for k, v in extra.items():
            d.setdefault(k, v)
        return d


_FIELDS = {f.name for f in dataclasses.fields(RoundRecord)} - {"extra"}


class RoundLedger:
    """Collects :class:`RoundRecord`\\ s for one run.

    ``bus`` (default: the process-global telemetry bus) supplies counter
    deltas: each :meth:`record` call diffs the bus's counters against
    the snapshot taken at the previous record and stores the non-zero
    deltas in the record's ``extra`` — so e.g. ``overlay.cache_misses``
    incremented during round k shows up on round k's row."""

    def __init__(self, bus: Optional[Telemetry] = None):
        self.bus = bus
        self.rows: List[RoundRecord] = []
        self._last_counters: Optional[Dict[str, float]] = None

    def _resolve_bus(self) -> Telemetry:
        return self.bus if self.bus is not None else get_telemetry()

    def record(self, **fields) -> RoundRecord:
        """Append one round.  Unknown keyword fields land in ``extra``;
        bus counter deltas since the last record are merged in under
        their counter names."""
        extra = dict(fields.pop("extra", {}))
        for key in list(fields):
            if key not in _FIELDS:
                extra[key] = fields.pop(key)
        bus = self._resolve_bus()
        if bus.enabled:
            now = bus.snapshot()
            prev = self._last_counters or {}
            for name, value in now.items():
                delta = value - prev.get(name, 0)
                if delta:
                    extra.setdefault(name, delta)
            self._last_counters = now
        rec = RoundRecord(extra=extra, **fields)
        self.rows.append(rec)
        return rec

    def __len__(self) -> int:
        return len(self.rows)

    # ---- export ----------------------------------------------------------
    def rows_as_dicts(self) -> List[Dict[str, Any]]:
        return [r.to_dict() for r in self.rows]

    def to_jsonl(self, path) -> int:
        """Write one JSON object per round (strict JSON: NaN losses
        become null); returns the row count."""
        with open(path, "w") as fh:
            for row in self.rows:
                d = {k: (None if isinstance(v, float) and v != v else v)
                     for k, v in row.to_dict().items()}
                fh.write(json.dumps(d, sort_keys=True,
                                    default=_jsonable) + "\n")
        return len(self.rows)

    def summary(self) -> Dict[str, Any]:
        """Whole-run aggregates (the shape benchmarks embed in their
        BENCH JSON next to the per-round rows)."""
        if not self.rows:
            return {"rounds": 0}
        rows = self.rows
        n = len(rows)
        wire = sum(r.wire_bytes_per_client for r in rows)
        payload = sum(r.payload_bytes_per_client for r in rows)
        losses = [r.loss for r in rows if r.loss == r.loss]  # drop NaN
        out = {
            "rounds": n,
            "loop": rows[-1].loop,
            "final_loss": losses[-1] if losses else None,
            "num_alive_last": rows[-1].num_alive,
            "retraces": rows[-1].retraces,
            "swaps": sum(1 for r in rows if r.swapped),
            "rebuilds": sum(1 for r in rows if r.rebuilt),
            "cache_hits": sum(1 for r in rows if r.cache_hit),
            "joins": sum(len(r.joined) for r in rows),
            "leaves": sum(len(r.left) for r in rows),
            "wire_mb_per_client": round(wire / 1e6, 6),
            "payload_mb_per_client": round(payload / 1e6, 6),
            "repair_ms_total": round(sum(r.repair_ms for r in rows), 3),
            "commit_ms_total": round(sum(r.commit_ms for r in rows), 3),
        }
        if wire and payload:
            out["wire_reduction"] = round(payload / wire, 3)
        return out

    def summary_table(self) -> str:
        """A terminal-friendly table of the run (header + aligned rows,
        capped at the last 20 rounds, plus a totals footer)."""
        cols = ("round", "alive", "part", "loss", "wire_kb", "retr",
                "swap", "hit", "repair_ms", "commit_ms", "churn")
        lines = [self._fmt_row(cols)]
        lines.append(self._fmt_row(("-" * len(c) for c in cols)))
        shown = self.rows[-20:]
        if len(self.rows) > len(shown):
            lines.append(f"  ... {len(self.rows) - len(shown)} earlier "
                         "rounds elided ...")
        for r in shown:
            churn = ""
            if r.joined:
                churn += f"+{len(r.joined)}"
            if r.left:
                churn += f"-{len(r.left)}"
            lines.append(self._fmt_row((
                r.round, r.num_alive, r.participating,
                f"{r.loss:.4f}" if r.loss == r.loss else "-",
                f"{r.wire_bytes_per_client / 1e3:.1f}",
                r.retrace_delta, "*" if r.swapped else "",
                "*" if r.cache_hit else "",
                f"{r.repair_ms:.2f}", f"{r.commit_ms:.2f}", churn)))
        s = self.summary()
        lines.append("")
        lines.append(
            f"rounds={s.get('rounds', 0)} retraces={s.get('retraces', 0)} "
            f"swaps={s.get('swaps', 0)} cache_hits={s.get('cache_hits', 0)} "
            f"joins={s.get('joins', 0)} leaves={s.get('leaves', 0)} "
            f"wire_mb/client={s.get('wire_mb_per_client', 0)}")
        return "\n".join(lines)

    @staticmethod
    def _fmt_row(cells) -> str:
        widths = (5, 5, 4, 9, 9, 4, 4, 3, 9, 9, 6)
        return "  ".join(str(c).rjust(w) for c, w in zip(cells, widths))


def _jsonable(obj):
    try:
        return float(obj)
    except Exception:
        return str(obj)


# ---- process-global ledger (mirrors the global telemetry bus) ------------

_LEDGER: Optional[RoundLedger] = None


def get_round_ledger() -> Optional[RoundLedger]:
    """The process-global ledger, or None (the default — loops only pay
    ledger bookkeeping when one is installed or passed explicitly)."""
    return _LEDGER


def set_round_ledger(ledger: Optional[RoundLedger]) -> Optional[RoundLedger]:
    global _LEDGER
    prev, _LEDGER = _LEDGER, ledger
    return prev


@contextmanager
def round_ledger(ledger: Optional[RoundLedger] = None
                 ) -> Iterator[RoundLedger]:
    """Scoped global ledger: install for the ``with`` body, restore the
    previous one on exit."""
    ledger = ledger if ledger is not None else RoundLedger()
    prev = set_round_ledger(ledger)
    try:
        yield ledger
    finally:
        set_round_ledger(prev)


@contextmanager
def disabled() -> Iterator[None]:
    """Force the fully-disabled state (NULL bus, no global ledger) for
    the ``with`` body — the control arm of overhead measurements."""
    from .events import set_telemetry
    prev_bus = set_telemetry(None)
    prev_ledger = set_round_ledger(None)
    try:
        yield
    finally:
        set_telemetry(prev_bus)
        set_round_ledger(prev_ledger)
