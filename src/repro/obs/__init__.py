"""`repro.obs` — the unified telemetry plane.

Every runtime layer of the stack reports into this package: the
:class:`~repro.obs.events.Telemetry` bus carries typed counters,
gauges, histograms, and span events; the
:class:`~repro.obs.rounds.RoundLedger` joins them with data-plane facts
into one record per training round; :mod:`repro.obs.profile` labels
device timelines and captures profiles.  The paper's practicality
claims (per-round communication cost, repair latency under churn,
convergence progress — FedLay §V/§VI) are all observable live through
this plane.

Observability contract
======================

**Disabled by default, zero-cost when disabled.**  The global bus is
the :data:`~repro.obs.events.NULL` no-op singleton and the global
ledger is ``None`` until a caller opts in (:func:`enable`,
``telemetry=...``, ``--telemetry-out``).  Instrumented code pays a
no-op method call (or a single ``is not None`` test) per *round*, never
per device op.

**Host-side only, at step/swap boundaries.**  Instruments are plain
Python updates recorded where the host already runs — controller
steps, commits, remaps, loop-step boundaries.  Nothing is branched or
called inside jitted code, so enabling telemetry cannot change traced
programs: the 0-retrace and kernel-fusion guarantees are byte-for-byte
untouched (the only in-trace construct is ``jax.named_scope``, which
exists at trace time only).  The end-to-end cost is gated < 2% of
steps/s by the ``telemetry_overhead`` axis of
``benchmarks/slot_runtime``.

**Event taxonomy.**  Names are ``<layer>.<signal>`` with unit suffixes
(``_ms``, ``_bytes``).  The layers currently emitting:

========================  ================================================
prefix                    signals
========================  ================================================
``overlay.*``             ``rebuilds``, ``swaps``, ``cache_hits``,
                          ``cache_misses``, ``churn_joins``,
                          ``churn_leaves``, ``rebuild_ms`` (histogram),
                          ``commit_ms`` (histogram)
``slot.*`` / ``churn.*``  ``steps``, ``remaps``, ``num_alive`` /
  / ``cohort.*``          ``participating`` (gauges), ``step_ms``
                          (span histogram), ``wire_bytes`` counter
``engine.*``              ``bytes_sent``, ``msgs_sent``, ``local_steps``,
                          ``suppressed``, ``evals``
``wire.*``                ``encodes``, ``decodes`` — ticked at *trace*
                          time (codec paths run inside jit), so they
                          count codec-program (re)compiles; zero in
                          steady state with a warm MixerCache
========================  ================================================

**Adding a counter** is one line at a host boundary::

    from ..obs import get_telemetry
    get_telemetry().count("overlay.my_signal")

No registration: the name shows up in :meth:`Telemetry.summary`, in
BENCH JSON telemetry blocks, and — as a per-round delta — in any
:class:`RoundLedger` bound to the bus.  Keep the ``<layer>.<signal>``
convention and unit suffixes so downstream joins stay mechanical.

**Per-round ledger.**  Loops accept ``ledger=`` (or pick up the global
one) and emit one :class:`RoundRecord` per round: wire/payload bytes
from the :func:`repro.dist.sync.sync_bytes_per_client` closed forms,
retrace deltas from :class:`~repro.runtime.loop.TraceCount`, cache
hit/miss and swap flags from the :class:`~repro.overlay.controller.
ControlReport`, repair (schedule rebuild) and commit latencies, churn
membership, masked loss/participation.  Export as JSONL
(``--telemetry-out``) or a terminal table (``summary_table()``).
"""

from .events import (NULL, NullTelemetry, Telemetry, TelemetryEvent,
                     disable, enable, get_telemetry, set_telemetry,
                     telemetry)
from .profile import annotation, capture, scope
from .rounds import (RoundLedger, RoundRecord, disabled, get_round_ledger,
                     round_ledger, set_round_ledger)

__all__ = [
    "NULL", "NullTelemetry", "Telemetry", "TelemetryEvent",
    "disable", "enable", "get_telemetry", "set_telemetry", "telemetry",
    "annotation", "capture", "scope",
    "RoundLedger", "RoundRecord", "disabled", "get_round_ledger",
    "round_ledger", "set_round_ledger",
]
