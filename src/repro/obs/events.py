"""The process-local telemetry bus: typed counters, gauges, histograms,
and span events on a monotonic clock.

One :class:`Telemetry` instance is a bag of named instruments plus an
append-only event log.  Every runtime layer reports into whichever bus
it was handed (or the process-global default, see :func:`get_telemetry`)
**host-side only**: instruments are plain Python dict/float updates at
step and swap boundaries, never inside jitted code, so enabling
telemetry cannot change trace behavior, fusion, or the zero-retrace
guarantees of :mod:`repro.runtime` / :mod:`repro.overlay`.

Disabled-by-default guarantee
-----------------------------
The global bus starts as :data:`NULL`, a no-op singleton whose methods
do nothing and allocate nothing (``enabled = False``).  Instrumented
code either calls the no-op methods directly (~a method call per round)
or guards bigger argument construction behind ``bus.enabled`` — both
are far below measurement noise per training step, and the telemetry
overhead benchmark (``benchmarks/slot_runtime``) gates the end-to-end
cost at < 2% of steps/s.

Clock
-----
All times come from :func:`time.perf_counter` (monotonic); events carry
seconds since bus creation, span durations are reported in
milliseconds.  Wall-clock timestamps are deliberately absent — stamp
them at export time if you need them.
"""

from __future__ import annotations

import dataclasses
import time
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional, Tuple

_CLOCK = time.perf_counter


@dataclasses.dataclass
class TelemetryEvent:
    """One point-in-time event: a name, seconds since bus creation, and
    free-form attributes (kept JSON-friendly by convention)."""

    name: str
    t: float
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "t": round(self.t, 6), **self.attrs}


class Histogram:
    """Streaming summary of an observed value (count/total/min/max).

    Deliberately not a bucketed histogram: the consumers here want
    per-round latency summaries and overhead accounting, and a four-
    float summary keeps ``observe`` allocation-free on the hot path."""

    __slots__ = ("count", "total", "min", "max")

    def __init__(self) -> None:
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def summary(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0}
        return {"count": self.count, "total": round(self.total, 6),
                "mean": round(self.mean, 6), "min": round(self.min, 6),
                "max": round(self.max, 6)}


class Telemetry:
    """A live telemetry bus.

    * :meth:`count` — monotone counters (``"overlay.cache_hits"``);
    * :meth:`gauge` — last-write-wins values (``"slot.num_alive"``);
    * :meth:`observe` — histogram samples (``"overlay.rebuild_ms"``);
    * :meth:`event` — timestamped structured events;
    * :meth:`span` — a context manager timing a host-side block, which
      feeds both a ``<name>.ms`` histogram and (optionally) an event.

    Naming convention: ``<layer>.<signal>`` with ``_ms`` / ``_bytes``
    suffixes on units — the round ledger (:mod:`repro.obs.rounds`)
    joins counter *deltas* per round by these names, and
    ``benchmarks/run.py`` snapshots :meth:`summary` into BENCH JSON.
    Adding a new signal is one call at a step/swap boundary; no schema
    registration needed.
    """

    enabled = True

    def __init__(self, *, max_events: int = 100_000):
        self.t0 = _CLOCK()
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, Histogram] = {}
        self.events: List[TelemetryEvent] = []
        self.max_events = max_events
        self.dropped_events = 0

    # ---- instruments -----------------------------------------------------
    def count(self, name: str, n: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        hist = self.histograms.get(name)
        if hist is None:
            hist = self.histograms[name] = Histogram()
        hist.observe(value)

    def event(self, name: str, **attrs) -> None:
        if len(self.events) >= self.max_events:
            self.dropped_events += 1
            return
        self.events.append(TelemetryEvent(name, _CLOCK() - self.t0, attrs))

    @contextmanager
    def span(self, name: str, **attrs) -> Iterator[None]:
        """Time a host-side block into the ``<name>.ms`` histogram (and
        an event when attributes are given)."""
        t0 = _CLOCK()
        try:
            yield
        finally:
            ms = (_CLOCK() - t0) * 1e3
            self.observe(name + ".ms", ms)
            if attrs:
                self.event(name, ms=round(ms, 4), **attrs)

    # ---- export ----------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        """A copy of the counter values — round ledgers diff successive
        snapshots to attribute control-plane activity per round."""
        return dict(self.counters)

    def summary(self) -> Dict[str, Any]:
        """JSON-friendly totals: counters, gauges, histogram summaries,
        and event counts (the shape BENCH JSON embeds)."""
        out: Dict[str, Any] = {}
        if self.counters:
            out["counters"] = {k: self.counters[k]
                               for k in sorted(self.counters)}
        if self.gauges:
            out["gauges"] = {k: self.gauges[k] for k in sorted(self.gauges)}
        if self.histograms:
            out["histograms"] = {k: self.histograms[k].summary()
                                 for k in sorted(self.histograms)}
        if self.events:
            out["num_events"] = len(self.events)
        if self.dropped_events:
            out["dropped_events"] = self.dropped_events
        return out


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTelemetry(Telemetry):
    """The disabled bus: every method is a no-op and nothing is ever
    allocated.  This is the process-global default — telemetry is
    strictly opt-in (:func:`enable` / an explicit ``telemetry=``)."""

    enabled = False

    def __init__(self):  # no state at all
        pass

    def count(self, name, n=1):
        pass

    def gauge(self, name, value):
        pass

    def observe(self, name, value):
        pass

    def event(self, name, **attrs):
        pass

    def span(self, name, **attrs):
        return _NULL_SPAN

    def snapshot(self):
        return {}

    def summary(self):
        return {}


#: The no-op singleton every layer sees until telemetry is enabled.
NULL = NullTelemetry()

_BUS: Telemetry = NULL


def get_telemetry() -> Telemetry:
    """The process-global bus (:data:`NULL` unless :func:`enable`\\ d)."""
    return _BUS


def set_telemetry(bus: Optional[Telemetry]) -> Telemetry:
    """Install ``bus`` (``None`` → :data:`NULL`) as the global bus and
    return the previous one."""
    global _BUS
    prev, _BUS = _BUS, (bus if bus is not None else NULL)
    return prev


def enable(bus: Optional[Telemetry] = None) -> Telemetry:
    """Turn the global bus on (a fresh :class:`Telemetry` unless one is
    given) and return it."""
    bus = bus if bus is not None else Telemetry()
    set_telemetry(bus)
    return bus


def disable() -> None:
    """Restore the disabled-by-default global state."""
    set_telemetry(None)


@contextmanager
def telemetry(bus: Optional[Telemetry] = None
              ) -> Iterator[Telemetry]:
    """Scoped :func:`enable`: install a bus for the ``with`` body and
    restore the previous global bus on exit (benchmark/test currency)."""
    bus = bus if bus is not None else Telemetry()
    prev = set_telemetry(bus)
    try:
        yield bus
    finally:
        set_telemetry(prev)
