"""jit'd front doors for the Pallas kernels.

``interpret`` defaults to auto: real TPU → compiled kernel; anything
else (this CPU container, tests) → ``interpret=True``, which executes
the kernel body in Python per grid cell — bit-accurate to the lowered
semantics, so the sweep tests validate the real kernel logic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_decode import flash_decode as _flash_decode
from .ssd_scan import ssd_scan as _ssd_scan
from .weighted_mix import weighted_mix as _weighted_mix


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def weighted_mix(models, weights, block_n: int = 65536,
                 interpret: bool | None = None):
    interp = _auto_interpret() if interpret is None else interpret
    return _weighted_mix(models, weights, block_n=block_n, interpret=interp)


@functools.partial(jax.jit, static_argnames=("block_l", "interpret"))
def flash_decode(q, k_cache, v_cache, pos, block_l: int = 512,
                 interpret: bool | None = None):
    interp = _auto_interpret() if interpret is None else interpret
    return _flash_decode(q, k_cache, v_cache, pos, block_l=block_l,
                         interpret=interp)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, chunk: int = 256,
             interpret: bool | None = None):
    interp = _auto_interpret() if interpret is None else interpret
    return _ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=interp)
