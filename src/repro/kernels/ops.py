"""jit'd front doors for the Pallas kernels.

``interpret`` defaults to auto everywhere — real TPU → compiled kernel;
anything else (this CPU container, tests) → ``interpret=True``, which
executes the kernel body per grid cell with plain jax ops —
bit-accurate to the lowered semantics, so the sweep tests validate the
real kernel logic.  The detection itself lives in
:func:`repro.kernels.interpret.resolve_interpret` and is applied inside
each kernel module, so direct kernel imports (the fused mixing hot path
in :mod:`repro.dist.sync`) get the same auto behavior as these jit
wrappers; passing ``interpret=None`` here simply forwards the auto
default.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_decode import flash_decode as _flash_decode
from .ssd_scan import ssd_scan as _ssd_scan
from .weighted_mix import weighted_mix as _weighted_mix


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def weighted_mix(models, weights, *, mask=None, block_n: int | None = None,
                 interpret: bool | None = None):
    # mask is keyword-only so the historical positional third argument
    # (block_n) can never be silently reinterpreted as a mask
    return _weighted_mix(models, weights, mask=mask, block_n=block_n,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_l", "interpret"))
def flash_decode(q, k_cache, v_cache, pos, block_l: int = 512,
                 interpret: bool | None = None):
    return _flash_decode(q, k_cache, v_cache, pos, block_l=block_l,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, chunk: int = 256,
             interpret: bool | None = None):
    return _ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
