"""Pallas TPU kernel: Mamba2 SSD chunked scan (state-space duality).

One (batch, head) pair per grid row; the chunk dimension is the
innermost (sequential) grid axis with the (P, N) recurrent state carried
in VMEM scratch.  Per chunk the kernel does exactly the SSD dual-form
work — three small matmuls on the MXU:

  scores  = (C·Bᵀ) ∘ L          (Q×Q, decay-masked)
  y_intra = scores · (dt∘x)     (Q×P)
  y_inter = (C·state) ∘ exp(cs) (Q×P)
  state'  = decay·state + Bᵀ·(dt∘exp(cs_end−cs)∘x)   (N×P → kept (P,N))

Q (chunk) and P (headdim) are 64/128-aligned so every contraction lands
on the MXU; VMEM per grid cell is O(Q·(P+N) + Q² + P·N) — a few hundred
KiB at the assigned sizes (Q=256, P=64..128, N=128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .interpret import resolve_interpret
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(nchunks, x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref,
                state_s):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_s[...] = jnp.zeros_like(state_s)

    x = x_ref[0, 0, 0].astype(jnp.float32)               # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)             # (Q, 1)
    a = a_ref[0, 0, 0, 0]                                # scalar A (negative)
    bm = b_ref[0, 0].astype(jnp.float32)                 # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)                 # (Q, N)

    da = dt * a                                          # (Q, 1) log-decay
    cs = jnp.cumsum(da, axis=0)                          # (Q, 1) inclusive
    xdt = x * dt                                         # (Q, P)

    # intra-chunk: L[i,j] = exp(cs_i - cs_j) for j <= i
    seg = cs - cs.T                                      # (Q, Q)
    q = seg.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    seg = jnp.where(jj <= ii, seg, -jnp.inf)
    decay = jnp.exp(seg)
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * decay
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    state = state_s[...]                                 # (P, N)
    y += jnp.exp(cs) * jax.lax.dot_general(
        cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # (Q, P)

    # state update: state' = exp(cs_end)·state + Σ_j w_j · x_j ⊗ B_j
    w = jnp.exp(cs[-1:] - cs)                            # (Q, 1) decay to end
    new_state = jnp.exp(cs[-1, 0]) * state + jax.lax.dot_general(
        xdt * w, bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)              # (P, N)
    state_s[...] = new_state

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int = 256,
             interpret: bool | None = None) -> jnp.ndarray:
    """x: (B, S, H, P); dt: (B, S, H) post-softplus; A: (H,) negative;
    Bm/Cm: (B, S, N) single-group.  Returns y (B, S, H, P).

    S must be a multiple of ``chunk`` (callers pad); state starts at 0.
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q

    xh = x.transpose(0, 2, 1, 3).reshape(Bsz, H, nc, Q, P)
    dth = dt.transpose(0, 2, 1).reshape(Bsz, H, nc, Q, 1)
    a2 = A.reshape(1, H, 1, 1)
    bh = Bm.reshape(Bsz, nc, Q, N)
    ch = Cm.reshape(Bsz, nc, Q, N)

    kern = functools.partial(_ssd_kernel, nc)
    y = pl.pallas_call(
        kern,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, 1), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda b, h, c: (0, h, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, H, nc, Q, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(xh, dth, a2, bh, ch)
    return y.reshape(Bsz, H, S, P).transpose(0, 2, 1, 3)
