"""Pallas quantize/dequantize kernels for the wire codecs.

The wire-compression subsystem (:mod:`repro.wire.codec`) compresses the
flat-row gossip payload of :mod:`repro.dist.sync`'s ``fuse="flat"``
paths.  Its hot codec — ``int8-block`` symmetric per-block quantization
— is implemented here as a kernel pair plus two **fused receive**
entries, so the decompressed model never exists in HBM:

* :func:`quantize_block` — encode: per-block symmetric scales
  ``s = max|block| / levels`` (stored in ``scale_dtype``, typically
  bf16) and ``q = round(x / s) ∈ [-levels, levels]`` as int8.  With
  ``with_residual=True`` the error-feedback residual ``x - q·s`` is
  produced *in the same kernel* while the input tile is live in VMEM —
  the EF update never re-reads or re-decodes the encoded buffer.
* :func:`dequantize_block` — the standalone decode (tests, generic
  codec fallbacks): ``q·s`` broadcast per block.
* :func:`dequant_accumulate` — the fused receive of the shard_map
  mixing path: ``acc + w[:, None] · dequant(q, s)`` in one kernel, the
  int8 sibling of :func:`repro.kernels.weighted_mix.mix_accumulate`.
  Each ppermute-received *compressed* row folds straight into the f32
  accumulator; only {own, acc, current int8 receive} are ever live, and
  the decompressed 2L stack is never materialized.
* :func:`gather_mix_int8` — the fused receive of the global round-matrix
  path: the int8 sibling of
  :func:`repro.kernels.weighted_mix.gather_mix`.  Each (C, bn) column
  tile of the *compressed* population buffer is dequantized in VMEM and
  immediately consumed by the stationary ``W @ tile`` matmul — HBM reads
  the int8 payload (4× fewer bytes than f32), HBM writes only the f32
  output.

**Block layout contract** (shared with :mod:`repro.wire.codec`): an
(B, N) f32 buffer is split along columns into ``NB = ceil(N / block)``
blocks of ``block`` elements (the tail zero-padded — zeros quantize to
0 and decode to 0, so padding is exact); ``q`` is (B, NB·block) int8
and ``scales`` (B, NB) with ``scales[b, j]`` scaling columns
``j·block : (j+1)·block``.  Quantization uses the *stored* (rounded to
``scale_dtype``) scale, so encode and decode agree exactly and the
error is bounded by ``s/2 ≤ max|block|/(2·levels) · (1 + ε_scale)`` per
element.  All-zero blocks store scale 0 and decode to exact zeros; a
stored scale that underflows to 0 quantizes through a safe scale of 1
(q rounds to 0, the residual carries the value).

Grids are 1-D over lane-aligned column tiles sized by the shared ~2 MB
budget of :func:`repro.kernels.weighted_mix._default_block_n`, rounded
to a multiple of lcm(block, LANE) so per-tile scale columns stay whole;
interpret mode (the CPU test mesh) runs a single cell.  The compiled
TPU path wants ``block`` a multiple of :data:`~repro.kernels.weighted_mix.LANE`
(the int8 min tile is (32, 128) — see the accelerator guide);
odd block sizes still work everywhere interpret mode runs.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .interpret import resolve_interpret
from .weighted_mix import LANE, _default_block_n, round_matrix


def padded_width(n: int, block: int) -> int:
    """The wire width of an ``n``-column buffer: ``ceil(n/block)·block``
    — what :func:`quantize_block` actually puts on the wire."""
    if block < 1:
        raise ValueError("block must be >= 1")
    return -(-n // block) * block


def _pad_cols(x: jnp.ndarray, width: int) -> jnp.ndarray:
    pad = width - x.shape[1]
    return jnp.pad(x, ((0, 0), (0, pad))) if pad else x


def _tile_width(np_: int, rows: int, block: int, interp: bool) -> int:
    """Columns per grid cell: the whole (block-padded) width in
    interpret mode; else the largest power-of-two multiple of
    lcm(block, LANE) dividing ``np_`` within the ~2 MB budget."""
    if interp:
        return np_
    unit = block * LANE // math.gcd(block, LANE)
    if np_ % unit:
        return np_                      # odd geometry: single cell
    budget = _default_block_n(np_, rows, False)
    bn = unit
    while bn * 2 <= min(budget, np_) and np_ % (bn * 2) == 0:
        bn *= 2
    return bn


def quantize_block(x: jnp.ndarray, *, block: int = 128, levels: int = 127,
                   scale_dtype=jnp.bfloat16, with_residual: bool = False,
                   interpret: Optional[bool] = None):
    """Encode ``x`` (B, N) float → ``(q, scales[, residual])``.

    ``q`` (B, NB·block) int8 in [-levels, levels]; ``scales`` (B, NB)
    in ``scale_dtype`` (the stored scale — decode multiplies by exactly
    this, so the pair is self-consistent); ``residual`` (B, N) f32
    ``x - q·s`` when ``with_residual`` (the error-feedback term, fused
    so the decode never re-runs).  See the module docstring for the
    block layout contract.
    """
    interp = resolve_interpret(interpret)
    B, N = x.shape
    if levels < 1:
        raise ValueError("levels must be >= 1")
    sdt = jnp.dtype(scale_dtype)
    Np = padded_width(N, block)
    xs = _pad_cols(x.astype(jnp.float32), Np)
    bn = _tile_width(Np, B, block, interp)
    nb = bn // block

    def kernel(x_ref, q_ref, s_ref, *res_ref):
        xv = x_ref[...].astype(jnp.float32).reshape(B, nb, block)
        amax = jnp.max(jnp.abs(xv), axis=2)                 # (B, nb)
        s = (amax / levels).astype(sdt)                     # stored scale
        s_used = jnp.where(s.astype(jnp.float32) > 0,
                           s.astype(jnp.float32), 1.0)
        q = jnp.clip(jnp.round(xv / s_used[:, :, None]), -levels, levels)
        q_ref[...] = q.reshape(B, bn).astype(jnp.int8)
        s_ref[...] = s
        if res_ref:
            res_ref[0][...] = (xv - q * s_used[:, :, None]).reshape(B, bn)

    row_spec = pl.BlockSpec((B, bn), lambda i: (0, i))
    s_spec = pl.BlockSpec((B, nb), lambda i: (0, i))
    out_shape = [jax.ShapeDtypeStruct((B, Np), jnp.int8),
                 jax.ShapeDtypeStruct((B, Np // block), sdt)]
    out_specs = [row_spec, s_spec]
    if with_residual:
        out_shape.append(jax.ShapeDtypeStruct((B, Np), jnp.float32))
        out_specs.append(row_spec)
    out = pl.pallas_call(
        kernel, grid=(Np // bn,), in_specs=[row_spec],
        out_specs=out_specs, out_shape=out_shape, interpret=interp)(xs)
    if with_residual:
        return out[0], out[1], out[2][:, :N]
    return out[0], out[1]


def dequantize_block(q: jnp.ndarray, scales: jnp.ndarray, *,
                     block: int = 128,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Decode ``(q, scales)`` → (B, NB·block) f32 (the standalone half
    of the pair; the mixing paths prefer the fused
    :func:`dequant_accumulate` / :func:`gather_mix_int8` receives)."""
    interp = resolve_interpret(interpret)
    B, Nq = q.shape
    if Nq % block or scales.shape != (B, Nq // block):
        raise ValueError(
            f"q {q.shape} / scales {scales.shape} do not agree with "
            f"block {block}")
    bn = _tile_width(Nq, B, block, interp)
    nb = bn // block

    def kernel(q_ref, s_ref, out_ref):
        s = s_ref[...].astype(jnp.float32)
        deq = q_ref[...].astype(jnp.float32).reshape(B, nb, block) \
            * s[:, :, None]
        out_ref[...] = deq.reshape(B, bn)

    out = pl.pallas_call(
        kernel, grid=(Nq // bn,),
        in_specs=[pl.BlockSpec((B, bn), lambda i: (0, i)),
                  pl.BlockSpec((B, nb), lambda i: (0, i))],
        out_specs=pl.BlockSpec((B, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((B, Nq), jnp.float32),
        interpret=interp)(q, scales)
    return out


def dequant_accumulate(acc: Optional[jnp.ndarray], q: jnp.ndarray,
                       scales: jnp.ndarray, w: jnp.ndarray, *,
                       block: int = 128,
                       interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused dequantize + mixing accumulate:
    ``acc + w[:, None] · dequant(q, scales)`` over (B, N) rows — the
    int8 receive of the shard_map flat path.  The dequantized tile
    exists only in VMEM while the accumulator tile is live; ``acc=None``
    is the init form ``w[:, None] · dequant(q, scales)``.  Returns
    (B, N) where N = acc's width (≤ the wire width; the wire's
    block-padding columns are dropped), or the full wire width for
    ``acc=None``."""
    interp = resolve_interpret(interpret)
    B, Nq = q.shape
    if Nq % block or scales.shape != (B, Nq // block):
        raise ValueError(
            f"q {q.shape} / scales {scales.shape} do not agree with "
            f"block {block}")
    bn = _tile_width(Nq, B, block, interp)
    nb = bn // block
    w2 = w.reshape(B, 1).astype(jnp.float32)
    N = Nq if acc is None else acc.shape[1]
    if N > Nq:
        raise ValueError(f"acc width {N} exceeds wire width {Nq}")

    def kernel(*refs):
        if acc is None:
            q_ref, s_ref, w_ref, out_ref = refs
            base = 0.0
        else:
            acc_ref, q_ref, s_ref, w_ref, out_ref = refs
            base = acc_ref[...].astype(jnp.float32)
        s = s_ref[...].astype(jnp.float32)
        deq = q_ref[...].astype(jnp.float32).reshape(B, nb, block) \
            * s[:, :, None]
        out_ref[...] = (base + w_ref[...] * deq.reshape(B, bn)).astype(
            out_ref.dtype)

    row_spec = pl.BlockSpec((B, bn), lambda i: (0, i))
    s_spec = pl.BlockSpec((B, nb), lambda i: (0, i))
    w_spec = pl.BlockSpec((B, 1), lambda i: (0, 0))
    if acc is None:
        out = pl.pallas_call(
            kernel, grid=(Nq // bn,),
            in_specs=[row_spec, s_spec, w_spec], out_specs=row_spec,
            out_shape=jax.ShapeDtypeStruct((B, Nq), jnp.float32),
            interpret=interp)(q, scales, w2)
        return out
    accs = _pad_cols(acc, Nq)
    out = pl.pallas_call(
        kernel, grid=(Nq // bn,),
        in_specs=[row_spec, row_spec, s_spec, w_spec], out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((B, Nq), acc.dtype),
        interpret=interp)(accs, q, scales, w2)
    return out[:, :N]


def gather_mix_int8(q: jnp.ndarray, scales: jnp.ndarray, srcs,
                    weights: jnp.ndarray, *, block: int = 128,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """Int8-aware round-matrix mixing: the compressed-population sibling
    of :func:`repro.kernels.weighted_mix.gather_mix`.

    ``q``/``scales`` are the :func:`quantize_block` encoding of the
    (C, N) population buffer; ``srcs``/``weights`` the (C, K1) source
    rows (host-static or traced) and runtime weights.  The (srcs,
    weights) table scatters into the dense (C, C) round matrix W and
    each column tile runs dequantize → ``W @ tile`` with the
    dequantized tile never leaving VMEM.  HBM traffic: C·N int8 + C·NB
    scales read, C·N f32 written — the read side is ~4× lighter than
    the uncompressed kernel.  Returns (C, NB·block) f32 (block-padded
    wire width; callers slice to N)."""
    interp = resolve_interpret(interpret)
    C, Nq = q.shape
    if Nq % block or scales.shape != (C, Nq // block):
        raise ValueError(
            f"q {q.shape} / scales {scales.shape} do not agree with "
            f"block {block}")
    W = round_matrix(C, srcs, weights)
    bn = _tile_width(Nq, C, block, interp)
    nb = bn // block

    def kernel(W_ref, q_ref, s_ref, out_ref):
        s = s_ref[...].astype(jnp.float32)
        deq = q_ref[...].astype(jnp.float32).reshape(C, nb, block) \
            * s[:, :, None]
        out_ref[...] = jnp.dot(W_ref[...], deq.reshape(C, bn),
                               preferred_element_type=jnp.float32)

    out = pl.pallas_call(
        kernel, grid=(Nq // bn,),
        in_specs=[pl.BlockSpec((C, C), lambda i: (0, 0)),
                  pl.BlockSpec((C, bn), lambda i: (0, i)),
                  pl.BlockSpec((C, nb), lambda i: (0, i))],
        out_specs=pl.BlockSpec((C, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((C, Nq), jnp.float32),
        interpret=interp)(W, q, scales)
    return out
