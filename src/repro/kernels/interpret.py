"""Interpret-mode resolution shared by every Pallas kernel entry point.

Pallas TPU kernels only *compile* on a real TPU backend; everywhere else
(this CPU container, tier-1 CI) they must run with ``interpret=True``,
which executes the lowered kernel semantics with plain jax ops —
bit-accurate, traceable under ``jit``/``shard_map``, just slower.

Historically the auto-detection lived only in the ``repro.kernels.ops``
jit wrappers, so any caller importing a kernel module directly (the
fused mixing hot path in :mod:`repro.dist.sync` does) hit the raw
``interpret=False`` default and died on CPU with "Only interpret mode is
supported on CPU backend".  Every kernel entry now defaults
``interpret=None`` and resolves it here, so the Pallas kernels run
(interpreted) in tier-1 without callers threading the flag.
"""

from __future__ import annotations

from typing import Optional

import jax


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """``None`` → auto: compiled on a real TPU backend, interpreted
    everywhere else.  An explicit bool always wins (tests force
    ``interpret=True`` to pin the interpreted semantics)."""
    if interpret is not None:
        return bool(interpret)
    return jax.default_backend() != "tpu"
