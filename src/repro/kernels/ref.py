"""Pure-jnp oracles for every Pallas kernel — the ground truth the
shape/dtype sweep tests assert against (``interpret=True`` kernel vs
these references, ``assert_allclose``)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def weighted_mix_ref(models: jnp.ndarray, weights: jnp.ndarray,
                     mask=None) -> jnp.ndarray:
    """models (K, N), weights (K,) → Σ_k w_k·models_k, in models.dtype.

    With ``mask`` (K,): drop masked-out models and renormalize the
    surviving weights (all-masked → zeros), mirroring the kernel's
    masked variant."""
    w = weights.astype(jnp.float32)
    if mask is not None:
        eff = w * mask.astype(jnp.float32)
        total = jnp.sum(eff)
        w = jnp.where(total > 0, eff / jnp.where(total > 0, total, 1.0),
                      jnp.zeros_like(eff))
    acc = jnp.sum(models.astype(jnp.float32) * w[:, None], axis=0)
    return acc.astype(models.dtype)


def mix_accumulate_ref(acc, x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """acc (B, N) or None, x (B, N), w (B,) → acc + w·x (w·x when acc is
    None), f32 math, cast to x (resp. acc) dtype."""
    wx = x.astype(jnp.float32) * w.astype(jnp.float32)[:, None]
    if acc is None:
        return wx.astype(x.dtype)
    return (acc.astype(jnp.float32) + wx).astype(acc.dtype)


def gather_mix_ref(buf: jnp.ndarray, srcs, weights: jnp.ndarray) -> jnp.ndarray:
    """buf (C, N), srcs (C, K1) static ints, weights (C, K1) →
    out[i] = Σ_k weights[i, k]·buf[srcs[i, k]], in buf.dtype."""
    gathered = buf.astype(jnp.float32)[jnp.asarray(srcs)]      # (C, K1, N)
    acc = jnp.sum(gathered * weights.astype(jnp.float32)[..., None], axis=1)
    return acc.astype(buf.dtype)


def flash_decode_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, pos) -> jnp.ndarray:
    """q (B, Hq, hd) vs caches (B, L, Hkv, hd), prefix-valid ≤ pos.

    ``pos`` is a scalar or a per-slot (B,) vector; rows with pos < 0
    are empty serving slots and come back exactly zero (the softmax row
    is multiplied by its validity mask, matching the kernel)."""
    B, Hq, hd = q.shape
    L, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bhgd,blhd->bhgl", qg, k_cache.astype(jnp.float32))
    s = s * (hd ** -0.5)
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (B,))
    valid = jnp.arange(L, dtype=jnp.int32)[None, :] <= pos[:, None]  # (B, L)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1) * valid[:, None, None, :]
    out = jnp.einsum("bhgl,blhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, hd).astype(q.dtype)


def ssd_scan_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                 Bm: jnp.ndarray, Cm: jnp.ndarray) -> jnp.ndarray:
    """Sequential SSD recurrence (the definitionally-correct oracle).

    x (B,S,H,P); dt (B,S,H); A (H,); Bm/Cm (B,S,N) → y (B,S,H,P).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(state, inputs):
        xt, dtt, bt, ct = inputs           # (B,H,P), (B,H), (B,N), (B,N)
        da = jnp.exp(dtt * A[None, :])     # (B,H)
        dbx = jnp.einsum("bhp,bn,bh->bhpn", xt, bt, dtt)
        state = state * da[:, :, None, None] + dbx
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    s0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2).astype(jnp.float32),
          Cm.transpose(1, 0, 2).astype(jnp.float32))
    _, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)
