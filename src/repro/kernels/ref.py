"""Pure-jnp oracles for every Pallas kernel — the ground truth the
shape/dtype sweep tests assert against (``interpret=True`` kernel vs
these references, ``assert_allclose``)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def weighted_mix_ref(models: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """models (K, N), weights (K,) → Σ_k w_k·models_k, in models.dtype."""
    acc = jnp.sum(models.astype(jnp.float32)
                  * weights.astype(jnp.float32)[:, None], axis=0)
    return acc.astype(models.dtype)


def flash_decode_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, pos) -> jnp.ndarray:
    """q (B, Hq, hd) vs caches (B, L, Hkv, hd), prefix-valid ≤ pos."""
    B, Hq, hd = q.shape
    L, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bhgd,blhd->bhgl", qg, k_cache.astype(jnp.float32))
    s = s * (hd ** -0.5)
    valid = jnp.arange(L) <= pos
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgl,blhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, hd).astype(q.dtype)


def ssd_scan_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                 Bm: jnp.ndarray, Cm: jnp.ndarray) -> jnp.ndarray:
    """Sequential SSD recurrence (the definitionally-correct oracle).

    x (B,S,H,P); dt (B,S,H); A (H,); Bm/Cm (B,S,N) → y (B,S,H,P).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(state, inputs):
        xt, dtt, bt, ct = inputs           # (B,H,P), (B,H), (B,N), (B,N)
        da = jnp.exp(dtt * A[None, :])     # (B,H)
        dbx = jnp.einsum("bhp,bn,bh->bhpn", xt, bt, dtt)
        state = state * da[:, :, None, None] + dbx
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    s0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          Bm.transpose(1, 0, 2).astype(jnp.float32),
          Cm.transpose(1, 0, 2).astype(jnp.float32))
    _, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)
