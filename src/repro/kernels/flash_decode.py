"""Pallas TPU kernel: single-token GQA flash-decode attention.

The serving hot spot for ``decode_32k`` / ``long_500k`` and the
continuous-batching serving plane (:mod:`repro.runtime.serving`): one
query token per sequence against a (L, Hkv, hd) KV cache.  Memory-bound
— the whole cache streams through VMEM once; the online-softmax
accumulator lives in VMEM scratch so nothing O(L) is ever written back
to HBM:

  HBM traffic = 2 · L · hd · sizeof(dtype) per (batch, kv-head)  (optimal)

Grid: (B, Hkv, L/BL) with the L dimension innermost (sequential):
scratch m/l/acc carry across L blocks; the (G, hd) output tile is
written once on the last block.  BL is lane-aligned (multiples of 128;
``pick_block_l`` — a bare ``min(block_l, L)`` was TPU-invalid for
128 < L < block_l with L % 128 != 0, the same class of bug as the PR 3
``weighted_mix`` tile); the q·Kᵀ and p·V contractions are
(G, hd)×(hd, BL) and (G, BL)×(BL, hd) matmuls that feed the MXU when
G ≥ 8 — exactly the GQA regime of the assigned architectures.

Per-slot positions
------------------
``pos`` is either a scalar (legacy whole-batch position) or a ``(B,)``
vector carrying each batch row's own absolute position — the contract
continuous batching needs, where every request slot sits at a different
decode depth.  Rows with ``pos < 0`` are **empty slots**: every cache
entry is masked invalid and the output row is exactly zero (the online
softmax multiplies the probability tile by the validity mask, so an
all-masked row accumulates l = 0 instead of the uniform-weight garbage
a plain ``exp(s - max)`` would produce).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .interpret import resolve_interpret
from .weighted_mix import LANE, aligned_block_n
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def pick_block_l(L: int, block_l: int) -> int:
    """The lane-aligned KV block actually used for an L-slot cache: the
    smallest multiple of 128 covering L, capped at ``block_l`` (itself
    rounded up to a lane multiple)."""
    return aligned_block_n(L, block_l, lane=LANE)


def _decode_kernel(nblocks, block_l, q_ref, k_ref, v_ref, pos_ref, o_ref,
                   m_s, l_s, acc_s):
    li = pl.program_id(2)

    @pl.when(li == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)                  # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (BL, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    hd = q.shape[-1]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * (hd ** -0.5)                                 # (G, BL)

    # validity: absolute slot index <= this row's pos (prefix-cache
    # semantics; pos < 0 masks the whole row — empty serving slot)
    pos = pos_ref[0, 0]
    idx = li * block_l + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = idx <= pos
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev, acc_prev = m_s[...], l_s[...], acc_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))   # (G, 1)
    # multiply by the mask: on an all-invalid block s - m_new == 0, and
    # a bare exp would contribute uniform weight 1 per masked entry
    p = jnp.exp(s - m_new) * valid.astype(jnp.float32)            # (G, BL)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
    acc_new = acc_prev * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_s[...], l_s[...], acc_s[...] = m_new, l_new, acc_new

    @pl.when(li == nblocks - 1)
    def _emit():
        o_ref[0, 0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)
                       ).astype(o_ref.dtype)


def flash_decode(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                 pos: jnp.ndarray, block_l: int = 512,
                 interpret: bool | None = None) -> jnp.ndarray:
    """q: (B, Hq, hd); caches: (B, L, Hkv, hd); pos: scalar or (B,) int32.

    Returns (B, Hq, hd).  Slots with index > pos are masked (prefix
    semantics; ring-buffer windows pass pos = L-1 once the buffer is
    full); rows with pos < 0 are empty slots and come back exactly
    zero.  L is padded to a lane-aligned block multiple internally.
    """
    B, Hq, hd = q.shape
    _, L, Hkv, _ = k_cache.shape
    if Hkv < 1 or Hq % Hkv:
        raise ValueError(
            f"flash_decode requires Hq to be an integer multiple of Hkv "
            f"(GQA query groups); got Hq={Hq}, Hkv={Hkv}")
    G = Hq // Hkv
    bl = pick_block_l(L, block_l)
    pad = (-L) % bl
    if pad:
        zk = jnp.zeros((B, pad, Hkv, hd), k_cache.dtype)
        k_cache = jnp.concatenate([k_cache, zk], axis=1)
        v_cache = jnp.concatenate([v_cache, zk], axis=1)
    Lp = k_cache.shape[1]
    nblocks = Lp // bl

    qg = q.reshape(B, Hkv, G, hd)
    kc = k_cache.transpose(0, 2, 1, 3)                   # (B, Hkv, Lp, hd)
    vc = v_cache.transpose(0, 2, 1, 3)
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim > 1 or (pos.ndim == 1 and pos.shape[0] != B):
        raise ValueError(
            f"pos must be a scalar or a ({B},) per-slot vector, got shape "
            f"{pos.shape}")
    pos2 = jnp.broadcast_to(pos.reshape(-1), (B,)).reshape(B, 1)

    kern = functools.partial(_decode_kernel, nblocks, bl)
    out = pl.pallas_call(
        kern,
        grid=(B, Hkv, nblocks),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, l: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bl, hd), lambda b, h, l: (b, h, l, 0)),
            pl.BlockSpec((1, 1, bl, hd), lambda b, h, l: (b, h, l, 0)),
            pl.BlockSpec((1, 1), lambda b, h, l: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, l: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(qg, kc, vc, pos2)
    return out.reshape(B, Hq, hd)
