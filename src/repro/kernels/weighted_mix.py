"""Pallas TPU kernels: MEP confidence-weighted model aggregation.

The FedLay/MEP hot path on device is ``w_u ← Σ_k c_k · W_k`` over the
own model plus the (up to 2L) neighbor models received via ppermute —
a purely memory-bound reduction over K same-shaped parameter vectors.
A naive jnp implementation materializes a full-model temporary per
neighbor; the kernels here stream lane-aligned tiles through VMEM and
write each output tile exactly once.  Three entries, matching the three
shapes the mixing paths produce (see :mod:`repro.dist.sync`):

* :func:`weighted_mix` — the stacked form ``(K, N) × (K,) → (N,)``,
  optionally masked (``mask=``): masked-out models are dropped and the
  surviving weights renormalized, the kernel image of
  :func:`repro.core.mixing.masked_mixing_matrix` row semantics.
  HBM traffic = (K + 1)·N·sizeof(dtype) — optimal.
* :func:`mix_accumulate` — the incremental form
  ``acc ← acc + w·x`` over ``(B, N)`` row buffers, so a mixing round
  folds each ppermute-received buffer into the accumulator as it
  arrives (receive overlapped with accumulation) instead of stacking
  2L full-model temporaries.  ``acc=None`` is the fused init
  ``acc ← w·x`` (the self-weight term).
* :func:`gather_mix` — the whole-round form for a resident ``(C, N)``
  flat population buffer: out row ``i`` = Σ_k ``weights[i, k] ·
  buf[srcs[i, k]]`` with **host-static** source rows (the schedule's
  perms are static per compiled mixer) and runtime weights (so churn
  masks renormalize with zero retrace).  One kernel per mixing round:
  each column tile of the population is read once and serves every
  output row — HBM traffic 2·C·N regardless of the overlay degree, and
  no materialized receive temporaries at all.

Grids are 1-D over N/BN lane-aligned tiles; K (≤ ~13: self + 2L
neighbors) and C (clients per controller, ≤ a few dozen) ride whole in
VMEM per tile.  The MXU is idle — these kernels live on the VPU — so
tiles are sized for bandwidth, not matmul alignment.  ``interpret``
defaults to auto (:func:`repro.kernels.interpret.resolve_interpret`):
compiled on TPU, interpreted (still traceable under jit/shard_map)
everywhere else.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..obs.profile import scope
from .interpret import resolve_interpret

#: TPU vector lane width — every block's minor dim must be a multiple.
LANE = 128


def aligned_block_n(n: int, block_n: int, lane: int = LANE) -> int:
    """The lane-aligned tile size actually used for an (K, n) mix.

    The smallest multiple of ``lane`` covering ``n``, capped at
    ``block_n`` (itself rounded up to a lane multiple).  A bare
    ``min(block_n, n)`` is TPU-invalid whenever ``lane < n < block_n``
    with ``n % lane != 0`` — it only ever worked in interpret mode."""
    need = -(-n // lane) * lane
    cap = max(lane, -(-block_n // lane) * lane)
    return min(cap, need)


def _default_block_n(n: int, rows: int, interp: bool) -> int:
    """Tile-width default shared by the mix entries.

    Tiling exists to fit VMEM, so it only applies to the compiled
    kernel: a ~2 MB f32 tile budget per (rows, bn) operand
    (bn ≈ 2^19 / rows elements).  Interpret mode has no VMEM — and its
    grid loop copies operands per cell — so it runs the whole
    (lane-padded) vector as one grid cell."""
    if interp:
        return max(LANE, n)
    return max(LANE, (2 ** 19 // max(rows, 1)) // LANE * LANE)


def _mix_kernel(models_ref, weights_ref, out_ref):
    # models_ref: (K, BN); weights_ref: (K, 1); out: (BN,)
    w = weights_ref[...].astype(jnp.float32)            # (K, 1)
    m = models_ref[...].astype(jnp.float32)             # (K, BN)
    out_ref[...] = jnp.sum(m * w, axis=0).astype(out_ref.dtype)


def weighted_mix(models: jnp.ndarray, weights: jnp.ndarray, *,
                 mask: Optional[jnp.ndarray] = None,
                 block_n: Optional[int] = None,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """models: (K, N) stacked flat model vectors; weights: (K,).

    Returns Σ_k weights[k]·models[k] as (N,) in models.dtype.
    N is padded to a lane multiple (128) internally.

    ``mask`` (optional (K,) 0/1 float) drops masked-out models and
    renormalizes the surviving weights to sum to the original total
    mass fraction 1 — i.e. effective weights ``w·m / Σ(w·m)`` — the
    kernel image of one :func:`repro.core.mixing.masked_mixing_matrix`
    row over its gathered sources.  A fully masked-out stack yields
    zeros (callers gate that case, exactly like the dense oracle's
    dead-row identity).  The renormalization is K scalar ops outside
    the kernel, so masking never retraces or re-tiles.
    """
    interp = resolve_interpret(interpret)
    K, N = models.shape
    if block_n is None:
        block_n = _default_block_n(N, K, interp)
    if mask is not None:
        eff = weights.astype(jnp.float32) * mask.astype(jnp.float32)
        total = jnp.sum(eff)
        weights = jnp.where(total > 0, eff / jnp.where(total > 0, total, 1.0),
                            jnp.zeros_like(eff))
    bn = aligned_block_n(N, block_n)
    pad = (-N) % bn
    if pad:
        models = jnp.pad(models, ((0, 0), (0, pad)))
    Np = models.shape[1]
    w2 = weights.reshape(K, 1).astype(jnp.float32)

    out = pl.pallas_call(
        _mix_kernel,
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((K, bn), lambda i: (0, i)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), models.dtype),
        interpret=interp,
    )(models, w2)
    return out[:N]


def _accum_kernel(acc_ref, x_ref, w_ref, out_ref):
    # acc/x: (B, BN); w: (B, 1) — one fused multiply-add per tile, the
    # output tile written exactly once.
    w = w_ref[...].astype(jnp.float32)
    out_ref[...] = (acc_ref[...].astype(jnp.float32)
                    + x_ref[...].astype(jnp.float32) * w).astype(
                        out_ref.dtype)


def _scale_kernel(x_ref, w_ref, out_ref):
    w = w_ref[...].astype(jnp.float32)
    out_ref[...] = (x_ref[...].astype(jnp.float32) * w).astype(out_ref.dtype)


def mix_accumulate(acc: Optional[jnp.ndarray], x: jnp.ndarray,
                   w: jnp.ndarray, block_n: Optional[int] = None,
                   interpret: Optional[bool] = None) -> jnp.ndarray:
    """Incremental mixing accumulate: ``acc + w[:, None]·x`` over (B, N)
    row buffers with per-row weights (B,), tiled so each output tile is
    written once and nothing but the running accumulator is ever
    materialized.  ``acc=None`` is the init form ``w[:, None]·x`` (the
    self-weight term of a mixing round), so a full round is

        acc = mix_accumulate(None, own, self_w)
        for each slot k:  acc = mix_accumulate(acc, receive(k), w_k)

    — receives overlap with accumulation; at any instant only {own,
    acc, current receive} exist, independent of the overlay degree 2L.
    """
    interp = resolve_interpret(interpret)
    B, N = x.shape
    if block_n is None:
        block_n = _default_block_n(N, B, interp)
    bn = aligned_block_n(N, block_n)
    pad = (-N) % bn
    xs = jnp.pad(x, ((0, 0), (0, pad))) if pad else x
    Np = xs.shape[1]
    w2 = w.reshape(B, 1).astype(jnp.float32)
    row_spec = pl.BlockSpec((B, bn), lambda i: (0, i))
    w_spec = pl.BlockSpec((B, 1), lambda i: (0, 0))
    if acc is None:
        with scope("kernels.mix_accumulate.init"):
            out = pl.pallas_call(
                _scale_kernel,
                grid=(Np // bn,),
                in_specs=[row_spec, w_spec],
                out_specs=row_spec,
                out_shape=jax.ShapeDtypeStruct((B, Np), x.dtype),
                interpret=interp,
            )(xs, w2)
        return out[:, :N]
    accs = jnp.pad(acc, ((0, 0), (0, pad))) if pad else acc
    with scope("kernels.mix_accumulate"):
        out = pl.pallas_call(
            _accum_kernel,
            grid=(Np // bn,),
            in_specs=[row_spec, row_spec, w_spec],
            out_specs=row_spec,
            out_shape=jax.ShapeDtypeStruct((B, Np), acc.dtype),
            interpret=interp,
        )(accs, xs, w2)
    return out[:, :N]


def round_matrix(C: int, srcs, weights: jnp.ndarray) -> jnp.ndarray:
    """Scatter a (C, K1) ``(srcs, weights)`` gather table into the dense
    (C, C) round-mixing matrix ``W[i, srcs[i, k]] += weights[i, k]``
    (duplicate sources add).  ``srcs`` host-static (validated eagerly)
    or traced (the cohort-streaming case) — shared by
    :func:`gather_mix` and the int8 wire-codec sibling
    :func:`repro.kernels.wire_codec.gather_mix_int8`."""
    static_srcs = not isinstance(srcs, jax.core.Tracer)
    if static_srcs:
        srcs = np.asarray(srcs, np.int64)
        if srcs.min() < 0 or srcs.max() >= C:
            raise ValueError(f"source rows out of range for {C} clients")
    if srcs.shape[0] != C or weights.shape != srcs.shape:
        raise ValueError(
            f"srcs {srcs.shape} / weights {weights.shape} do not match "
            f"{(C,)} clients")
    rows = np.broadcast_to(np.arange(C)[:, None], srcs.shape)
    return jnp.zeros((C, C), jnp.float32).at[rows, srcs].add(
        weights.astype(jnp.float32))


def _gather_mix_kernel(W_ref, models_ref, out_ref):
    # W: (C, C) round-mixing matrix (stationary across tiles);
    # models: (C, BN) — the whole population's column tile, read once
    # and serving every output row via one MXU matmul.
    out_ref[...] = jnp.dot(
        W_ref[...], models_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


def gather_mix(buf: jnp.ndarray, srcs, weights: jnp.ndarray,
               block_n: Optional[int] = None,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """One whole mixing round over a resident flat population buffer.

    ``buf`` (C, N): every client's raveled model; ``srcs`` (C, K1) int
    source rows (column 0 is conventionally the client itself, the rest
    its schedule sources — duplicates are fine, their weights just
    add); ``weights`` (C, K1) runtime float row-mixing weights.
    ``srcs`` may be host-static (numpy: validated eagerly, the per-
    compiled-mixer schedule case) **or traced** (jnp under jit: the
    cohort-streaming case, where the round's source table is data — any
    sequence of cohort compositions reuses one compiled program, since
    the kernel only ever sees the scattered (C, C) matrix; out-of-range
    traced sources are the caller's contract).  Returns (C, N) in
    ``buf.dtype`` with

        out[i] = Σ_k weights[i, k] · buf[srcs[i, k]]

    The (srcs, weights) table is scattered into the dense (C, C)
    round-mixing matrix W (a tiny runtime op — the schedule bounds its
    row support at K1 nonzeros) and the kernel runs one stationary
    ``W @ tile`` matmul per (C, bn) column tile: the tile is read once
    and serves all C output rows — no gather op, no materialized
    receive temporaries — so HBM traffic is 2·C·N regardless of the
    overlay degree, and masking only changes the runtime weight table
    (zero retrace; the source table is static per compiled mixer,
    churn swaps whole programs via the
    :class:`repro.overlay.controller.MixerCache`).  Sized for one
    controller's population (C ≲ a few hundred: the C² matmul flops
    stay far below the memory bound): the C-row tile must fit VMEM —
    the default ``block_n=None`` budgets the compiled tile at ~2 MB
    (bn ≈ 2^19/C elements; shrink for larger C) and runs interpret
    mode as a single cell (no VMEM to fit).

    Degraded-round contract (:mod:`repro.faults`): unreachable edges
    never reach this kernel as structure — the masked mixers zero the
    affected entries of the runtime ``weights`` table (after
    renormalizing the survivors, see
    ``repro.dist.sync.global_mixer``'s ``masked_tables``), so a link
    outage, straggler, or partition round runs the *same* compiled
    program with a different weight table: zero retraces, same
    MixerCache entry.
    """
    interp = resolve_interpret(interpret)
    C, N = buf.shape
    if block_n is None:
        block_n = _default_block_n(N, C, interp)
    W = round_matrix(C, srcs, weights)
    bn = aligned_block_n(N, block_n)
    pad = (-N) % bn
    bufs = jnp.pad(buf, ((0, 0), (0, pad))) if pad else buf
    Np = bufs.shape[1]

    with scope("kernels.gather_mix"):
        out = pl.pallas_call(
            _gather_mix_kernel,
            grid=(Np // bn,),
            in_specs=[
                pl.BlockSpec((C, C), lambda i: (0, 0)),
                pl.BlockSpec((C, bn), lambda i: (0, i)),
            ],
            out_specs=pl.BlockSpec((C, bn), lambda i: (0, i)),
            out_shape=jax.ShapeDtypeStruct((C, Np), buf.dtype),
            interpret=interp,
        )(W, bufs)
    return out[:, :N]
