"""Pallas TPU kernel: MEP confidence-weighted K-model aggregation.

The FedLay/MEP hot path on device is ``w_u ← Σ_k c_k · W_k`` over the
own model plus the (up to 2L) neighbor models received via ppermute —
a purely memory-bound reduction over K same-shaped parameter vectors.
A naive jnp implementation materializes K-1 intermediate sums; the
kernel streams one lane-aligned tile of every model through VMEM and
writes each output tile exactly once:

  HBM traffic  = (K + 1) · N · sizeof(dtype)   (optimal)
  VMEM working = K · BN · 4 bytes              (BN chosen to fit)

Grid: 1-D over N/BN tiles.  K (≤ ~13: self + 2L neighbors) rides whole
in VMEM per tile.  The MXU is idle — this kernel lives on the VPU —
so the tile is sized for bandwidth, not matmul alignment.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


#: TPU vector lane width — every block's minor dim must be a multiple.
LANE = 128


def aligned_block_n(n: int, block_n: int, lane: int = LANE) -> int:
    """The lane-aligned tile size actually used for an (K, n) mix.

    The smallest multiple of ``lane`` covering ``n``, capped at
    ``block_n`` (itself rounded up to a lane multiple).  A bare
    ``min(block_n, n)`` is TPU-invalid whenever ``lane < n < block_n``
    with ``n % lane != 0`` — it only ever worked in interpret mode."""
    need = -(-n // lane) * lane
    cap = max(lane, -(-block_n // lane) * lane)
    return min(cap, need)


def _mix_kernel(models_ref, weights_ref, out_ref):
    # models_ref: (K, BN); weights_ref: (K, 1); out: (BN,)
    w = weights_ref[...].astype(jnp.float32)            # (K, 1)
    m = models_ref[...].astype(jnp.float32)             # (K, BN)
    out_ref[...] = jnp.sum(m * w, axis=0).astype(out_ref.dtype)


def weighted_mix(models: jnp.ndarray, weights: jnp.ndarray,
                 block_n: int = 65536, interpret: bool = False) -> jnp.ndarray:
    """models: (K, N) stacked flat model vectors; weights: (K,).

    Returns Σ_k weights[k]·models[k] as (N,) in models.dtype.
    N is padded to a lane multiple (128) internally.
    """
    K, N = models.shape
    bn = aligned_block_n(N, block_n)
    pad = (-N) % bn
    if pad:
        models = jnp.pad(models, ((0, 0), (0, pad)))
    Np = models.shape[1]
    w2 = weights.reshape(K, 1).astype(jnp.float32)

    out = pl.pallas_call(
        _mix_kernel,
        grid=(Np // bn,),
        in_specs=[
            pl.BlockSpec((K, bn), lambda i: (0, i)),
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Np,), models.dtype),
        interpret=interpret,
    )(models, w2)
    return out[:N]
