# Pallas TPU kernels for the perf-critical compute layers, with pure-jnp
# oracles in ref.py and jit'd wrappers in ops.py (interpret=True on CPU).

from .ops import flash_decode, ssd_scan, weighted_mix
from . import ref

__all__ = ["flash_decode", "ssd_scan", "weighted_mix", "ref"]
