"""The distribution layer: host-side overlay artifacts compiled into
on-device sharding + collective programs.

Three modules:

* :mod:`repro.dist.sharding` — PartitionSpec rules for every parameter /
  cache / batch pytree (FSDP, tensor-parallel, expert-parallel, and the
  DFL client axis), plus divisibility enforcement against a mesh.
* :mod:`repro.dist.flat` — the flat-buffer layout of the fused mixing
  hot path: :class:`~repro.dist.flat.FlatSpec` ravels a ``(B, ...)``
  params tree into one contiguous lane-padded ``(B, N)`` buffer with
  dtype-preserving per-leaf offsets (and back, exactly).
* :mod:`repro.dist.sync` — the FedLay overlay compiled into static
  ``ppermute`` mixing (the TPU image of the paper's NDMP neighbor
  tables) with the opt-in ``fuse="flat"`` Pallas fused round, the
  all-reduce / ring / none baselines, and the paper's per-client
  communication accounting.
"""

from . import compat, flat, sharding, sync
from .compat import make_client_mesh, shard_map
from .flat import FlatSpec
from .sharding import (batch_spec, cache_specs, enforce_divisibility,
                       param_specs, spec_for_leaf)
from .sync import (FUSE_MODES, check_fuse, fedlay_mix, global_mixer,
                   make_mixer, ring_schedule, sync_bytes_per_client)

__all__ = [
    "compat", "flat", "sharding", "sync",
    "make_client_mesh", "shard_map",
    "FlatSpec",
    "batch_spec", "cache_specs", "enforce_divisibility", "param_specs",
    "spec_for_leaf",
    "FUSE_MODES", "check_fuse",
    "fedlay_mix", "global_mixer", "make_mixer", "ring_schedule",
    "sync_bytes_per_client",
]
