"""The distribution layer: host-side overlay artifacts compiled into
on-device sharding + collective programs.

Two modules:

* :mod:`repro.dist.sharding` — PartitionSpec rules for every parameter /
  cache / batch pytree (FSDP, tensor-parallel, expert-parallel, and the
  DFL client axis), plus divisibility enforcement against a mesh.
* :mod:`repro.dist.sync` — the FedLay overlay compiled into static
  ``ppermute`` mixing (the TPU image of the paper's NDMP neighbor
  tables), the all-reduce / ring / none baselines, and the paper's
  per-client communication accounting.
"""

from . import compat, sharding, sync
from .compat import make_client_mesh, shard_map
from .sharding import (batch_spec, cache_specs, enforce_divisibility,
                       param_specs, spec_for_leaf)
from .sync import (fedlay_mix, global_mixer, make_mixer, ring_schedule,
                   sync_bytes_per_client)

__all__ = [
    "compat", "sharding", "sync",
    "make_client_mesh", "shard_map",
    "batch_spec", "cache_specs", "enforce_divisibility", "param_specs",
    "spec_for_leaf",
    "fedlay_mix", "global_mixer", "make_mixer", "ring_schedule",
    "sync_bytes_per_client",
]
