"""FedLay mixing compiled onto the device mesh (the paper's NDMP tables
as static collectives).

The control plane (``repro.core.ndmp``) converges neighbor tables
host-side; ``repro.core.mixing.build_permute_schedule`` (static mesh
layout) or ``repro.core.mixing.schedule_from_addresses`` (the live NDMP
alive set, via :class:`repro.overlay.OverlayController`) freezes them
into a :class:`~repro.core.mixing.PermuteSchedule` (2L ring rotations +
MEP confidence weights).  Schedules hash by content, so the overlay
controller keys its mixer compile cache on them and hot-swaps the
programs built here between training steps under churn.  This module
turns a schedule into device programs two ways:

* :func:`fedlay_mix` / :func:`make_mixer` — the explicit ``shard_map``
  path: with the 1:1 layout (one client per device) one
  ``jax.lax.ppermute`` per (space × direction) slot; with the **grouped
  layout** (``clients_per_device = G > 1``) each device holds G
  clients' replicas as a leading local-client dim, intra-device edges
  become local gathers (zero network bytes), and cross-device edges run
  as the edge-colored batched ppermute rounds of
  :func:`repro.core.mixing.grouped_routing`.  Verified equal to the
  dense ``schedule_mixing_matrix`` / ``masked_mixing_matrix`` products
  in ``tests/test_dist.py`` and ``tests/test_grouped.py``.
* :func:`global_mixer` — the global-view (auto-sharded jit) path used by
  ``repro.launch.steps.dfl_train_bundle``: permutation gathers along the
  leading client axis, which GSPMD lowers to collective-permutes when
  that axis is client-sharded.  Layout-agnostic: with ``num_clients =
  G · num_devices`` rows client-sharded over the mesh, GSPMD routes
  on-device rows locally for free.

**The grouped ``(G, ...)`` contract** (shard_map path): the client axis
maps onto devices block-contiguously — client ``i`` lives on device
``i // G`` at local row ``i % G``; every tree leaf carries a leading
local-client dim of size G, ``weights`` is the local (G, 2L) slice of
the schedule's weight table and ``self_weight`` the local (G,) slice
(i.e. the (n, 2L)/(n,) host tables sharded over the client axis), and
``mask`` — when given — the local (G,) slice of the (n,) participation
mask.  ``G == 1`` degenerates to the original one-ppermute-per-slot
program.

**The flat-buffer fused hot path** (``fuse="flat"``, opt-in on both
mixer families): instead of walking the tree once per leaf per slot —
which materializes up to 2L full-model temporaries per round — the
params tree is raveled once into a contiguous lane-padded (B, N)
buffer (:class:`repro.dist.flat.FlatSpec`: per-leaf dtype-preserving
lane-aligned offsets) and the whole round runs on that buffer with the
:mod:`repro.kernels.weighted_mix` Pallas kernels:

* shard_map path — each ppermute moves one flat row; every received
  row streams into the accumulator via the incremental
  :func:`~repro.kernels.weighted_mix.mix_accumulate` entry, so only
  {own, acc, current receive} ever exist at once, independent of 2L;
* global path — one :func:`~repro.kernels.weighted_mix.gather_mix`
  kernel per round over the resident (C, N) population buffer: static
  source rows (the schedule's perms), runtime weight table.  Masking
  (dead capacity slots, multirate skips) only rewrites the (C, 2L+1)
  weight table — renormalizing over surviving sources, identity rows
  for dead clients — with **zero retrace**.  Note GSPMD treats the
  kernel as opaque, so the fused global path shines where the
  population buffer is resident per process (slot runtime, capacity
  controllers); wire-optimal multi-device mixing stays with the
  shard_map path.

Both fused paths are pinned ≡ the dense ``masked_mixing_matrix`` /
``schedule_mixing_matrix`` oracles (and the tree walk) in
``tests/test_flat.py``.

Plus :func:`sync_bytes_per_client`, the paper's per-round communication
accounting (§IV-D / Fig. 20) shared by the scalability benchmarks —
grouped mixing pays network bytes only for cross-device edges.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.mixing import PermuteSchedule, check_group_size, grouped_routing
from ..kernels.weighted_mix import gather_mix, mix_accumulate
from ..obs.events import get_telemetry
from ..obs.profile import scope
from ..wire.codec import WireCodec, get_codec
from .flat import FlatSpec

#: Sync strategies understood by both mixer factories.
SYNC_STRATEGIES = ("fedlay", "allreduce", "ring", "none")

#: Mixing-round execution modes: ``None``/``"tree"`` — the per-leaf jnp
#: tree walk; ``"flat"`` — the FlatSpec + Pallas fused hot path.
FUSE_MODES = (None, "tree", "flat")


def check_fuse(fuse: Optional[str]) -> Optional[str]:
    """Validate a fuse mode and normalize the default spelling
    (``"tree"`` ≡ ``None``, the unfused walk)."""
    if fuse not in FUSE_MODES:
        raise ValueError(
            f"unknown fuse mode {fuse!r}; choose from {FUSE_MODES}")
    return None if fuse == "tree" else fuse


def resolve_wire(codec, fuse: Optional[str]
                 ) -> "tuple[Optional[WireCodec], Optional[str]]":
    """Normalize the ``(codec, fuse)`` knob pair shared by every mixing
    entry point.  Codecs operate on the flat row buffer
    (:mod:`repro.wire.codec` wire-format contract), so any codec —
    including the exact ``"none"`` — implies ``fuse="flat"``; without a
    codec the fuse mode passes through unchanged (``None`` stays the
    tree walk, byte-identical to pre-codec behavior)."""
    fuse = check_fuse(fuse)
    codec = get_codec(codec)
    if codec is not None:
        fuse = "flat"
    return codec, fuse


def ring_schedule(num_clients: int) -> PermuteSchedule:
    """The identity-ring overlay as a PermuteSchedule: one space, simple
    average over {self, predecessor, successor} (degenerates correctly
    at n ≤ 2, where the two directions collide)."""
    n = num_clients
    pred = tuple((i - 1) % n for i in range(n))
    succ = tuple((i + 1) % n for i in range(n))
    weights = np.zeros((n, 2), dtype=np.float64)
    self_w = np.ones((n,), dtype=np.float64)
    for i in range(n):
        seen = {i}
        for k, src in enumerate((pred[i], succ[i])):
            if src not in seen:
                weights[i, k] = 1.0
                seen.add(src)
    total = self_w + weights.sum(axis=1)
    weights /= total[:, None]
    self_w /= total
    return PermuteSchedule(num_clients=n, num_spaces=1, perms=(pred, succ),
                           weights=weights.astype(np.float32),
                           self_weight=self_w.astype(np.float32))


def fedlay_mix(tree, sched: PermuteSchedule, weights: jnp.ndarray,
               self_weight: jnp.ndarray, axis_name: str,
               mask: Optional[jnp.ndarray] = None,
               fuse: Optional[str] = None,
               codec=None, residual: Optional[jnp.ndarray] = None):
    """One FedLay mixing round inside ``shard_map``.

    ``tree`` leaves carry a leading local-client dim of size G (the
    module-level grouped ``(G, ...)`` contract: client ``i`` lives on
    device ``i // G``, so ``sched.num_clients == G · axis_size``);
    ``weights`` is the local (G, 2L) confidence-weight slice and
    ``self_weight`` the local (G,) self weight.  Equivalent to the dense
    ``W @ X`` of ``schedule_mixing_matrix(sched)``.

    With ``G == 1`` (the original 1:1 layout) each slot is one
    ``ppermute`` of the full local replica.  With ``G > 1`` edges whose
    source lives on the same device are local gathers (zero network
    bytes) and cross-device edges run as the edge-colored ppermute
    rounds of :func:`repro.core.mixing.grouped_routing` — at most ~G
    batched single-row permutes per slot, moving exactly the weight>0
    cross edges.

    ``mask`` (optional, local (G,) 0/1 float) makes the round mask-aware:
    a masked-out client (dead capacity slot, or a slow client skipping
    this collective under multirate participation) keeps its own model,
    and live clients drop its contribution and renormalize over the
    surviving weights — the per-device image of
    :func:`repro.core.mixing.masked_mixing_matrix`.  The mask rides the
    same routing as the models, so masking adds scalar permutes, not a
    retrace.

    ``fuse="flat"`` (opt-in) runs the round on the flat-buffer fused
    hot path (module docstring): the tree is raveled once into a
    lane-padded (G, N) buffer, each slot's receive moves that one row
    and streams straight into the Pallas
    :func:`~repro.kernels.weighted_mix.mix_accumulate` accumulator —
    same routing, same weights, same mask semantics, O(1) live
    full-model temporaries instead of one per leaf per slot.

    ``codec`` (a :mod:`repro.wire.codec` name or instance; implies the
    flat path) compresses the wire: each slot's receive routes the
    *encoded* parts of the own flat row — int8 payload + per-block
    scales, or top-k (values, indices) — through exactly the same
    ppermute/grouped routing, and the receive folds them into the f32
    accumulator via the codec's fused
    :meth:`~repro.wire.codec.WireCodec.accumulate` (the decompressed 2L
    stack never exists).  The self term always uses the true local row
    (it is never on the wire), so exact codecs reproduce the
    uncompressed round bit-for-bit and lossy ones stay within the
    codec's documented per-element tolerance of the dense oracle.  For
    an error-feedback codec, ``residual`` ((G, N) f32) is required and
    the call returns ``(tree, new_residual)``: the wire carries
    ``enc(buf + residual)``; masked-out rows keep their residual
    unchanged (they send nothing anyone counts).
    """
    codec, fuse = resolve_wire(codec, fuse)
    ef = codec is not None and codec.error_feedback
    if ef and residual is None:
        raise ValueError(
            f"codec {codec.name!r} uses error feedback; pass the (G, N) "
            f"residual state (and consume the returned new residual)")
    G = jax.tree.leaves(tree)[0].shape[0]
    # psum of a literal is evaluated statically under shard_map tracing,
    # so a schedule/mesh layout mismatch fails loudly at trace time
    # instead of silently mixing zeros on the surplus devices.
    axis_size = jax.lax.psum(1, axis_name)
    if isinstance(axis_size, int) and sched.num_clients != G * axis_size:
        raise ValueError(
            f"schedule is for {sched.num_clients} clients but the "
            f"grouped layout holds {G} × {axis_size} devices on axis "
            f"{axis_name!r}")
    masked = mask is not None

    if G == 1:
        # 1:1 layout: one full-replica ppermute per slot (the original
        # program; grouped routing degenerates to this anyway, but the
        # direct form keeps existing compiled programs byte-stable).
        def receive(x, k):
            return jax.lax.ppermute(x, axis_name,
                                    perm=sched.ppermute_pairs(k))
    else:
        rt = grouped_routing(sched, G)
        i = jax.lax.axis_index(axis_name)

        def receive(x, k):
            isrc = jnp.asarray(rt.intra_src[k])[i]          # (G,)
            ion = jnp.asarray(rt.intra_on[k])[i]            # (G,)
            shape = (G,) + (1,) * (x.ndim - 1)
            recv = jnp.take(x, isrc, axis=0) * ion.reshape(shape).astype(
                x.dtype)
            for rnd in rt.rounds[k]:
                row = jnp.take(x, jnp.asarray(rnd.send_row)[i], axis=0)
                got = jax.lax.ppermute(row, axis_name,
                                       perm=list(rnd.pairs))
                on = jnp.asarray(rnd.recv_on)[i].astype(x.dtype)
                recv = recv.at[jnp.asarray(rnd.recv_slot)[i]].add(got * on)
            return recv

    if masked:
        m = mask.astype(jnp.float32)
        eff = [weights[:, k].astype(jnp.float32) * receive(m, k)
               for k in range(sched.num_slots)]
        total = self_weight.astype(jnp.float32) + sum(eff)
        ok = (m > 0) & (total > 0)
        safe = jnp.where(total > 0, total, 1.0)
        self_w = (self_weight.astype(jnp.float32) / safe)
        slot_w = [e / safe for e in eff]
    else:
        self_w = self_weight
        slot_w = [weights[:, k] for k in range(sched.num_slots)]

    if fuse == "flat":
        spec = FlatSpec.for_tree(tree)
        buf = spec.ravel(tree)                       # (G, N) lane-padded
        if codec is not None:
            # trace-time tick: codec paths run inside jit, so these
            # count (re)compiles of the codec program — steady state
            # with a warm MixerCache adds zero.
            bus = get_telemetry()
            bus.count("wire.encodes")
            bus.count("wire.decodes", sched.num_slots)
            with scope(f"wire.{codec.name}.encode"):
                if ef:
                    if residual.shape != buf.shape:
                        raise ValueError(
                            f"residual shape {residual.shape} != flat "
                            f"buffer {buf.shape}")
                    wire, res = codec.encode_ef(buf + residual)
                    if masked:
                        res = jnp.where((m > 0)[:, None], res, residual)
                else:
                    wire, res = codec.encode(buf), None
            acc = mix_accumulate(None, buf, self_w)
            for k in range(sched.num_slots):
                with scope(f"fedlay_mix.slot{k}"):
                    wk = tuple(receive(part, k) for part in wire)
                    acc = codec.accumulate(acc, wk, slot_w[k])
            if masked:
                acc = jnp.where(ok[:, None], acc, buf)
            out = spec.unravel(acc)
            return (out, res) if ef else out
        acc = mix_accumulate(None, buf, self_w)
        for k in range(sched.num_slots):
            with scope(f"fedlay_mix.slot{k}"):
                acc = mix_accumulate(acc, receive(buf, k), slot_w[k])
        if masked:
            acc = jnp.where(ok[:, None], acc, buf)
        return spec.unravel(acc)

    def mix_leaf(leaf):
        shape = (G,) + (1,) * (leaf.ndim - 1)
        acc = leaf * self_w.reshape(shape).astype(leaf.dtype)
        for k in range(sched.num_slots):
            recv = receive(leaf, k)
            w = slot_w[k].reshape(shape).astype(leaf.dtype)
            acc = acc + recv * w
        if masked:
            acc = jnp.where(ok.reshape(shape), acc, leaf)
        return acc

    return jax.tree.map(mix_leaf, tree)


def make_mixer(strategy: str, sched: Optional[PermuteSchedule],
               axis_name: str, num_clients: int,
               clients_per_device: int = 1,
               fuse: Optional[str] = None,
               codec=None) -> Callable:
    """Build a ``shard_map``-body mixer ``(tree, weights, self_w) -> tree``
    for one sync strategy over the client axis ``axis_name``.

    ``num_clients`` is the **total** client count; with
    ``clients_per_device = G > 1`` the mesh axis holds ``num_clients / G``
    devices and tree leaves carry the grouped leading (G, ...) dim (the
    module-level contract).  ``fuse="flat"`` selects the flat-buffer
    fused hot path for the fedlay/ring rounds (module docstring);
    allreduce/none have no per-slot accumulate to fuse and ignore it.

    ``codec`` (:mod:`repro.wire.codec`) compresses the fedlay/ring
    gossip wire (implies ``fuse="flat"``; see :func:`fedlay_mix`).  For
    an error-feedback codec the mixer signature grows a trailing
    residual: ``(tree, weights, self_w, residual) -> (tree, residual)``.
    allreduce reduces in-network (no per-neighbor wire to compress) and
    none sends nothing, so both ignore ``codec``.

    * ``fedlay``   — static ppermutes from ``sched`` (paper §III); with
      G > 1, intra-device sub-mixing + edge-colored cross-device rounds;
    * ``allreduce``— uniform mean over all clients (centralized image;
      local G-row mean, then ``pmean`` over devices);
    * ``ring``     — identity-ring neighbor average (ignores ``sched``'s
      weights; uses its own uniform ring schedule over all clients);
    * ``none``     — isolated local training.
    """
    G = clients_per_device
    check_group_size(num_clients, G)
    codec, fuse = resolve_wire(codec, fuse)
    ef = (codec is not None and codec.error_feedback
          and strategy in ("fedlay", "ring"))

    if strategy == "none":
        return lambda tree, weights, self_w: tree

    if strategy == "allreduce":
        def allreduce_mixer(tree, weights, self_w):
            def mean_leaf(leaf):
                m = jnp.mean(leaf.astype(jnp.float32), axis=0, keepdims=True)
                m = jax.lax.pmean(m, axis_name)
                return jnp.broadcast_to(m.astype(leaf.dtype), leaf.shape)
            return jax.tree.map(mean_leaf, tree)
        return allreduce_mixer

    if strategy == "ring":
        ring = ring_schedule(num_clients)
        ring_w = jnp.asarray(ring.weights)
        ring_s = jnp.asarray(ring.self_weight)

        if ef:
            def ring_mixer_ef(tree, weights, self_w, residual):
                i = jax.lax.axis_index(axis_name)
                w = jax.lax.dynamic_slice_in_dim(ring_w, i * G, G, axis=0)
                s = jax.lax.dynamic_slice_in_dim(ring_s, i * G, G, axis=0)
                return fedlay_mix(tree, ring, w, s, axis_name, fuse=fuse,
                                  codec=codec, residual=residual)
            return ring_mixer_ef

        def ring_mixer(tree, weights, self_w):
            i = jax.lax.axis_index(axis_name)
            w = jax.lax.dynamic_slice_in_dim(ring_w, i * G, G, axis=0)
            s = jax.lax.dynamic_slice_in_dim(ring_s, i * G, G, axis=0)
            return fedlay_mix(tree, ring, w, s, axis_name, fuse=fuse,
                              codec=codec)
        return ring_mixer

    if strategy == "fedlay":
        if sched is None:
            raise ValueError("fedlay mixer needs a PermuteSchedule")
        if sched.num_clients != num_clients:
            raise ValueError(
                f"schedule is for {sched.num_clients} clients, "
                f"mesh axis {axis_name!r} holds {num_clients} "
                f"(= {num_clients // G} devices × {G})")
        if ef:
            return lambda tree, weights, self_w, residual: fedlay_mix(
                tree, sched, weights, self_w, axis_name, fuse=fuse,
                codec=codec, residual=residual)
        return lambda tree, weights, self_w: fedlay_mix(
            tree, sched, weights, self_w, axis_name, fuse=fuse, codec=codec)

    raise ValueError(
        f"unknown sync strategy {strategy!r}; choose from {SYNC_STRATEGIES}")


def global_mixer(strategy: str,
                 sched: Optional[PermuteSchedule] = None,
                 masked: bool = False,
                 clients_per_device: int = 1,
                 fuse: Optional[str] = None,
                 codec=None,
                 flat_io: bool = False) -> Callable:
    """Build a global-view mixer ``params -> params`` over the leading
    client axis (for auto-sharded jit, e.g. ``dfl_train_bundle``).

    For ``fedlay``/``ring`` each of the 2L slots is a permutation gather
    ``params[perm_k]`` along the client dim — GSPMD lowers it to a
    collective-permute when that dim is client-sharded, i.e. exactly the
    neighbor exchange :func:`fedlay_mix` spells out by hand.

    The global view is grouped-layout agnostic: the program operates on
    all ``sched.num_clients`` rows and GSPMD routes whatever fraction of
    each permutation stays on-device for free, so ``clients_per_device``
    is validation-only here — it asserts the client count divides into
    groups of G (``num_clients = G · num_devices``) so the caller's
    client-sharded leading axis actually lands G rows per device.

    With ``masked=True`` the returned callable is ``(params, mask) ->
    params`` where ``mask`` is a (C,) 0/1 float *runtime input* (no
    retrace when it changes): masked-out rows keep their own model, live
    rows drop masked-out sources and renormalize — the device image of
    :func:`repro.core.mixing.masked_mixing_matrix`.  This is the seam
    the fixed-capacity slot runtime (dead slots) and multirate
    participation (slow clients skipping a collective) both plug into.
    Masked fedlay/ring mixers additionally accept a keyword-only
    ``edge_mask`` — a (C, 2L) 0/1 runtime input that drops individual
    unreachable edges before renormalizing (degraded rounds under
    :mod:`repro.faults`); like ``mask`` it is a runtime value, so fault
    storms never retrace.

    ``fuse="flat"`` (fedlay/ring) replaces the per-leaf permutation
    gathers with **one Pallas kernel per round** over the raveled
    (C, N) population buffer
    (:func:`repro.kernels.weighted_mix.gather_mix`): the schedule's
    perms become a static (C, 2L+1) source-row table (column 0 = self)
    and the confidence weights a runtime (C, 2L+1) table.  The masked
    variant only rewrites that weight table — renormalized over
    surviving sources, identity rows for dead/starved clients — so the
    mask stays a zero-retrace runtime input.  allreduce/none have no
    per-slot accumulate to fuse and ignore ``fuse``.

    ``codec`` (:mod:`repro.wire.codec`; implies ``fuse="flat"``)
    compresses the fedlay/ring round: the population buffer is encoded
    once per round and the neighbor term mixes the *encoded* form
    through the codec's fused
    :meth:`~repro.wire.codec.WireCodec.gather` (int8: the
    :func:`repro.kernels.wire_codec.gather_mix_int8` round-matrix
    kernel dequantizing tiles in VMEM), while the self term always uses
    the true row.  For an error-feedback codec the signature grows a
    trailing (C, N) f32 residual and returns ``(params, residual)``
    (masked rows keep their residual).  allreduce/none ignore ``codec``
    (no per-neighbor wire).

    ``flat_io=True`` (fedlay/ring flat path only) makes the mixer
    operate **directly on the (C, N) flat buffer** instead of a params
    tree — the resident-flat-params mode of
    :class:`repro.runtime.SlotTrainLoop`, which keeps the population
    raveled across steps so steady-state training never pays per-round
    ravel/unravel copies.  Same signatures with ``params`` replaced by
    the buffer.
    """
    codec, fuse = resolve_wire(codec, fuse)
    if flat_io:
        if fuse != "flat" or strategy not in ("fedlay", "ring"):
            raise ValueError(
                "flat_io mixers operate on the raveled buffer: they "
                "require fuse='flat' (or a codec) and a fedlay/ring "
                "strategy")
    if sched is not None:
        check_group_size(sched.num_clients, clients_per_device)
    elif clients_per_device < 1:
        raise ValueError("clients_per_device must be >= 1")
    if strategy == "none":
        if masked:
            return lambda params, mask, *, edge_mask=None: params
        return lambda params: params

    if strategy == "allreduce":
        def allreduce(params):
            return jax.tree.map(
                lambda l: jnp.broadcast_to(
                    jnp.mean(l.astype(jnp.float32), axis=0,
                             keepdims=True).astype(l.dtype), l.shape),
                params)

        def allreduce_masked(params, mask, *, edge_mask=None):
            # allreduce has no per-edge structure; a degraded node is a
            # node-level fault (fold it into ``mask``), so edge_mask is
            # accepted for signature parity and ignored
            m = mask.astype(jnp.float32)
            denom = jnp.maximum(jnp.sum(m), 1.0)

            def mean_leaf(leaf):
                shape = (leaf.shape[0],) + (1,) * (leaf.ndim - 1)
                mm = m.reshape(shape)
                mean = jnp.sum(leaf.astype(jnp.float32) * mm, axis=0,
                               keepdims=True) / denom
                out = jnp.broadcast_to(mean.astype(leaf.dtype), leaf.shape)
                return jnp.where(mm > 0, out, leaf)
            return jax.tree.map(mean_leaf, params)
        return allreduce_masked if masked else allreduce

    if strategy in ("fedlay", "ring"):
        if sched is None:
            raise ValueError(f"{strategy} mixer needs a PermuteSchedule")
        C = sched.num_clients
        perms = jnp.asarray(np.array(sched.perms), jnp.int32)   # (2L, C)
        weights = jnp.asarray(sched.weights)                    # (C, 2L)
        self_w = jnp.asarray(sched.self_weight)                 # (C,)

        def masked_tables(mask, edge_mask=None):
            """(sw (C,), ew (C, 2L), ok (C,)) of mask-renormalized
            weights — shared by the tree-walk and fused masked
            variants so their semantics cannot drift apart.

            ``edge_mask`` is an optional (C, 2L) 0/1 runtime input
            (degraded rounds, :mod:`repro.faults`): entry [i, k] = 0
            drops the edge from slot i's k-th source *before*
            renormalizing, so unreachable neighbors are renormalized
            away exactly like dead ones.  A fully isolated live row
            (all edges down) degenerates to total = self_w > 0 and
            keeps its own model."""
            m = mask.astype(jnp.float32)
            # source contributions gated by the source's mask, rows
            # renormalized over what survives
            eff = weights * jnp.take(m, perms, axis=0).T
            if edge_mask is not None:
                eff = eff * edge_mask.astype(jnp.float32)
            total = self_w + eff.sum(axis=1)
            ok = (m > 0) & (total > 0)
            safe = jnp.where(total > 0, total, 1.0)
            return self_w / safe, eff / safe[:, None], ok

        if fuse == "flat":
            # (C, 2L+1) static source rows: self first, then the 2L
            # schedule sources — one gather_mix kernel mixes the round.
            srcs = np.concatenate(
                [np.arange(C)[:, None], np.array(sched.perms).T], axis=1)
            base_table = jnp.concatenate(
                [self_w[:, None], weights], axis=1).astype(jnp.float32)
            ef = codec is not None and codec.error_feedback

            def round_flat(buf, table, ok=None, residual=None):
                """One fused round on the (C, N) buffer → (out, res).
                Codec-free: one gather_mix over the full table (identity
                rows where ~ok).  With a codec: the self column uses the
                true rows, neighbors mix the encoded buffer through the
                codec's fused gather; EF encodes buf+residual and
                returns the fresh residual (mask-gating is the
                caller's)."""
                if codec is None:
                    if ok is not None:
                        ident = jnp.zeros_like(table).at[:, 0].set(1.0)
                        table = jnp.where(ok[:, None], table, ident)
                    with scope("global_mixer.gather_mix"):
                        return gather_mix(buf, srcs, table), None
                bus = get_telemetry()           # trace-time tick (see
                bus.count("wire.encodes")       # fedlay_mix): counts
                bus.count("wire.decodes")       # codec (re)compiles
                with scope(f"wire.{codec.name}.encode"):
                    if ef:
                        wire, res = codec.encode_ef(buf + residual)
                    else:
                        wire, res = codec.encode(buf), None
                with scope(f"global_mixer.{codec.name}.gather"):
                    out = mix_accumulate(None, buf, table[:, 0])
                    out = out + codec.gather(wire, srcs[:, 1:],
                                             table[:, 1:], buf.shape[1])
                if ok is not None:
                    out = jnp.where(ok[:, None], out, buf)
                return out, res

            def mix_buf(buf):
                return round_flat(buf, base_table)[0]

            def mix_buf_masked(buf, mask, *, edge_mask=None):
                sw, ew, ok = masked_tables(mask, edge_mask)
                table = jnp.concatenate([sw[:, None], ew], axis=1)
                return round_flat(buf, table, ok=ok)[0]

            def mix_buf_ef(buf, residual):
                return round_flat(buf, base_table, residual=residual)

            def mix_buf_masked_ef(buf, mask, residual, *, edge_mask=None):
                sw, ew, ok = masked_tables(mask, edge_mask)
                table = jnp.concatenate([sw[:, None], ew], axis=1)
                out, res = round_flat(buf, table, ok=ok, residual=residual)
                # masked-out rows (dead slots, multirate skips) keep
                # their residual: they contributed nothing this round
                res = jnp.where((mask > 0)[:, None], res, residual)
                return out, res

            inner = {(False, False): mix_buf,
                     (True, False): mix_buf_masked,
                     (False, True): mix_buf_ef,
                     (True, True): mix_buf_masked_ef}[(masked, ef)]
            if flat_io:
                return inner

            if ef:
                def mix_flat_ef(params, *rest, **kw):
                    spec = FlatSpec.for_tree(params)
                    out, res = inner(spec.ravel(params), *rest, **kw)
                    return spec.unravel(out), res
                return mix_flat_ef

            def mix_flat(params, *rest, **kw):
                spec = FlatSpec.for_tree(params)
                return spec.unravel(inner(spec.ravel(params), *rest, **kw))
            return mix_flat

        def mix(params):
            def mix_leaf(leaf):
                shape = (C,) + (1,) * (leaf.ndim - 1)
                acc = leaf * self_w.reshape(shape).astype(leaf.dtype)
                for k in range(sched.num_slots):
                    recv = jnp.take(leaf, perms[k], axis=0)  # permutation
                    w = weights[:, k].reshape(shape)
                    acc = acc + recv * w.astype(leaf.dtype)
                return acc
            return jax.tree.map(mix_leaf, params)

        def mix_masked(params, mask, *, edge_mask=None):
            sw, ew, ok = masked_tables(mask, edge_mask)

            def mix_leaf(leaf):
                shape = (C,) + (1,) * (leaf.ndim - 1)
                acc = leaf * sw.reshape(shape).astype(leaf.dtype)
                for k in range(sched.num_slots):
                    recv = jnp.take(leaf, perms[k], axis=0)
                    acc = acc + recv * ew[:, k].reshape(shape).astype(
                        leaf.dtype)
                return jnp.where(ok.reshape(shape), acc, leaf)
            return jax.tree.map(mix_leaf, params)
        return mix_masked if masked else mix

    raise ValueError(
        f"unknown sync strategy {strategy!r}; choose from {SYNC_STRATEGIES}")


def sync_bytes_per_client(strategy: str, model_bytes: int, num_clients: int,
                          num_spaces: Optional[int] = None,
                          clients_per_device: int = 1,
                          active_clients: Optional[int] = None,
                          codec=None) -> float:
    """*Network* bytes each **active** client sends per mixing round
    (paper §IV-D accounting).  With the grouped layout
    (``clients_per_device = G``) edges between clients co-hosted on one
    device cost 0 network bytes, so every strategy's wire cost shrinks —
    to exactly 0 when the whole active set shares one device.

    ``active_clients = K`` models cohort streaming
    (:mod:`repro.scale.cohort`): only K of the ``num_clients`` capacity
    slots participate, the round's overlay is rebuilt over the cohort
    (induced-subgraph schedule), and the SlotMap packs the cohort into
    the lowest slots — so the closed forms are the full-participation
    forms with K in place of n.  The observed FedLay degree is capped by
    the cohort: ``min(2L, K−1)`` (K−1 peers exist at all; tiny cohorts
    cannot realize 2L distinct neighbors).  Default ``None`` means full
    participation (K = n), reproducing the original forms exactly.

    * ``fedlay``: degree ≤ min(2L, K−1), each ring neighbor uniform over
      the other K−1 active clients ⇒ expected
      ``min(2L, K−1) · (K−G)/(K−1) · model_bytes`` — at K = n, G = 1
      this is the paper's constant-in-n headline ``2L·model_bytes``
      (exact per-schedule counts:
      :attr:`repro.core.mixing.GroupedRouting.cross_edges`, the
      regression oracle in ``tests/test_grouped.py``);
    * ``ring``: two neighbors; block-contiguous packing makes the
      cohort ring device-contiguous, so only ``2·D_K`` of the ``2K``
      messages cross the ``D_K = ⌈K/G⌉`` occupied devices ⇒
      ``2·D_K/K · model_bytes`` per active client (``2/G`` at K = n);
    * ``complete``: all K−1 active peers, K−G of them remote;
    * ``allreduce``: device-local reduce first (free), then a
      bandwidth-optimal ring all-reduce over the ``D_K`` occupied
      devices, amortized over the active clients per device:
      ``2·(D_K−1)/D_K · D_K/K · model_bytes``;
    * ``none``: no communication.

    ``codec`` (a name or :class:`repro.wire.codec.WireCodec`) replaces
    the gossip payload with its wire image: ``model_bytes`` is
    interpreted as the f32 flat row (``model_bytes / 4`` elements) and
    every peer-to-peer strategy (fedlay / ring / complete) ships
    ``codec.wire_bytes(elements)`` instead.  ``allreduce`` ignores the
    codec — in-network reduction has no per-edge wire image to
    compress.
    """
    n, G = num_clients, clients_per_device
    check_group_size(n, G)
    codec = get_codec(codec)
    if codec is not None and strategy in ("fedlay", "ring", "complete"):
        model_bytes = codec.wire_bytes(int(round(model_bytes / 4.0)))
    K = n if active_clients is None else int(active_clients)
    if not 1 <= K <= n:
        raise ValueError(f"active_clients {K} out of range for "
                         f"{n} clients")
    d_k = -(-K // G)                 # occupied devices, lowest-slot packing
    if strategy == "fedlay":
        if num_spaces is None:
            raise ValueError("fedlay accounting needs num_spaces")
        if K <= 1 or d_k == 1:
            return 0.0
        degree = min(2 * num_spaces, K - 1)
        return degree * model_bytes * (K - G) / (K - 1)
    if strategy == "ring":
        return 0.0 if d_k == 1 else 2.0 * d_k * model_bytes / K
    if strategy == "complete":
        return float(max(K - G, 0)) * model_bytes
    if strategy in ("allreduce", "fedavg"):
        return 2.0 * (d_k - 1) / d_k * d_k * model_bytes / K \
            if d_k > 1 else 0.0
    if strategy == "none":
        return 0.0
    raise ValueError(
        f"unknown sync strategy {strategy!r}; choose from "
        f"{SYNC_STRATEGIES + ('complete', 'fedavg')}")
