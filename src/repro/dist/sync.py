"""FedLay mixing compiled onto the device mesh (the paper's NDMP tables
as static collectives).

The control plane (``repro.core.ndmp``) converges neighbor tables
host-side; ``repro.core.mixing.build_permute_schedule`` (static mesh
layout) or ``repro.core.mixing.schedule_from_addresses`` (the live NDMP
alive set, via :class:`repro.overlay.OverlayController`) freezes them
into a :class:`~repro.core.mixing.PermuteSchedule` (2L ring rotations +
MEP confidence weights).  Schedules hash by content, so the overlay
controller keys its mixer compile cache on them and hot-swaps the
programs built here between training steps under churn.  This module
turns a schedule into device programs two ways:

* :func:`fedlay_mix` / :func:`make_mixer` — the explicit ``shard_map``
  path: one ``jax.lax.ppermute`` per (space × direction) slot, each
  device holding one client's replica on the client axis.  Verified
  equal to the dense ``schedule_mixing_matrix`` product in
  ``tests/test_dist.py``.
* :func:`global_mixer` — the global-view (auto-sharded jit) path used by
  ``repro.launch.steps.dfl_train_bundle``: permutation gathers along the
  leading client axis, which GSPMD lowers to collective-permutes when
  that axis is client-sharded.

Plus :func:`sync_bytes_per_client`, the paper's per-round communication
accounting (§IV-D / Fig. 20) shared by the scalability benchmarks.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.mixing import PermuteSchedule

#: Sync strategies understood by both mixer factories.
SYNC_STRATEGIES = ("fedlay", "allreduce", "ring", "none")


def ring_schedule(num_clients: int) -> PermuteSchedule:
    """The identity-ring overlay as a PermuteSchedule: one space, simple
    average over {self, predecessor, successor} (degenerates correctly
    at n ≤ 2, where the two directions collide)."""
    n = num_clients
    pred = tuple((i - 1) % n for i in range(n))
    succ = tuple((i + 1) % n for i in range(n))
    weights = np.zeros((n, 2), dtype=np.float64)
    self_w = np.ones((n,), dtype=np.float64)
    for i in range(n):
        seen = {i}
        for k, src in enumerate((pred[i], succ[i])):
            if src not in seen:
                weights[i, k] = 1.0
                seen.add(src)
    total = self_w + weights.sum(axis=1)
    weights /= total[:, None]
    self_w /= total
    return PermuteSchedule(num_clients=n, num_spaces=1, perms=(pred, succ),
                           weights=weights.astype(np.float32),
                           self_weight=self_w.astype(np.float32))


def fedlay_mix(tree, sched: PermuteSchedule, weights: jnp.ndarray,
               self_weight: jnp.ndarray, axis_name: str,
               mask: Optional[jnp.ndarray] = None):
    """One FedLay mixing round inside ``shard_map``.

    ``tree`` leaves carry a leading local-client dim (size 1 when the
    client axis maps 1:1 onto ``axis_name`` devices, which is the only
    supported layout); ``weights`` is the local (1, 2L) confidence-weight
    slice and ``self_weight`` the local (1,) self weight.  Equivalent to
    the dense ``W @ X`` of ``schedule_mixing_matrix(sched)``.

    ``mask`` (optional, local (c,) 0/1 float) makes the round mask-aware:
    a masked-out client (dead capacity slot, or a slow client skipping
    this collective under multirate participation) keeps its own model,
    and live clients drop its contribution and renormalize over the
    surviving weights — the per-device image of
    :func:`repro.core.mixing.masked_mixing_matrix`.  The mask rides the
    same ppermutes as the models, so masking adds 2L scalar permutes,
    not a retrace.
    """
    masked = mask is not None
    if masked:
        m = mask.astype(jnp.float32)
        eff = []
        for k in range(sched.num_slots):
            src_m = jax.lax.ppermute(m, axis_name,
                                     perm=sched.ppermute_pairs(k))
            eff.append(weights[:, k].astype(jnp.float32) * src_m)
        total = self_weight.astype(jnp.float32) + sum(eff)
        ok = (m > 0) & (total > 0)
        safe = jnp.where(total > 0, total, 1.0)
        self_w = (self_weight.astype(jnp.float32) / safe)
        slot_w = [e / safe for e in eff]
    else:
        self_w = self_weight
        slot_w = [weights[:, k] for k in range(sched.num_slots)]

    def mix_leaf(leaf):
        c = leaf.shape[0]
        shape = (c,) + (1,) * (leaf.ndim - 1)
        acc = leaf * self_w.reshape(shape).astype(leaf.dtype)
        for k in range(sched.num_slots):
            recv = jax.lax.ppermute(leaf, axis_name,
                                    perm=sched.ppermute_pairs(k))
            w = slot_w[k].reshape(shape).astype(leaf.dtype)
            acc = acc + recv * w
        if masked:
            acc = jnp.where(ok.reshape(shape), acc, leaf)
        return acc

    return jax.tree.map(mix_leaf, tree)


def make_mixer(strategy: str, sched: Optional[PermuteSchedule],
               axis_name: str, num_clients: int) -> Callable:
    """Build a ``shard_map``-body mixer ``(tree, weights, self_w) -> tree``
    for one sync strategy over the client axis ``axis_name``.

    * ``fedlay``   — 2L static ppermutes from ``sched`` (paper §III);
    * ``allreduce``— uniform mean over all clients (centralized image);
    * ``ring``     — identity-ring neighbor average (ignores ``sched``'s
      weights; uses its own uniform ring schedule);
    * ``none``     — isolated local training.
    """
    if strategy == "none":
        return lambda tree, weights, self_w: tree

    if strategy == "allreduce":
        def allreduce_mixer(tree, weights, self_w):
            def mean_leaf(leaf):
                m = jnp.mean(leaf.astype(jnp.float32), axis=0, keepdims=True)
                m = jax.lax.pmean(m, axis_name)
                return jnp.broadcast_to(m.astype(leaf.dtype), leaf.shape)
            return jax.tree.map(mean_leaf, tree)
        return allreduce_mixer

    if strategy == "ring":
        ring = ring_schedule(num_clients)
        ring_w = jnp.asarray(ring.weights)
        ring_s = jnp.asarray(ring.self_weight)

        def ring_mixer(tree, weights, self_w):
            i = jax.lax.axis_index(axis_name)
            return fedlay_mix(tree, ring, ring_w[i][None], ring_s[i][None],
                              axis_name)
        return ring_mixer

    if strategy == "fedlay":
        if sched is None:
            raise ValueError("fedlay mixer needs a PermuteSchedule")
        if sched.num_clients != num_clients:
            raise ValueError(
                f"schedule is for {sched.num_clients} clients, "
                f"mesh axis {axis_name!r} has {num_clients}")
        return lambda tree, weights, self_w: fedlay_mix(
            tree, sched, weights, self_w, axis_name)

    raise ValueError(
        f"unknown sync strategy {strategy!r}; choose from {SYNC_STRATEGIES}")


def global_mixer(strategy: str,
                 sched: Optional[PermuteSchedule] = None,
                 masked: bool = False) -> Callable:
    """Build a global-view mixer ``params -> params`` over the leading
    client axis (for auto-sharded jit, e.g. ``dfl_train_bundle``).

    For ``fedlay``/``ring`` each of the 2L slots is a permutation gather
    ``params[perm_k]`` along the client dim — GSPMD lowers it to a
    collective-permute when that dim is client-sharded, i.e. exactly the
    neighbor exchange :func:`fedlay_mix` spells out by hand.

    With ``masked=True`` the returned callable is ``(params, mask) ->
    params`` where ``mask`` is a (C,) 0/1 float *runtime input* (no
    retrace when it changes): masked-out rows keep their own model, live
    rows drop masked-out sources and renormalize — the device image of
    :func:`repro.core.mixing.masked_mixing_matrix`.  This is the seam
    the fixed-capacity slot runtime (dead slots) and multirate
    participation (slow clients skipping a collective) both plug into.
    """
    if strategy == "none":
        if masked:
            return lambda params, mask: params
        return lambda params: params

    if strategy == "allreduce":
        def allreduce(params):
            return jax.tree.map(
                lambda l: jnp.broadcast_to(
                    jnp.mean(l.astype(jnp.float32), axis=0,
                             keepdims=True).astype(l.dtype), l.shape),
                params)

        def allreduce_masked(params, mask):
            m = mask.astype(jnp.float32)
            denom = jnp.maximum(jnp.sum(m), 1.0)

            def mean_leaf(leaf):
                shape = (leaf.shape[0],) + (1,) * (leaf.ndim - 1)
                mm = m.reshape(shape)
                mean = jnp.sum(leaf.astype(jnp.float32) * mm, axis=0,
                               keepdims=True) / denom
                out = jnp.broadcast_to(mean.astype(leaf.dtype), leaf.shape)
                return jnp.where(mm > 0, out, leaf)
            return jax.tree.map(mean_leaf, params)
        return allreduce_masked if masked else allreduce

    if strategy in ("fedlay", "ring"):
        if sched is None:
            raise ValueError(f"{strategy} mixer needs a PermuteSchedule")
        C = sched.num_clients
        perms = jnp.asarray(np.array(sched.perms), jnp.int32)   # (2L, C)
        weights = jnp.asarray(sched.weights)                    # (C, 2L)
        self_w = jnp.asarray(sched.self_weight)                 # (C,)

        def mix(params):
            def mix_leaf(leaf):
                shape = (C,) + (1,) * (leaf.ndim - 1)
                acc = leaf * self_w.reshape(shape).astype(leaf.dtype)
                for k in range(sched.num_slots):
                    recv = jnp.take(leaf, perms[k], axis=0)  # permutation
                    w = weights[:, k].reshape(shape)
                    acc = acc + recv * w.astype(leaf.dtype)
                return acc
            return jax.tree.map(mix_leaf, params)

        def mix_masked(params, mask):
            m = mask.astype(jnp.float32)
            # (C, 2L) effective weights: source contributions gated by
            # the source's mask, rows renormalized over what survives
            eff = weights * jnp.take(m, perms, axis=0).T
            total = self_w + eff.sum(axis=1)
            ok = (m > 0) & (total > 0)
            safe = jnp.where(total > 0, total, 1.0)
            sw = self_w / safe
            ew = eff / safe[:, None]

            def mix_leaf(leaf):
                shape = (C,) + (1,) * (leaf.ndim - 1)
                acc = leaf * sw.reshape(shape).astype(leaf.dtype)
                for k in range(sched.num_slots):
                    recv = jnp.take(leaf, perms[k], axis=0)
                    acc = acc + recv * ew[:, k].reshape(shape).astype(
                        leaf.dtype)
                return jnp.where(ok.reshape(shape), acc, leaf)
            return jax.tree.map(mix_leaf, params)
        return mix_masked if masked else mix

    raise ValueError(
        f"unknown sync strategy {strategy!r}; choose from {SYNC_STRATEGIES}")


def sync_bytes_per_client(strategy: str, model_bytes: int, num_clients: int,
                          num_spaces: Optional[int] = None) -> float:
    """Bytes each client sends per mixing round (paper §IV-D accounting).

    * ``fedlay``: degree ≤ 2L ⇒ at most ``2L · model_bytes`` — constant
      in n, the paper's headline scalability claim;
    * ``ring``: two neighbors;
    * ``complete``: all n−1 peers (the dense-DFL strawman);
    * ``allreduce``: bandwidth-optimal ring all-reduce,
      ``2·(n−1)/n · model_bytes``;
    * ``none``: no communication.
    """
    n = num_clients
    if strategy == "fedlay":
        if num_spaces is None:
            raise ValueError("fedlay accounting needs num_spaces")
        return 2.0 * num_spaces * model_bytes
    if strategy == "ring":
        return 2.0 * model_bytes
    if strategy == "complete":
        return float(n - 1) * model_bytes
    if strategy in ("allreduce", "fedavg"):
        return 2.0 * (n - 1) / n * model_bytes
    if strategy == "none":
        return 0.0
    raise ValueError(
        f"unknown sync strategy {strategy!r}; choose from "
        f"{SYNC_STRATEGIES + ('complete', 'fedavg')}")
