"""jax version compatibility for the distribution layer.

The APIs the dist layer leans on drifted across jax releases:
``shard_map`` moved from ``jax.experimental`` to the top level and its
replication-check kwarg renamed ``check_rep`` → ``check_vma``;
``jax.make_mesh`` grew an ``axis_types`` kwarg (with
``jax.sharding.AxisType``).  Everything in-repo (and the subprocess
probes in tests/benchmarks) goes through these wrappers so one tree runs
on both API generations.
"""

from __future__ import annotations

import inspect

import jax

try:                                    # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map
except ImportError:                     # older jax: experimental module
    from jax.experimental.shard_map import shard_map as _shard_map

# The kwarg rename (check_rep → check_vma) happened independently of the
# export move, so probe the signature rather than the import location.
_CHECK_KW = ("check_vma" if "check_vma" in
             inspect.signature(_shard_map).parameters else "check_rep")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` across API generations (``check_vma`` maps onto
    ``check_rep`` for older jax)."""
    kwargs = {} if check_vma is None else {_CHECK_KW: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)


def auto_axis_types(n: int):
    """``(AxisType.Auto,) * n`` where supported, else None."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return None
    return (axis_type.Auto,) * n


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """``jax.make_mesh`` with auto axis types when the kwarg exists."""
    kwargs = {} if devices is None else {"devices": devices}
    types = auto_axis_types(len(tuple(axis_names)))
    if types is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=types, **kwargs)
        except TypeError:
            pass
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


def make_client_mesh(num_clients: int, axis_name: str = "data"):
    """The 1-axis client mesh every DFL shard_map program runs on."""
    return make_mesh((num_clients,), (axis_name,))
