"""PartitionSpec rules for every pytree the launch layer shards.

One rule set, four layouts:

* **FSDP + TP** (centralized train/prefill): matmul weights shard their
  d_model-ish dim over the ``fsdp`` axis and their parallel dim over the
  ``tp`` axis (column-parallel in-projections, row-parallel
  out-projections, Megatron-style).
* **Expert parallelism**: MoE expert stacks shard the expert dim over
  the ``tp`` axis (experts are data-parallel internally), the shared
  expert follows dense rules.
* **Serving**: cache specs shard batch over ``dp`` and KV heads over
  ``tp`` (or cache length, under the :data:`CACHE_LEN_TP` knob).
* **DFL client axis**: every leaf gains a leading client dim sharded
  over ``client_axis``; clients own their full replica, so FSDP is off
  and only TP applies inside the replica.  The client dim holds
  ``num_clients = clients_per_device · num_devices`` rows
  (:func:`dfl_client_count`): with G > 1 each device hosts a
  block-contiguous group of G clients (client ``i`` → device ``i // G``
  — the grouped layout of :mod:`repro.dist.sync`), which is exactly
  what GSPMD produces for a size-``G·D`` dim sharded over a size-``D``
  axis.

``enforce_divisibility`` drops any axis whose size does not divide the
corresponding dim — GSPMD would otherwise pad-and-mask, which is never
what a benchmark wants to measure.
"""

from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P


# Perf knob (§Perf hillclimb): serving caches shard the KV-head dim over
# the tp axis by default — at few KV heads (GQA) that caps tp
# utilization.  True shards the cache *length* dim instead (ring-style
# attention over fragments), trading an all-gather of the query per step
# for full-width cache parallelism.  Baseline = False.
CACHE_LEN_TP = False

#: Column-parallel in-projections: (d_in over fsdp, d_out over tp).
_COLUMN = frozenset({"wq", "wk", "wv", "wq_a", "wq_b", "wkv_a", "wkv_b",
                     "w_gate", "w_up", "in_proj"})
#: Row-parallel out-projections: (d_in over tp, d_out over fsdp).
_ROW = frozenset({"wo", "w_down", "out_proj"})


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for entry in path:
        if isinstance(entry, str):
            names.append(entry)
        elif hasattr(entry, "key"):
            names.append(str(entry.key))
        elif hasattr(entry, "name"):
            names.append(str(entry.name))
        elif hasattr(entry, "idx"):
            names.append(str(entry.idx))
        else:
            names.append(str(entry))
    return tuple(names)


def spec_for_leaf(path, leaf, *, fsdp: Optional[str] = None,
                  tp: Optional[str] = None) -> P:
    """Base PartitionSpec of one parameter leaf (no leading stack dims).

    ``path`` is a sequence of pytree keys (strings or jax KeyPath
    entries); the last entry names the parameter, earlier entries give
    context (expert weights live under ``moe`` but not ``shared``).
    """
    names = _path_names(path)
    name = names[-1] if names else ""
    in_expert = "moe" in names[:-1] and "shared" not in names
    if name in ("embed", "lm_head"):
        return P(tp, fsdp)                       # (vocab, d_model)
    if name == "mtp_proj":
        return P(fsdp, tp)                       # (2·d_model, d_model)
    if in_expert:
        if name == "router":
            return P(fsdp, None)                 # (d_model, E) — tiny, f32
        if name in ("w_gate", "w_up"):
            return P(tp, fsdp, None)             # (E, d_model, d_ff_e)
        if name == "w_down":
            return P(tp, None, fsdp)             # (E, d_ff_e, d_model)
    if name == "conv_w":
        return P(None, tp)                       # (d_conv, channels)
    if name in _COLUMN:
        return P(fsdp, tp)
    if name in _ROW:
        return P(tp, fsdp)
    # norms, biases, gates, A_log/D, anything 1-D: replicated
    return P(None) if leaf.ndim >= 1 else P()


def param_specs(params, fsdp: Optional[str] = None, tp: Optional[str] = None,
                client_axis: Optional[str] = None):
    """PartitionSpecs for a parameter pytree (or any stacked image of it).

    Leading dims beyond a leaf's base rank are stack dims: segment scan
    stacks get ``None``; with ``client_axis`` the outermost stack dim is
    the DFL client dim, sharded over that axis, and FSDP is disabled
    (each client owns its whole replica — the paper's deployment model).
    """
    if client_axis is not None:
        fsdp = None

    def one(path, leaf):
        base = tuple(spec_for_leaf(path, leaf, fsdp=fsdp, tp=tp))
        pad = leaf.ndim - len(base)
        if pad <= 0:
            return P(*base[len(base) - leaf.ndim:])
        if client_axis is not None:
            return P(client_axis, *([None] * (pad - 1)), *base)
        return P(*([None] * pad), *base)

    return jax.tree_util.tree_map_with_path(one, params)


def dfl_client_count(mesh, clients_per_device: int = 1) -> int:
    """Total DFL clients a mesh hosts: ``G ·  Π(non-model axis sizes)``.

    The client axis of every DFL bundle is sized by this rule, so the
    grouped layout stays consistent across the param/batch/mask specs:
    GSPMD shards the leading ``G·D`` client dim over the ``D`` data
    devices into exactly the block-contiguous groups
    :func:`repro.dist.sync.fedlay_mix` assumes."""
    if clients_per_device < 1:
        raise ValueError("clients_per_device must be >= 1")
    n = clients_per_device
    for a in mesh.axis_names:
        if a != "model":
            n *= mesh.shape[a]
    return n


def enforce_divisibility(specs, shapes, axis_sizes: Mapping[str, int]):
    """Replace any sharded dim whose mesh-axis product does not divide
    the dim size with ``None`` (replicated) — per dim, not per leaf."""

    def fix(spec, shp):
        dims = tuple(shp.shape)
        entries = tuple(spec) + (None,) * (len(dims) - len(tuple(spec)))
        out = []
        for dim, entry in zip(dims, entries):
            axes = entry if isinstance(entry, tuple) else (
                (entry,) if entry is not None else ())
            size = 1
            for a in axes:
                size *= int(axis_sizes.get(a, 1))
            out.append(entry if size <= 1 or dim % size == 0 else None)
        return P(*out)

    return jax.tree.map(fix, specs, shapes,
                        is_leaf=lambda x: isinstance(x, P))


def cache_specs(cache, dp=None, tp: Optional[str] = None,
                shard_batch: bool = True):
    """Decode-cache PartitionSpecs.

    Cache leaves are stacked per segment (leading repeat dim), then
    batch: KV caches shard batch over ``dp`` and heads over ``tp``
    (cache length instead under :data:`CACHE_LEN_TP`); SSM states shard
    their head dim over ``tp``; scalars (``pos``) are replicated.
    """

    def one(path, leaf):
        name = _path_names(path)[-1]
        nd = leaf.ndim
        if nd == 0:
            return P()
        b = dp if shard_batch else None
        if name in ("k", "v", "mem_k", "mem_v") and nd == 5:
            if CACHE_LEN_TP:
                return P(None, b, tp, None, None)   # (R, B, L, Hkv, hd)
            return P(None, b, None, tp, None)
        if name in ("c_kv", "k_rope") and nd == 4:   # (R, B, L, r)
            return P(None, b, tp if CACHE_LEN_TP else None, None)
        if name == "state" and nd == 5:              # (R, B, nh, hd, N)
            return P(None, b, tp, None, None)
        if name == "conv" and nd == 4:               # (R, B, w, ch)
            return P(None, b, None, tp)
        if nd >= 2:
            return P(None, b, *([None] * (nd - 2)))
        return P(None)

    return jax.tree_util.tree_map_with_path(one, cache)


def batch_spec(kind: str, dp_axes: Sequence[str],
               tp: Optional[str] = None) -> Dict[str, P]:
    """Input-batch PartitionSpecs for one step kind: batch over the data
    axes, everything else replicated (``tp`` reserved for future
    sequence-sharded inputs)."""
    dp = tuple(dp_axes)
    dp_spec: Any = dp if len(dp) > 1 else (dp[0] if dp else None)
    if kind in ("train", "prefill"):
        return {
            "tokens": P(dp_spec, None),
            "labels": P(dp_spec, None),
            "enc_embeds": P(dp_spec, None, None),
        }
    if kind in ("serve", "decode"):
        return {"token": P(dp_spec, None)}
    raise ValueError(f"unknown step kind {kind!r}")
