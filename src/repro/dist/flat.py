"""Flat-buffer layout for the fused mixing hot path.

The paper's per-round cost is dominated by moving and folding up to 2L
neighbor models (§III); doing that as a per-leaf tree walk pays every
collective and every accumulate once *per leaf*.  :class:`FlatSpec`
freezes a parameter tree's layout so each client's whole model lives in
**one contiguous lane-padded row**: ``ravel`` turns a ``(B, ...)``-leaf
tree into a single ``(B, N)`` buffer, ``unravel`` restores it exactly.
The fused paths in :mod:`repro.dist.sync` then run a whole mixing round
on that buffer — one ppermute moves one flat row instead of a tree of
leaves, and the accumulate is a :mod:`repro.kernels.weighted_mix`
Pallas kernel streaming tiles through VMEM.

**The flat-buffer contract**

* **Leading batch dim**: every leaf carries the same leading dim B (the
  local-client dim G under ``shard_map``, the population dim C in the
  global view).  Raveling maps leaf ``l`` to columns
  ``offsets[l] : offsets[l] + sizes[l]`` of the (B, N) buffer.
* **Lane padding**: each leaf's segment is zero-padded up to a multiple
  of :data:`repro.kernels.weighted_mix.LANE` (128), so every offset is
  lane-aligned and the total width N is a lane multiple — the kernels
  tile the buffer without re-padding, and per-leaf segments remain
  TPU-sliceable.  Pad columns are mixed like everything else (mixing is
  linear, zeros stay zeros) and dropped by ``unravel``.
* **Dtype-preserving offsets**: the buffer itself is a single floating
  dtype (default f32) and the spec records each leaf's original dtype;
  ``unravel`` casts back, so ``unravel ∘ ravel`` is the exact identity
  for every leaf dtype that embeds losslessly in the buffer dtype
  (bf16/f16/f32 into f32 — params trees).  Wider or non-float leaves
  are rejected loudly rather than rounded silently.

Specs are pure shape/dtype metadata (hashable, built at trace time from
tracers), so a jitted mixer rebuilds its spec deterministically per
trace and zero-retrace behavior is untouched.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.weighted_mix import LANE


def _pad_to(n: int, lane: int) -> int:
    return -(-n // lane) * lane


@dataclasses.dataclass(frozen=True)
class FlatSpec:
    """Frozen layout of one parameter tree inside a (B, N) flat buffer.

    Built with :meth:`for_tree`; ``ravel``/``unravel`` are exact
    inverses under the module-level contract."""

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]     # per-leaf trailing shapes
    dtypes: Tuple[Any, ...]                 # per-leaf original dtypes
    offsets: Tuple[int, ...]                # lane-aligned segment starts
    sizes: Tuple[int, ...]                  # unpadded element counts
    batch: int                              # the shared leading dim B
    size: int                               # N: total padded width
    dtype: Any                              # buffer dtype

    @classmethod
    def for_tree(cls, tree, dtype=jnp.float32, lane: int = LANE) -> "FlatSpec":
        """Freeze the layout of ``tree`` (leaves shaped (B, ...))."""
        leaves, treedef = jax.tree.flatten(tree)
        if not leaves:
            raise ValueError("cannot build a FlatSpec over an empty tree")
        buf_dt = jnp.dtype(dtype)
        if not jnp.issubdtype(buf_dt, jnp.floating):
            raise ValueError(f"buffer dtype must be floating, got {buf_dt}")
        batch = np.shape(leaves[0])[0] if np.ndim(leaves[0]) else None
        shapes, dtypes, offsets, sizes = [], [], [], []
        off = 0
        for i, leaf in enumerate(leaves):
            shape = tuple(np.shape(leaf))
            if not shape or shape[0] != batch:
                raise ValueError(
                    f"leaf {i} shape {shape} does not carry the shared "
                    f"leading batch dim {batch}")
            dt = jnp.dtype(getattr(leaf, "dtype", None)
                           or jnp.result_type(leaf))
            if (not jnp.issubdtype(dt, jnp.floating)
                    or jnp.finfo(dt).bits > jnp.finfo(buf_dt).bits):
                raise ValueError(
                    f"leaf {i} dtype {dt} does not embed losslessly in "
                    f"the {buf_dt} buffer (floating, ≤ {jnp.finfo(buf_dt).bits}"
                    f" bits required)")
            size = int(np.prod(shape[1:], dtype=np.int64)) if shape[1:] else 1
            shapes.append(shape[1:])
            dtypes.append(dt)
            offsets.append(off)
            sizes.append(size)
            off += _pad_to(size, lane)
        return cls(treedef=treedef, shapes=tuple(shapes), dtypes=tuple(dtypes),
                   offsets=tuple(offsets), sizes=tuple(sizes), batch=batch,
                   size=off, dtype=buf_dt)

    def ravel(self, tree) -> jnp.ndarray:
        """Tree of (B, ...) leaves → one contiguous (B, N) buffer."""
        leaves = self.treedef.flatten_up_to(tree)
        parts = []
        for leaf, shape, size, off, nxt in zip(
                leaves, self.shapes, self.sizes, self.offsets,
                self.offsets[1:] + (self.size,)):
            flat = jnp.reshape(leaf, (self.batch, size)).astype(self.dtype)
            pad = (nxt - off) - size
            if pad:
                flat = jnp.pad(flat, ((0, 0), (0, pad)))
            parts.append(flat)
        return jnp.concatenate(parts, axis=1)

    def unravel(self, buf: jnp.ndarray):
        """(B, N) buffer → the original tree, dtypes restored."""
        if buf.shape != (self.batch, self.size):
            raise ValueError(
                f"buffer shape {buf.shape} != ({self.batch}, {self.size})")
        leaves = []
        for shape, dt, off, size in zip(self.shapes, self.dtypes,
                                        self.offsets, self.sizes):
            seg = jax.lax.slice_in_dim(buf, off, off + size, axis=1)
            leaves.append(jnp.reshape(seg, (self.batch,) + shape).astype(dt))
        return self.treedef.unflatten(leaves)

    def unravel_row(self, row: jnp.ndarray):
        """One (N,) flat row → the per-client tree *without* the batch
        dim (leaf l gets shape ``shapes[l]``).  The serving plane's hot
        model-reload seam: a single client's trained weights lift
        straight out of the training loop's flat buffer into a
        ready-to-serve parameter tree — no host round-trip, no re-stack.
        """
        if row.shape != (self.size,):
            raise ValueError(f"row shape {row.shape} != ({self.size},)")
        leaves = []
        for shape, dt, off, size in zip(self.shapes, self.dtypes,
                                        self.offsets, self.sizes):
            seg = jax.lax.slice_in_dim(row, off, off + size, axis=0)
            leaves.append(jnp.reshape(seg, shape).astype(dt))
        return self.treedef.unflatten(leaves)
