from .noniid import (Partition, biased_locality_partition, iid_partition,
                     shard_partition)
from .synthetic import (CharLMData, ClassificationData, char_lm, cifar_like,
                        mnist_like, token_batches)

__all__ = [
    "Partition", "biased_locality_partition", "iid_partition",
    "shard_partition", "CharLMData", "ClassificationData", "char_lm",
    "cifar_like", "mnist_like", "token_batches",
]
