"""Token pipelines for LM-scale training/serving.

Two faces:

* ``input_specs(cfg, shape, ...)`` — ShapeDtypeStruct stand-ins for every
  model input of a (architecture × input-shape) pair: weak-type-correct,
  shardable, zero allocation.  This is what the multi-pod dry-run lowers
  against.
* ``TokenStream`` — a real deterministic synthetic stream with learnable
  n-gram structure for the end-to-end drivers (offline container: no
  downloaded corpora).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ArchConfig, InputShape


def enc_frames_for(cfg: ArchConfig, seq_len: int) -> int:
    """Encoder-memory length for the enc-dec (audio) family: the modality
    frontend is a stub per the carve-out; we size its output at 1/4 the
    decoder length (a 4x conv-downsampled mel stream), min 128 frames."""
    return max(128, seq_len // 4)


def input_specs(cfg: ArchConfig, shape: InputShape,
                dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one (arch × input shape) pair, as specs."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        if cfg.enc_dec:
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, enc_frames_for(cfg, S), cfg.d_model), dtype)
        return specs
    # decode: one new token against a seq_len-deep cache
    return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


# --------------------------------------------------------------------------
# Real synthetic stream (end-to-end drivers)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class TokenStream:
    """Deterministic synthetic next-token batches with short-range n-gram
    structure (loss measurably drops within a few hundred steps).

    ``client`` skews the n-gram table per DFL client → non-iid shards.
    """

    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    client: int = 0

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        rng = np.random.default_rng(self.seed * 1000003 + self.client)
        mult = int(rng.integers(3, 64)) * 2 + 1
        add = int(rng.integers(1, self.vocab_size))
        while True:
            base = rng.integers(0, self.vocab_size,
                                size=(self.batch, self.seq_len + 1))
            dep = (base[:, :-1] * mult + add) % self.vocab_size
            gate = rng.random((self.batch, self.seq_len)) < 0.7
            nxt = np.where(gate, dep, base[:, 1:])
            full = np.concatenate([base[:, :1], nxt], axis=1)
            yield (full[:, :-1].astype(np.int32), full[:, 1:].astype(np.int32))

    def batches(self, n: int):
        it = iter(self)
        for _ in range(n):
            yield next(it)
