"""Synthetic stand-ins for the paper's datasets (offline container — no
MNIST/CIFAR-10/Shakespeare downloads).

Shapes and label structure mirror the originals so the paper's non-iid
sharding protocol, models, and relative method orderings carry over:

* ``mnist_like``   — 10-class 8×8 "digit" images: class-specific
  prototype strokes + pixel noise (MLP task).
* ``cifar_like``   — 10-class 16×16×3 images: class-specific color/
  texture patterns + noise (CNN task).
* ``char_lm``      — role-conditioned Markov character streams over a
  vocabulary of 32 chars; each "speaking role" (client shard) has its
  own transition bias, mirroring Shakespeare's per-role sharding
  (LSTM next-character task).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class ClassificationData:
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    num_classes: int


def mnist_like(n_train: int = 4000, n_test: int = 1000, image: int = 8,
               noise: float = 0.7, seed: int = 0) -> ClassificationData:
    """10 classes of 8x8 images built from class prototypes + noise."""
    rng = np.random.default_rng(seed)
    k = 10
    protos = rng.normal(0, 1, size=(k, image * image))
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)

    def sample(n):
        y = rng.integers(0, k, size=n)
        x = protos[y] + noise * rng.normal(0, 1, size=(n, image * image)) / np.sqrt(image * image)
        return x.astype(np.float32), y.astype(np.int32)

    xtr, ytr = sample(n_train)
    xte, yte = sample(n_test)
    return ClassificationData(xtr, ytr, xte, yte, k)


def cifar_like(n_train: int = 4000, n_test: int = 1000, image: int = 16,
               noise: float = 1.0, seed: int = 0) -> ClassificationData:
    """10 classes of 16x16x3 images: per-class low-frequency pattern +
    color bias + iid noise — hard enough that a linear model underfits
    but a small CNN separates (mirrors the paper's CIFAR accuracy band).
    """
    rng = np.random.default_rng(seed)
    k = 10
    yy, xx = np.mgrid[0:image, 0:image].astype(np.float32) / image
    patterns = []
    for c in range(k):
        fx, fy = rng.integers(1, 4, size=2)
        phase = rng.random(2) * 2 * np.pi
        pat = np.sin(2 * np.pi * fx * xx + phase[0]) * np.cos(2 * np.pi * fy * yy + phase[1])
        color = rng.normal(0, 1, size=3)
        patterns.append(pat[..., None] * color[None, None, :])
    patterns = np.stack(patterns)  # (k, H, W, 3)

    def sample(n):
        y = rng.integers(0, k, size=n)
        x = patterns[y] + noise * rng.normal(0, 1, size=(n, image, image, 3))
        return x.astype(np.float32), y.astype(np.int32)

    xtr, ytr = sample(n_train)
    xte, yte = sample(n_test)
    return ClassificationData(xtr, ytr, xte, yte, k)


@dataclasses.dataclass
class CharLMData:
    """Role-sharded character streams (one role ≈ one client shard)."""

    role_streams: np.ndarray   # (num_roles, stream_len) int32 tokens
    role_labels: np.ndarray    # (num_roles,) pseudo-label = dominant char class
    test_stream: np.ndarray    # (test_len,) mixture of all roles
    vocab_size: int


def char_lm(num_roles: int = 64, stream_len: int = 2048, test_len: int = 8192,
            vocab: int = 32, seed: int = 0) -> CharLMData:
    """Markov text: a shared base transition matrix + per-role bias toward
    a role-specific subset of characters (the non-iid structure)."""
    rng = np.random.default_rng(seed)
    base = rng.dirichlet(np.ones(vocab) * 0.5, size=vocab)  # (v, v)
    streams = np.zeros((num_roles, stream_len), dtype=np.int32)
    role_labels = np.zeros(num_roles, dtype=np.int32)
    for r in range(num_roles):
        fav = rng.choice(vocab, size=4, replace=False)
        role_labels[r] = fav[0] % 10
        T = base.copy()
        T[:, fav] *= 4.0
        T /= T.sum(axis=1, keepdims=True)
        s = rng.integers(vocab)
        for t in range(stream_len):
            streams[r, t] = s
            s = rng.choice(vocab, p=T[s])
    # test stream: mixture of role dynamics
    test = np.zeros(test_len, dtype=np.int32)
    s = rng.integers(vocab)
    T = base / base.sum(axis=1, keepdims=True)
    for t in range(test_len):
        test[t] = s
        s = rng.choice(vocab, p=T[s])
    return CharLMData(streams, role_labels, test, vocab)


# --------------------------------------------------------------------------
# Token-stream pipeline for LM-scale training (used by launch/train.py)
# --------------------------------------------------------------------------

def token_batches(vocab_size: int, batch: int, seq_len: int, num_batches: int,
                  seed: int = 0):
    """Deterministic synthetic next-token batches: a linear-congruential
    sequence with learnable short-range structure — enough for loss to
    drop measurably in a few hundred steps."""
    rng = np.random.default_rng(seed)
    mix = rng.integers(1, vocab_size, size=7)
    for b in range(num_batches):
        base = rng.integers(0, vocab_size, size=(batch, seq_len + 1))
        # inject n-gram structure: x[t+1] depends on x[t] half the time
        dep = (base[:, :-1] * 31 + mix[b % 7]) % vocab_size
        gate = rng.random((batch, seq_len)) < 0.5
        tokens = np.where(gate, dep, base[:, 1:])
        full = np.concatenate([base[:, :1], tokens], axis=1)
        yield full[:, :-1].astype(np.int32), full[:, 1:].astype(np.int32)
