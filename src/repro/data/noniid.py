"""Non-iid data partitioning (paper §IV-A2, "Learning with non-iid data").

The paper generates non-iid client datasets by *sharding*: the training
set is sorted by label and split into shards, each shard containing only
one label; each client receives a limited number of shards.  Fewer
shards per client ⇒ more non-iid.  We implement exactly that, plus the
paper's *biased-locality* grouping (each of 10 groups holds 6 of 10
labels, shifted by one label per group) used in §IV-C.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Partition:
    """client -> example indices, plus label bookkeeping."""

    client_indices: List[np.ndarray]
    num_classes: int

    def label_histogram(self, labels: np.ndarray, client: int) -> np.ndarray:
        h = np.bincount(labels[self.client_indices[client]], minlength=self.num_classes)
        return h.astype(np.float64)

    @property
    def num_clients(self) -> int:
        return len(self.client_indices)


def shard_partition(labels: np.ndarray, num_clients: int, shards_per_client: int,
                    num_classes: Optional[int] = None, seed: int = 0,
                    allow_overlap: bool = False) -> Partition:
    """The paper's sharding method.

    Sort by label, cut into ``num_clients * shards_per_client`` single-
    label shards, deal ``shards_per_client`` random shards to each
    client.  ``allow_overlap=True`` reuses shards when there are more
    clients than data supports (the paper's large-scale-simulation mode).
    """
    labels = np.asarray(labels)
    num_classes = num_classes or int(labels.max()) + 1
    rng = np.random.default_rng(seed)
    order = np.argsort(labels, kind="stable")
    total_shards = num_clients * shards_per_client
    shards = np.array_split(order, total_shards)
    if allow_overlap:
        assignment = rng.integers(0, total_shards, size=total_shards)
    else:
        assignment = rng.permutation(total_shards)
    client_indices = []
    for c in range(num_clients):
        ids = assignment[c * shards_per_client:(c + 1) * shards_per_client]
        client_indices.append(np.concatenate([shards[i] for i in ids]))
    return Partition(client_indices=client_indices, num_classes=num_classes)


def biased_locality_partition(labels: np.ndarray, num_clients: int,
                              num_groups: int = 10, labels_per_group: int = 6,
                              samples_per_label: int = 200, seed: int = 0) -> Partition:
    """§IV-C biased-locality setting: clients split evenly into groups;
    group g holds labels {g, g+1, .., g+labels_per_group-1} (mod K), i.e.
    adjacent groups differ by exactly one label."""
    labels = np.asarray(labels)
    num_classes = int(labels.max()) + 1
    rng = np.random.default_rng(seed)
    by_label = [np.nonzero(labels == k)[0] for k in range(num_classes)]
    client_indices = []
    for c in range(num_clients):
        g = c * num_groups // num_clients
        idx = []
        for off in range(labels_per_group):
            k = (g + off) % num_classes
            take = rng.choice(by_label[k], size=min(samples_per_label, len(by_label[k])),
                              replace=len(by_label[k]) < samples_per_label)
            idx.append(take)
        client_indices.append(np.concatenate(idx))
    return Partition(client_indices=client_indices, num_classes=num_classes)


def iid_partition(labels: np.ndarray, num_clients: int, seed: int = 0) -> Partition:
    labels = np.asarray(labels)
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(labels))
    return Partition(client_indices=list(np.array_split(order, num_clients)),
                     num_classes=int(labels.max()) + 1)
