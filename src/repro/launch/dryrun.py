import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
against the production mesh, with zero allocation (ShapeDtypeStruct
inputs), and extract the roofline terms from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.jsonl

Every row records: per-device memory (args/temp/output), per-device HLO
FLOPs and HBM bytes from ``cost_analysis``, collective op counts and
ring-model wire bytes from the HLO text, the three roofline terms in
seconds, the dominant term, and MODEL_FLOPS/HLO_FLOPs.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs import INPUT_SHAPES, REGISTRY, for_shape, get
from ..models.config import ArchConfig, InputShape
from ..models.model import find_segments, layer_plan
from ..optim.optimizers import adamw
from .hlo_stats import collective_stats, reshape_transpose_count
from .mesh import make_production_mesh
from .steps import bundle_for, jit_bundle

# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def model_flops(cfg: ArchConfig, shape: InputShape) -> float:
    """Useful model FLOPs per step: 6·N·D train, 2·N·D prefill/decode,
    with N = active params (MoE counts top-k + shared only)."""
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch      # decode: one token per seq


# --------------------------------------------------------------------------
# Depth-probe cost correction.
#
# XLA's HloCostAnalysis visits a while-loop body ONCE — it does not
# multiply by the trip count — so the full model's cost_analysis
# understates scan-stacked layers by ~num_layers×.  We correct by
# two-point depth extrapolation: compile the same step at per-segment
# depths r=4 and r=8 (both in the nested-remat regime, so the marginal
# per-layer cost matches the full model) and extend linearly:
#     cost(R) = cost(base) + (R - r_base) · [cost(bump) - cost(base)] / Δr
# This also corrects collective wire bytes for collectives inside scan
# bodies.  Exact for costs linear in depth, which scans are.
# --------------------------------------------------------------------------

def _depth_units(cfg: ArchConfig) -> List[Tuple[str, int, int]]:
    """(unit name, superblock size, full repeats) per scanned stack."""
    units = [(f"seg{i}", len(pat), reps)
             for i, (pat, reps) in enumerate(find_segments(layer_plan(cfg)))]
    if cfg.enc_dec:
        units.append(("enc", 1, cfg.enc_layers))
    return units


def _with_reps(cfg: ArchConfig, units, reps: List[int]) -> ArchConfig:
    kw = {}
    dec_layers = 0
    for (name, p, _), r in zip(units, reps):
        if name == "enc":
            kw["enc_layers"] = r
        else:
            dec_layers += r * p
    kw["num_layers"] = dec_layers
    if cfg.first_dense_layers > 0:
        # seg0 is the leading dense run
        kw["first_dense_layers"] = reps[0] * units[0][1]
    return dataclasses.replace(cfg, **kw)


def _measure(cfg: ArchConfig, shape: InputShape, mesh, optimizer,
             dtype) -> Tuple[float, float, float]:
    from ..models import attention as attn_mod
    from ..models import model as model_mod
    model_mod.SCAN_UNROLL = True            # cost analysis needs straight-line HLO
    attn_mod.CHUNK_OVERRIDE = 4096          # fewer, bigger blocks (same FLOPs)
    try:
        bundle = bundle_for(cfg, shape, mesh, optimizer, dtype=dtype)
        jitted = jit_bundle(bundle, mesh)
        with mesh:
            compiled = jitted.lower(*bundle.arg_shapes).compile()
    finally:
        model_mod.SCAN_UNROLL = False
        attn_mod.CHUNK_OVERRIDE = None
    ca = compiled.cost_analysis() or {}
    coll = collective_stats(compiled.as_text())
    return (float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0)),
            coll.wire_bytes_per_device)


def probed_costs(cfg: ArchConfig, shape: InputShape, mesh, optimizer,
                 dtype) -> Tuple[float, float, float]:
    """Depth-corrected (flops, hbm_bytes, wire_bytes) per device."""
    units = _depth_units(cfg)
    # train probes must sit in the nested-remat regime (repeats ≥ 4) so
    # the marginal per-layer cost matches the full model; inference steps
    # have no remat, so shallower probes suffice.  For multi-layer
    # superblocks (hybrid patterns) the repeat counts are scaled down so
    # the unrolled probe stays ≤ ~8 layers — those probes run in the
    # plain-remat regime (one fewer forward recompute per layer), which
    # understates train FLOPs for such archs by ≤ ~20% (recorded).
    lo, hi = (4, 8) if shape.kind == "train" else (2, 4)

    def scaled(v: int, p: int) -> int:
        return max(1, v // p) if p > 1 else v

    base_reps = [min(r, scaled(lo, p)) for (_, p, r) in units]
    base_cfg = _with_reps(cfg, units, base_reps)
    base = _measure(base_cfg, shape, mesh, optimizer, dtype)
    total = list(base)
    for i, (name, p, r_full) in enumerate(units):
        if r_full <= base_reps[i]:
            continue
        bump_reps = list(base_reps)
        bump_reps[i] = min(r_full, max(base_reps[i] + 1, scaled(hi, p)))
        bump = _measure(_with_reps(cfg, units, bump_reps), shape, mesh,
                        optimizer, dtype)
        dr = bump_reps[i] - base_reps[i]
        scale = (r_full - base_reps[i]) / dr
        for k in range(3):
            total[k] += (bump[k] - base[k]) * scale
    return tuple(total)


OPT_FLAGS = ("bf16c", "seqp", "moepe", "servetp", "cachelp")


def set_opts(opts: str) -> Dict[str, bool]:
    """Apply §Perf optimization toggles (comma-separated):

    bf16c  — bf16 dot outputs ⇒ bf16 partial-sum collectives
    seqp   — sequence-parallel inter-layer activations
    moepe  — per-example MoE dispatch (batch-sharded routing)
    """
    from ..dist import sharding as sharding_mod
    from ..models import layers as layers_mod
    from ..models import moe as moe_mod
    from . import steps as steps_mod
    flags = {f: (f in opts.split(",")) for f in OPT_FLAGS} if opts else \
        {f: False for f in OPT_FLAGS}
    layers_mod.F32_DOT_OUTPUT = not flags["bf16c"]
    steps_mod.SEQ_PARALLEL = flags["seqp"]
    moe_mod.PER_EXAMPLE = flags["moepe"]
    steps_mod.SERVE_WEIGHT_STATIONARY = flags["servetp"]
    sharding_mod.CACHE_LEN_TP = flags["cachelp"]
    return flags


def run_one(arch: str, shape_name: str, multi_pod: bool,
            dtype=jnp.bfloat16, verbose: bool = True,
            probe: bool = True, opts: str = "", sync: str = "standard") -> Dict:
    flags = set_opts(opts)
    shape = INPUT_SHAPES[shape_name]
    cfg = for_shape(get(arch), shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    optimizer = adamw(3e-4) if shape.kind == "train" else None

    t0 = time.time()
    if sync != "standard":
        # DFL mode: the paper's technique at production scale — one
        # FedLay client per data-axis position, model sync = 2L
        # permutation exchanges (or the allreduce/FedAvg baseline).
        from .steps import dfl_train_bundle
        assert shape.kind == "train", "DFL mode lowers train_step"
        bundle = dfl_train_bundle(cfg, shape, mesh, optimizer, dtype=dtype,
                                  sync=sync)
    else:
        bundle = bundle_for(cfg, shape, mesh, optimizer, dtype=dtype)
    jitted = jit_bundle(bundle, mesh)
    with mesh:
        lowered = jitted.lower(*bundle.arg_shapes)
        compiled = lowered.compile()
    compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    coll = collective_stats(hlo)
    resh, tran = reshape_transpose_count(hlo)

    if probe:
        flops_dev, bytes_dev, wire_dev = probed_costs(
            cfg, shape, mesh, optimizer, dtype)
    else:
        flops_dev = float(ca.get("flops", 0.0))
        bytes_dev = float(ca.get("bytes accessed", 0.0))
        wire_dev = coll.wire_bytes_per_device
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = wire_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, shape) / chips      # per-device useful FLOPs
    row = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "opts": opts or "baseline", "sync": sync,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "attn": ("sliding" if cfg.sliding_window else
                 ("none" if cfg.family == "ssm" else "full")),
        "compile_s": round(compile_s, 1),
        "mem_args_gib": round(mem.argument_size_in_bytes / 2**30, 3),
        "mem_temp_gib": round(mem.temp_size_in_bytes / 2**30, 3),
        "mem_out_gib": round(mem.output_size_in_bytes / 2**30, 3),
        "flops_per_dev": flops_dev,
        "hbm_bytes_per_dev": bytes_dev,
        "collective_counts": coll.counts,
        "wire_bytes_per_dev": wire_dev,
        "depth_probed": probe,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops_per_dev": mf,
        "useful_flops_ratio": (mf / flops_dev) if flops_dev else 0.0,
        "reshapes": resh, "transposes": tran,
    }
    if verbose:
        print(json.dumps(row))
        sys.stdout.flush()
    return row


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id")
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="all 10 archs x 4 shapes")
    ap.add_argument("--out", default=None, help="append JSONL rows here")
    ap.add_argument("--dtype", default="bf16", choices=["bf16", "f32"])
    ap.add_argument("--no-probe", action="store_true",
                    help="skip depth-probe cost correction (rolled costs)")
    ap.add_argument("--opts", default="",
                    help="perf toggles: comma-set of "
                         "bf16c,seqp,moepe,servetp,cachelp")
    ap.add_argument("--sync", default="standard",
                    choices=["standard", "fedlay", "allreduce", "ring",
                             "none"],
                    help="DFL mode: one FedLay client per data position")
    args = ap.parse_args()

    dtype = jnp.bfloat16 if args.dtype == "bf16" else jnp.float32
    archs = sorted(REGISTRY) if args.all or not args.arch else [args.arch]
    shapes = list(INPUT_SHAPES) if args.all or not args.shape else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    rows = []
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                tag = f"{arch} x {shape_name} x {'2x16x16' if multi else '16x16'}"
                try:
                    row = run_one(arch, shape_name, multi, dtype=dtype,
                                  probe=not args.no_probe, opts=args.opts,
                                  sync=args.sync)
                    rows.append(row)
                    if args.out:
                        with open(args.out, "a") as f:
                            f.write(json.dumps(row) + "\n")
                except Exception as e:  # noqa: BLE001 — report every pair
                    failures.append(tag)
                    print(f"FAIL {tag}: {type(e).__name__}: {e}", file=sys.stderr)
                    traceback.print_exc()
    print(f"\n{len(rows)} ok, {len(failures)} failed", file=sys.stderr)
    for f in failures:
        print(f"  FAILED: {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
