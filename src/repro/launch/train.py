"""End-to-end DFL training driver — the paper's system on the TPU path.

Each device of the mesh's client axis hosts ``--clients-per-device``
FedLay clients (default 1): full model replicas training on their own
non-iid token shards, stacked on a leading local-client dim.  After
every local step the clients mix models over the FedLay overlay —
grouped ``ppermute`` rotations with MEP confidence weights inside
``shard_map``; with G > 1 intra-device edges never touch the wire — or
with the selectable baselines (``allreduce`` = centralized FedAvg
aggregation, ``ring``, ``none`` = isolated local training).

Runs on real multi-device meshes and on CPU via host-platform devices:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --clients 8 --steps 200 \
      --sync fedlay --spaces 3

  # 16 clients on 8 devices (2 per device):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python -m repro.launch.train --clients 16 \
      --clients-per-device 2 --steps 200
"""

from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json
import sys
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.mixing import build_permute_schedule
from ..data.tokens import TokenStream
from ..dist.compat import make_client_mesh, shard_map
from ..dist.sync import make_mixer
from ..models.config import ArchConfig
from ..models.model import init_params, train_loss
from ..optim.optimizers import adamw, apply_updates, clip_by_global_norm


def tiny_lm(vocab: int = 512, d_model: int = 128, layers: int = 4) -> ArchConfig:
    return ArchConfig(name="tiny-lm", family="dense", num_layers=layers,
                      d_model=d_model, num_heads=4, num_kv_heads=2,
                      head_dim=32, d_ff=4 * d_model, vocab_size=vocab,
                      tie_embeddings=True, rope_theta=10_000.0)


def make_dfl_step(cfg: ArchConfig, optimizer, mixer, mesh: Mesh,
                  axis: str = "data", error_feedback: bool = False):
    """One DFL round: local grad step on each client, then overlay mix.
    The leading local-client dim inside shard_map is G (= 1 for the
    flat layout), so the local step vmaps over it.  With
    ``error_feedback`` (lossy wire codec) the step carries the (G, N)
    compression residual through the round."""

    def one(p, o, b):
        loss, grads = jax.value_and_grad(
            lambda q: train_loss(cfg, q, b, remat=False))(p)
        grads, _ = clip_by_global_norm(grads, 1.0)
        updates, o = optimizer.update(grads, o, p)
        return apply_updates(p, updates), o, loss

    spec_c = P(axis)       # leading client dim

    if error_feedback:
        def body_ef(params_l, opt_l, batch_l, w_l, sw_l, res_l):
            params_l, opt_l, loss = jax.vmap(one)(params_l, opt_l, batch_l)
            mixed, res_l = mixer(params_l, w_l, sw_l, res_l)
            mean_loss = jax.lax.pmean(jnp.mean(loss), axis)
            return mixed, opt_l, res_l, mean_loss

        body_sm = shard_map(
            body_ef, mesh=mesh,
            in_specs=(spec_c, spec_c, spec_c, spec_c, spec_c,
                      P(axis, None)),
            out_specs=(spec_c, spec_c, P(axis, None), P()),
            check_vma=False)
        return jax.jit(body_sm)

    def body(params_l, opt_l, batch_l, w_l, sw_l):
        params_l, opt_l, loss = jax.vmap(one)(params_l, opt_l, batch_l)
        mixed = mixer(params_l, w_l, sw_l)
        mean_loss = jax.lax.pmean(jnp.mean(loss), axis)
        return mixed, opt_l, mean_loss

    body_sm = shard_map(
        body, mesh=mesh,
        in_specs=(spec_c, spec_c, spec_c, spec_c, spec_c),
        out_specs=(spec_c, spec_c, P()),
        check_vma=False)
    return jax.jit(body_sm)


def run(args) -> Dict:
    n, G = args.clients, args.clients_per_device
    if n % G:
        raise SystemExit(f"--clients {n} must be a multiple of "
                         f"--clients-per-device {G}")
    mesh = make_client_mesh(n // G, "data")
    cfg = tiny_lm(vocab=args.vocab, d_model=args.d_model, layers=args.layers)

    # per-client params (same init — standard DFL assumption) + opt state
    key = jax.random.PRNGKey(args.seed)
    p0 = init_params(cfg, key)
    optimizer = adamw(args.lr, weight_decay=0.0)
    o0 = optimizer.init(p0)
    stack = lambda t: jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n,) + x.shape), t)
    params, opt_state = stack(p0), stack(o0)
    shard_c = NamedSharding(mesh, P("data"))
    params = jax.tree.map(lambda x: jax.device_put(x, shard_c), params)
    opt_state = jax.tree.map(lambda x: jax.device_put(x, shard_c), opt_state)

    # FedLay overlay over client ids 0..n-1, compiled to the ppermute
    # schedule (MEP confidence weights from the per-client data skew)
    sched = build_permute_schedule(n, args.spaces)
    codec_name = getattr(args, "codec", None)
    mixer = make_mixer(args.sync, sched, "data", n, clients_per_device=G,
                       fuse=getattr(args, "fuse", None), codec=codec_name)
    weights = jax.device_put(jnp.asarray(sched.weights), shard_c)
    self_w = jax.device_put(jnp.asarray(sched.self_weight), shard_c)

    from ..dist.sync import resolve_wire
    codec, _ = resolve_wire(codec_name, getattr(args, "fuse", None))
    ef = (codec is not None and codec.error_feedback
          and args.sync in ("fedlay", "ring"))
    residual = None
    if ef:
        from ..dist.flat import FlatSpec
        nflat = FlatSpec.for_tree(params).size
        residual = jax.device_put(jnp.zeros((n, nflat), jnp.float32),
                                  NamedSharding(mesh, P("data", None)))

    # non-iid client shards
    streams = [iter(TokenStream(cfg.vocab_size, args.batch, args.seq,
                                seed=args.seed, client=c)) for c in range(n)]

    step_fn = make_dfl_step(cfg, optimizer, mixer, mesh, error_feedback=ef)

    # opt-in observability: --telemetry-out installs a bus + per-round
    # ledger for the run; --profile-dir wraps it in a profiler capture
    from ..obs import (RoundLedger, Telemetry, capture, round_ledger,
                       telemetry)
    from ..dist.sync import sync_bytes_per_client
    telemetry_out = getattr(args, "telemetry_out", None)
    bus = Telemetry() if telemetry_out else None
    ledger = RoundLedger(bus=bus) if telemetry_out else None
    row_elems = sum(int(np.prod(l.shape[1:], dtype=np.int64))
                    for l in jax.tree.leaves(params))
    wire = sync_bytes_per_client(
        args.sync, 4 * row_elems, n, num_spaces=args.spaces,
        clients_per_device=G, codec=codec_name)
    payload = (sync_bytes_per_client(
        args.sync, 4 * row_elems, n, num_spaces=args.spaces,
        clients_per_device=G) if codec_name is not None else wire)

    # crash/resume: --ckpt-dir periodically checkpoints the full
    # training state (params, optimizer state, EF residual) as a
    # flattened leaf list (optimizer states are NamedTuples the ckpt
    # treedef spec doesn't cover) and resumes from the newest
    # checkpoint on startup.  Data streams are deterministic in
    # (seed, client, step), so replaying from step k is exact.
    manager = None
    start_step = 0
    if getattr(args, "ckpt_dir", None):
        from ..ckpt.checkpoint import CheckpointManager
        manager = CheckpointManager(args.ckpt_dir)

        def _state():
            state = {"params": params, "opt_state": opt_state}
            if ef:
                state["residual"] = residual
            return state

        if manager.latest() is not None:
            tree, meta = manager.restore()
            template = _state()
            treedef = jax.tree.structure(template)
            leaves = [jnp.asarray(l) for l in tree["leaves"]]
            state = jax.tree.unflatten(treedef, leaves)
            put = lambda t: jax.tree.map(
                lambda x: jax.device_put(x, shard_c), t)
            params, opt_state = put(state["params"]), put(state["opt_state"])
            if ef:
                residual = jax.device_put(
                    state["residual"], NamedSharding(mesh, P("data", None)))
            start_step = int(meta["step"])
            # fast-forward the deterministic shards to the resume point
            for s in streams:
                for _ in range(start_step):
                    next(s)
            print(f"resumed from {args.ckpt_dir} at step {start_step}",
                  flush=True)

    losses = []
    t0 = time.time()
    with contextlib.ExitStack() as stack_ctx:
        if bus is not None:
            stack_ctx.enter_context(telemetry(bus))
            stack_ctx.enter_context(round_ledger(ledger))
        if getattr(args, "profile_dir", None):
            stack_ctx.enter_context(capture(args.profile_dir))
        for step in range(start_step, args.steps):
            xs, ys = zip(*(next(s) for s in streams))
            batch = {"tokens": jnp.asarray(np.stack(xs)),
                     "labels": jnp.asarray(np.stack(ys))}
            batch = jax.tree.map(lambda x: jax.device_put(x, shard_c), batch)
            if ef:
                params, opt_state, residual, loss = step_fn(
                    params, opt_state, batch, weights, self_w, residual)
            else:
                params, opt_state, loss = step_fn(params, opt_state, batch,
                                                  weights, self_w)
            losses.append(float(loss))
            if ledger is not None:
                bus.count("train.steps")
                ledger.record(round=step, time=time.time() - t0,
                              loop="train", num_alive=n, participating=n,
                              loss=losses[-1],
                              wire_bytes_per_client=wire,
                              payload_bytes_per_client=payload)
            if manager is not None and (
                    (step + 1) % max(getattr(args, "ckpt_every", 0), 1) == 0
                    or step == args.steps - 1):
                leaves = [np.asarray(jax.device_get(l))
                          for l in jax.tree.leaves(_state())]
                manager.save(step + 1, {"leaves": leaves})
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                      f"({(time.time()-t0)/(step+1):.2f}s/step)", flush=True)
    result = {"sync": args.sync, "clients": n, "clients_per_device": G,
              "steps": args.steps, "codec": codec_name,
              "start_step": start_step,
              "first_loss": losses[0] if losses else float("nan"),
              "final_loss": losses[-1] if losses else float("nan"),
              "losses": losses}
    if ledger is not None:
        rows = ledger.to_jsonl(telemetry_out)
        result["telemetry"] = ledger.summary()
        print(f"wrote {rows} round records to {telemetry_out}")
        print(ledger.summary_table())
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f)
    return result


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--clients", type=int, default=len(jax.devices()))
    ap.add_argument("--clients-per-device", type=int, default=1,
                    help="G local clients per mesh device "
                         "(total clients = G × devices)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--sync", default="fedlay",
                    choices=["fedlay", "allreduce", "ring", "none"])
    ap.add_argument("--fuse", default=None,
                    choices=["tree", "flat"],
                    help="mixing-round execution: per-leaf tree walk "
                         "(default) or the flat-buffer Pallas fused path")
    ap.add_argument("--codec", default=None,
                    choices=["none", "bf16", "int8-block", "int4-block",
                             "topk"],
                    help="wire codec for the fedlay/ring gossip payload "
                         "(implies --fuse flat; lossy codecs carry an "
                         "error-feedback residual through the run)")
    ap.add_argument("--spaces", type=int, default=3)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None, metavar="DIR",
                    help="crash/resume: checkpoint the training state "
                         "into DIR every --ckpt-every steps and resume "
                         "from the newest checkpoint on startup")
    ap.add_argument("--ckpt-every", type=int, default=50,
                    help="steps between checkpoints (with --ckpt-dir)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--telemetry-out", default=None, metavar="PATH",
                    help="enable the repro.obs plane for this run and "
                         "write the per-round ledger as JSONL to PATH "
                         "(also prints the summary table)")
    ap.add_argument("--profile-dir", default=None, metavar="PATH",
                    help="capture a jax.profiler trace of the run into "
                         "PATH (view with TensorBoard / Perfetto)")
    args = ap.parse_args()
    res = run(args)
    print(f"loss {res['first_loss']:.4f} -> {res['final_loss']:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
