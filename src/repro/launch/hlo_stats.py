"""Collective-traffic statistics parsed from compiled HLO text.

``cost_analysis()`` gives FLOPs and HBM bytes but not inter-chip
traffic, so the roofline's collective term is derived here: every
``all-gather`` / ``all-reduce`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` op is extracted from the HLO and converted into
**wire bytes per participating device** using the standard ring-
algorithm cost model:

    all-gather:          (g-1)/g · result_bytes
    reduce-scatter:      (g-1)   · result_bytes      (= (g-1)/g · operand)
    all-reduce:        2·(g-1)/g · bytes
    all-to-all:          (g-1)/g · bytes
    collective-permute:            bytes             (point-to-point)

with ``g`` the replica-group size parsed from the op's
``replica_groups`` attribute.  Ops inside while/scan bodies execute
once per iteration; HLO text does not annotate trip counts, so counts
here are per-execution of the (already scan-rolled) module — consistent
with ``cost_analysis`` which also reports rolled counts.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*\)|[\w\[\],{}\s/]+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.M)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRCDST_RE = re.compile(r"source_target_pairs=\{([^}]*)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    result_bytes: Dict[str, int]          # sum of result sizes per op kind
    wire_bytes_per_device: float          # ring-model per-device traffic

    @property
    def total_count(self) -> int:
        return sum(self.counts.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = defaultdict(int)
    result_bytes: Dict[str, int] = defaultdict(int)
    wire = 0.0
    seen_done = set()
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        line = hlo_text[m.start():hlo_text.find("\n", m.start())]
        if "-done(" in line:
            continue  # count async pairs once (at -start)
        nbytes = _shape_bytes(shape_str)
        counts[kind] += 1
        result_bytes[kind] += nbytes

        # replica group size
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            groups = [x for x in gm.group(1).split("}") if x.strip(" ,{")]
            first = groups[0].strip(" ,{") if groups else ""
            g = max(1, len([t for t in first.split(",") if t.strip()]))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                g = int(gi.group(2))
        if kind == "all-gather":
            wire += (g - 1) / max(g, 1) * nbytes
        elif kind == "reduce-scatter":
            wire += (g - 1) * nbytes
        elif kind == "all-reduce":
            wire += 2 * (g - 1) / max(g, 1) * nbytes
        elif kind == "all-to-all":
            wire += (g - 1) / max(g, 1) * nbytes
        elif kind == "collective-permute":
            wire += nbytes
    return CollectiveStats(counts=dict(counts), result_bytes=dict(result_bytes),
                           wire_bytes_per_device=wire)


def reshape_transpose_count(hlo_text: str) -> Tuple[int, int]:
    """Layout-churn indicator for the perf loop."""
    resh = len(re.findall(r"=\s*[\w\[\],{}\s/]+?\s+reshape\(", hlo_text))
    tran = len(re.findall(r"=\s*[\w\[\],{}\s/]+?\s+transpose\(", hlo_text))
    return resh, tran
