"""Step builders: train / prefill / serve steps with their sharding specs.

Shared by the dry-run (lower+compile against ShapeDtypeStructs), the
real training drivers, and the benchmarks — one definition, everywhere.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.mixing import PermuteSchedule
from ..dist.sharding import (batch_spec, cache_specs, dfl_client_count,
                             enforce_divisibility, param_specs)
from ..dist.sync import SYNC_STRATEGIES, global_mixer, ring_schedule
from ..models import decode_step, init_cache, init_params, train_loss
from ..models.config import ArchConfig, InputShape
from ..optim.optimizers import (AdamWState, Optimizer, apply_updates,
                                clip_by_global_norm)


# --------------------------------------------------------------------------
# Standard (centralized-baseline) steps
# --------------------------------------------------------------------------

# Perf knob (§Perf hillclimb): sequence parallelism — shard the sequence
# dim of inter-layer activations over the model axis, so norms/residuals
# and the saved remat stacks are 16× smaller and row-parallel all-reduces
# lower to reduce-scatter + all-gather.  Baseline = False.
SEQ_PARALLEL = False


def _act_specs(mesh: Mesh):
    """(B,S,D) activation spec + (B,S,V) logit spec: batch over all data
    axes, vocab over model (d_model left unsharded; sequence/tensor
    sharding of activations is the SEQ_PARALLEL perf knob)."""
    dp = tuple(a for a in mesh.axis_names if a != "model")
    dp_spec = dp if len(dp) > 1 else dp[0]
    seq = "model" if SEQ_PARALLEL else None
    return P(dp_spec, seq, None), P(dp_spec, None, "model")


def make_train_step(cfg: ArchConfig, optimizer: Optimizer, mesh: Mesh,
                    remat: bool = True) -> Callable:
    act, logit = _act_specs(mesh)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: train_loss(cfg, p, batch, remat=remat,
                                 act_spec=act, logit_spec=logit))(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}
    return train_step


def make_prefill_step(cfg: ArchConfig, mesh: Mesh) -> Callable:
    """Forward-only loss eval at prefill shape (the inference-prefill
    dry-run target: logits over the full sequence)."""
    act, logit = _act_specs(mesh)

    def prefill_step(params, batch):
        loss = train_loss(cfg, params, batch, remat=False,
                          act_spec=act, logit_spec=logit)
        return {"loss": loss}
    return prefill_step


def make_serve_step(cfg: ArchConfig, mesh: Mesh) -> Callable:
    def serve_step(params, cache, batch):
        logits, cache = decode_step(cfg, params, cache, batch["token"])
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache
    return serve_step


# --------------------------------------------------------------------------
# Sharding-spec bundles
# --------------------------------------------------------------------------

def opt_state_specs(opt_state_shape, p_specs):
    """Optimizer-state specs mirror the param specs."""
    if isinstance(opt_state_shape, AdamWState):
        return AdamWState(mu=p_specs, nu=p_specs, count=P())
    if opt_state_shape == () or opt_state_shape is None:
        return ()
    return p_specs  # momentum tree


@dataclasses.dataclass
class StepBundle:
    """A jit-ready step with its arg specs (everything the dry-run and
    drivers need)."""
    step: Callable
    in_specs: Tuple
    out_specs: Any
    arg_shapes: Tuple


def train_bundle(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                 optimizer: Optimizer, dtype=jnp.bfloat16,
                 remat: bool = True, fsdp: Optional[str] = "data") -> StepBundle:
    from ..data.tokens import input_specs as data_specs
    dp = tuple(a for a in mesh.axis_names if a != "model")
    dp_spec = dp if len(dp) > 1 else dp[0]

    params_shape = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), dtype=dtype))
    p_specs = param_specs(params_shape, fsdp=fsdp, tp="model")
    p_specs = enforce_divisibility(p_specs, params_shape, dict(mesh.shape))
    opt_shape = jax.eval_shape(optimizer.init, params_shape)
    o_specs = opt_state_specs(opt_shape, p_specs)

    b_shapes = data_specs(cfg, shape, dtype)
    b_spec_all = batch_spec("train", dp_axes=dp, tp="model")
    b_specs = {k: b_spec_all[k] for k in b_shapes}

    step = make_train_step(cfg, optimizer, mesh, remat=remat)
    return StepBundle(
        step=step,
        in_specs=(p_specs, o_specs, b_specs),
        out_specs=(p_specs, o_specs, {"loss": P(), "grad_norm": P()}),
        arg_shapes=(params_shape, opt_shape, b_shapes),
    )


def prefill_bundle(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                   dtype=jnp.bfloat16) -> StepBundle:
    from ..data.tokens import input_specs as data_specs
    dp = tuple(a for a in mesh.axis_names if a != "model")
    params_shape = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), dtype=dtype))
    p_specs = param_specs(params_shape, fsdp="data", tp="model")
    p_specs = enforce_divisibility(p_specs, params_shape, dict(mesh.shape))
    b_shapes = data_specs(cfg, shape, dtype)
    b_spec_all = batch_spec("prefill", dp_axes=dp, tp="model")
    b_specs = {k: b_spec_all[k] for k in b_shapes}
    return StepBundle(
        step=make_prefill_step(cfg, mesh),
        in_specs=(p_specs, b_specs),
        out_specs={"loss": P()},
        arg_shapes=(params_shape, b_shapes),
    )


# Perf knob (§Perf hillclimb): serving keeps params FSDP-sharded over
# the data axis by default (baseline, minimal HBM) — but then EVERY
# decode step all-gathers every layer's weights.  True = weight-
# stationary serving: params sharded over the model axis only
# (replicated across data), trading HBM for zero per-token parameter
# collectives.  Only valid when params_bf16/model_axis fits HBM.
SERVE_WEIGHT_STATIONARY = False


def serve_bundle(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                 dtype=jnp.bfloat16) -> StepBundle:
    from ..data.tokens import enc_frames_for, input_specs as data_specs
    dp = tuple(a for a in mesh.axis_names if a != "model")
    dp_spec = dp if len(dp) > 1 else dp[0]
    B = shape.global_batch

    params_shape = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), dtype=dtype))
    fsdp = None if SERVE_WEIGHT_STATIONARY else "data"
    p_specs = param_specs(params_shape, fsdp=fsdp, tp="model")
    p_specs = enforce_divisibility(p_specs, params_shape, dict(mesh.shape))

    enc_shape = None
    if cfg.enc_dec:
        enc_shape = jax.ShapeDtypeStruct(
            (B, enc_frames_for(cfg, shape.seq_len), cfg.d_model), dtype)
    cache_shape = jax.eval_shape(
        functools.partial(init_cache, cfg, batch=B, cache_len=shape.seq_len,
                          dtype=dtype),
        params_shape, enc_embeds=enc_shape)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    shard_batch = (B % dp_size == 0)
    c_specs = cache_specs(cache_shape, dp=dp_spec, tp="model",
                          shard_batch=shard_batch)
    c_specs = enforce_divisibility(c_specs, cache_shape, dict(mesh.shape))

    b_shapes = data_specs(cfg, shape, dtype)
    b_specs = {"token": P(dp_spec if shard_batch else None, None)}
    return StepBundle(
        step=make_serve_step(cfg, mesh),
        in_specs=(p_specs, c_specs, b_specs),
        out_specs=(P(dp_spec if shard_batch else None), c_specs),
        arg_shapes=(params_shape, cache_shape, b_shapes),
    )


# --------------------------------------------------------------------------
# DFL-mode training: the paper's technique at production scale.
# Every position of the client axis (= data axis) holds one FedLay
# client's full replica (leading num_clients dim; TP over model inside
# the replica; no FSDP — clients own their weights).  After the local
# step, models mix over the overlay via ``repro.dist.sync.global_mixer``
# (permutation gathers along the client-sharded axis — GSPMD lowers them
# to collective-permutes, i.e. exactly the paper's neighbor-to-neighbor
# exchange).  ``sync`` selects the strategy: "fedlay", "ring",
# "allreduce" (uniform mean = centralized baseline), or "none".
# --------------------------------------------------------------------------

def dfl_train_bundle(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                     optimizer: Optimizer, dtype=jnp.bfloat16,
                     sync: str = "fedlay", num_spaces: int = 3,
                     remat: bool = True,
                     sched: Optional[PermuteSchedule] = None,
                     masked: bool = False,
                     clients_per_device: int = 1,
                     fuse: Optional[str] = None,
                     codec=None) -> StepBundle:
    """``sched`` overrides the internally built overlay schedule, e.g.
    to bake an :class:`repro.overlay.OverlayController`'s converged NDMP
    schedule into a static bundle; when None the static overlay over
    mesh data positions is built here.  (The live-churn loop,
    :class:`repro.overlay.runtime.ChurnTrainLoop`, instead composes a
    ``sync="none"`` bundle with the controller's hot-swapped mixer, so
    the local step never recompiles on topology change.)

    ``masked=True`` builds the mask-aware step for the fixed-capacity
    slot runtime (:class:`repro.runtime.SlotTrainLoop`) and multirate
    participation: the step signature gains a trailing (C,) float32
    0/1 ``mask`` input — dead or non-participating slots compute but
    their param/optimizer updates are ``where``-gated away, mixing
    drops masked-out sources and renormalizes
    (:func:`repro.dist.sync.global_mixer` ``masked`` path), and the
    reported loss is the masked mean over live slots.  The mask is a
    runtime input, so it changes every step with zero retrace.

    ``clients_per_device`` (G) sizes the client axis at
    ``C = G · num_devices`` (:func:`repro.dist.sharding.dfl_client_count`)
    — the grouped layout: each data-axis device hosts a block-contiguous
    group of G clients, so a simulation (or a capacity-mode slot runtime
    with ``capacity = C``) is no longer capped at the device count.
    GSPMD keeps intra-group mixing edges on-device for free.

    ``fuse="flat"`` (opt-in) swaps the mixing step onto the flat-buffer
    fused hot path: the stacked params tree is raveled once into a
    lane-padded (C, N) buffer and the whole round runs as one Pallas
    :func:`repro.kernels.weighted_mix.gather_mix` kernel
    (:func:`repro.dist.sync.global_mixer` ``fuse`` docs; masked rounds
    stay zero-retrace runtime-mask programs).

    ``codec`` (a :mod:`repro.wire.codec` name or instance) compresses
    the fedlay/ring gossip wire (implies ``fuse="flat"``).  For an
    **error-feedback** codec the step signature grows a trailing
    (C, N) f32 ``residual`` arg and returns the fresh residual —
    ``in_specs``/``arg_shapes``/``out_specs`` all carry the extra leaf,
    sharded over the client axis like every capacity-stacked row.
    allreduce/none sync ignores the codec."""
    from ..core.mixing import build_permute_schedule
    from ..data.tokens import input_specs as data_specs
    if sync not in SYNC_STRATEGIES:
        raise ValueError(
            f"unknown sync strategy {sync!r}; choose from {SYNC_STRATEGIES}")
    dp = tuple(a for a in mesh.axis_names if a != "model")
    client_axis = dp if len(dp) > 1 else dp[0]
    C = dfl_client_count(mesh, clients_per_device)
    if shape.global_batch % C:
        raise ValueError(
            f"global batch {shape.global_batch} does not divide over "
            f"{C} clients ({clients_per_device} per device)")
    # multi-pod: bias 2 of the L ring spaces pod-local (the §Perf Pareto
    # point) so most mixing volume stays on intra-pod links
    pods = mesh.shape.get("pod")
    if sched is not None:
        if sync not in ("fedlay", "ring"):
            raise ValueError(
                f"an explicit schedule only applies to fedlay/ring sync, "
                f"not {sync!r}")
        if sched.num_clients != C:
            raise ValueError(
                f"schedule is for {sched.num_clients} clients, mesh data "
                f"axes hold {C}")
    elif sync == "fedlay":
        sched = build_permute_schedule(
            C, num_spaces, pod_bias=pods if pods and pods > 1 else None,
            pod_bias_spaces=max(1, num_spaces - 1) if pods and pods > 1
            else None)
    elif sync == "ring":
        sched = ring_schedule(C)
    mix = global_mixer(sync, sched, masked=masked,
                       clients_per_device=clients_per_device, fuse=fuse,
                       codec=codec)
    from ..dist.sync import resolve_wire
    wire_codec, _ = resolve_wire(codec, fuse)
    ef = (wire_codec is not None and wire_codec.error_feedback
          and sync in ("fedlay", "ring"))

    params_shape = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), dtype=dtype))
    stacked_shape = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((C,) + l.shape, l.dtype), params_shape)
    p_specs = param_specs(stacked_shape, client_axis=client_axis, tp="model")
    p_specs = enforce_divisibility(p_specs, stacked_shape, dict(mesh.shape))
    opt_shape = jax.eval_shape(jax.vmap(optimizer.init), stacked_shape)
    if isinstance(opt_shape, AdamWState):
        o_specs: Any = AdamWState(mu=p_specs, nu=p_specs, count=P(None))
    else:
        o_specs = opt_state_specs(opt_shape, p_specs)

    b_shapes = data_specs(cfg, shape, dtype)
    # batch (B, S): per-client slice = B/C rows; reshape to (C, B/C, S)
    b_shapes = {k: jax.ShapeDtypeStruct(
        (C, v.shape[0] // C) + v.shape[1:], v.dtype)
        for k, v in b_shapes.items()}
    b_specs = {k: P(client_axis, *([None] * (len(v.shape) - 1)))
               for k, v in b_shapes.items()}

    act = P(None, None, None)

    def per_client_loss(p, b):
        return train_loss(cfg, p, b, remat=remat, act_spec=act)

    def local_updates(params, opt_state, batch):
        loss, grads = jax.vmap(jax.value_and_grad(per_client_loss))(
            params, batch)
        grads, _ = jax.vmap(lambda g: clip_by_global_norm(g, 1.0))(grads)
        updates, opt_state = jax.vmap(optimizer.update)(grads, opt_state,
                                                        params)
        params = jax.vmap(apply_updates)(params, updates)
        return params, opt_state, loss

    r_spec = r_shape = None
    if ef:
        from ..dist.flat import FlatSpec
        r_spec = P(client_axis, None)
        r_shape = jax.ShapeDtypeStruct(
            (C, FlatSpec.for_tree(stacked_shape).size), jnp.float32)

    if masked:
        from ..runtime.masked import masked_mean, masked_where

        def masked_local(params, opt_state, batch, mask):
            new_params, new_opt, loss = local_updates(params, opt_state,
                                                      batch)
            params = masked_where(mask, new_params, params)
            opt_state = masked_where(mask, new_opt, opt_state)
            return params, opt_state, {"loss": masked_mean(loss, mask),
                                       "num_alive": jnp.sum(mask)}

        if ef:
            def masked_train_step_ef(params, opt_state, batch, mask,
                                     residual):
                params, opt_state, metrics = masked_local(
                    params, opt_state, batch, mask)
                params, residual = mix(params, mask, residual)
                return params, opt_state, metrics, residual

            return StepBundle(
                step=masked_train_step_ef,
                in_specs=(p_specs, o_specs, b_specs, P(client_axis),
                          r_spec),
                out_specs=(p_specs, o_specs,
                           {"loss": P(), "num_alive": P()}, r_spec),
                arg_shapes=(stacked_shape, opt_shape, b_shapes,
                            jax.ShapeDtypeStruct((C,), jnp.float32),
                            r_shape),
            )

        def masked_train_step(params, opt_state, batch, mask):
            params, opt_state, metrics = masked_local(
                params, opt_state, batch, mask)
            params = mix(params, mask)
            return params, opt_state, metrics

        return StepBundle(
            step=masked_train_step,
            in_specs=(p_specs, o_specs, b_specs, P(client_axis)),
            out_specs=(p_specs, o_specs, {"loss": P(), "num_alive": P()}),
            arg_shapes=(stacked_shape, opt_shape, b_shapes,
                        jax.ShapeDtypeStruct((C,), jnp.float32)),
        )

    if ef:
        def train_step_ef(params, opt_state, batch, residual):
            params, opt_state, loss = local_updates(params, opt_state,
                                                    batch)
            params, residual = mix(params, residual)
            return params, opt_state, {"loss": jnp.mean(loss)}, residual

        return StepBundle(
            step=train_step_ef,
            in_specs=(p_specs, o_specs, b_specs, r_spec),
            out_specs=(p_specs, o_specs, {"loss": P()}, r_spec),
            arg_shapes=(stacked_shape, opt_shape, b_shapes, r_shape),
        )

    def train_step(params, opt_state, batch):
        params, opt_state, loss = local_updates(params, opt_state, batch)
        params = mix(params)
        return params, opt_state, {"loss": jnp.mean(loss)}

    return StepBundle(
        step=train_step,
        in_specs=(p_specs, o_specs, b_specs),
        out_specs=(p_specs, o_specs, {"loss": P()}),
        arg_shapes=(stacked_shape, opt_shape, b_shapes),
    )


def bundle_for(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
               optimizer: Optional[Optimizer] = None,
               dtype=jnp.bfloat16) -> StepBundle:
    if shape.kind == "train":
        assert optimizer is not None
        return train_bundle(cfg, shape, mesh, optimizer, dtype=dtype)
    if shape.kind == "prefill":
        return prefill_bundle(cfg, shape, mesh, dtype=dtype)
    return serve_bundle(cfg, shape, mesh, dtype=dtype)


def jit_bundle(bundle: StepBundle, mesh: Mesh):
    to_shard = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    return jax.jit(bundle.step,
                   in_shardings=to_shard(bundle.in_specs),
                   out_shardings=to_shard(bundle.out_specs))
