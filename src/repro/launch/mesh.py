"""Production mesh construction.

Single pod: 256 chips as (data=16, model=16).  Multi-pod: 2 pods × 256
chips as (pod=2, data=16, model=16).  Defined as functions so importing
this module never touches jax device state — only ``dryrun.py`` forces
the 512-device host platform.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from ..dist.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Tiny mesh over the real local devices (CPU smoke tests)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, n // data)
    return make_mesh((data, model), ("data", "model"))


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The batch-sharding axes of a mesh (everything except model)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def num_clients(mesh: Mesh, client_axis: str = "data") -> int:
    return mesh.shape[client_axis]
