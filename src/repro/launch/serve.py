"""Serving drivers: batched greedy decode, and the slot-based
continuous-batching plane.

Two modes, both small-model CPU-runnable demonstrations of the serving
stack the dry-run lowers at production scale:

* ``--mode batch`` (default): prefill a fixed batch of prompts in ONE
  batched forward pass (:func:`repro.models.model.prefill` — the
  teacher-forced one-token-at-a-time loop this replaces cost
  O(prompt_len) dispatches), then decode autoregressively.
* ``--mode slots``: drive :class:`repro.runtime.serving.ServeLoop`
  under a Poisson arrival trace — continuous batching over a
  fixed-capacity request SlotMap with per-slot positions.

  PYTHONPATH=src python -m repro.launch.serve --batch 4 --prompt-len 32 \
      --gen 32 --arch tiny
  PYTHONPATH=src python -m repro.launch.serve --mode slots --capacity 8 \
      --requests 32 --policy continuous

Timing uses ``time.perf_counter`` (monotonic — the repro.obs standard;
wall-clock ``time.time`` can step backwards under NTP and made the old
tok/s numbers untrustworthy), and the decode tok/s denominator counts
every sampled token including the first (the old ``gen - 1`` silently
under-reported throughput).
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import REGISTRY, reduce_for_smoke
from ..models.model import decode_step, init_cache, init_params, prefill
from .train import tiny_lm


def _check_tokens(gen_tokens: jnp.ndarray, vocab: int) -> None:
    """Output-validity gate.  A real ``raise`` — the old ``assert``
    vanished under ``python -O``."""
    if bool(jnp.any(gen_tokens < 0)) or bool(jnp.any(gen_tokens >= vocab)):
        raise RuntimeError(
            f"generated tokens escaped the vocab [0, {vocab}): "
            f"min={int(gen_tokens.min())} max={int(gen_tokens.max())}")


def run_batch(cfg, params, args, rng) -> int:
    B = args.batch
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(B, args.prompt_len)), jnp.int32)
    enc = None
    if cfg.enc_dec:
        enc = jnp.asarray(rng.normal(size=(B, 64, cfg.d_model)), jnp.float32)

    cache_len = args.prompt_len + args.gen
    cache = init_cache(cfg, params, B, cache_len, enc_embeds=enc)

    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))
    prefill_j = jax.jit(lambda p, c, t: prefill(cfg, p, c, t))

    # batched prefill: the whole prompt in one forward pass
    t0 = time.perf_counter()
    logits, cache = prefill_j(params, cache, prompts)
    jax.block_until_ready(logits)
    prefill_s = time.perf_counter() - t0

    # greedy generation; every sampled token counts, including the one
    # drawn from the prefill logits
    t0 = time.perf_counter()
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    for _ in range(args.gen - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    gen_s = time.perf_counter() - t0

    gen_tokens = jnp.concatenate(out, axis=1)
    print(f"prefill: {args.prompt_len} tokens/row in one pass, "
          f"{prefill_s:.3f}s; decode: "
          f"{B * args.gen / max(gen_s, 1e-9):.1f} tok/s")
    print("sample:", np.asarray(gen_tokens[0, :16]).tolist())
    _check_tokens(gen_tokens, cfg.vocab_size)
    return 0


def run_slots(cfg, params, args, rng) -> int:
    from ..obs.events import telemetry
    from ..obs.rounds import round_ledger
    from ..runtime.serving import ServeLoop

    with telemetry() as bus, round_ledger() as ledger:
        loop = ServeLoop(cfg, params, capacity=args.capacity,
                         cache_len=args.prompt_len + args.gen,
                         prompt_len=args.prompt_len, policy=args.policy)
        for i in range(args.requests):
            plen = int(rng.integers(1, args.prompt_len + 1))
            loop.submit(rng.integers(0, cfg.vocab_size, plen),
                        max_new=int(rng.integers(1, args.gen + 1)))
        t0 = time.perf_counter()
        done = loop.run()
        wall = time.perf_counter() - t0
    lat = sorted(r.latency_s for r in done)
    toks = sum(len(r.tokens) for r in done)
    for r in done:
        _check_tokens(jnp.asarray(r.tokens), cfg.vocab_size)
    print(f"{args.policy}: {len(done)} requests in {wall:.3f}s "
          f"({len(done) / wall:.1f} req/s, {toks / wall:.1f} tok/s), "
          f"p50 {lat[len(lat) // 2] * 1e3:.1f}ms "
          f"p99 {lat[min(len(lat) - 1, int(len(lat) * 0.99))] * 1e3:.1f}ms, "
          f"retraces after warmup: {loop.retraces}")
    print("ledger:", ledger.summary())
    print("counters:", bus.snapshot())
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="tiny",
                    help="'tiny' or any assigned arch id (reduced variant)")
    ap.add_argument("--mode", choices=("batch", "slots"), default="batch")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--capacity", type=int, default=8,
                    help="request slots (slots mode)")
    ap.add_argument("--requests", type=int, default=32,
                    help="trace length (slots mode)")
    ap.add_argument("--policy", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.arch == "tiny":
        cfg = tiny_lm()
    else:
        cfg = reduce_for_smoke(REGISTRY[args.arch])
    print(f"serving {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")

    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    rng = np.random.default_rng(args.seed)
    if args.mode == "slots":
        return run_slots(cfg, params, args, rng)
    return run_batch(cfg, params, args, rng)


if __name__ == "__main__":
    sys.exit(main())
