"""Batched serving driver: greedy decode with per-layer KV caches.

Small-model CPU-runnable demonstration of the ``serve_step`` the dry-run
lowers at production scale: prefill a batch of prompts, then decode
autoregressively against the cache.

  PYTHONPATH=src python -m repro.launch.serve --batch 4 --prompt-len 32 \
      --gen 32 --arch tiny
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import REGISTRY, reduce_for_smoke
from ..models.model import decode_step, forward, init_cache, init_params
from .train import tiny_lm


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="tiny",
                    help="'tiny' or any assigned arch id (reduced variant)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.arch == "tiny":
        cfg = tiny_lm()
    else:
        cfg = reduce_for_smoke(REGISTRY[args.arch])
    print(f"serving {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"vocab={cfg.vocab_size}")

    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    rng = np.random.default_rng(args.seed)
    B = args.batch
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(B, args.prompt_len)), jnp.int32)
    enc = None
    if cfg.enc_dec:
        enc = jnp.asarray(rng.normal(size=(B, 64, cfg.d_model)), jnp.float32)

    cache_len = args.prompt_len + args.gen
    cache = init_cache(cfg, params, B, cache_len, enc_embeds=enc)

    step = jax.jit(lambda p, c, t: decode_step(cfg, p, c, t))

    # prefill by stepping the prompt through the cache (teacher-forced)
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = step(params, cache, prompts[:, t:t + 1])
    prefill_s = time.time() - t0

    # greedy generation
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    out = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    gen_s = time.time() - t0

    gen_tokens = jnp.concatenate(out, axis=1)
    print(f"prefill: {args.prompt_len} steps in {prefill_s:.2f}s; "
          f"decode: {B * (args.gen - 1) / max(gen_s, 1e-9):.1f} tok/s")
    print("sample:", np.asarray(gen_tokens[0, :16]).tolist())
    assert not bool(jnp.any(gen_tokens < 0)) and \
        not bool(jnp.any(gen_tokens >= cfg.vocab_size))
    return 0


if __name__ == "__main__":
    sys.exit(main())
