"""Wire codecs: compressed gossip on the flat buffer.

The paper's deployment story is bandwidth-bound D2D gossip
("Overlay-based DFL in Bandwidth-limited Networks", PAPERS.md):
per-round wire volume, not topology maintenance, is the binding
constraint.  :class:`repro.dist.flat.FlatSpec` already collapses each
client's sync payload into one contiguous lane-padded f32 row — the
natural seam for compression.  A :class:`WireCodec` maps that (B, N)
row buffer to the tuple of arrays that actually cross the network
(``encode``), back (``decode``), and prices it (``wire_bytes``);
:mod:`repro.dist.sync` threads a codec through both ``fuse="flat"``
mixing families so every ppermute moves the encoded parts and every
receive folds them into the accumulator through the fused Pallas
kernels of :mod:`repro.kernels.wire_codec` (the decompressed 2L stack
is never materialized).

**The wire-format contract**

* ``encode(buf) -> wire`` — ``buf`` (B, N) f32 (the FlatSpec buffer);
  ``wire`` a tuple of same-leading-dim arrays, each of which rides the
  mixing path's routing independently (ppermute / local gather row by
  row).  Shapes/dtypes are pure functions of (N, codec), so churn masks
  and cohort swaps never retrace.
* ``decode(wire, n) -> (B, n) f32`` — the receiver image.  ``n`` is the
  original column count (the wire is not self-describing; the mixer
  knows its FlatSpec).
* ``wire_bytes(n) -> int`` — exact bytes per row on the wire, the
  closed form :func:`repro.dist.sync.sync_bytes_per_client` multiplies
  into the paper's §IV-D accounting and
  ``benchmarks/sync_collectives.py`` pins against HLO-measured
  collective bytes.
* **Exactness contract** — ``exact=True`` means ``decode ∘ encode`` is
  the bit-exact identity on f32; lossy codecs document an element-wise
  error bound via :meth:`WireCodec.tolerance` (the test currency for
  the dense-oracle parity pins).
* **Error feedback** — ``error_feedback=True`` codecs are compensated:
  the mixer sends ``enc(x + e)`` and carries the new residual
  ``e' = (x + e) - dec(enc(x + e))`` as a (B, N) f32 leaf of the slot
  runtime state (:class:`repro.runtime.SlotTrainLoop`).  Residual
  churn semantics: a masked-out row (dead slot, multirate skip) keeps
  its residual unchanged; joiner and leaver slots are zeroed
  (:func:`repro.runtime.slots.plan_reset_slots`).  ``encode_ef`` fuses
  the residual computation into the encode (no re-decode).

**The codecs**

=============  ======  ====  ===========================================
name           bytes/N  EF    exactness
=============  ======  ====  ===========================================
``none``       4 N     no    bit-exact (identity; the codec-path
                             plumbing check)
``bf16``       2 N     no    bit-exact on bf16-representable values;
                             else |err| ≤ |x|·2⁻⁸ (round-to-nearest
                             mantissa truncation)
``int8-block`` ~1.02 N yes   |err| ≤ max|block|/127 · (1/2 + ε_bf16);
                             documented test bound max|block|/127
``int4-block`` ~0.52 N yes   |err| ≤ max|block|/7 · (1/2 + ε_bf16);
                             documented test bound max|block|/7
``topk``       8 k     yes   kept entries exact; dropped entries err =
                             |x| (EF carries them to later rounds)
=============  ======  ====  ===========================================

``int8-block``/``int4-block`` layout: N columns split into
``ceil(N/block)`` blocks (tail zero-padded — exact), one symmetric
scale per block stored as bf16 *after* rounding, so encoder and decoder
multiply by the identical scale (see
:mod:`repro.kernels.wire_codec`).  ``topk`` keeps each row's k
largest-magnitude entries as (values f32, indices int32) pairs —
``k = max(1, round(rate·n))``.

Codecs are frozen dataclasses: hashable and value-equal, so the
:class:`repro.overlay.controller.MixerCache` keys compiled mixers on
``(schedule, fuse, codec)`` and churn swaps stay zero-retrace.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from ..kernels.weighted_mix import gather_mix, mix_accumulate
from ..kernels.wire_codec import (dequant_accumulate, dequantize_block,
                                  gather_mix_int8, padded_width,
                                  quantize_block)
from ..obs.profile import scope

Wire = Tuple[jnp.ndarray, ...]


@dataclasses.dataclass(frozen=True)
class WireCodec:
    """Base codec: the identity-coding API plus generic (decode-then-mix)
    receive hooks that concrete codecs override with fused kernels.
    See the module docstring for the wire-format contract."""

    #: registry name (class attribute on subclasses)
    name = "abstract"
    #: decode ∘ encode is the bit-exact f32 identity
    exact = False
    #: the mixer carries a compensated residual for this codec
    error_feedback = False

    # ---- the coding pair -------------------------------------------------
    def encode(self, buf: jnp.ndarray) -> Wire:
        raise NotImplementedError

    def decode(self, wire: Wire, n: int) -> jnp.ndarray:
        raise NotImplementedError

    def wire_bytes(self, n: int) -> int:
        """Exact bytes one encoded n-column row puts on the wire."""
        raise NotImplementedError

    def payload_bytes(self, n: int) -> int:
        """Bytes of the value payload alone, excluding per-block scale
        side-channel overhead (== :meth:`wire_bytes` for codecs without
        one).  ``4n / payload_bytes(n)`` is the headline compression
        factor; ``4n / wire_bytes(n)`` the honest on-the-wire one."""
        return self.wire_bytes(n)

    def tolerance(self, buf: jnp.ndarray) -> jnp.ndarray:
        """Element-wise upper bound on |decode(encode(buf)) − buf| —
        the documented exactness contract, used by the oracle-parity
        tests."""
        raise NotImplementedError

    # ---- error feedback --------------------------------------------------
    def encode_ef(self, buf: jnp.ndarray) -> Tuple[Wire, jnp.ndarray]:
        """(wire, residual = buf − decode(wire)).  Generic form decodes
        once; fused codecs override (int8 computes the residual inside
        the quantize kernel)."""
        with scope(f"wire.{self.name}.encode_ef"):
            wire = self.encode(buf)
            return wire, buf.astype(jnp.float32) - self.decode(
                wire, buf.shape[1])

    # ---- fused receive hooks ---------------------------------------------
    def accumulate(self, acc: Optional[jnp.ndarray], wire: Wire,
                   w: jnp.ndarray) -> jnp.ndarray:
        """``acc + w[:, None]·decode(wire)`` — the shard_map receive.
        Generic form materializes one decoded buffer (never a 2L
        stack); fused codecs dequantize in-kernel."""
        n = acc.shape[1]
        with scope(f"wire.{self.name}.decode"):
            return mix_accumulate(acc, self.decode(wire, n), w)

    def gather(self, wire: Wire, srcs, weights: jnp.ndarray,
               n: int) -> jnp.ndarray:
        """Round-matrix mixing over the encoded population — the global
        fused receive.  Generic form decodes once then calls
        :func:`~repro.kernels.weighted_mix.gather_mix`."""
        with scope(f"wire.{self.name}.decode"):
            return gather_mix(self.decode(wire, n), srcs, weights)


@dataclasses.dataclass(frozen=True)
class NoneCodec(WireCodec):
    """Identity codec: the uncompressed f32 row, routed through the
    codec plumbing (the exactness control arm — must be bit-equal to
    the codec-free flat path)."""

    name = "none"
    exact = True

    def encode(self, buf):
        return (buf.astype(jnp.float32),)

    def decode(self, wire, n):
        return wire[0][:, :n]

    def wire_bytes(self, n):
        return 4 * n

    def tolerance(self, buf):
        return jnp.zeros_like(buf, dtype=jnp.float32)


@dataclasses.dataclass(frozen=True)
class Bf16Codec(WireCodec):
    """Truncate the wire row to bf16 (2 bytes/element): bit-exact for
    values already representable in bf16 (e.g. bf16-dtype param leaves
    raveled into the f32 buffer), |err| ≤ |x|·2⁻⁸ otherwise.  No error
    feedback — the relative error is already at parameter-noise level.

    The wire part carries the raw bf16 bits **bitcast to uint16**:
    with a plain bf16 array XLA recognizes the f32→bf16→f32 round-trip
    around the collective, fuses the converts, and sends the full f32
    row (observed on the CPU backend; ``optimization_barrier`` does not
    survive its pass pipeline).  A bitcast is opaque to that
    simplification, so the permute genuinely moves 2 bytes/element.
    The receive hooks upcast to f32 *before* the mixing kernels: the
    kernels accumulate in their input dtype, and a bf16 accumulator
    would add a second rounding on every partial sum."""

    name = "bf16"

    @staticmethod
    def _bits(part):
        return jax.lax.bitcast_convert_type(part, jnp.bfloat16)

    def encode(self, buf):
        return (jax.lax.bitcast_convert_type(
            buf.astype(jnp.bfloat16), jnp.uint16),)

    def decode(self, wire, n):
        return self._bits(wire[0])[:, :n].astype(jnp.float32)

    def wire_bytes(self, n):
        return 2 * n

    def tolerance(self, buf):
        return jnp.abs(buf.astype(jnp.float32)) * 2.0 ** -8

    def accumulate(self, acc, wire, w):
        return mix_accumulate(acc, self.decode(wire, acc.shape[1]), w)

    def gather(self, wire, srcs, weights, n):
        return gather_mix(self.decode(wire, n), srcs, weights)


@dataclasses.dataclass(frozen=True)
class Int8BlockCodec(WireCodec):
    """Symmetric per-block int8 quantization (~4× wire reduction):
    ``q = round(x/s) ∈ [-127, 127]`` with one bf16 scale
    ``s = max|block|/127`` per ``block`` columns — the
    :mod:`repro.kernels.wire_codec` kernel pair, with the dequantize
    fused into both receive paths.  Error feedback compensates the
    ≤ s/2 per-element rounding."""

    block: int = 128

    name = "int8-block"
    error_feedback = True
    levels = 127

    def encode(self, buf):
        return quantize_block(buf, block=self.block, levels=self.levels)

    def encode_ef(self, buf):
        q, s, res = quantize_block(buf, block=self.block, levels=self.levels,
                                   with_residual=True)
        return (q, s), res

    def decode(self, wire, n):
        q, s = wire
        return dequantize_block(q, s, block=self.block)[:, :n]

    def wire_bytes(self, n):
        nb = -(-n // self.block)
        return nb * self.block + 2 * nb          # int8 payload + bf16 scales

    def payload_bytes(self, n):
        return -(-n // self.block) * self.block  # 1 byte/element, padded

    def tolerance(self, buf):
        x = buf.astype(jnp.float32)
        B, n = x.shape
        nb = -(-n // self.block)
        xp = jnp.pad(x, ((0, 0), (0, nb * self.block - n)))
        amax = jnp.max(jnp.abs(xp.reshape(B, nb, self.block)), axis=2)
        bound = jnp.repeat(amax / self.levels, self.block, axis=1)
        return bound[:, :n]

    def accumulate(self, acc, wire, w):
        q, s = wire
        return dequant_accumulate(acc, q, s, w, block=self.block)

    def gather(self, wire, srcs, weights, n):
        q, s = wire
        return gather_mix_int8(q, s, srcs, weights,
                               block=self.block)[:, :n]


@dataclasses.dataclass(frozen=True)
class Int4BlockCodec(WireCodec):
    """4-bit symmetric per-block quantization (~8× wire reduction):
    levels ±7, two values packed per byte (biased nibbles: byte =
    (q₂ᵢ₊₁+8)·16 + (q₂ᵢ+8)), bf16 scales as in int8-block.  Packing
    runs as cheap jnp byte ops on top of the shared quantize kernel;
    the receive decodes through the generic hooks (one materialized
    buffer — the payload is small enough that fusion stops mattering)."""

    block: int = 128

    name = "int4-block"
    error_feedback = True
    levels = 7

    def _pack_width(self, n: int) -> int:
        return -(-padded_width(n, self.block) // 2)

    def encode(self, buf):
        q, s = quantize_block(buf, block=self.block, levels=self.levels)
        return self._pack(q) + (s,)

    def encode_ef(self, buf):
        q, s, res = quantize_block(buf, block=self.block, levels=self.levels,
                                   with_residual=True)
        return self._pack(q) + (s,), res

    def _pack(self, q) -> Wire:
        if q.shape[1] % 2:
            q = jnp.pad(q, ((0, 0), (0, 1)))
        qb = (q.astype(jnp.int32) + 8).astype(jnp.uint8)
        return (qb[:, 0::2] | (qb[:, 1::2] << 4),)

    def decode(self, wire, n):
        packed, s = wire
        B = packed.shape[0]
        lo = (packed & 0xF).astype(jnp.int32) - 8
        hi = (packed >> 4).astype(jnp.int32) - 8
        q = jnp.stack([lo, hi], axis=-1).reshape(B, -1)
        nq = padded_width(n, self.block)
        return dequantize_block(q[:, :nq].astype(jnp.int8), s,
                                block=self.block)[:, :n]

    def wire_bytes(self, n):
        nb = -(-n // self.block)
        return self._pack_width(n) + 2 * nb

    def payload_bytes(self, n):
        return self._pack_width(n)               # half byte/element, padded

    def tolerance(self, buf):
        x = buf.astype(jnp.float32)
        B, n = x.shape
        nb = -(-n // self.block)
        xp = jnp.pad(x, ((0, 0), (0, nb * self.block - n)))
        amax = jnp.max(jnp.abs(xp.reshape(B, nb, self.block)), axis=2)
        return jnp.repeat(amax / self.levels, self.block, axis=1)[:, :n]


@dataclasses.dataclass(frozen=True)
class TopKCodec(WireCodec):
    """Magnitude top-k sparsification: each row keeps its k
    largest-|x| entries as (values f32, indices int32) — 8k bytes, a
    ``1/(2·rate)``× wire reduction.  Kept entries are exact; dropped
    entries are the error, so this codec is only sensible with error
    feedback (the residual re-submits dropped mass every round)."""

    rate: float = 0.0625

    name = "topk"
    error_feedback = True

    def __post_init__(self):
        if not 0.0 < self.rate <= 1.0:
            raise ValueError(f"topk rate {self.rate} not in (0, 1]")

    def k_for(self, n: int) -> int:
        return max(1, int(round(self.rate * n)))

    def encode(self, buf):
        x = buf.astype(jnp.float32)
        _, idx = jax.lax.top_k(jnp.abs(x), self.k_for(x.shape[1]))
        vals = jnp.take_along_axis(x, idx, axis=1)
        return vals, idx.astype(jnp.int32)

    def encode_ef(self, buf):
        x = buf.astype(jnp.float32)
        vals, idx = self.encode(x)
        rows = jnp.broadcast_to(jnp.arange(x.shape[0])[:, None], idx.shape)
        return (vals, idx), x.at[rows, idx].set(0.0)

    def decode(self, wire, n):
        vals, idx = wire
        B, k = vals.shape
        rows = jnp.broadcast_to(jnp.arange(B)[:, None], (B, k))
        return jnp.zeros((B, n), jnp.float32).at[rows, idx].add(vals)

    def wire_bytes(self, n):
        return 8 * self.k_for(n)

    def tolerance(self, buf):
        # dropped entries lose their whole value; kept ones are exact.
        return jnp.abs(buf.astype(jnp.float32))


#: Registry of default codec instances by name (CLI / config currency).
WIRE_CODECS = {c.name: c for c in (
    NoneCodec(), Bf16Codec(), Int8BlockCodec(), Int4BlockCodec(),
    TopKCodec())}


def get_codec(codec: Union[None, str, WireCodec]) -> Optional[WireCodec]:
    """Resolve a codec knob: ``None`` → no codec (the uncompressed
    paths, byte-identical to pre-codec behavior), a registry name →
    its default instance, an instance → itself."""
    if codec is None or isinstance(codec, WireCodec):
        return codec
    got = WIRE_CODECS.get(codec)
    if got is None:
        raise ValueError(f"unknown wire codec {codec!r}; choose from "
                         f"{tuple(WIRE_CODECS)}")
    return got
