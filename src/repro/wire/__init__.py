"""Wire compression for the flat-row gossip payload (see
:mod:`repro.wire.codec` for the format contract)."""

from .codec import (WIRE_CODECS, Bf16Codec, Int4BlockCodec, Int8BlockCodec,
                    NoneCodec, TopKCodec, WireCodec, get_codec)

__all__ = ["WIRE_CODECS", "WireCodec", "NoneCodec", "Bf16Codec",
           "Int8BlockCodec", "Int4BlockCodec", "TopKCodec", "get_codec"]
