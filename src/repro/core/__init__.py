# The paper's primary contribution: the FedLay overlay network for
# decentralized federated learning — topology, metrics, NDMP control
# protocols, MEP model-exchange protocol, mixing schedules, and the DFL
# training engines used in the paper's evaluation.

from .coords import NodeAddress, circular_distance, coordinate, coordinates
from .topology import Topology, correctness, fedlay_topology, ring_orders
from .metrics import (TopologyReport, convergence_factor, evaluate_topology,
                      metropolis_hastings_matrix, spectral_lambda)
from .baselines import TOPOLOGY_REGISTRY
from .ndmp import Simulator
from .mep import (ClientProfile, FingerprintTable, aggregation_weights,
                  data_confidence, link_period, model_fingerprint)
from .mixing import (PermuteSchedule, build_permute_schedule,
                     confidence_mixing_matrix, gossip_step,
                     schedule_mixing_matrix)
from .dfl import (METHOD_REGISTRY, Engine, MethodSpec, RunResult,
                  capacity_periods, register_method, resolve_method,
                  run_gossip, run_method)

__all__ = [
    "NodeAddress", "circular_distance", "coordinate", "coordinates",
    "Topology", "correctness", "fedlay_topology", "ring_orders",
    "TopologyReport", "convergence_factor", "evaluate_topology",
    "metropolis_hastings_matrix", "spectral_lambda",
    "TOPOLOGY_REGISTRY", "Simulator",
    "ClientProfile", "FingerprintTable", "aggregation_weights",
    "data_confidence", "link_period", "model_fingerprint",
    "PermuteSchedule", "build_permute_schedule", "confidence_mixing_matrix",
    "gossip_step", "schedule_mixing_matrix",
    "METHOD_REGISTRY", "Engine", "MethodSpec", "RunResult",
    "capacity_periods", "register_method", "resolve_method",
    "run_gossip", "run_method",
]
