"""The three DFL topology metrics of paper §II-B.

1. *Convergence factor* ``c_G = 1 / (1 - λ)²`` with
   ``λ = max(|λ₂(M)|, |λ_N(M)|)`` of a symmetric doubly-stochastic
   mixing matrix M of the graph (we use the Metropolis–Hastings matrix,
   as the paper does, citing Boyd–Diaconis–Xiao).
2. *Diameter* — longest shortest path.
3. *Average shortest-path length*.

All are exact (dense eigensolve + BFS); the paper evaluates n ≤ 1000
where this is trivially cheap.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from .topology import Topology


def metropolis_hastings_matrix(A: np.ndarray) -> np.ndarray:
    """Metropolis–Hastings mixing matrix of an adjacency matrix.

    M[i,j] = 1 / (1 + max(d_i, d_j)) for edges, M[i,i] = 1 - Σ_j M[i,j].
    Symmetric, doubly stochastic, and valid for irregular degrees —
    which is exactly why the paper uses it (FedLay nodes can have
    degree < 2L when a peer is adjacent in several spaces).
    """
    n = A.shape[0]
    deg = A.sum(axis=1)
    M = np.zeros_like(A, dtype=np.float64)
    ii, jj = np.nonzero(A)
    M[ii, jj] = 1.0 / (1.0 + np.maximum(deg[ii], deg[jj]))
    M[np.arange(n), np.arange(n)] = 1.0 - M.sum(axis=1)
    return M


def uniform_mixing_matrix(A: np.ndarray) -> np.ndarray:
    """Equal-weight aggregation over {u} ∪ N_u (DFedAvg simple average).

    Row-stochastic but only symmetric for regular graphs; provided for
    the MEP ablation (confidence weighting vs simple average).
    """
    n = A.shape[0]
    W = A + np.eye(n)
    return W / W.sum(axis=1, keepdims=True)


def spectral_lambda(M: np.ndarray) -> float:
    """λ(M) = max(|λ₂|, |λ_N|) for a symmetric mixing matrix."""
    if M.shape[0] < 2:
        return 0.0
    if not np.allclose(M, M.T, atol=1e-10):
        # Fall back to singular values for non-symmetric mixing matrices.
        s = np.linalg.svd(M - np.ones_like(M) / M.shape[0], compute_uv=False)
        return float(s[0])
    ev = np.sort(np.linalg.eigvalsh(M))  # ascending
    return float(max(abs(ev[0]), abs(ev[-2])))


def convergence_factor(topology: Topology, mixing: str = "metropolis") -> float:
    """c_G = 1 / (1 - λ)² (paper §II-B1). Infinite for disconnected graphs."""
    A = topology.adjacency()
    M = metropolis_hastings_matrix(A) if mixing == "metropolis" else uniform_mixing_matrix(A)
    lam = spectral_lambda(M)
    if lam >= 1.0 - 1e-12:
        return float("inf")
    return 1.0 / (1.0 - lam) ** 2


def generalization_gap_bound(lam: float) -> float:
    """O(2λ² + 4λ² ln(1/λ) + 2λ + 2/ln(1/λ)) — the paper's second bound.

    Increasing in λ on (0,1), so minimizing c_G also minimizes this;
    exposed for completeness / tests."""
    if lam <= 0.0:
        return 0.0
    if lam >= 1.0:
        return float("inf")
    ln_inv = np.log(1.0 / lam)
    return float(2 * lam**2 + 4 * lam**2 * ln_inv + 2 * lam + 2.0 / ln_inv)


def _bfs_dists(nbr: Dict[int, List[int]], src: int) -> Dict[int, int]:
    dist = {src: 0}
    q = deque([src])
    while q:
        u = q.popleft()
        for v in nbr[u]:
            if v not in dist:
                dist[v] = dist[u] + 1
                q.append(v)
    return dist


def shortest_path_stats(topology: Topology) -> "PathStats":
    """Diameter and average shortest-path length via all-pairs BFS."""
    nbr = topology.neighbor_map()
    n = topology.n
    if n < 2:
        return PathStats(diameter=0, avg_shortest_path=0.0, connected=True)
    diameter = 0
    total = 0
    pairs = 0
    for u in topology.nodes:
        dist = _bfs_dists(nbr, u)
        if len(dist) != n:
            return PathStats(diameter=-1, avg_shortest_path=float("inf"), connected=False)
        for v, d in dist.items():
            if v > u:
                total += d
                pairs += 1
                diameter = max(diameter, d)
    return PathStats(diameter=diameter, avg_shortest_path=total / pairs, connected=True)


@dataclasses.dataclass(frozen=True)
class PathStats:
    diameter: int
    avg_shortest_path: float
    connected: bool


@dataclasses.dataclass(frozen=True)
class TopologyReport:
    """All three §II-B metrics for one topology."""

    name: str
    n: int
    avg_degree: float
    max_degree: int
    spectral_lambda: float
    convergence_factor: float
    diameter: int
    avg_shortest_path: float
    connected: bool


def evaluate_topology(topology: Topology, mixing: str = "metropolis") -> TopologyReport:
    A = topology.adjacency()
    deg = A.sum(axis=1)
    M = metropolis_hastings_matrix(A) if mixing == "metropolis" else uniform_mixing_matrix(A)
    lam = spectral_lambda(M)
    cf = float("inf") if lam >= 1.0 - 1e-12 else 1.0 / (1.0 - lam) ** 2
    ps = shortest_path_stats(topology)
    return TopologyReport(
        name=topology.name,
        n=topology.n,
        avg_degree=float(deg.mean()) if topology.n else 0.0,
        max_degree=int(deg.max()) if topology.n else 0,
        spectral_lambda=lam,
        convergence_factor=cf,
        diameter=ps.diameter,
        avg_shortest_path=ps.avg_shortest_path,
        connected=ps.connected,
    )
