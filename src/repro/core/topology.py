"""FedLay overlay topology (paper §II-C) and the Definition-1 correctness test.

A FedLay overlay over a node set is fully determined by the nodes'
virtual coordinates: in each of the L ring spaces every node is adjacent
to its predecessor and successor in coordinate order, and its overlay
neighbor set is the union of ring adjacencies over all spaces (at most
2L neighbors; fewer when the same peer is adjacent in several spaces).

This module holds the *static* graph math — building the ideal topology
from coordinates, adjacency queries, and Definition-1 correctness
checking of a (possibly damaged) neighbor-table state.  The *dynamic*
construction/maintenance protocols that converge to this topology live
in :mod:`repro.core.ndmp`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, Iterable, List, Sequence, Tuple

import numpy as np

from .coords import NodeAddress


@dataclasses.dataclass(frozen=True)
class Topology:
    """An undirected overlay graph G = (V, E) with node metadata."""

    nodes: Tuple[int, ...]
    edges: FrozenSet[Tuple[int, int]]  # canonical (min, max) pairs
    name: str = "graph"

    # ---- basic graph API -------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.nodes)

    def neighbors(self, u: int) -> List[int]:
        out = []
        for a, b in self.edges:
            if a == u:
                out.append(b)
            elif b == u:
                out.append(a)
        return sorted(out)

    def neighbor_map(self) -> Dict[int, List[int]]:
        nbr: Dict[int, List[int]] = {u: [] for u in self.nodes}
        for a, b in self.edges:
            nbr[a].append(b)
            nbr[b].append(a)
        return {u: sorted(v) for u, v in nbr.items()}

    def degrees(self) -> Dict[int, int]:
        return {u: len(v) for u, v in self.neighbor_map().items()}

    def adjacency(self) -> np.ndarray:
        """Dense 0/1 adjacency matrix in ``self.nodes`` order."""
        index = {u: i for i, u in enumerate(self.nodes)}
        A = np.zeros((self.n, self.n), dtype=np.float64)
        for a, b in self.edges:
            A[index[a], index[b]] = 1.0
            A[index[b], index[a]] = 1.0
        return A

    def is_connected(self) -> bool:
        if self.n == 0:
            return True
        nbr = self.neighbor_map()
        seen = {self.nodes[0]}
        stack = [self.nodes[0]]
        while stack:
            u = stack.pop()
            for v in nbr[u]:
                if v not in seen:
                    seen.add(v)
                    stack.append(v)
        return len(seen) == self.n


def make_edge(u: int, v: int) -> Tuple[int, int]:
    if u == v:
        raise ValueError(f"self-loop on node {u}")
    return (u, v) if u < v else (v, u)


def ring_adjacent(addrs: Sequence[NodeAddress], space: int) -> List[Tuple[int, int]]:
    """Ring-adjacency pairs in one virtual space (clockwise order edges)."""
    order = sorted(addrs, key=lambda a: (a.coords[space], a.node_id))
    n = len(order)
    if n < 2:
        return []
    if n == 2:
        return [make_edge(order[0].node_id, order[1].node_id)]
    return [make_edge(order[i].node_id, order[(i + 1) % n].node_id) for i in range(n)]


def fedlay_topology(addrs: Sequence[NodeAddress], name: str = "fedlay") -> Topology:
    """The correct FedLay overlay (Definition 1) for a set of addresses."""
    if not addrs:
        return Topology(nodes=(), edges=frozenset(), name=name)
    num_spaces = addrs[0].num_spaces
    edges = set()
    for s in range(num_spaces):
        edges.update(ring_adjacent(addrs, s))
    return Topology(nodes=tuple(sorted(a.node_id for a in addrs)), edges=frozenset(edges), name=name)


def correct_neighbor_sets(addrs: Sequence[NodeAddress]) -> Dict[int, FrozenSet[int]]:
    """Definition 1: for every node, the set of ring-adjacent nodes over all spaces."""
    topo = fedlay_topology(addrs)
    nbr = topo.neighbor_map()
    return {u: frozenset(v) for u, v in nbr.items()}


def correctness(
    neighbor_tables: Dict[int, Iterable[int]], addrs: Sequence[NodeAddress]
) -> float:
    """Topology correctness metric (paper §IV-A3).

    ``number of correct neighbor entries / total required neighbor
    entries`` over all nodes, where the required entries are the
    Definition-1 neighbor sets.  1.0 ⇔ a correct FedLay (every node has
    exactly its ring-adjacent peers; extra stale entries also count
    against correctness).
    """
    want = correct_neighbor_sets(addrs)
    total = sum(len(w) for w in want.values())
    if total == 0:
        return 1.0
    got_correct = 0
    extra = 0
    for u, w in want.items():
        have = frozenset(neighbor_tables.get(u, ()))
        got_correct += len(have & w)
        extra += len(have - w)
    return got_correct / (total + extra) if (total + extra) else 1.0


def ring_orders(addrs: Sequence[NodeAddress]) -> List[List[int]]:
    """Clockwise node-id order per virtual space — the static schedule the
    distribution layer compiles into ``ppermute`` rotations."""
    if not addrs:
        return []
    num_spaces = addrs[0].num_spaces
    return [
        [a.node_id for a in sorted(addrs, key=lambda a: (a.coords[s], a.node_id))]
        for s in range(num_spaces)
    ]
