"""DFL execution engines (paper §IV) behind one registry front door.

A method is a :class:`MethodSpec` — engine kind, overlay topology
factory, aggregation mode (MEP confidence weights vs simple average),
and pacing (per-client async periods vs slowest-client sync rounds) —
looked up in :data:`METHOD_REGISTRY` and executed by
:meth:`Engine.run`, the single entry point shared by every benchmark and
example.  Ablation variants compose as name suffixes in either order:
``"fedlay-noconf-sync"`` ≡ ``"fedlay-sync-noconf"``.

Registered methods (paper §IV-A4):

* ``fedlay``   — DFL over the FedLay overlay, MEP confidence-weighted
  aggregation, asynchronous per-client periods (the paper's system);
* ``fedavg``   — centralized FL upper bound (synchronous rounds paced by
  the slowest client, dataset-size-weighted global average);
* ``gaia``     — geo-distributed regions, server per region, complete
  graph across region servers, *simple* averaging (no non-iid handling);
* ``dfl-dds``  — topology-free DFL between geographically close mobile
  nodes (random-waypoint proximity graph, simple average);
* ``chord`` / ``ring`` / every other registered topology — DFL gossip
  over that overlay (the paper's Chord comparisons);
* ``*-sync``   — synchronous rounds (Fig 12 ablation);
* ``*-noconf`` — simple average instead of confidence weights
  (Figs 16/17 ablation).

The engine is generic over a :class:`Task` (model init / local train /
evaluate), so the same loops drive the paper's MLP/CNN/LSTM workloads
and the synthetic stand-ins used in this offline container.  The TPU
image of the same mixing semantics lives in :mod:`repro.dist.sync`
(static ``ppermute`` schedules compiled by
:func:`repro.core.mixing.build_permute_schedule`).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import warnings
from typing import (Any, Callable, Dict, List, Mapping, Optional, Protocol,
                    Sequence, Tuple, Union)

import numpy as np

from .baselines import TOPOLOGY_REGISTRY
from .mep import (ClientProfile, FingerprintTable, aggregation_weights,
                  link_period, model_fingerprint)
from .topology import Topology


# --------------------------------------------------------------------------
# Task protocol
# --------------------------------------------------------------------------

class Task(Protocol):
    """A federated ML task: local data lives inside the task, addressed by
    client id, so the engine never sees raw data (as in real FL)."""

    num_clients: int

    def init_params(self, seed: int) -> np.ndarray: ...           # flat f32
    def local_train(self, params: np.ndarray, client: int, seed: int) -> np.ndarray: ...
    def evaluate(self, params: np.ndarray) -> float: ...          # test accuracy
    def label_histogram(self, client: int) -> np.ndarray: ...
    def train_cost(self, client: int) -> float: ...               # relative compute


@dataclasses.dataclass
class TraceRow:
    time: float
    mean_acc: float
    min_acc: float
    max_acc: float
    accs: Optional[np.ndarray] = None


@dataclasses.dataclass
class RunResult:
    method: str
    trace: List[TraceRow]
    comm_bytes_per_client: float
    messages_per_client: float
    suppressed_sends: int
    local_steps_per_client: float
    final_params: List[np.ndarray]

    @property
    def final_mean_acc(self) -> float:
        return self.trace[-1].mean_acc if self.trace else 0.0


def make_profiles(task: Task, periods: Sequence[float]) -> Dict[int, ClientProfile]:
    return {
        i: ClientProfile(client_id=i, period=float(periods[i]),
                         label_histogram=task.label_histogram(i))
        for i in range(task.num_clients)
    }


def capacity_periods(n: int, base_period: float, seed: int = 0,
                     fractions: Tuple[float, float, float] = (0.2, 0.6, 0.2)) -> np.ndarray:
    """The paper's 3-tier client heterogeneity: 20% high (2/3·T),
    60% medium (T), 20% low (2·T)."""
    rng = np.random.default_rng(seed)
    tiers = rng.choice(3, size=n, p=list(fractions))
    mult = np.array([2.0 / 3.0, 1.0, 2.0])[tiers]
    return base_period * mult


# --------------------------------------------------------------------------
# Method specs + registry
# --------------------------------------------------------------------------

#: Topology factory: (num_clients, num_spaces) -> Topology.  Baseline
#: overlays ignore num_spaces; a pre-built Topology is also accepted.
TopologyFactory = Callable[[int, int], Topology]


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """Everything the engine needs to run one DFL method.

    ``engine`` selects the event loop: ``"gossip"`` (asynchronous
    overlay gossip — FedLay and every topology baseline) or one of the
    round-paced engines (``"fedavg"``, ``"gaia"``, ``"dfl-dds"``), which
    are inherently synchronous and simple-averaging, so ``aggregation``
    and ``pacing`` only steer the gossip engine.
    """

    name: str
    engine: str = "gossip"
    topology: Optional[Union[Topology, TopologyFactory]] = None
    aggregation: str = "confidence"        # "confidence" | "simple"
    pacing: str = "async"                  # "async" | "sync"
    options: Tuple[Tuple[str, Any], ...] = ()

    def variant(self, aggregation: Optional[str] = None,
                pacing: Optional[str] = None) -> "MethodSpec":
        """The ablation variant with its canonical suffixed name."""
        agg = aggregation or self.aggregation
        pace = pacing or self.pacing
        name = (self.name + ("-noconf" if agg == "simple" and
                             self.aggregation != "simple" else "")
                + ("-sync" if pace == "sync" and
                   self.pacing != "sync" else ""))
        return dataclasses.replace(self, name=name, aggregation=agg,
                                   pacing=pace)


METHOD_REGISTRY: Dict[str, MethodSpec] = {}


def register_method(spec: MethodSpec) -> MethodSpec:
    METHOD_REGISTRY[spec.name] = spec
    return spec


def resolve_method(method: str) -> MethodSpec:
    """Look up a method name, honoring ``-sync`` / ``-noconf`` suffixes
    in either order (``fedlay-noconf-sync`` ≡ ``fedlay-sync-noconf``)."""
    base, pacing, aggregation = method, None, None
    stripped = True
    while stripped:
        stripped = False
        if base.endswith("-sync"):
            base, pacing, stripped = base[:-len("-sync")], "sync", True
        elif base.endswith("-noconf"):
            base, aggregation, stripped = base[:-len("-noconf")], "simple", True
    spec = METHOD_REGISTRY.get(base)
    if spec is None and base in TOPOLOGY_REGISTRY:
        # call-time fallback: overlays added to TOPOLOGY_REGISTRY after
        # this module imported are still runnable as gossip methods
        factory = TOPOLOGY_REGISTRY[base]
        spec = MethodSpec(base, topology=lambda n, L, _f=factory: _f(n))
    if spec is None:
        known = ", ".join(sorted(set(METHOD_REGISTRY) | set(TOPOLOGY_REGISTRY)))
        raise ValueError(
            f"unknown method {method!r} (base {base!r}); known methods: "
            f"{known} — each optionally suffixed with '-sync' and/or "
            f"'-noconf' in any order")
    if aggregation or pacing:
        spec = spec.variant(aggregation=aggregation, pacing=pacing)
    return spec


def _register_builtin_methods() -> None:
    register_method(MethodSpec(
        "fedlay",
        topology=lambda n, L: TOPOLOGY_REGISTRY["fedlay"](n, L)))
    register_method(MethodSpec("fedavg", engine="fedavg",
                               aggregation="simple", pacing="sync"))
    register_method(MethodSpec("gaia", engine="gaia",
                               aggregation="simple", pacing="sync"))
    register_method(MethodSpec("dfl-dds", engine="dfl-dds",
                               aggregation="simple", pacing="sync"))
    for topo_name, factory in TOPOLOGY_REGISTRY.items():
        if topo_name == "fedlay":
            continue
        register_method(MethodSpec(
            topo_name, topology=lambda n, L, _f=factory: _f(n)))


# --------------------------------------------------------------------------
# Shared run bookkeeping
# --------------------------------------------------------------------------

class _Recorder:
    """Trace + per-client communication/compute counters, shared by every
    engine loop (this is the scaffolding the four pre-registry loops each
    duplicated).

    Reports into the :mod:`repro.obs` plane: every snapshot ticks
    ``engine.*`` signals on the telemetry bus and — when a round ledger
    is installed — lands one ``loop="engine"`` record per evaluation
    point (wire bytes = mean per-client bytes sent since the previous
    snapshot); :meth:`result` flushes the run totals as ``engine.*``
    counters.  All no-ops under the disabled-by-default globals."""

    def __init__(self, task: Task):
        self.task = task
        self.n = task.num_clients
        self.trace: List[TraceRow] = []
        self.bytes_sent = np.zeros(self.n)
        self.msgs_sent = np.zeros(self.n)
        self.local_steps = np.zeros(self.n)
        self.suppressed = 0
        self._last_bytes = 0.0
        self._last_steps = 0.0

    def snapshot(self, t: float, params: Sequence[np.ndarray]) -> None:
        cache: Dict[int, float] = {}      # distinct arrays evaluated once
        for p in params:
            if id(p) not in cache:
                cache[id(p)] = self.task.evaluate(p)
        accs = np.array([cache[id(p)] for p in params])
        self.trace.append(TraceRow(
            time=t, mean_acc=float(accs.mean()), min_acc=float(accs.min()),
            max_acc=float(accs.max()), accs=accs))
        from ..obs import get_telemetry
        from ..obs.rounds import get_round_ledger
        bus = get_telemetry()
        if bus.enabled:
            bus.count("engine.evals")
            bus.gauge("engine.mean_acc", float(accs.mean()))
        ledger = get_round_ledger()
        if ledger is not None:
            mean_b = float(self.bytes_sent.mean())
            mean_s = float(self.local_steps.mean())
            ledger.record(
                round=len(self.trace) - 1, time=t, loop="engine",
                num_alive=self.n, participating=self.n,
                wire_bytes_per_client=mean_b - self._last_bytes,
                payload_bytes_per_client=mean_b - self._last_bytes,
                mean_acc=float(accs.mean()), min_acc=float(accs.min()),
                max_acc=float(accs.max()),
                local_steps_per_client=mean_s - self._last_steps)
            self._last_bytes, self._last_steps = mean_b, mean_s

    def result(self, method: str, params: Sequence[np.ndarray]) -> RunResult:
        from ..obs import get_telemetry
        bus = get_telemetry()
        if bus.enabled:
            bus.count("engine.bytes_sent", float(self.bytes_sent.sum()))
            bus.count("engine.msgs_sent", float(self.msgs_sent.sum()))
            bus.count("engine.local_steps", float(self.local_steps.sum()))
            bus.count("engine.suppressed", int(self.suppressed))
        return RunResult(
            method=method, trace=self.trace,
            comm_bytes_per_client=float(self.bytes_sent.mean()),
            messages_per_client=float(self.msgs_sent.mean()),
            suppressed_sends=int(self.suppressed),
            local_steps_per_client=float(self.local_steps.mean()),
            final_params=list(params))


# --------------------------------------------------------------------------
# Round-paced engines (centralized / clustered / mobility baselines)
# --------------------------------------------------------------------------

class _FedAvgRounds:
    """Centralized FedAvg: the server averages all client models each
    round (dataset-size weighted)."""

    def __init__(self, task: Task, rec: _Recorder, rng: np.random.Generator,
                 seed: int, model_bytes: int, round_time: float,
                 options: Mapping[str, Any]):
        self.task, self.rec, self.rng = task, rec, rng
        self.model_bytes = model_bytes
        n = task.num_clients
        sw = np.array(options.get("sample_weights") if options.get(
            "sample_weights") is not None else
            [task.label_histogram(i).sum() for i in range(n)], np.float64)
        self.sw = sw / sw.sum()
        self.global_params = task.init_params(seed)

    def round(self) -> None:
        task, rng, n = self.task, self.rng, self.task.num_clients
        locals_ = [task.local_train(self.global_params.copy(), u,
                                    seed=int(rng.integers(2**31)))
                   for u in range(n)]
        self.global_params = np.sum(
            [self.sw[u] * locals_[u] for u in range(n)], axis=0)
        self.rec.bytes_sent += 2 * self.model_bytes   # up + down per client
        self.rec.msgs_sent += 2
        self.rec.local_steps += 1

    def client_params(self) -> List[np.ndarray]:
        return [self.global_params] * self.task.num_clients


class _GaiaRounds:
    """Gaia: FedAvg inside each geo region; region servers form a
    complete graph and simple-average each round.  No non-iid handling."""

    def __init__(self, task: Task, rec: _Recorder, rng: np.random.Generator,
                 seed: int, model_bytes: int, round_time: float,
                 options: Mapping[str, Any]):
        self.task, self.rec, self.rng = task, rec, rng
        self.model_bytes = model_bytes
        self.num_regions = int(options.get("num_regions", 4))
        self.region = np.arange(task.num_clients) % self.num_regions
        self.region_params = [task.init_params(seed)
                              for _ in range(self.num_regions)]

    def round(self) -> None:
        task, rng, mb = self.task, self.rng, self.model_bytes
        n = task.num_clients
        for r in range(self.num_regions):
            members = np.nonzero(self.region == r)[0]
            locals_ = [task.local_train(self.region_params[r].copy(), int(u),
                                        seed=int(rng.integers(2**31)))
                       for u in members]
            self.region_params[r] = np.mean(locals_, axis=0)
            self.rec.bytes_sent[members] += 2 * mb
            self.rec.msgs_sent[members] += 2
        self.rec.local_steps += 1
        # inter-region complete-graph simple average (server-to-server)
        mixed = np.mean(self.region_params, axis=0)
        self.region_params = [mixed.copy() for _ in range(self.num_regions)]
        self.rec.bytes_sent += mb * self.num_regions * (self.num_regions - 1) / n

    def client_params(self) -> List[np.ndarray]:
        return [self.region_params[self.region[u]]
                for u in range(self.task.num_clients)]


class _DflDdsRounds:
    """DFL-DDS-style mobility DFL: nodes move (random waypoint) in the
    unit square; each round a node simple-averages with nodes within
    ``radius``."""

    def __init__(self, task: Task, rec: _Recorder, rng: np.random.Generator,
                 seed: int, model_bytes: int, round_time: float,
                 options: Mapping[str, Any]):
        self.task, self.rec, self.rng = task, rec, rng
        self.model_bytes = model_bytes
        self.radius = float(options.get("radius", 0.25))
        self.round_time = round_time
        n = task.num_clients
        self.pos = rng.random((n, 2))
        self.vel = (rng.random((n, 2)) - 0.5) * 0.2
        self.params = [task.init_params(seed) for _ in range(n)]

    def round(self) -> None:
        task, rng, n = self.task, self.rng, self.task.num_clients
        self.pos = (self.pos + self.vel * self.round_time) % 1.0
        new_params = []
        for u in range(n):
            d = np.linalg.norm(self.pos - self.pos[u], axis=1)
            nbr = [v for v in np.nonzero(d < self.radius)[0] if v != u]
            group = [self.params[u]] + [self.params[v] for v in nbr]
            agg = np.mean(group, axis=0)
            new_params.append(task.local_train(
                agg, u, seed=int(rng.integers(2**31))))
            self.rec.bytes_sent[u] += self.model_bytes * len(nbr)
            self.rec.msgs_sent[u] += len(nbr)
        self.params = new_params
        self.rec.local_steps += 1

    def client_params(self) -> List[np.ndarray]:
        return self.params


_ROUND_ENGINES = {
    "fedavg": _FedAvgRounds,
    "gaia": _GaiaRounds,
    "dfl-dds": _DflDdsRounds,
}


# --------------------------------------------------------------------------
# The engine
# --------------------------------------------------------------------------

class Engine:
    """The single DFL execution front door.

    ``Engine().run(task, "fedlay", total_time=..., model_bytes=...)``
    runs any registered method (or an ad-hoc :class:`MethodSpec`) and
    returns a :class:`RunResult`; the method string accepts the
    ``-sync`` / ``-noconf`` ablation suffixes in any order.
    """

    def __init__(self, *, alpha_d: float = 0.5, alpha_c: float = 0.5):
        self.alpha_d = alpha_d
        self.alpha_c = alpha_c

    def run(self, task: Task, method: Union[str, MethodSpec], *,
            total_time: float, model_bytes: int, base_period: float = 1.0,
            num_spaces: int = 3, periods: Optional[Sequence[float]] = None,
            seed: int = 0, eval_every: float = 0.0,
            init_params: Optional[List[np.ndarray]] = None,
            telemetry=None, ledger=None) -> RunResult:
        """Run one DFL method end to end.

        ``periods`` overrides the paper's 3-tier heterogeneity model
        (:func:`capacity_periods`); ``init_params`` warm-starts the
        per-client models (churn experiments; gossip engine only).
        ``eval_every`` paces gossip trace snapshots — round-paced
        engines always snapshot once per round.

        ``telemetry`` (a :class:`repro.obs.Telemetry`) and ``ledger``
        (a :class:`repro.obs.rounds.RoundLedger`) scope the
        :mod:`repro.obs` plane to this run: the bus/ledger are installed
        for the duration and restored afterwards, and the run's
        evaluation snapshots land as ``loop="engine"`` ledger records.
        Without them the run reports into the process globals (no-ops
        by default).
        """
        if telemetry is not None or ledger is not None:
            from ..obs.events import telemetry as telemetry_scope
            from ..obs.rounds import round_ledger as ledger_scope
            from contextlib import ExitStack
            with ExitStack() as stack:
                if telemetry is not None:
                    stack.enter_context(telemetry_scope(telemetry))
                if ledger is not None:
                    stack.enter_context(ledger_scope(ledger))
                return self.run(
                    task, method, total_time=total_time,
                    model_bytes=model_bytes, base_period=base_period,
                    num_spaces=num_spaces, periods=periods, seed=seed,
                    eval_every=eval_every, init_params=init_params)
        spec = resolve_method(method) if isinstance(method, str) else method
        n = task.num_clients
        if periods is None:
            periods = capacity_periods(n, base_period, seed=seed)
        periods = np.asarray(periods, dtype=np.float64)

        if spec.engine == "gossip":
            topo = spec.topology
            if topo is None:
                raise ValueError(
                    f"gossip method {spec.name!r} needs a topology")
            if not isinstance(topo, Topology):
                topo = topo(n, num_spaces)
            return self._run_gossip(task, spec, topo, periods,
                                    total_time=total_time,
                                    model_bytes=model_bytes, seed=seed,
                                    eval_every=eval_every,
                                    init_params=init_params)

        impl_cls = _ROUND_ENGINES.get(spec.engine)
        if impl_cls is None:
            raise ValueError(
                f"unknown engine {spec.engine!r} for method {spec.name!r}; "
                f"expected 'gossip' or one of {sorted(_ROUND_ENGINES)}")
        if init_params is not None:
            raise ValueError(
                f"init_params warm-start is only supported by the gossip "
                f"engine, not {spec.engine!r}")
        return self._run_rounds(task, spec, impl_cls, periods,
                                total_time=total_time,
                                model_bytes=model_bytes, seed=seed)

    # -- round-paced loop (fedavg / gaia / dfl-dds) ------------------------

    def _run_rounds(self, task: Task, spec: MethodSpec, impl_cls, periods,
                    *, total_time: float, model_bytes: int,
                    seed: int) -> RunResult:
        """Synchronous rounds paced by the slowest client — the one loop
        behind every centralized/clustered baseline."""
        rec = _Recorder(task)
        rng = np.random.default_rng(seed)
        round_time = float(np.max(periods))
        impl = impl_cls(task, rec, rng, seed, model_bytes, round_time,
                        dict(spec.options))
        rec.snapshot(0.0, impl.client_params())
        t = 0.0
        while t + round_time <= total_time:
            t += round_time
            impl.round()
            rec.snapshot(t, impl.client_params())
        return rec.result(spec.name, impl.client_params())

    # -- asynchronous gossip loop (FedLay and topology baselines) ----------

    def _run_gossip(self, task: Task, spec: MethodSpec, topology: Topology,
                    periods, *, total_time: float, model_bytes: int,
                    seed: int, eval_every: float,
                    init_params: Optional[List[np.ndarray]]) -> RunResult:
        """Event-driven asynchronous DFL gossip (MEP semantics).

        Every client u wakes at its own period T_u (sync pacing: all
        clients paced by max T): aggregate the latest models received
        from neighbors with confidence weights, run local training, then
        send the new model to each neighbor unless (a) the per-link
        period max(T_u,T_v) has not elapsed or (b) the fingerprint is
        unchanged.
        """
        n = task.num_clients
        confidence_weighted = spec.aggregation != "simple"
        rng = np.random.default_rng(seed)
        nbrs = topology.neighbor_map()
        profiles = make_profiles(task, periods)
        if spec.pacing == "sync":
            periods = np.full(n, float(np.max(periods)))

        if init_params is not None:
            assert len(init_params) == n
            params: List[np.ndarray] = [p.copy() for p in init_params]
            task.init_params(seed)   # ensure the task's unflatten spec exists
        else:
            params = [task.init_params(seed) for _ in range(n)]
        inbox: List[Dict[int, np.ndarray]] = [dict() for _ in range(n)]
        fingerprints = [FingerprintTable() for _ in range(n)]
        last_link_send: Dict[Tuple[int, int], float] = {}
        rec = _Recorder(task)

        heap: List[Tuple[float, int, int]] = []
        counter = itertools.count()
        for u in range(n):
            heapq.heappush(heap, (float(periods[u]) * (0.5 + 0.5 * rng.random()),
                                  next(counter), u))

        eval_every = eval_every or max(float(np.max(periods)), total_time / 20.0)
        rec.snapshot(0.0, params)
        next_eval = eval_every
        now = 0.0
        while heap and heap[0][0] <= total_time:
            now, _, u = heapq.heappop(heap)
            while next_eval <= now:
                rec.snapshot(next_eval, params)
                next_eval += eval_every
            # 1) MEP aggregation over {u} ∪ received neighbor models
            rx = [(v, m) for v, m in inbox[u].items()]
            if rx:
                w = aggregation_weights(profiles[u],
                                        [profiles[v] for v, _ in rx],
                                        self.alpha_d, self.alpha_c,
                                        confidence_weighted)
                agg = w[0] * params[u]
                for k, (_, m) in enumerate(rx):
                    agg = agg + w[k + 1] * m
                params[u] = agg
            # 2) local training
            params[u] = task.local_train(params[u], u,
                                         seed=int(rng.integers(2**31)))
            rec.local_steps[u] += 1
            # 3) push to neighbors (link period + fingerprint suppression)
            fp = model_fingerprint(params[u])
            for v in nbrs[u]:
                lp = link_period(float(periods[u]), float(periods[v]))
                last = last_link_send.get((u, v), -np.inf)
                if now - last < lp * 0.999:
                    continue
                if not fingerprints[u].should_send(v, fp):
                    continue
                fingerprints[u].record(v, fp)
                inbox[v][u] = params[u].copy()
                last_link_send[(u, v)] = now
                rec.bytes_sent[u] += model_bytes
                rec.msgs_sent[u] += 1
            heapq.heappush(heap, (now + float(periods[u]), next(counter), u))
        while next_eval <= total_time:
            rec.snapshot(next_eval, params)
            next_eval += eval_every

        rec.suppressed = sum(f.suppressed for f in fingerprints)
        return rec.result(spec.name, params)


_register_builtin_methods()


# --------------------------------------------------------------------------
# Compatibility wrappers
# --------------------------------------------------------------------------

def run_gossip(task: Task, topology: Topology, periods: Sequence[float],
               total_time: float, model_bytes: int,
               confidence_weighted: bool = True,
               synchronous: bool = False,
               alpha_d: float = 0.5, alpha_c: float = 0.5,
               eval_every: float = 0.0, seed: int = 0,
               method_name: str = "gossip",
               init_params: Optional[List[np.ndarray]] = None) -> RunResult:
    """Gossip over an explicit topology — sugar for :meth:`Engine.run`
    with an ad-hoc :class:`MethodSpec` (custom overlays, churn phases)."""
    spec = MethodSpec(
        name=method_name, engine="gossip", topology=topology,
        aggregation="confidence" if confidence_weighted else "simple",
        pacing="sync" if synchronous else "async")
    return Engine(alpha_d=alpha_d, alpha_c=alpha_c).run(
        task, spec, total_time=total_time, model_bytes=model_bytes,
        periods=periods, seed=seed, eval_every=eval_every,
        init_params=init_params)


def run_method(method: str, task: Task, total_time: float, model_bytes: int,
               base_period: float = 1.0, num_spaces: int = 3, degree: int = 0,
               seed: int = 0, eval_every: float = 0.0) -> RunResult:
    """Deprecated string front door.

    Use ``Engine().run(task, method, ...)`` instead — this shim resolves
    the same method names (including suffix variants, now in either
    order) through :data:`METHOD_REGISTRY` and will be removed once
    nothing imports it.  ``degree`` was always ignored and remains so.
    """
    warnings.warn(
        "run_method is deprecated; use repro.core.dfl.Engine().run(task, "
        "method, ...)", DeprecationWarning, stacklevel=2)
    return Engine().run(task, method, total_time=total_time,
                        model_bytes=model_bytes, base_period=base_period,
                        num_spaces=num_spaces, seed=seed,
                        eval_every=eval_every)
