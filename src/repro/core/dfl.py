"""DFL training engines (paper §IV) — FedLay/MEP plus every comparison method.

The engine is generic over a :class:`Task` (model init / local train /
evaluate) so the same loop drives the paper's MLP/CNN/LSTM workloads and
the synthetic stand-ins used in this offline container.

Methods implemented (paper §IV-A4):

* ``fedlay``   — DFL over the FedLay overlay, MEP confidence-weighted
  aggregation, asynchronous per-client periods (the paper's system);
* ``fedavg``   — centralized FL upper bound (synchronous rounds paced by
  the slowest client, dataset-size-weighted global average);
* ``gaia``     — geo-distributed regions, server per region, complete
  graph across region servers, *simple* averaging (no non-iid handling);
* ``dfl-dds``  — topology-free DFL between geographically close mobile
  nodes (random-waypoint proximity graph, simple average);
* ``chord`` / ``ring`` / any registered topology — DFL gossip over that
  overlay (used for the paper's Chord comparisons);
* ``fedlay-sync`` — FedLay with synchronous rounds (Fig 12 ablation);
* ``*-noconf``   — simple average instead of confidence weights
  (Figs 16/17 ablation).
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Protocol, Sequence, Tuple

import numpy as np

from .baselines import TOPOLOGY_REGISTRY
from .mep import (ClientProfile, FingerprintTable, aggregation_weights,
                  link_period, model_fingerprint)
from .topology import Topology


# --------------------------------------------------------------------------
# Task protocol
# --------------------------------------------------------------------------

class Task(Protocol):
    """A federated ML task: local data lives inside the task, addressed by
    client id, so the engine never sees raw data (as in real FL)."""

    num_clients: int

    def init_params(self, seed: int) -> np.ndarray: ...           # flat f32
    def local_train(self, params: np.ndarray, client: int, seed: int) -> np.ndarray: ...
    def evaluate(self, params: np.ndarray) -> float: ...          # test accuracy
    def label_histogram(self, client: int) -> np.ndarray: ...
    def train_cost(self, client: int) -> float: ...               # relative compute


@dataclasses.dataclass
class TraceRow:
    time: float
    mean_acc: float
    min_acc: float
    max_acc: float
    accs: Optional[np.ndarray] = None


@dataclasses.dataclass
class RunResult:
    method: str
    trace: List[TraceRow]
    comm_bytes_per_client: float
    messages_per_client: float
    suppressed_sends: int
    local_steps_per_client: float
    final_params: List[np.ndarray]

    @property
    def final_mean_acc(self) -> float:
        return self.trace[-1].mean_acc if self.trace else 0.0


def make_profiles(task: Task, periods: Sequence[float]) -> Dict[int, ClientProfile]:
    return {
        i: ClientProfile(client_id=i, period=float(periods[i]),
                         label_histogram=task.label_histogram(i))
        for i in range(task.num_clients)
    }


def capacity_periods(n: int, base_period: float, seed: int = 0,
                     fractions: Tuple[float, float, float] = (0.2, 0.6, 0.2)) -> np.ndarray:
    """The paper's 3-tier client heterogeneity: 20% high (2/3·T),
    60% medium (T), 20% low (2·T)."""
    rng = np.random.default_rng(seed)
    tiers = rng.choice(3, size=n, p=list(fractions))
    mult = np.array([2.0 / 3.0, 1.0, 2.0])[tiers]
    return base_period * mult


# --------------------------------------------------------------------------
# The asynchronous gossip engine (FedLay and topology baselines)
# --------------------------------------------------------------------------

def run_gossip(task: Task, topology: Topology, periods: Sequence[float],
               total_time: float, model_bytes: int,
               confidence_weighted: bool = True,
               synchronous: bool = False,
               alpha_d: float = 0.5, alpha_c: float = 0.5,
               eval_every: float = 0.0, seed: int = 0,
               method_name: str = "gossip",
               init_params: Optional[List[np.ndarray]] = None) -> RunResult:
    """Event-driven asynchronous DFL gossip (MEP semantics).

    Every client u wakes at its own period T_u (synchronous mode: all
    clients paced by max T): aggregate the latest models received from
    neighbors with confidence weights, run local training, then send the
    new model to each neighbor unless (a) the per-link period
    max(T_u,T_v) has not elapsed or (b) the fingerprint is unchanged.
    """
    n = task.num_clients
    rng = np.random.default_rng(seed)
    nbrs = topology.neighbor_map()
    profiles = make_profiles(task, periods)
    if synchronous:
        periods = np.full(n, float(np.max(periods)))

    if init_params is not None:
        assert len(init_params) == n
        params: List[np.ndarray] = [p.copy() for p in init_params]
        task.init_params(seed)   # ensure the task's unflatten spec exists
    else:
        params = [task.init_params(seed) for _ in range(n)]
    inbox: List[Dict[int, np.ndarray]] = [dict() for _ in range(n)]
    fingerprints = [FingerprintTable() for _ in range(n)]
    last_link_send: Dict[Tuple[int, int], float] = {}
    bytes_sent = np.zeros(n)
    msgs_sent = np.zeros(n)
    local_steps = np.zeros(n)

    heap: List[Tuple[float, int, int]] = []
    counter = itertools.count()
    for u in range(n):
        heapq.heappush(heap, (float(periods[u]) * (0.5 + 0.5 * rng.random()),
                              next(counter), u))

    trace: List[TraceRow] = []
    eval_every = eval_every or max(float(np.max(periods)), total_time / 20.0)
    next_eval = 0.0

    def snapshot(t: float) -> None:
        accs = np.array([task.evaluate(p) for p in params])
        trace.append(TraceRow(time=t, mean_acc=float(accs.mean()),
                              min_acc=float(accs.min()), max_acc=float(accs.max()),
                              accs=accs))

    snapshot(0.0)
    next_eval = eval_every
    now = 0.0
    while heap and heap[0][0] <= total_time:
        now, _, u = heapq.heappop(heap)
        while next_eval <= now:
            snapshot(next_eval)
            next_eval += eval_every
        # 1) MEP aggregation over {u} ∪ received neighbor models
        rx = [(v, m) for v, m in inbox[u].items()]
        if rx:
            w = aggregation_weights(profiles[u], [profiles[v] for v, _ in rx],
                                    alpha_d, alpha_c, confidence_weighted)
            agg = w[0] * params[u]
            for k, (_, m) in enumerate(rx):
                agg = agg + w[k + 1] * m
            params[u] = agg
        # 2) local training
        params[u] = task.local_train(params[u], u, seed=int(rng.integers(2**31)))
        local_steps[u] += 1
        # 3) push to neighbors (link period + fingerprint suppression)
        fp = model_fingerprint(params[u])
        for v in nbrs[u]:
            lp = link_period(float(periods[u]), float(periods[v]))
            last = last_link_send.get((u, v), -np.inf)
            if now - last < lp * 0.999:
                continue
            if not fingerprints[u].should_send(v, fp):
                continue
            fingerprints[u].record(v, fp)
            inbox[v][u] = params[u].copy()
            last_link_send[(u, v)] = now
            bytes_sent[u] += model_bytes
            msgs_sent[u] += 1
        heapq.heappush(heap, (now + float(periods[u]), next(counter), u))
    while next_eval <= total_time:
        snapshot(next_eval)
        next_eval += eval_every

    return RunResult(
        method=method_name, trace=trace,
        comm_bytes_per_client=float(bytes_sent.mean()),
        messages_per_client=float(msgs_sent.mean()),
        suppressed_sends=int(sum(f.suppressed for f in fingerprints)),
        local_steps_per_client=float(local_steps.mean()),
        final_params=params,
    )


# --------------------------------------------------------------------------
# Centralized / clustered baselines
# --------------------------------------------------------------------------

def run_fedavg(task: Task, periods: Sequence[float], total_time: float,
               model_bytes: int, seed: int = 0,
               sample_weights: Optional[np.ndarray] = None) -> RunResult:
    """Centralized FedAvg: synchronous rounds paced by the slowest client;
    the server averages all client models (dataset-size weighted)."""
    n = task.num_clients
    rng = np.random.default_rng(seed)
    round_time = float(np.max(periods))
    if sample_weights is None:
        sample_weights = np.array([task.label_histogram(i).sum() for i in range(n)],
                                  dtype=np.float64)
    sw = sample_weights / sample_weights.sum()
    global_params = task.init_params(seed)
    trace = [TraceRow(0.0, task.evaluate(global_params),
                      task.evaluate(global_params), task.evaluate(global_params))]
    t = 0.0
    bytes_sent = 0.0
    msgs = 0.0
    steps = 0.0
    while t + round_time <= total_time:
        t += round_time
        locals_ = [task.local_train(global_params.copy(), u,
                                    seed=int(rng.integers(2**31))) for u in range(n)]
        steps += 1
        global_params = np.sum([sw[u] * locals_[u] for u in range(n)], axis=0)
        bytes_sent += 2 * model_bytes  # up + down per client
        msgs += 2
        acc = task.evaluate(global_params)
        trace.append(TraceRow(t, acc, acc, acc))
    return RunResult(method="fedavg", trace=trace,
                     comm_bytes_per_client=bytes_sent,
                     messages_per_client=msgs, suppressed_sends=0,
                     local_steps_per_client=steps,
                     final_params=[global_params] * n)


def run_gaia(task: Task, periods: Sequence[float], total_time: float,
             model_bytes: int, num_regions: int = 4, seed: int = 0) -> RunResult:
    """Gaia: FedAvg inside each geo region; region servers form a complete
    graph and simple-average each round.  No non-iid handling."""
    n = task.num_clients
    rng = np.random.default_rng(seed)
    region = np.arange(n) % num_regions
    round_time = float(np.max(periods))
    region_params = [task.init_params(seed) for _ in range(num_regions)]
    t = 0.0
    bytes_sent = 0.0
    msgs = 0.0
    steps = 0.0
    trace = []

    def snapshot(t):
        accs = np.array([task.evaluate(region_params[region[u]]) for u in range(n)])
        trace.append(TraceRow(t, float(accs.mean()), float(accs.min()), float(accs.max()),
                              accs=accs))

    snapshot(0.0)
    while t + round_time <= total_time:
        t += round_time
        # intra-region FedAvg
        for r in range(num_regions):
            members = np.nonzero(region == r)[0]
            locals_ = [task.local_train(region_params[r].copy(), int(u),
                                        seed=int(rng.integers(2**31))) for u in members]
            region_params[r] = np.mean(locals_, axis=0)
            bytes_sent += 2 * model_bytes * len(members)
            msgs += 2 * len(members)
        steps += 1
        # inter-region complete-graph simple average (server-to-server)
        mixed = np.mean(region_params, axis=0)
        region_params = [mixed.copy() for _ in range(num_regions)]
        bytes_sent += model_bytes * num_regions * (num_regions - 1)
        snapshot(t)
    return RunResult(method="gaia", trace=trace,
                     comm_bytes_per_client=bytes_sent / n,
                     messages_per_client=msgs / n, suppressed_sends=0,
                     local_steps_per_client=steps,
                     final_params=[region_params[region[u]] for u in range(n)])


def run_dfl_dds(task: Task, periods: Sequence[float], total_time: float,
                model_bytes: int, radius: float = 0.25, seed: int = 0) -> RunResult:
    """DFL-DDS-style mobility DFL: nodes move (random waypoint) in the unit
    square; each round a node simple-averages with nodes within ``radius``."""
    n = task.num_clients
    rng = np.random.default_rng(seed)
    pos = rng.random((n, 2))
    vel = (rng.random((n, 2)) - 0.5) * 0.2
    round_time = float(np.max(periods))
    params = [task.init_params(seed) for _ in range(n)]
    t = 0.0
    bytes_sent = np.zeros(n)
    msgs = np.zeros(n)
    steps = 0.0
    trace = []

    def snapshot(t):
        accs = np.array([task.evaluate(p) for p in params])
        trace.append(TraceRow(t, float(accs.mean()), float(accs.min()),
                              float(accs.max()), accs=accs))

    snapshot(0.0)
    while t + round_time <= total_time:
        t += round_time
        pos = (pos + vel * round_time) % 1.0
        new_params = []
        for u in range(n):
            d = np.linalg.norm(pos - pos[u], axis=1)
            nbr = [v for v in np.nonzero(d < radius)[0] if v != u]
            group = [params[u]] + [params[v] for v in nbr]
            agg = np.mean(group, axis=0)
            new_params.append(task.local_train(agg, u, seed=int(rng.integers(2**31))))
            bytes_sent[u] += model_bytes * len(nbr)
            msgs[u] += len(nbr)
        params = new_params
        steps += 1
        snapshot(t)
    return RunResult(method="dfl-dds", trace=trace,
                     comm_bytes_per_client=float(bytes_sent.mean()),
                     messages_per_client=float(msgs.mean()), suppressed_sends=0,
                     local_steps_per_client=steps, final_params=params)


# --------------------------------------------------------------------------
# Front door
# --------------------------------------------------------------------------

def run_method(method: str, task: Task, total_time: float, model_bytes: int,
               base_period: float = 1.0, num_spaces: int = 3, degree: int = 0,
               seed: int = 0, eval_every: float = 0.0) -> RunResult:
    """Run one DFL method end to end with the paper's heterogeneity model."""
    n = task.num_clients
    periods = capacity_periods(n, base_period, seed=seed)
    if method == "fedavg":
        return run_fedavg(task, periods, total_time, model_bytes, seed)
    if method == "gaia":
        return run_gaia(task, periods, total_time, model_bytes, seed=seed)
    if method == "dfl-dds":
        return run_dfl_dds(task, periods, total_time, model_bytes, seed=seed)

    sync = method.endswith("-sync")
    noconf = "-noconf" in method
    base = method.replace("-sync", "").replace("-noconf", "")
    if base == "fedlay":
        topo = TOPOLOGY_REGISTRY["fedlay"](n, num_spaces)
    elif base in TOPOLOGY_REGISTRY:
        topo = TOPOLOGY_REGISTRY[base](n)
    else:
        raise ValueError(f"unknown method {method!r}")
    return run_gossip(task, topo, periods, total_time, model_bytes,
                      confidence_weighted=not noconf, synchronous=sync,
                      eval_every=eval_every, seed=seed, method_name=method)
