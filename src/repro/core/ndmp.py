"""NDMP — Neighbor Discovery and Maintenance Protocols (paper §III-B).

A faithful discrete-event implementation of the FedLay control plane:

* **join** — the joining node u asks any existing node to greedy-route a
  ``Neighbor_discovery`` message toward u's coordinate in every virtual
  space (Theorem 1: greedy routing on circular distance always stops at
  the globally closest node); the stop node splices u into the ring and
  introduces both ring-adjacent peers.
* **leave** — the leaving node tells its ring-adjacent pair in every
  space to splice around it.
* **maintenance** — periodic heartbeats every ``T``; a neighbor silent
  for ``3T`` is declared failed and a ``Neighbor_repair`` message is
  greedy-routed *directionally* around the failed coordinate
  (Theorem 2: it stops at the failed node's other ring-adjacent node).
  Every node additionally sends periodic bidirectional repair probes to
  its own coordinate, which is the paper's mechanism for converging
  under *concurrent* joins and failures.

NDMP is a host-side control protocol in any real deployment (it speaks
TCP, not ICI), so on TPU it stays host-side: the simulator is exact —
per-message latencies, per-node clocks, no global knowledge — and its
converged neighbor tables are what the distribution layer compiles into
static ``ppermute`` schedules
(:func:`repro.core.mixing.build_permute_schedule` →
:func:`repro.dist.sync.make_mixer`).  Churn-triggered recompilation is
closed by the :mod:`repro.overlay` control plane: it polls
:meth:`Simulator.tables_version` / :meth:`Simulator.neighbor_tables`
between training steps, diffs them into table deltas, and hot-swaps the
compiled mixer for the new alive set.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Protocol, Tuple, runtime_checkable

import numpy as np

from .coords import NodeAddress, circular_distance, coordinates
from .topology import correctness as topology_correctness


# --------------------------------------------------------------------------
# The engine seam
# --------------------------------------------------------------------------

@runtime_checkable
class SimulatorProtocol(Protocol):
    """What the overlay control plane needs from *any* NDMP engine.

    :class:`Simulator` (exact per-message discrete events, the small-n
    oracle) and :class:`repro.scale.ndmp_vec.VectorSimulator` (flat-array
    batched engine for 10^5–10^6 nodes) both satisfy this, so
    :class:`repro.overlay.controller.OverlayController` is engine-
    agnostic: it only ever polls the delta API and replays churn through
    the three membership calls.

    ``tables_version()`` may return any equatable value — the control
    plane compares stamps for equality, never inspects them.
    """

    now: float
    num_spaces: int

    def advance(self, dt: float) -> None: ...
    def run_until(self, t: float) -> None: ...
    def alive_ids(self) -> List[int]: ...
    def alive_addresses(self) -> List[NodeAddress]: ...
    def neighbor_tables(self) -> Dict[int, frozenset]: ...
    def tables_version(self) -> object: ...
    def correctness(self) -> float: ...
    def join(self, node_id: int, bootstrap: int,
             seeds: Tuple[int, ...] = ()) -> None: ...
    def leave(self, node_id: int) -> None: ...
    def fail(self, node_id: int) -> None: ...


# --------------------------------------------------------------------------
# Messages
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Discovery:
    """Greedy-routed join probe toward ``target`` in ``space``."""

    space: int
    target: float
    joiner: int
    joiner_coords: tuple
    hops: int = 0


@dataclasses.dataclass
class DiscoveryReply:
    """Stop node tells the joiner its two ring-adjacent peers in ``space``."""

    space: int
    pred: int
    pred_coords: tuple
    succ: int
    succ_coords: tuple


@dataclasses.dataclass
class SpliceIn:
    """Stop node tells the displaced adjacent peer to point at the joiner."""

    space: int
    joiner: int
    joiner_coords: tuple
    side: str  # "pred" or "succ": which pointer of the receiver to update


@dataclasses.dataclass
class LeaveNotice:
    """Leaving node tells one adjacent peer to adopt the other."""

    space: int
    side: str  # pointer of the receiver to rewrite
    other: int
    other_coords: tuple


@dataclasses.dataclass
class Repair:
    """Directionally greedy-routed around a (suspected-failed) coordinate."""

    space: int
    target: float
    direction: str  # "cw" | "ccw"
    origin: int
    origin_coords: tuple
    hops: int = 0


@dataclasses.dataclass
class RepairStop:
    """The node where Repair stopped introduces itself to the origin."""

    space: int
    direction: str
    stopper: int
    stopper_coords: tuple


@dataclasses.dataclass
class Heartbeat:
    sender: int


Message = object


# --------------------------------------------------------------------------
# Node state
# --------------------------------------------------------------------------

@dataclasses.dataclass
class NodeState:
    node_id: int
    coords: tuple
    alive: bool = True
    joined: bool = False
    bootstrap: Optional[int] = None
    # rendezvous seed list: extra contacts to retry through if the
    # primary bootstrap dies mid-join (real deployments ship a seed
    # list; the paper's minimum assumption is one *live* contact)
    seeds: Tuple[int, ...] = ()
    # per-space ring pointers (clockwise successor / predecessor)
    succ: List[Optional[int]] = dataclasses.field(default_factory=list)
    pred: List[Optional[int]] = dataclasses.field(default_factory=list)
    # coordinates of every node we currently reference
    addr_book: Dict[int, tuple] = dataclasses.field(default_factory=dict)
    last_seen: Dict[int, float] = dataclasses.field(default_factory=dict)
    sent_messages: int = 0
    join_messages: int = 0
    # monotone count of actual pointer rewrites — the per-node half of
    # the cheap change stamp ``Simulator.tables_version`` exposes to the
    # overlay control plane
    version: int = 0

    def init_spaces(self, num_spaces: int) -> None:
        self.succ = [None] * num_spaces
        self.pred = [None] * num_spaces

    @property
    def neighbor_set(self) -> frozenset:
        out = set()
        for x in itertools.chain(self.succ, self.pred):
            if x is not None and x != self.node_id:
                out.add(x)
        return frozenset(out)

    def set_pointer(self, space: int, side: str, peer: Optional[int],
                    peer_coords: Optional[tuple]) -> None:
        if side == "succ":
            if self.succ[space] != peer:
                self.version += 1
            self.succ[space] = peer
        else:
            if self.pred[space] != peer:
                self.version += 1
            self.pred[space] = peer
        if peer is not None and peer_coords is not None:
            self.addr_book[peer] = peer_coords
        self._prune_addr_book()

    def improve_pointer(self, space: int, side: str, peer: int,
                        peer_coords: tuple) -> bool:
        """Monotone pointer update: adopt ``peer`` only if it is strictly
        closer (in the pointer's ring direction) than the current entry.

        This is what makes concurrent-churn recovery *converge*: a repair
        or probe that stopped early on a damaged view can never clobber a
        better pointer, while genuinely closer ring-adjacent candidates
        are always accepted."""
        if peer == self.node_id:
            return False
        cur = self.succ[space] if side == "succ" else self.pred[space]
        if cur == peer:
            self.addr_book[peer] = peer_coords
            return False
        mine = self.coords[space]
        new_x = peer_coords[space]
        arc_new = ((new_x - mine) % 1.0) if side == "succ" else ((mine - new_x) % 1.0)
        if arc_new == 0.0:
            arc_new = 1.0
        if cur is not None and cur in self.addr_book:
            cur_x = self.addr_book[cur][space]
            arc_cur = ((cur_x - mine) % 1.0) if side == "succ" else ((mine - cur_x) % 1.0)
            if arc_cur == 0.0:
                arc_cur = 1.0
            if arc_new >= arc_cur:
                return False
        self.set_pointer(space, side, peer, peer_coords)
        return True

    def _prune_addr_book(self) -> None:
        keep = self.neighbor_set
        for k in list(self.addr_book):
            if k not in keep:
                del self.addr_book[k]
                self.last_seen.pop(k, None)


def _dir_arc(src: float, dst: float, direction: str) -> float:
    """Arc length from ``src`` to ``dst`` travelling in ``direction``.

    Zero-length (same point) is treated as a full wrap so that a repair
    probe targeting the sender's own coordinate routes all the way
    around to the true ring-adjacent node.
    """
    if direction == "ccw":
        arc = (src - dst) % 1.0
    else:
        arc = (dst - src) % 1.0
    return arc if arc > 0.0 else 1.0


# --------------------------------------------------------------------------
# The simulator
# --------------------------------------------------------------------------

class Simulator:
    """Discrete-event FedLay control-plane simulator.

    ``latency`` may be a float (constant one-way delay, seconds) or a
    callable ``(rng) -> float``.  All protocol logic lives in the node
    handlers below and uses **only** local state + received messages —
    no node ever reads another node's tables directly.
    """

    def __init__(self, num_spaces: int, latency: float | Callable = 0.35,
                 heartbeat_period: float = 1.0, probe_period: float = 2.0,
                 seed: int = 0, salt: str = "", max_hops: int = 512):
        self.num_spaces = num_spaces
        self.heartbeat_period = heartbeat_period
        self.probe_period = probe_period
        self.salt = salt
        self.max_hops = max_hops
        self.rng = np.random.default_rng(seed)
        self._latency = latency
        self.now = 0.0
        self._heap: List[Tuple[float, int, Tuple]] = []
        self._seq = itertools.count()
        self.nodes: Dict[int, NodeState] = {}
        self.dropped_messages = 0
        self.delivered_messages = 0
        # optional per-message fault seam (repro.faults): consulted on
        # every send; None = the fault-free transport
        self._msg_filter: Optional[Callable] = None
        # monotone count of membership operations (join/leave/fail) —
        # folded into tables_version so a fail→rejoin of the same node
        # inside one control window can never alias an unchanged stamp
        self.churn_ops = 0

    # ---- event plumbing ---------------------------------------------------
    def latency(self) -> float:
        if callable(self._latency):
            return float(self._latency(self.rng))
        return float(self._latency)

    def _schedule(self, when: float, item: Tuple) -> None:
        heapq.heappush(self._heap, (when, next(self._seq), item))

    def set_message_filter(self, fn: Optional[Callable]) -> None:
        """Install a transport fault seam (or ``None`` to remove it).

        ``fn(now, src, dst, msg)`` is consulted on every :meth:`send` and
        returns ``None`` for normal delivery or a ``(deliver, extra_delay,
        duplicates)`` verdict: ``deliver=False`` drops the message (the
        sender still counts it as sent — it went onto the wire),
        ``extra_delay`` adds seconds of transit time, and ``duplicates``
        schedules that many extra copies (at-least-once transports).
        This is the control-plane fault-injection seam of
        :class:`repro.faults.plan.ChaosEngine`; NDMP's handlers are
        already idempotent under loss/duplication (monotone
        ``improve_pointer``, retried discoveries, periodic probes)."""
        self._msg_filter = fn

    def send(self, src: int, dst: int, msg: Message, *, join_phase: bool = False) -> None:
        node = self.nodes.get(src)
        if node is not None:
            node.sent_messages += 1
            if join_phase:
                node.join_messages += 1
        delay = self.latency()
        if self._msg_filter is not None:
            verdict = self._msg_filter(self.now, src, dst, msg)
            if verdict is not None:
                deliver, extra_delay, duplicates = verdict
                if not deliver:
                    self.dropped_messages += 1
                    return
                delay += extra_delay
                for _ in range(duplicates):
                    self._schedule(self.now + delay, ("msg", src, dst, msg))
        self._schedule(self.now + delay, ("msg", src, dst, msg))

    def run_until(self, t: float) -> None:
        while self._heap and self._heap[0][0] <= t:
            when, _, item = heapq.heappop(self._heap)
            self.now = when
            self._dispatch(item)
        self.now = max(self.now, t)

    def run_for(self, dt: float) -> None:
        self.run_until(self.now + dt)

    def advance(self, dt: float) -> None:
        """Protocol-name alias for :meth:`run_for` (SimulatorProtocol)."""
        self.run_for(dt)

    def _dispatch(self, item: Tuple) -> None:
        kind = item[0]
        if kind == "msg":
            _, src, dst, msg = item
            node = self.nodes.get(dst)
            if node is None or not node.alive:
                self.dropped_messages += 1
                return
            self.delivered_messages += 1
            if src in node.addr_book or src in node.neighbor_set:
                node.last_seen[src] = self.now
            self._handle(node, src, msg)
        elif kind == "timer":
            _, node_id, what = item
            node = self.nodes.get(node_id)
            if node is None or not node.alive:
                return
            if what == "heartbeat":
                self._on_heartbeat_timer(node)
            elif what == "probe":
                self._on_probe_timer(node)
            elif what == "join_retry":
                if any(node.succ[s] is None or node.pred[s] is None
                       for s in range(self.num_spaces)):
                    self._send_discoveries(node)
                    self._schedule(self.now + self.probe_period,
                                   ("timer", node_id, "join_retry"))

    # ---- topology bootstrap -------------------------------------------------
    def seed_network(self, node_ids: List[int]) -> None:
        """Instantiate an already-correct FedLay over ``node_ids`` (the
        recursive base case: built by joining nodes one at a time is
        equivalent; this shortcut makes large-churn experiments cheap)."""
        addrs = [NodeAddress.create(i, self.num_spaces, self.salt) for i in node_ids]
        for a in addrs:
            st = NodeState(node_id=a.node_id, coords=a.coords, joined=True)
            st.init_spaces(self.num_spaces)
            self.nodes[a.node_id] = st
        for s in range(self.num_spaces):
            order = sorted(addrs, key=lambda a: (a.coords[s], a.node_id))
            n = len(order)
            for i, a in enumerate(order):
                nxt, prv = order[(i + 1) % n], order[(i - 1) % n]
                st = self.nodes[a.node_id]
                st.succ[s] = nxt.node_id if nxt.node_id != a.node_id else None
                st.pred[s] = prv.node_id if prv.node_id != a.node_id else None
                st.addr_book[nxt.node_id] = nxt.coords
                st.addr_book[prv.node_id] = prv.coords
        for nid in node_ids:
            self._arm_timers(nid)

    def _arm_timers(self, node_id: int) -> None:
        jitter = float(self.rng.random())
        self._schedule(self.now + jitter * self.heartbeat_period, ("timer", node_id, "heartbeat"))
        self._schedule(self.now + jitter * self.probe_period, ("timer", node_id, "probe"))

    # ---- public churn API ---------------------------------------------------
    def join(self, node_id: int, bootstrap: int,
             seeds: Tuple[int, ...] = ()) -> None:
        """NDMP join: node_id enters through existing node ``bootstrap``
        (``seeds``: optional fallback contacts for bootstrap failure)."""
        self.churn_ops += 1
        coords = coordinates(node_id, self.num_spaces, self.salt)
        st = NodeState(node_id=node_id, coords=coords, bootstrap=bootstrap,
                       seeds=tuple(seeds))
        st.init_spaces(self.num_spaces)
        self.nodes[node_id] = st
        self._send_discoveries(st, all_spaces=True)
        self._arm_timers(node_id)
        self._schedule(self.now + self.probe_period, ("timer", node_id, "join_retry"))

    def _send_discoveries(self, st: NodeState, all_spaces: bool = False) -> None:
        """(Re)issue Neighbor_discovery for every space still missing a
        pointer — joins are retried until they succeed, so discovery
        messages dropped at failed relays are not fatal."""
        entry = None
        if st.bootstrap is not None and st.bootstrap in self.nodes \
                and self.nodes[st.bootstrap].alive:
            entry = st.bootstrap
        if entry is None and st.addr_book:
            entry = sorted(st.addr_book)[0]
        if entry is None:
            for s in st.seeds:          # rendezvous fallback
                if s in self.nodes and self.nodes[s].alive:
                    entry = s
                    break
        if entry is None:
            return
        for s in range(self.num_spaces):
            if all_spaces or st.succ[s] is None or st.pred[s] is None:
                msg = Discovery(space=s, target=st.coords[s], joiner=st.node_id,
                                joiner_coords=st.coords)
                self.send(st.node_id, entry, msg, join_phase=True)

    def rejoin(self, node_id: int, bootstrap: int) -> None:
        """Re-anchor an *already-alive* node through ``bootstrap``:
        re-issue Neighbor_discovery in every space as if joining afresh,
        keeping the current tables (the monotone ``improve_pointer`` rule
        only ever adopts strictly closer peers).

        This is the partition heal-merge mechanism: after an asymmetric
        or full partition, each side's failure detection prunes the other
        side out of every addr book, leaving two internally-correct but
        disjoint overlays that no amount of probing can reconnect (probes
        route through addr books).  Re-joining the nodes of one side
        through any live contact on the other re-establishes cross-side
        reachability; Theorem 1 splices each rejoiner at its globally
        closest coordinate and the periodic bidirectional probes converge
        the merged rings from there."""
        st = self.nodes[node_id]
        if not st.alive:
            raise KeyError(f"node {node_id} is not alive; use join()")
        self.churn_ops += 1
        st.bootstrap = bootstrap
        self._send_discoveries(st, all_spaces=True)
        self._schedule(self.now + self.probe_period,
                       ("timer", node_id, "join_retry"))

    def leave(self, node_id: int) -> None:
        """NDMP leave: notify ring-adjacent pairs, then depart."""
        self.churn_ops += 1
        st = self.nodes[node_id]
        for s in range(self.num_spaces):
            p, q = st.pred[s], st.succ[s]
            if p is not None and q is not None and p != node_id and q != node_id:
                pc = st.addr_book.get(p)
                qc = st.addr_book.get(q)
                if qc is not None:
                    self.send(node_id, p, LeaveNotice(space=s, side="succ", other=q, other_coords=qc))
                if pc is not None:
                    self.send(node_id, q, LeaveNotice(space=s, side="pred", other=p, other_coords=pc))
        st.alive = False

    def fail(self, node_id: int) -> None:
        """Abrupt failure: the node disappears without notice."""
        self.churn_ops += 1
        self.nodes[node_id].alive = False

    # ---- message handlers -----------------------------------------------------
    def _handle(self, node: NodeState, src: int, msg: Message) -> None:
        if isinstance(msg, Discovery):
            self._on_discovery(node, msg)
        elif isinstance(msg, DiscoveryReply):
            self._on_discovery_reply(node, msg)
        elif isinstance(msg, SpliceIn):
            node.improve_pointer(msg.space, msg.side, msg.joiner, msg.joiner_coords)
        elif isinstance(msg, LeaveNotice):
            # The leaving sender vacates the slot unconditionally; the
            # proposed replacement then competes under the improvement rule.
            cur = node.succ[msg.space] if msg.side == "succ" else node.pred[msg.space]
            if cur == src:
                node.set_pointer(msg.space, msg.side, msg.other, msg.other_coords)
            else:
                node.improve_pointer(msg.space, msg.side, msg.other, msg.other_coords)
        elif isinstance(msg, Repair):
            self._on_repair(node, msg)
        elif isinstance(msg, RepairStop):
            self._on_repair_stop(node, msg)
        elif isinstance(msg, Heartbeat):
            pass  # last_seen already updated in _dispatch

    # --- join: greedy routing on circular distance (Lemma 1 / Theorem 1) ---
    def _on_discovery(self, node: NodeState, msg: Discovery) -> None:
        s, x = msg.space, msg.target
        if msg.hops >= self.max_hops:
            return
        best, best_cd = None, circular_distance(node.coords[s], x)
        for w, wc in node.addr_book.items():
            cd = circular_distance(wc[s], x)
            if cd < best_cd or (cd == best_cd and best is not None and w < best):
                best, best_cd = w, cd
        if best is not None:
            self.send(node.node_id, best,
                      dataclasses.replace(msg, hops=msg.hops + 1), join_phase=True)
            return
        # Stop: this node is closest to the joiner's coordinate (Thm 1).
        self._splice_joiner(node, msg)

    def _splice_joiner(self, node: NodeState, msg: Discovery) -> None:
        s, x, u = msg.space, msg.target, msg.joiner
        succ, pred = node.succ[s], node.pred[s]
        if succ is None or pred is None:
            # Degenerate tiny ring (1-2 nodes): adopt joiner on both sides.
            node.set_pointer(s, "succ", u, msg.joiner_coords)
            if pred is None:
                node.set_pointer(s, "pred", u, msg.joiner_coords)
            self.send(node.node_id, u, DiscoveryReply(
                space=s, pred=node.node_id, pred_coords=node.coords,
                succ=node.node_id, succ_coords=node.coords), join_phase=True)
            return
        succ_c = node.addr_book.get(succ, node.coords)
        # Is x on the clockwise arc (node -> succ)?  cw arc lengths:
        arc_to_x = (x - node.coords[s]) % 1.0
        arc_to_succ = (succ_c[s] - node.coords[s]) % 1.0
        if arc_to_x <= arc_to_succ or succ == node.node_id:
            # u sits between node and its successor.
            old = succ
            old_c = node.addr_book.get(old)
            node.improve_pointer(s, "succ", u, msg.joiner_coords)
            if old is not None and old != node.node_id and old_c is not None:
                self.send(node.node_id, old,
                          SpliceIn(space=s, joiner=u, joiner_coords=msg.joiner_coords,
                                   side="pred"), join_phase=True)
                self.send(node.node_id, u, DiscoveryReply(
                    space=s, pred=node.node_id, pred_coords=node.coords,
                    succ=old, succ_coords=old_c), join_phase=True)
        else:
            # u sits between node's predecessor and node.
            old = pred
            old_c = node.addr_book.get(old)
            node.improve_pointer(s, "pred", u, msg.joiner_coords)
            if old is not None and old != node.node_id and old_c is not None:
                self.send(node.node_id, old,
                          SpliceIn(space=s, joiner=u, joiner_coords=msg.joiner_coords,
                                   side="succ"), join_phase=True)
                self.send(node.node_id, u, DiscoveryReply(
                    space=s, pred=old, pred_coords=old_c,
                    succ=node.node_id, succ_coords=node.coords), join_phase=True)

    def _on_discovery_reply(self, node: NodeState, msg: DiscoveryReply) -> None:
        node.improve_pointer(msg.space, "pred", msg.pred, msg.pred_coords)
        node.improve_pointer(msg.space, "succ", msg.succ, msg.succ_coords)
        node.joined = True

    # --- maintenance: heartbeats, failure detection, directional repair ---
    def _on_heartbeat_timer(self, node: NodeState) -> None:
        for nbr in node.neighbor_set:
            self.send(node.node_id, nbr, Heartbeat(sender=node.node_id))
        # failure detection: 3T silence
        deadline = self.now - 3.0 * self.heartbeat_period
        for nbr in list(node.neighbor_set):
            seen = node.last_seen.get(nbr)
            if seen is None:
                node.last_seen[nbr] = self.now  # grace period for new links
                continue
            if seen < deadline:
                self._declare_failed(node, nbr)
        self._schedule(self.now + self.heartbeat_period, ("timer", node.node_id, "heartbeat"))

    def _declare_failed(self, node: NodeState, failed: int) -> None:
        failed_coords = node.addr_book.get(failed)
        for s in range(self.num_spaces):
            if node.succ[s] == failed:
                # we are the failed node's predecessor -> route ccw, which
                # converges (by the directional arc metric) on its successor.
                node.set_pointer(s, "succ", None, None)
                if failed_coords is not None:
                    self._start_repair(node, s, failed_coords[s], direction="ccw")
            if node.pred[s] == failed:
                # we are the failed node's successor -> route cw to its pred.
                node.set_pointer(s, "pred", None, None)
                if failed_coords is not None:
                    self._start_repair(node, s, failed_coords[s], direction="cw")

    def _start_repair(self, node: NodeState, space: int, target: float, direction: str) -> None:
        """Route around ``target``.  Direction semantics (paper Fig. 7):
        the *predecessor* of the failed node routes **ccw** — the message
        approaches the target's coordinate from the clockwise side and
        stops at the failed node's successor; the successor routes **cw**
        and stops at the failed node's predecessor."""
        msg = Repair(space=space, target=target, direction=direction,
                     origin=node.node_id, origin_coords=node.coords)
        self._forward_repair(node, msg, first=True)

    def _forward_repair(self, node: NodeState, msg: Repair, first: bool = False) -> None:
        s, x, d = msg.space, msg.target, msg.direction
        my_arc = _dir_arc(node.coords[s], x, d)
        best, best_arc = None, my_arc
        for w, wc in node.addr_book.items():
            if w == msg.origin and not first:
                continue
            arc = _dir_arc(wc[s], x, d)
            if arc < best_arc or (arc == best_arc and best is not None and w < best):
                best, best_arc = w, arc
        if best is not None and msg.hops < self.max_hops:
            self.send(node.node_id, best, dataclasses.replace(msg, hops=msg.hops + 1))
            return
        if first:
            return  # nowhere to route (isolated) — probes will retry later
        # Stop: this node is the target's ring-adjacent node on this side.
        if node.node_id != msg.origin:
            self.send(node.node_id, msg.origin, RepairStop(
                space=s, direction=d, stopper=node.node_id, stopper_coords=node.coords))
            # ccw repair stops at the failed node's *successor*: adopt origin as pred.
            side = "pred" if d == "ccw" else "succ"
            node.improve_pointer(s, side, msg.origin, msg.origin_coords)

    def _on_repair(self, node: NodeState, msg: Repair) -> None:
        self._forward_repair(node, msg)

    def _on_repair_stop(self, node: NodeState, msg: RepairStop) -> None:
        # origin routed ccw (it was the pred) -> stopper is its new succ.
        side = "succ" if msg.direction == "ccw" else "pred"
        node.improve_pointer(msg.space, side, msg.stopper, msg.stopper_coords)

    def _on_probe_timer(self, node: NodeState) -> None:
        """Bidirectional self-probes for concurrent-churn convergence."""
        for s in range(self.num_spaces):
            for d in ("ccw", "cw"):
                msg = Repair(space=s, target=node.coords[s], direction=d,
                             origin=node.node_id, origin_coords=node.coords)
                self._forward_repair(node, msg, first=True)
        self._schedule(self.now + self.probe_period, ("timer", node.node_id, "probe"))

    # ---- measurement ---------------------------------------------------------
    def alive_addresses(self) -> List[NodeAddress]:
        return [NodeAddress(node_id=n.node_id, coords=n.coords)
                for n in self.nodes.values() if n.alive]

    def correctness(self) -> float:
        """Definition-1 correctness of the live network (paper §IV-A3)."""
        tables = {n.node_id: n.neighbor_set for n in self.nodes.values() if n.alive}
        return topology_correctness(tables, self.alive_addresses())

    def neighbor_tables(self) -> Dict[int, frozenset]:
        return {n.node_id: n.neighbor_set for n in self.nodes.values() if n.alive}

    # ---- delta API (consumed by repro.overlay) -------------------------------
    def alive_ids(self) -> List[int]:
        """Sorted ids of live nodes — the control plane's slot order."""
        return sorted(n.node_id for n in self.nodes.values() if n.alive)

    def tables_version(self) -> Tuple[frozenset, int, int]:
        """Cheap O(n) change stamp over the live neighbor tables.

        ``churn_ops`` advances on every join/leave/fail (so a fail→rejoin
        of the same node can never alias, even though it resets that
        node's per-pointer version), the frozenset tracks membership, and
        within fixed membership every pointer rewrite strictly increases
        the version sum — so two equal stamps imply unchanged tables,
        letting :class:`repro.overlay.events.DeltaTracker` skip the full
        diff on quiescent control steps."""
        alive = [n for n in self.nodes.values() if n.alive]
        return (frozenset(n.node_id for n in alive), self.churn_ops,
                sum(n.version for n in alive))

    def avg_messages_per_node(self, join_only: bool = False) -> float:
        counts = [(n.join_messages if join_only else n.sent_messages)
                  for n in self.nodes.values()]
        return float(np.mean(counts)) if counts else 0.0

    def export_state(self) -> Dict[str, np.ndarray]:
        """Bulk flat-array snapshot of the live network — the bridge into
        the vectorized engine's state layout (and the parity tests'
        common currency).

        Returns ``ids`` (n,) int64 sorted; ``coords`` (n, L) float64;
        ``succ``/``pred`` (L, n) int64 neighbor *ids* with −1 for an
        unset pointer; ``version`` (n,) int64 per-node pointer-rewrite
        counts."""
        ids = self.alive_ids()
        n, L = len(ids), self.num_spaces
        coords = np.empty((n, L), dtype=np.float64)
        succ = np.full((L, n), -1, dtype=np.int64)
        pred = np.full((L, n), -1, dtype=np.int64)
        version = np.empty((n,), dtype=np.int64)
        for r, u in enumerate(ids):
            st = self.nodes[u]
            coords[r] = st.coords
            version[r] = st.version
            for s in range(L):
                if st.succ[s] is not None:
                    succ[s, r] = st.succ[s]
                if st.pred[s] is not None:
                    pred[s, r] = st.pred[s]
        return {"ids": np.asarray(ids, dtype=np.int64), "coords": coords,
                "succ": succ, "pred": pred, "version": version}
