"""Mixing-matrix construction and the static ``ppermute`` schedule.

Two consumers:

* the **simulation path** (:class:`repro.core.dfl.Engine`) applies the
  row-stochastic confidence-weighted mixing matrix to stacked client
  models, and
* the **TPU path** (:func:`repro.dist.sync.make_mixer` /
  :func:`repro.dist.sync.global_mixer`) compiles the same FedLay overlay
  into 2L static ring rotations: each virtual ring space is a cyclic
  order over the mesh's data positions, so one space = one ``ppermute``
  rotation in each direction.  Confidence weights and duplicate-
  adjacency masks (a peer adjacent in several spaces is counted once —
  the bulk-synchronous image of MEP fingerprint dedup) are precomputed
  host-side into dense per-device weight tables.
  ``tests/test_dist.py`` pins the two paths equal.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .coords import NodeAddress, coordinate
from .mep import ClientProfile, aggregation_weights
from .topology import Topology, fedlay_topology, ring_orders


# --------------------------------------------------------------------------
# Confidence-weighted mixing matrix (simulation path)
# --------------------------------------------------------------------------

def confidence_mixing_matrix(topology: Topology,
                             profiles: Dict[int, ClientProfile],
                             alpha_d: float = 0.5, alpha_c: float = 0.5,
                             confidence_weighted: bool = True) -> np.ndarray:
    """Row i = MEP aggregation weights of client i over {i} ∪ N_i.

    Row-stochastic by construction.  With ``confidence_weighted=False``
    this is the DFedAvg simple average (the paper's ablation)."""
    index = {u: k for k, u in enumerate(topology.nodes)}
    n = topology.n
    W = np.zeros((n, n), dtype=np.float64)
    nbrs = topology.neighbor_map()
    for u in topology.nodes:
        others = nbrs[u]
        w = aggregation_weights(profiles[u], [profiles[v] for v in others],
                                alpha_d, alpha_c, confidence_weighted)
        W[index[u], index[u]] = w[0]
        for k, v in enumerate(others):
            W[index[u], index[v]] = w[k + 1]
    return W


def gossip_step(stacked_models: np.ndarray, W: np.ndarray) -> np.ndarray:
    """One synchronous mixing round: X ← W·X for (n, dim) stacked models."""
    return W @ stacked_models


# --------------------------------------------------------------------------
# Static ppermute schedule (TPU path)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class PermuteSchedule:
    """Everything :func:`repro.dist.sync.fedlay_mix` (shard_map path) and
    :func:`repro.dist.sync.global_mixer` (auto-sharded path) need, all
    host-side static.

    ``perms[k]`` is the source-permutation of the k-th incoming slot:
    device ``i`` receives the model held by device ``perms[k][i]``.
    Slots come in (space, direction) order: (0,cw),(0,ccw),(1,cw)...
    ``weights[i, k]`` is the MEP confidence weight of that incoming
    model at device ``i`` — already zeroed for duplicate adjacencies and
    self-loops — and ``self_weight[i]`` is c_i.  Rows are normalized so
    ``self_weight[i] + Σ_k weights[i,k] == 1``.

    Schedules are value-hashable (perms + weights digest), so they can
    key the overlay controller's mixer compile cache and dict/set-based
    test assertions directly.
    """

    num_clients: int
    num_spaces: int
    perms: Tuple[Tuple[int, ...], ...]        # (2L, n) source index per device
    weights: np.ndarray                       # (n, 2L) float32
    self_weight: np.ndarray                   # (n,) float32

    def ppermute_pairs(self, slot: int) -> List[Tuple[int, int]]:
        """(src, dst) pairs for jax.lax.ppermute for one incoming slot."""
        return [(src, dst) for dst, src in enumerate(self.perms[slot])]

    @property
    def num_slots(self) -> int:
        return 2 * self.num_spaces

    def digest(self) -> str:
        """Stable content hash over shape, perms, and (f32-exact) weights."""
        cached = self.__dict__.get("_digest")
        if cached is None:
            h = hashlib.sha256()
            h.update(np.asarray([self.num_clients, self.num_spaces],
                                np.int64).tobytes())
            h.update(np.asarray(self.perms, np.int64).tobytes())
            h.update(np.ascontiguousarray(self.weights,
                                          np.float32).tobytes())
            h.update(np.ascontiguousarray(self.self_weight,
                                          np.float32).tobytes())
            cached = h.hexdigest()
            object.__setattr__(self, "_digest", cached)
        return cached

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, PermuteSchedule):
            return NotImplemented
        return self.digest() == other.digest()

    def __hash__(self) -> int:
        return hash(self.digest())


def build_permute_schedule(num_clients: int, num_spaces: int,
                           profiles: Optional[Dict[int, ClientProfile]] = None,
                           alpha_d: float = 0.5, alpha_c: float = 0.5,
                           confidence_weighted: bool = True,
                           salt: str = "",
                           pod_bias: Optional[int] = None,
                           pod_bias_spaces: Optional[int] = None) -> PermuteSchedule:
    """Compile a FedLay overlay over mesh data positions 0..n-1 into the
    2L-rotation ``ppermute`` schedule.

    Client identity = flattened mesh (pod, data) index; coordinates are
    hashed from it exactly as the paper hashes IP addresses.

    ``pod_bias`` (beyond-paper, §Perf): with P pods of n/P clients each,
    coordinates become ``(pod(i) + H(i|s)) / P`` — each virtual ring
    orders clients pod-by-pod, so exactly P of its n edges cross a pod
    boundary instead of the ≈ n·(P−1)/P of unbiased random coordinates.
    Within a pod the order is still hash-random, so intra-pod mixing
    keeps the near-RRG property; cross-pod mixing degrades to a ring
    over pods, trading a slightly larger λ for an O(n/P)× reduction in
    inter-pod ICI traffic.
    """
    n = num_clients
    if pod_bias:
        assert n % pod_bias == 0
        per = n // pod_bias
        nb = num_spaces if pod_bias_spaces is None else pod_bias_spaces

        def coord(i: int, s: int) -> float:
            u = coordinate(i, s, salt)
            if s < nb:          # pod-contiguous ring
                return (i // per + u) / pod_bias
            return u            # fully random ring (mixing quality)

        addrs = [NodeAddress(node_id=i, coords=tuple(
            coord(i, s) for s in range(num_spaces))) for i in range(n)]
    else:
        addrs = [NodeAddress.create(i, num_spaces, salt) for i in range(n)]
    return schedule_from_addresses(addrs, profiles=profiles, alpha_d=alpha_d,
                                   alpha_c=alpha_c,
                                   confidence_weighted=confidence_weighted)


def schedule_from_addresses(addrs: Sequence[NodeAddress],
                            profiles: Optional[Dict[int, ClientProfile]] = None,
                            alpha_d: float = 0.5, alpha_c: float = 0.5,
                            confidence_weighted: bool = True) -> PermuteSchedule:
    """Compile the FedLay overlay over an explicit node set into a
    :class:`PermuteSchedule` — device slot ``i`` hosts ``addrs[i]``.

    This is the live-churn entry point used by
    :class:`repro.overlay.controller.OverlayController`: node ids are
    arbitrary (NDMP identities, not mesh indices), ``profiles`` is keyed
    by node id, and the returned perms/weights are in *slot* space so
    they drop straight into :func:`repro.dist.sync.make_mixer` /
    :func:`repro.dist.sync.global_mixer` for the current alive set.
    """
    n = len(addrs)
    if n == 0:
        raise ValueError("cannot build a schedule over zero nodes")
    num_spaces = addrs[0].num_spaces
    slot_of = {a.node_id: i for i, a in enumerate(addrs)}
    if len(slot_of) != n:
        raise ValueError("duplicate node ids in address list")
    orders = ring_orders(addrs)  # per space: clockwise id order

    # incoming source slot per device slot per (space, direction)
    perms: List[Tuple[int, ...]] = []
    senders = np.zeros((n, 2 * num_spaces), dtype=np.int64)
    for s in range(num_spaces):
        order = [slot_of[u] for u in orders[s]]
        pos = {u: k for k, u in enumerate(order)}
        succ = [0] * n
        pred = [0] * n
        for u in range(n):
            succ[u] = order[(pos[u] + 1) % n]
            pred[u] = order[(pos[u] - 1) % n]
        # slot 2s: receive from clockwise predecessor; slot 2s+1: successor
        perms.append(tuple(pred))
        perms.append(tuple(succ))
        senders[:, 2 * s] = pred
        senders[:, 2 * s + 1] = succ

    # confidence weights with duplicate-adjacency masking
    topo = fedlay_topology(addrs)
    nbr_map = topo.neighbor_map()
    if profiles is None:
        profiles = {
            a.node_id: ClientProfile(client_id=a.node_id, period=1.0,
                                     label_histogram=np.ones(2))
            for a in addrs
        }
    weights = np.zeros((n, 2 * num_spaces), dtype=np.float64)
    self_w = np.zeros((n,), dtype=np.float64)
    for i, a in enumerate(addrs):
        others = nbr_map[a.node_id]
        w = aggregation_weights(profiles[a.node_id],
                                [profiles[v] for v in others],
                                alpha_d, alpha_c, confidence_weighted)
        self_w[i] = w[0]
        per_peer = {slot_of[v]: w[k + 1] for k, v in enumerate(others)}
        seen: set = set()
        for k in range(2 * num_spaces):
            src = int(senders[i, k])
            if src == i or src in seen:
                weights[i, k] = 0.0  # self-ring (n small) or duplicate adjacency
            else:
                weights[i, k] = per_peer[src]
                seen.add(src)
    total = self_w + weights.sum(axis=1)
    weights /= total[:, None]
    self_w /= total
    return PermuteSchedule(
        num_clients=n, num_spaces=num_spaces,
        perms=tuple(perms),
        weights=weights.astype(np.float32),
        self_weight=self_w.astype(np.float32),
    )


def pad_schedule(sched: PermuteSchedule, slots: Sequence[int],
                 capacity: int) -> PermuteSchedule:
    """Embed an n-client schedule into a fixed ``capacity``-slot layout.

    ``slots[i]`` is the capacity slot hosting schedule slot ``i`` (the
    :class:`repro.runtime.slots.SlotMap` assignment).  Dead capacity
    slots **self-loop with weight 1**: identity perms, zero incoming
    weights, self weight 1 — so a mixer compiled over the padded
    schedule leaves dead rows untouched and never reads from them, and
    the padded mixing matrix stays row-stochastic.  Padded schedules
    hash by content like any other, so the overlay controller's compile
    cache keys on them directly (same alive set + same slot layout ⇒
    zero retrace).
    """
    n = sched.num_clients
    if len(slots) != n:
        raise ValueError(f"need one slot per schedule client: got "
                         f"{len(slots)} slots for {n} clients")
    if len(set(slots)) != n:
        raise ValueError("duplicate capacity slots")
    if any(s < 0 or s >= capacity for s in slots):
        raise ValueError(f"slot out of range for capacity {capacity}")
    perms: List[Tuple[int, ...]] = []
    for k in range(sched.num_slots):
        perm = list(range(capacity))          # dead slots: self-loop
        for i in range(n):
            perm[slots[i]] = slots[sched.perms[k][i]]
        perms.append(tuple(perm))
    weights = np.zeros((capacity, sched.num_slots), dtype=np.float32)
    self_w = np.ones((capacity,), dtype=np.float32)
    idx = np.asarray(slots, dtype=np.int64)
    weights[idx] = sched.weights
    self_w[idx] = sched.self_weight
    return PermuteSchedule(num_clients=capacity, num_spaces=sched.num_spaces,
                           perms=tuple(perms), weights=weights,
                           self_weight=self_w)


# --------------------------------------------------------------------------
# Grouped layout: G local clients per device
# --------------------------------------------------------------------------
#
# With ``clients_per_device = G`` the flat client axis maps onto mesh
# devices block-contiguously: client ``i`` lives on device ``i // G`` at
# local row ``i % G``.  A schedule slot's source permutation then splits
# into *intra-device* edges (source on the same device — a local gather,
# zero network bytes) and *cross-device* edges.  The cross edges of one
# slot are NOT a device permutation in general (a device may receive
# from up to G distinct peers per slot), and ``jax.lax.ppermute``
# requires unique sources and destinations — so they are edge-colored
# into at most ~G rounds, each a valid partial device permutation
# carrying one packed model row per participating device.  Zero-weight
# edges (self-loops at tiny n, duplicate adjacencies, dead capacity
# slots of a padded schedule) are pruned and never touch the wire.

@dataclasses.dataclass(frozen=True)
class CrossRound:
    """One edge-color class of a slot's cross-device edges: a partial
    device permutation (unique sources, unique destinations) moving one
    model row per participating device."""

    pairs: Tuple[Tuple[int, int], ...]   # (src_dev, dst_dev) ppermute pairs
    send_row: np.ndarray                 # (D,) int32: local row each source sends
    recv_slot: np.ndarray                # (D,) int32: local row the value lands in
    recv_on: np.ndarray                  # (D,) float32: 1 where this device receives


@dataclasses.dataclass(frozen=True)
class GroupedRouting:
    """Host-static routing tables turning a flat n-client schedule into
    a grouped (G clients per device) device program — consumed by
    :func:`repro.dist.sync.fedlay_mix`, verified host-side by
    :func:`grouped_mix_reference`."""

    clients_per_device: int
    num_devices: int
    intra_src: Tuple[np.ndarray, ...]            # per slot: (D, G) int32
    intra_on: Tuple[np.ndarray, ...]             # per slot: (D, G) float32
    rounds: Tuple[Tuple[CrossRound, ...], ...]   # per slot

    @property
    def cross_edges(self) -> int:
        """Cross-device (weight > 0) edges per mixing round — each costs
        one model row on the wire."""
        return sum(len(r.pairs) for slot in self.rounds for r in slot)

    @property
    def max_rounds(self) -> int:
        return max((len(slot) for slot in self.rounds), default=0)


def check_group_size(num_clients: int, clients_per_device: int) -> int:
    """Validate the grouped-layout contract (shared by every
    ``clients_per_device`` consumer) and return the device count
    ``num_clients // clients_per_device``."""
    if clients_per_device < 1:
        raise ValueError("clients_per_device must be >= 1")
    if num_clients % clients_per_device:
        raise ValueError(
            f"{num_clients} clients do not divide into groups of "
            f"{clients_per_device}")
    return num_clients // clients_per_device


def _bipartite_edge_coloring(edges: List[Tuple[int, int]],
                             num_nodes: int) -> List[int]:
    """Color a bipartite multigraph's edges (src node → dst node, the
    two sides indexed independently) with exactly Δ colors (König's
    theorem, constructive Kempe-chain proof): every color class has
    unique sources and unique destinations.

    Returns one color per edge, all in ``range(Δ)`` where Δ is the max
    degree of any source or destination.  O(E·Δ) — each insertion flips
    at most one alternating path."""
    if not edges:
        return []
    deg_s = [0] * num_nodes
    deg_d = [0] * num_nodes
    for s, d in edges:
        deg_s[s] += 1
        deg_d[d] += 1
    delta = max(max(deg_s), max(deg_d))
    # per-node color tables: color -> edge id (or -1)
    s_used = [[-1] * delta for _ in range(num_nodes)]
    d_used = [[-1] * delta for _ in range(num_nodes)]
    color = [-1] * len(edges)
    for eid, (u, v) in enumerate(edges):
        a = next(c for c in range(delta) if s_used[u][c] == -1)
        b = next(c for c in range(delta) if d_used[v][c] == -1)
        if a != b:
            # Kempe chain: flip the maximal a/b-alternating path from v
            # (starting along v's a-edge).  It cannot reach u — left
            # nodes are entered via a-edges and a is free at u — so a
            # becomes free at both endpoints.
            x, side = v, 1                   # 1: destination side
            ca, cb = a, b
            e = d_used[x][ca]
            while e != -1:
                es, ed = edges[e]
                y = es if side == 1 else ed  # the far endpoint
                ytab = s_used if side == 1 else d_used
                nxt = ytab[y][cb]            # continuation, pre-overwrite
                if s_used[es][ca] == e:
                    s_used[es][ca] = -1
                if d_used[ed][ca] == e:
                    d_used[ed][ca] = -1
                s_used[es][cb] = e
                d_used[ed][cb] = e
                color[e] = cb
                x, side = y, 1 - side
                ca, cb = cb, ca
                e = nxt
        color[eid] = a
        s_used[u][a] = eid
        d_used[v][a] = eid
    return color


@functools.lru_cache(maxsize=256)
def grouped_routing(sched: PermuteSchedule,
                    clients_per_device: int) -> GroupedRouting:
    """Decompose a schedule for the grouped layout (client ``i`` →
    device ``i // G``): per slot, intra-device gather tables plus
    **optimally** edge-colored cross-device ppermute rounds.  One
    slot's cross edges form a bipartite multigraph of max degree
    Δ ≤ G (each client receives once and sends once per slot —
    ``sched.perms[k]`` is a permutation), so König coloring packs them
    into exactly Δ ≤ G rounds — the greedy coloring this replaced
    could take up to 2G−1.  Cached by schedule content (schedules hash
    by digest), so repeated mixer compiles over the same topology
    reuse the tables."""
    G = clients_per_device
    n = sched.num_clients
    D = check_group_size(n, G)
    intra_src: List[np.ndarray] = []
    intra_on: List[np.ndarray] = []
    all_rounds: List[Tuple[CrossRound, ...]] = []
    for k in range(sched.num_slots):
        isrc = np.zeros((D, G), np.int32)
        ion = np.zeros((D, G), np.float32)
        cross: List[Tuple[int, int]] = []       # (src_dev, dst_dev)
        cross_rows: List[Tuple[int, int]] = []  # (send_row, recv_slot)
        for i in range(n):
            if float(sched.weights[i, k]) <= 0.0:
                continue    # self-loop, duplicate adjacency, or dead slot
            src = sched.perms[k][i]
            d, l = divmod(i, G)
            sd, sl = divmod(src, G)
            if sd == d:
                isrc[d, l] = sl
                ion[d, l] = 1.0
            else:
                cross.append((sd, d))
                cross_rows.append((sl, l))
        colors = _bipartite_edge_coloring(cross, D)
        rounds: List[dict] = []
        for c in range(max(colors) + 1 if colors else 0):
            rounds.append({"pairs": [],
                           "send": np.zeros((D,), np.int32),
                           "recv": np.zeros((D,), np.int32),
                           "on": np.zeros((D,), np.float32)})
        for (sd, d), (sl, l), c in zip(cross, cross_rows, colors):
            r = rounds[c]
            r["pairs"].append((sd, d))
            r["send"][sd] = sl
            r["recv"][d] = l
            r["on"][d] = 1.0
        # the routing is lru_cached and shared across compiles: freeze
        # every array so an in-place consumer mutation fails loudly
        # instead of poisoning future mixers for this schedule
        for arr in (isrc, ion, *(a for r in rounds
                                 for a in (r["send"], r["recv"], r["on"]))):
            arr.flags.writeable = False
        intra_src.append(isrc)
        intra_on.append(ion)
        all_rounds.append(tuple(
            CrossRound(pairs=tuple(r["pairs"]), send_row=r["send"],
                       recv_slot=r["recv"], recv_on=r["on"])
            for r in rounds))
    return GroupedRouting(
        clients_per_device=G, num_devices=D,
        intra_src=tuple(intra_src), intra_on=tuple(intra_on),
        rounds=tuple(all_rounds))


def grouped_mix_reference(sched: PermuteSchedule, X: np.ndarray,
                          clients_per_device: int,
                          mask: Optional[Sequence[float]] = None) -> np.ndarray:
    """The grouped dense oracle: mix (n, dim) stacked models via the
    *grouped decomposition* (intra gathers + edge-colored cross rounds)
    in pure numpy.  Must equal ``masked_mixing_matrix(sched, mask) @ X``
    (or ``schedule_mixing_matrix(sched) @ X`` unmasked) for every
    schedule and G — the host-side proof that the routing tables
    reconstruct the flat schedule before the device path is trusted."""
    rt = grouped_routing(sched, clients_per_device)
    G, D = rt.clients_per_device, rt.num_devices
    Xf = np.asarray(X, np.float64)
    local = Xf.reshape((D, G) + Xf.shape[1:])
    m = (np.ones((sched.num_clients,)) if mask is None
         else np.asarray(mask, np.float64)).reshape(D, G)

    def receive(vals):
        """Per slot: (D, G, ...) array of each local row's source value."""
        out = []
        for k in range(sched.num_slots):
            V = np.zeros_like(vals)
            for d in range(D):
                for l in range(G):
                    if rt.intra_on[k][d, l] > 0:
                        V[d, l] = vals[d, rt.intra_src[k][d, l]]
            for rnd in rt.rounds[k]:
                for sd, dd in rnd.pairs:
                    V[dd, rnd.recv_slot[dd]] = vals[sd, rnd.send_row[sd]]
            out.append(V)
        return out

    recv_vals = receive(local)
    recv_mask = receive(m)
    W = sched.weights.astype(np.float64).reshape(
        (D, G, sched.num_slots))
    self_w = sched.self_weight.astype(np.float64).reshape(D, G)
    eff = [W[:, :, k] * recv_mask[k] for k in range(sched.num_slots)]
    total = self_w + sum(eff)
    ok = (m > 0) & (total > 0)
    safe = np.where(total > 0, total, 1.0)
    bshape = (D, G) + (1,) * (Xf.ndim - 1)
    acc = local * (self_w / safe).reshape(bshape)
    for k in range(sched.num_slots):
        acc = acc + recv_vals[k] * (eff[k] / safe).reshape(bshape)
    acc = np.where(ok.reshape(bshape), acc, local)
    return acc.reshape(Xf.shape)


def masked_mixing_matrix(sched: PermuteSchedule,
                         mask: Sequence[float],
                         edge_mask: Optional[np.ndarray] = None) -> np.ndarray:
    """Dense equivalent of mask-aware mixing (the test oracle for
    :func:`repro.dist.sync.global_mixer` with ``masked=True``).

    Row ``i`` with ``mask[i] == 0`` is the identity (a dead or skipping
    client keeps its own model and contributes to nobody).  Live rows
    drop masked-out sources and renormalize over the surviving weights,
    so the matrix stays row-stochastic for any 0/1 mask.

    ``edge_mask`` (optional, (n, 2L) 0/1) additionally drops the edge
    from row ``i``'s k-th source before renormalizing — the degraded
    -round oracle for :mod:`repro.faults` link outages/stragglers.  A
    live row with every edge down degenerates to the identity (it
    keeps its own model: total = self_weight > 0)."""
    m = np.asarray(mask, dtype=np.float64)
    n = sched.num_clients
    if m.shape != (n,):
        raise ValueError(f"mask shape {m.shape} != ({n},)")
    if edge_mask is not None:
        edge_mask = np.asarray(edge_mask, dtype=np.float64)
        if edge_mask.shape != (n, sched.num_slots):
            raise ValueError(
                f"edge_mask shape {edge_mask.shape} != ({n}, {sched.num_slots})")
    W = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        if m[i] == 0.0:
            W[i, i] = 1.0
            continue
        eff = np.asarray(
            [float(sched.weights[i, k]) * m[sched.perms[k][i]]
             for k in range(sched.num_slots)])
        if edge_mask is not None:
            eff = eff * edge_mask[i]
        total = float(sched.self_weight[i]) + eff.sum()
        if total <= 0.0:
            W[i, i] = 1.0
            continue
        W[i, i] = float(sched.self_weight[i]) / total
        for k in range(sched.num_slots):
            W[i, sched.perms[k][i]] += eff[k] / total
    return W


def schedule_mixing_matrix(sched: PermuteSchedule) -> np.ndarray:
    """Dense equivalent W of a permute schedule (for tests: the TPU path
    and the simulation path must agree)."""
    n = sched.num_clients
    W = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        W[i, i] = sched.self_weight[i]
        for k in range(sched.num_slots):
            src = sched.perms[k][i]
            W[i, src] += float(sched.weights[i, k])
    return W


def cross_pod_messages(sched: PermuteSchedule, pods: int) -> int:
    """Messages per mixing round that cross a pod boundary (clients are
    laid out pod-contiguously: pod(i) = i // (n/pods))."""
    n = sched.num_clients
    per = n // pods
    crossing = 0
    for k in range(sched.num_slots):
        for dst, src in enumerate(sched.perms[k]):
            if src // per != dst // per:
                crossing += 1
    return crossing


def participation_mults(periods: Sequence[float]) -> np.ndarray:
    """Per-client periods → integer step multiples k_u (client u joins
    the mixing collective every k_u local steps).  Host-side static; the
    on-device mask for a traced step counter is
    :func:`repro.runtime.masked.participation_mask`."""
    base = min(periods)
    return np.maximum(1, np.round(np.asarray(periods) / base).astype(np.int64))


def multirate_participation(periods: Sequence[float], step: int) -> np.ndarray:
    """Bulk-synchronous image of MEP asynchrony: client u participates in
    the mixing collective at step t iff t % k_u == 0, where k_u is its
    period expressed in (integer) local steps.  Returns a 0/1 mask."""
    return (step % participation_mults(periods) == 0).astype(np.float32)
