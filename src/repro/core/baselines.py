"""Baseline overlay topologies the paper compares against (Table I, §II-C, §IV).

Every constructor returns a :class:`~repro.core.topology.Topology` over
nodes ``0..n-1`` so they are directly comparable under
:func:`~repro.core.metrics.evaluate_topology` and usable as alternative
``--sync`` graphs in the distribution layer.

Included: ring, dynamic chain, 2D grid, torus, hypercube, complete
graph, d-cliques, Chord, Viceroy-like constant-degree butterfly,
Waxman, distributed-Delaunay-triangulation (2D), a social-network proxy
(Barabási–Albert preferential attachment — same heavy-tail degree
family as the Facebook ego graph the paper samples), and random
d-regular graphs incl. the paper's "Best of 100" procedure.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .coords import NodeAddress
from .metrics import evaluate_topology
from .topology import Topology, fedlay_topology, make_edge


# --------------------------------------------------------------------------
# Simple fixed topologies (He et al. / Vogels et al. baselines)
# --------------------------------------------------------------------------

def ring(n: int) -> Topology:
    edges = {make_edge(i, (i + 1) % n) for i in range(n)} if n > 1 else set()
    return Topology(nodes=tuple(range(n)), edges=frozenset(edges), name="ring")


def chain(n: int) -> Topology:
    """The (static snapshot of the) GADMM dynamic chain: a path graph."""
    edges = {make_edge(i, i + 1) for i in range(n - 1)}
    return Topology(nodes=tuple(range(n)), edges=frozenset(edges), name="chain")


def grid_2d(n: int) -> Topology:
    """2D grid on ⌈√n⌉ columns (non-wrap)."""
    cols = int(math.ceil(math.sqrt(n)))
    edges = set()
    for i in range(n):
        r, c = divmod(i, cols)
        if c + 1 < cols and i + 1 < n:
            edges.add(make_edge(i, i + 1))
        if (r + 1) * cols + c < n:
            edges.add(make_edge(i, (r + 1) * cols + c))
    return Topology(nodes=tuple(range(n)), edges=frozenset(edges), name="grid2d")


def torus(n: int) -> Topology:
    """2D torus (wrap-around grid), degree 4."""
    cols = int(math.ceil(math.sqrt(n)))
    rows = int(math.ceil(n / cols))
    # use exactly rows*cols >= n; wrap edges only valid on full rectangle,
    # so clamp n to rows*cols by reusing modulo indexing over n.
    edges = set()
    for i in range(n):
        r, c = divmod(i, cols)
        right = r * cols + (c + 1) % cols
        down = ((r + 1) % rows) * cols + c
        for j in (right, down):
            j = j % n
            if j != i:
                edges.add(make_edge(i, j))
    return Topology(nodes=tuple(range(n)), edges=frozenset(edges), name="torus")


def hypercube(n: int) -> Topology:
    """Hypercube over the smallest 2^k ≥ n, folded onto n nodes (mod n)."""
    k = max(1, int(math.ceil(math.log2(max(2, n)))))
    edges = set()
    for i in range(n):
        for b in range(k):
            j = (i ^ (1 << b)) % n
            if j != i:
                edges.add(make_edge(i, j))
    return Topology(nodes=tuple(range(n)), edges=frozenset(edges), name="hypercube")


def complete_graph(n: int) -> Topology:
    edges = {make_edge(i, j) for i in range(n) for j in range(i + 1, n)}
    return Topology(nodes=tuple(range(n)), edges=frozenset(edges), name="complete")


def d_cliques(n: int, clique_size: int = 10) -> Topology:
    """D-Cliques-style topology: dense intra-clique + a ring of cliques."""
    edges = set()
    num_cliques = max(1, math.ceil(n / clique_size))
    cliques: List[List[int]] = [[] for _ in range(num_cliques)]
    for i in range(n):
        cliques[i // clique_size].append(i)
    for members in cliques:
        for a in range(len(members)):
            for b in range(a + 1, len(members)):
                edges.add(make_edge(members[a], members[b]))
    for ci in range(num_cliques):
        nxt = (ci + 1) % num_cliques
        if nxt != ci and cliques[ci] and cliques[nxt]:
            edges.add(make_edge(cliques[ci][0], cliques[nxt][0]))
    return Topology(nodes=tuple(range(n)), edges=frozenset(edges), name="dcliques")


# --------------------------------------------------------------------------
# P2P / DHT overlays
# --------------------------------------------------------------------------

def chord(n: int) -> Topology:
    """Chord ring with finger tables: node i links to (i + 2^k) mod n.

    Degree ≈ 2 log₂ n as in the paper's comparison."""
    edges = set()
    k_max = max(1, int(math.ceil(math.log2(max(2, n)))))
    for i in range(n):
        for k in range(k_max):
            j = (i + (1 << k)) % n
            if j != i:
                edges.add(make_edge(i, j))
    return Topology(nodes=tuple(range(n)), edges=frozenset(edges), name="chord")


def viceroy(n: int, rng: Optional[np.random.Generator] = None) -> Topology:
    """Constant-degree butterfly-style overlay in the spirit of Viceroy.

    Each node picks a level ℓ ∈ {1..log n}; ring edges over all nodes,
    level rings, and butterfly down-links to ~position·2 at level ℓ+1.
    This reproduces Viceroy's qualitative profile the paper reports:
    decent spectral properties but long paths at constant degree.
    """
    rng = rng or np.random.default_rng(0)
    levels = max(1, int(round(math.log2(max(2, n)))))
    lvl = rng.integers(1, levels + 1, size=n)
    edges = set()
    for i in range(n):  # global ring (successor links)
        if n > 1:
            edges.add(make_edge(i, (i + 1) % n))
    # butterfly links: to approx double/half position among next level
    order = np.argsort(rng.random(n))  # virtual ring positions
    pos = np.empty(n)
    pos[order] = np.arange(n) / n
    for i in range(n):
        if lvl[i] < levels:
            targets = [j for j in range(n) if lvl[j] == lvl[i] + 1]
            if targets:
                for t_pos in ((pos[i] * 2) % 1.0, (pos[i] * 2 + 1.0 / (1 << int(lvl[i]))) % 1.0):
                    j = min(targets, key=lambda j: abs(pos[j] - t_pos))
                    if j != i:
                        edges.add(make_edge(i, j))
    return Topology(nodes=tuple(range(n)), edges=frozenset(edges), name="viceroy")


# --------------------------------------------------------------------------
# Geometric overlays
# --------------------------------------------------------------------------

def waxman(n: int, alpha: float = 0.25, beta: float = 0.4,
           rng: Optional[np.random.Generator] = None) -> Topology:
    """Waxman random geometric graph: P(u~v) = β·exp(-d(u,v)/(α·d_max))."""
    rng = rng or np.random.default_rng(0)
    pts = rng.random((n, 2))
    dmax = math.sqrt(2.0)
    edges = set()
    for i in range(n):
        for j in range(i + 1, n):
            d = float(np.linalg.norm(pts[i] - pts[j]))
            if rng.random() < beta * math.exp(-d / (alpha * dmax)):
                edges.add(make_edge(i, j))
    topo = Topology(nodes=tuple(range(n)), edges=frozenset(edges), name="waxman")
    return _ensure_connected_ring(topo)


def delaunay(n: int, rng: Optional[np.random.Generator] = None) -> Topology:
    """Distributed Delaunay triangulation overlay on random 2D points."""
    from scipy.spatial import Delaunay as _Delaunay

    rng = rng or np.random.default_rng(0)
    pts = rng.random((n, 2))
    tri = _Delaunay(pts)
    edges = set()
    for simplex in tri.simplices:
        a, b, c = (int(x) for x in simplex)
        edges.add(make_edge(a, b))
        edges.add(make_edge(b, c))
        edges.add(make_edge(a, c))
    return Topology(nodes=tuple(range(n)), edges=frozenset(edges), name="delaunay")


def social(n: int, m: int = 3, rng: Optional[np.random.Generator] = None) -> Topology:
    """Barabási–Albert preferential-attachment proxy for the Facebook
    ego-network sample the paper uses (heavy-tail degrees, high clustering
    relative to RRGs)."""
    rng = rng or np.random.default_rng(0)
    edges = set()
    targets = list(range(m))
    repeated: List[int] = list(range(m))
    for v in range(m, n):
        chosen: set = set()
        while len(chosen) < min(m, len(set(repeated))):
            chosen.add(int(repeated[rng.integers(len(repeated))]))
        for u in chosen:
            edges.add(make_edge(u, v))
            repeated.extend((u, v))
    topo = Topology(nodes=tuple(range(n)), edges=frozenset(edges), name="social")
    return _ensure_connected_ring(topo)


# --------------------------------------------------------------------------
# Random regular graphs — the paper's "Best of 100" reference
# --------------------------------------------------------------------------

def random_regular(n: int, d: int, rng: Optional[np.random.Generator] = None,
                   max_tries: int = 200) -> Topology:
    """Random d-regular simple graph via the configuration model with
    retry-on-collision (standard near-uniform sampler)."""
    if (n * d) % 2 != 0:
        raise ValueError("n*d must be even for a d-regular graph")
    if d >= n:
        raise ValueError("degree must be < n")
    rng = rng or np.random.default_rng(0)
    for _ in range(max_tries):
        stubs = np.repeat(np.arange(n), d)
        rng.shuffle(stubs)
        pairs = [[int(stubs[i]), int(stubs[i + 1])]
                 for i in range(0, len(stubs), 2)]
        # repair self-loops / duplicate edges by random edge swaps (the
        # standard fix: a raw configuration-model draw at d≥4 almost
        # always has a few collisions)
        for _ in range(50 * len(pairs)):
            seen = set()
            bad = None
            for idx, (a, b) in enumerate(pairs):
                e = (min(a, b), max(a, b))
                if a == b or e in seen:
                    bad = idx
                    break
                seen.add(e)
            if bad is None:
                edges = frozenset(make_edge(a, b) for a, b in pairs)
                return Topology(nodes=tuple(range(n)), edges=edges,
                                name=f"rrg-d{d}")
            j = int(rng.integers(len(pairs)))
            if j == bad:
                continue
            a, b = pairs[bad]
            c, e2 = pairs[j]
            pairs[bad], pairs[j] = [a, c], [b, e2]
    raise RuntimeError("failed to sample a simple d-regular graph")


def best_of_rrgs(n: int, d: int, trials: int = 100, metric: str = "convergence_factor",
                 seed: int = 0) -> Topology:
    """The paper's "Best" baseline: generate ``trials`` random d-regular
    graphs (centralized!) and keep the best under ``metric``."""
    best_topo, best_val = None, float("inf")
    for t in range(trials):
        topo = random_regular(n, d, rng=np.random.default_rng(seed + t))
        rep = evaluate_topology(topo)
        val = getattr(rep, metric)
        if val < best_val:
            best_topo, best_val = topo, val
    assert best_topo is not None
    return Topology(nodes=best_topo.nodes, edges=best_topo.edges, name=f"best100-d{d}")


def fedlay(n: int, num_spaces: int, salt: str = "") -> Topology:
    """The FedLay topology for n synthetic clients (degree ≤ 2·num_spaces)."""
    addrs = [NodeAddress.create(i, num_spaces, salt) for i in range(n)]
    topo = fedlay_topology(addrs, name=f"fedlay-L{num_spaces}")
    return topo


def _ensure_connected_ring(topo: Topology) -> Topology:
    """Random graphs (Waxman/BA) can be disconnected at small n; patch with
    a thin ring so metrics are finite — noted in benchmarks."""
    if topo.is_connected():
        return topo
    edges = set(topo.edges)
    nodes = list(topo.nodes)
    for i in range(len(nodes)):
        edges.add(make_edge(nodes[i], nodes[(i + 1) % len(nodes)]))
    return Topology(nodes=topo.nodes, edges=frozenset(edges), name=topo.name)


TOPOLOGY_REGISTRY: Dict[str, Callable[..., Topology]] = {
    "ring": ring,
    "chain": chain,
    "grid2d": grid_2d,
    "torus": torus,
    "hypercube": hypercube,
    "complete": complete_graph,
    "dcliques": d_cliques,
    "chord": chord,
    "viceroy": viceroy,
    "waxman": waxman,
    "delaunay": delaunay,
    "social": social,
    "fedlay": fedlay,
}
