"""MEP — Model Exchange Protocol (paper §III-C).

Three components, exactly as the paper specifies:

1. **Asynchronous model exchange** — each client u has its own period
   ``T_u`` (coarse device-tier presets or fine-grained
   ``T_u = η·T_{u,min}``); neighbors (u,v) exchange at period
   ``max(T_u, T_v)``.
2. **Confidence parameters** —
   ``c_d^u = exp(-KL(D_loc ‖ D_iid))`` (data-divergence confidence,
   D_iid estimated as uniform over labels) and ``c_c^u = 1/T_u``
   (communication confidence); the overall confidence
   ``c^u = α_d·c_d^u/max_N(c_d) + α_c·c_c^u/max_N(c_c)`` normalizes by
   the *neighborhood* maxima.  Aggregation is the confidence-weighted
   average over ``{u} ∪ N_u``.
3. **Model fingerprinting** — a public hash of the weights; a neighbor
   holding a matching fingerprint skips the (re)send.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .coords import fnv1a_64


# --------------------------------------------------------------------------
# Device tiers (coarse-grained period presets, paper §III-C1 + §IV-A2)
# --------------------------------------------------------------------------

#: Relative period multipliers for the paper's three capacity tiers:
#: high-capacity clients run at 2/3 the period of medium ones, low at 2x.
TIER_MULTIPLIER = {"high": 2.0 / 3.0, "medium": 1.0, "low": 2.0}

#: Coarse device/communication type presets (relative units).
DEVICE_PRESETS = {
    "server-lan": 0.5,
    "pc-lan": 2.0 / 3.0,
    "laptop-wlan": 1.0,
    "phone-lte": 1.5,
    "iot-wlan": 2.0,
}


def tier_period(base_period: float, tier: str) -> float:
    return base_period * TIER_MULTIPLIER[tier]


def fine_grained_period(t_min: float, eta: float = 1.2) -> float:
    """Fine-grained setting: T_u = η·T_{u,min}, η > 1."""
    if eta <= 1.0:
        raise ValueError("η must be > 1")
    return eta * t_min


def link_period(t_u: float, t_v: float) -> float:
    """Per-link exchange period = max(T_u, T_v)."""
    return max(t_u, t_v)


# --------------------------------------------------------------------------
# Confidence parameters
# --------------------------------------------------------------------------

def kl_divergence(p: np.ndarray, q: np.ndarray, eps: float = 1e-12) -> float:
    """KL(p ‖ q) with clamping; p, q are label histograms (normalized here)."""
    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    p = p / max(p.sum(), eps)
    q = q / max(q.sum(), eps)
    mask = p > eps
    return float(np.sum(p[mask] * np.log(p[mask] / np.maximum(q[mask], eps))))


def data_confidence(label_histogram: np.ndarray,
                    iid_distribution: Optional[np.ndarray] = None) -> float:
    """c_d = 1 / exp(KL(D_loc ‖ D_iid)) ∈ (0, 1]; D_iid defaults to uniform."""
    hist = np.asarray(label_histogram, dtype=np.float64)
    if iid_distribution is None:
        iid_distribution = np.full(hist.shape, 1.0 / hist.size)
    return float(np.exp(-kl_divergence(hist, iid_distribution)))


def communication_confidence(period: float) -> float:
    """c_c = 1 / T_u."""
    return 1.0 / period


@dataclasses.dataclass(frozen=True)
class ClientProfile:
    """Everything MEP needs to know about one client."""

    client_id: int
    period: float                       # T_u
    label_histogram: np.ndarray         # local label counts
    iid_distribution: Optional[np.ndarray] = None

    @property
    def c_d(self) -> float:
        return data_confidence(self.label_histogram, self.iid_distribution)

    @property
    def c_c(self) -> float:
        return communication_confidence(self.period)


def overall_confidence(profile: ClientProfile,
                       neighborhood: Sequence[ClientProfile],
                       alpha_d: float = 0.5, alpha_c: float = 0.5) -> float:
    """c^u = α_d·c_d/max(c_d) + α_c·c_c/max(c_c), maxima over u's
    neighborhood (paper: "from all u's neighbors"; we include u itself so
    the normalization is well defined for isolated nodes)."""
    group = list(neighborhood) + [profile]
    max_cd = max(p.c_d for p in group)
    max_cc = max(p.c_c for p in group)
    return alpha_d * profile.c_d / max_cd + alpha_c * profile.c_c / max_cc


def aggregation_weights(self_profile: ClientProfile,
                        neighbor_profiles: Sequence[ClientProfile],
                        alpha_d: float = 0.5, alpha_c: float = 0.5,
                        confidence_weighted: bool = True) -> np.ndarray:
    """Normalized aggregation weights over [self] + neighbors.

    ``confidence_weighted=False`` gives the simple-average ablation
    (paper Figs. 16/17)."""
    all_profiles = [self_profile] + list(neighbor_profiles)
    if not confidence_weighted:
        w = np.ones(len(all_profiles))
    else:
        w = np.array([
            overall_confidence(p, [q for q in all_profiles if q is not p],
                               alpha_d, alpha_c)
            for p in all_profiles
        ])
    return w / w.sum()


# --------------------------------------------------------------------------
# Model fingerprinting
# --------------------------------------------------------------------------

def model_fingerprint(flat_params: np.ndarray) -> int:
    """Public 64-bit fingerprint of a model (paper §III-C3).

    Hashes the raw bytes of the (float32-rounded) parameter vector so
    that the sender and receiver compute identical fingerprints."""
    arr = np.ascontiguousarray(np.asarray(flat_params, dtype=np.float32))
    return fnv1a_64(arr.tobytes())


class FingerprintTable:
    """Per-client table of the last fingerprint seen from each neighbor —
    sends are suppressed when the fingerprint is unchanged."""

    def __init__(self) -> None:
        self._last: Dict[int, int] = {}
        self.suppressed = 0
        self.sent = 0

    def should_send(self, neighbor: int, fingerprint: int) -> bool:
        if self._last.get(neighbor) == fingerprint:
            self.suppressed += 1
            return False
        self.sent += 1
        return True

    def record(self, neighbor: int, fingerprint: int) -> None:
        self._last[neighbor] = fingerprint

    def forget(self, neighbor: int) -> None:
        self._last.pop(neighbor, None)
