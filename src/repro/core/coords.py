"""Virtual coordinates and circular distance (paper §II-C, Definition 2).

Every FedLay node derives an L-dimensional virtual coordinate vector
``⟨x_1, .., x_L⟩`` with each ``x_i ∈ [0, 1)``.  The paper computes
``x_i = H(IP_x | i)`` for a public hash function H; we use the stable
64-bit FNV-1a hash of ``"{node_id}|{i}"`` mapped into [0, 1), which has
the same uniformity / determinism properties and works for arbitrary
node identifiers (IP strings, integers, mesh indices).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

_FNV_OFFSET = 0xCBF29CE484222325
_FNV_PRIME = 0x100000001B3
_MASK64 = (1 << 64) - 1


def _fmix64(h: int) -> int:
    """Murmur3 64-bit finalizer — full avalanche so that inputs differing
    in one trailing byte (e.g. "7|0" vs "7|1") map to independent points."""
    h ^= h >> 33
    h = (h * 0xFF51AFD7ED558CCD) & _MASK64
    h ^= h >> 33
    h = (h * 0xC4CEB9FE1A85EC53) & _MASK64
    h ^= h >> 33
    return h


def fnv1a_64(data: bytes) -> int:
    """Stable 64-bit hash (FNV-1a + murmur finalizer), deterministic
    across runs and platforms (the paper's "publicly known hash H")."""
    h = _FNV_OFFSET
    for b in data:
        h ^= b
        h = (h * _FNV_PRIME) & _MASK64
    return _fmix64(h)


def coordinate(node_id: object, space: int, salt: str = "") -> float:
    """The node's virtual coordinate in ring space ``space`` (paper: H(IP|i)).

    Returns a float in [0, 1).  ``salt`` lets tests / simulations draw
    independent coordinate systems for repeated trials.
    """
    h = fnv1a_64(f"{salt}{node_id}|{space}".encode())
    return (h >> 11) / float(1 << 53)  # 53-bit mantissa-exact uniform


def coordinates(node_id: object, num_spaces: int, salt: str = "") -> tuple:
    """The full L-dimensional coordinate vector of a node."""
    return tuple(coordinate(node_id, i, salt) for i in range(num_spaces))


def coordinates_batch(node_ids: Sequence[int], num_spaces: int,
                      salt: str = "") -> "np.ndarray":
    """(n, L) float64 coordinate matrix, bit-exact vs :func:`coordinate`.

    Vectorizes the FNV-1a byte loop over a padded byte matrix: every
    hash input ``f"{salt}{id}|{space}"`` is expanded to the same width,
    and the per-byte ``h = (h ^ b) * prime`` update runs across all
    rows at once in uint64 (numpy wraps at 2^64 exactly like the
    scalar ``& _MASK64``).  Padding columns are handled by masking:
    rows shorter than the width keep their running hash unchanged on
    the columns past their own length.  This is what lets the
    vectorized NDMP engine hash 10^5–10^6 node coordinates in
    milliseconds instead of minutes.
    """
    ids = list(node_ids)
    n = len(ids)
    out = np.empty((n, num_spaces), dtype=np.float64)
    if n == 0:
        return out
    prime = np.uint64(_FNV_PRIME)
    for space in range(num_spaces):
        keys = [f"{salt}{u}|{space}".encode() for u in ids]
        width = max(len(k) for k in keys)
        mat = np.zeros((n, width), dtype=np.uint64)
        lens = np.empty((n,), dtype=np.int64)
        for r, k in enumerate(keys):
            lens[r] = len(k)
            mat[r, :len(k)] = np.frombuffer(k, dtype=np.uint8)
        h = np.full((n,), _FNV_OFFSET, dtype=np.uint64)
        cols = np.arange(width)
        for c in range(width):
            live = lens > cols[c]
            h = np.where(live, (h ^ mat[:, c]) * prime, h)
        # murmur3 fmix64 finalizer, elementwise
        h ^= h >> np.uint64(33)
        h *= np.uint64(0xFF51AFD7ED558CCD)
        h ^= h >> np.uint64(33)
        h *= np.uint64(0xC4CEB9FE1A85EC53)
        h ^= h >> np.uint64(33)
        out[:, space] = (h >> np.uint64(11)).astype(np.float64) / float(1 << 53)
    return out


def circular_distance(x: float, y: float) -> float:
    """Definition 2: CD(x, y) = min(|x - y|, 1 - |x - y|).

    The length of the smaller arc between two ring positions, with the
    ring perimeter normalized to 1.
    """
    d = abs(x - y)
    return min(d, 1.0 - d)


def ccw_arc(src: float, dst: float) -> float:
    """Arc length travelling counterclockwise (decreasing coordinate,
    wrapping 0 → 1) from ``src`` to ``dst``.

    We adopt the convention that coordinates increase clockwise, so the
    counterclockwise arc from x to y has length ``(x - y) mod 1``.
    """
    return (src - dst) % 1.0


def cw_arc(src: float, dst: float) -> float:
    """Arc length travelling clockwise (increasing coordinate) src → dst."""
    return (dst - src) % 1.0


def closer(x: float, y: float, target: float, tie_x: int = 0, tie_y: int = 0) -> bool:
    """True iff x is strictly closer to ``target`` than y on the ring.

    Ties in circular distance are broken by the smaller tie value
    (paper: smaller IP address wins), so exactly one node is closest to
    any coordinate.
    """
    dx, dy = circular_distance(x, target), circular_distance(y, target)
    if dx != dy:
        return dx < dy
    return tie_x < tie_y


@dataclasses.dataclass(frozen=True)
class NodeAddress:
    """Identity + coordinates of a FedLay node.

    ``node_id`` doubles as the paper's IP address for tie-breaking: it
    must be orderable and unique.
    """

    node_id: int
    coords: tuple

    @property
    def num_spaces(self) -> int:
        return len(self.coords)

    @classmethod
    def create(cls, node_id: int, num_spaces: int, salt: str = "") -> "NodeAddress":
        return cls(node_id=node_id, coords=coordinates(node_id, num_spaces, salt))


def ring_order(addrs: Sequence[NodeAddress], space: int) -> list:
    """Node ids sorted by coordinate in ``space`` (clockwise ring order).

    Identical coordinates are ordered by node id (the paper's IP-address
    tie-break)."""
    return [a.node_id for a in sorted(addrs, key=lambda a: (a.coords[space], a.node_id))]
