"""Checkpointing: pytree → (npz arrays + json treedef) on local disk.

Simple, dependency-free, and exact: arbitrary nested dict/list/tuple
pytrees of jnp/np arrays round-trip including dtypes (bf16 stored as
uint16 views).  Supports step-numbered checkpoints with ``latest()``
discovery and retention.
"""

from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree, prefix="") -> List[Tuple[str, Any]]:
    if isinstance(tree, dict):
        out = []
        for k in sorted(tree):
            out.extend(_flatten_with_paths(tree[k], f"{prefix}/{k}"))
        return out
    if isinstance(tree, (list, tuple)):
        out = []
        for i, v in enumerate(tree):
            out.extend(_flatten_with_paths(v, f"{prefix}/#{i}"))
        return out
    return [(prefix, tree)]


def _treedef(tree):
    if isinstance(tree, dict):
        return {"__kind__": "dict", "keys": {k: _treedef(v) for k, v in tree.items()}}
    if isinstance(tree, tuple):
        return {"__kind__": "tuple", "items": [_treedef(v) for v in tree]}
    if isinstance(tree, list):
        return {"__kind__": "list", "items": [_treedef(v) for v in tree]}
    return {"__kind__": "leaf"}


def _rebuild(defn, leaves: Dict[str, np.ndarray], prefix=""):
    kind = defn["__kind__"]
    if kind == "dict":
        return {k: _rebuild(v, leaves, f"{prefix}/{k}")
                for k, v in defn["keys"].items()}
    if kind in ("tuple", "list"):
        items = [_rebuild(v, leaves, f"{prefix}/#{i}")
                 for i, v in enumerate(defn["items"])]
        return tuple(items) if kind == "tuple" else items
    return leaves[prefix]


def save(path: str, tree: Any, metadata: Optional[dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays, dtypes = {}, {}
    for name, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        dtypes[name] = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
        arrays[name] = arr
    np.savez(path + ".npz", **{k.replace("/", "|"): v for k, v in arrays.items()})
    with open(path + ".json", "w") as f:
        json.dump({"treedef": _treedef(tree), "dtypes": dtypes,
                   "metadata": metadata or {}}, f)


def load(path: str) -> Tuple[Any, dict]:
    with open(path + ".json") as f:
        spec = json.load(f)
    with np.load(path + ".npz") as z:
        leaves = {}
        for k in z.files:
            name = k.replace("|", "/")
            arr = z[k]
            if spec["dtypes"][name] == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            leaves[name] = arr
    return _rebuild(spec["treedef"], leaves), spec["metadata"]


class CheckpointManager:
    """Step-numbered checkpoints with retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:08d}")

    def save(self, step: int, tree: Any, metadata: Optional[dict] = None) -> str:
        meta = dict(metadata or {})
        meta["step"] = step
        save(self._path(step), tree, meta)
        self._retain()
        return self._path(step)

    def steps(self) -> List[int]:
        out = []
        for f in os.listdir(self.directory):
            m = re.match(r"ckpt_(\d+)\.json$", f)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: Optional[int] = None) -> Tuple[Any, dict]:
        step = self.latest() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return load(self._path(step))

    def _retain(self) -> None:
        for s in self.steps()[:-self.keep]:
            for ext in (".npz", ".json"):
                try:
                    os.remove(self._path(s) + ext)
                except OSError:
                    pass
