from .checkpoint import CheckpointManager, load, save

__all__ = ["CheckpointManager", "load", "save"]
