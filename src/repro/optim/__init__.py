from .optimizers import (AdamWState, Optimizer, adamw, apply_updates,
                         clip_by_global_norm, cosine_schedule, global_norm,
                         sgd)

__all__ = ["AdamWState", "Optimizer", "adamw", "apply_updates",
           "clip_by_global_norm", "cosine_schedule", "global_norm", "sgd"]
