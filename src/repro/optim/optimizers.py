"""Optimizers — pure-pytree SGD(+momentum) and AdamW, no external deps.

Each optimizer is an (init, update) pair over arbitrary pytrees; states
are pytrees with the same sharding as the params (so FSDP carries the
optimizer state shards for free).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params)


def sgd(lr: float, momentum: float = 0.0, nesterov: bool = False,
        weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, state, params):
        if weight_decay:
            grads = jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr * g, grads), ()
        new_m = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                             state, grads)
        if nesterov:
            step = jax.tree.map(lambda m, g: -lr * (momentum * m + g), new_m, grads)
        else:
            step = jax.tree.map(lambda m: -lr * m, new_m)
        return step, new_m

    return Optimizer(init, update)


@dataclasses.dataclass(frozen=True)
class AdamWState:
    mu: Any
    nu: Any
    count: jnp.ndarray


jax.tree_util.register_dataclass(AdamWState, ["mu", "nu", "count"], [])


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1,
          lr_schedule: Optional[Callable] = None) -> Optimizer:
    def init(params):
        zeros = lambda: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamWState(mu=zeros(), nu=zeros(),
                          count=jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        count = state.count + 1
        cur_lr = lr if lr_schedule is None else lr * lr_schedule(count)
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, g32)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, g32)
        mu_hat = jax.tree.map(lambda m: m / (1 - b1 ** count.astype(jnp.float32)), mu)
        nu_hat = jax.tree.map(lambda v: v / (1 - b2 ** count.astype(jnp.float32)), nu)
        step = jax.tree.map(
            lambda m, v, p: (-cur_lr * (m / (jnp.sqrt(v) + eps)
                                        + weight_decay * p.astype(jnp.float32))
                             ).astype(p.dtype),
            mu_hat, nu_hat, params)
        return step, AdamWState(mu=mu, nu=nu, count=count)

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def cosine_schedule(warmup: int, total: int, floor: float = 0.1) -> Callable:
    def sched(count):
        c = count.astype(jnp.float32)
        warm = c / max(warmup, 1)
        prog = jnp.clip((c - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(c < warmup, warm, cos)
    return sched


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm
