"""Deterministic fault plans and the chaos engine that executes them.

A :class:`FaultPlan` is a frozen, seed-reproducible description of
everything that goes wrong in a run; :class:`ChaosEngine` wraps either
NDMP engine behind the :class:`repro.core.ndmp.SimulatorProtocol` seam
and injects the plan while delegating the normal protocol surface.
The same plan therefore drives the per-message object
:class:`~repro.core.ndmp.Simulator` (exact transport faults) and the
flat-array :class:`~repro.scale.ndmp_vec.VectorSimulator` (their
converged image) — see the package docstring for the equivalence
argument.

Data-plane faults (link outages, stragglers, active partitions) never
touch NDMP; they surface through :meth:`ChaosEngine.data_faults` as a
:class:`DataFaults` snapshot that :func:`edge_mask_for` lowers to the
``(C, 2L)`` unreachable-edge mask consumed by the masked mixers.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from ..obs import get_telemetry

__all__ = ["FaultPlan", "Partition", "LinkOutage", "Straggler",
           "DataFaults", "ChaosEngine", "edge_mask_for"]


# --------------------------------------------------------------------------
# plan vocabulary
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Partition:
    """A timed network partition over ``groups`` of node ids.

    During ``[start, end)`` cross-group control-plane messages are
    dropped.  ``symmetric=True`` severs both directions; with
    ``symmetric=False`` only traffic *from* ``groups[0]`` to the other
    groups is dropped (one-way outage).  Nodes not listed in any group
    are unaffected.  At ``end`` the chaos engine runs the heal-merge
    sweep (rejoin every non-anchor side through a cross-side
    bootstrap).  The vector engine models every partition
    symmetrically — the converged approximation.
    """
    start: float
    end: float
    groups: Tuple[Tuple[int, ...], ...]
    symmetric: bool = True

    def __post_init__(self):
        if self.end <= self.start:
            raise ValueError("partition end must be after start")
        if len(self.groups) < 2:
            raise ValueError("partition needs >= 2 groups")
        flat = [u for g in self.groups for u in g]
        if len(flat) != len(set(flat)):
            raise ValueError("partition groups overlap")

    def group_of(self, node: int) -> Optional[int]:
        for gi, g in enumerate(self.groups):
            if node in g:
                return gi
        return None


@dataclasses.dataclass(frozen=True)
class LinkOutage:
    """Data-plane outage of the undirected edge ``{a, b}`` over ``[start, end)``."""
    start: float
    end: float
    a: int
    b: int


@dataclasses.dataclass(frozen=True)
class Straggler:
    """Node ``node`` is too slow to exchange models during ``[start, end)``.

    A straggler stays in the overlay (its heartbeats are fine); only
    its data-plane edges are masked, so every neighbor renormalizes
    away from it and the straggler keeps its own model for the round.
    """
    start: float
    end: float
    node: int


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Everything that will go wrong in a run, declared up front.

    Probabilities are per-message and independent; all randomness
    derives from ``seed`` (and the host simulator's own seeded RNG),
    so a plan replays bit-identically.

    * ``msg_loss`` — drop probability per NDMP message.
    * ``msg_delay`` / ``delay_factor`` — with probability ``msg_delay``
      a message takes ``delay_factor`` extra one-way latencies.
    * ``msg_dup`` — duplicate probability (at-least-once transport).
    * ``partitions`` — timed :class:`Partition` windows.
    * ``crashes`` — ``(time, node)`` crash-without-leave events.
    * ``rejoins`` — ``(time, node, bootstrap)`` scheduled re-entries:
      an alive node re-anchors (``rejoin``), a crashed one joins fresh.
    * ``link_outages`` / ``stragglers`` — data-plane faults, surfaced
      only through :meth:`ChaosEngine.data_faults`.
    """
    seed: int = 0
    msg_loss: float = 0.0
    msg_delay: float = 0.0
    delay_factor: float = 3.0
    msg_dup: float = 0.0
    partitions: Tuple[Partition, ...] = ()
    crashes: Tuple[Tuple[float, int], ...] = ()
    rejoins: Tuple[Tuple[float, int, int], ...] = ()
    link_outages: Tuple[LinkOutage, ...] = ()
    stragglers: Tuple[Straggler, ...] = ()

    def __post_init__(self):
        for name in ("msg_loss", "msg_delay", "msg_dup"):
            p = getattr(self, name)
            if not 0.0 <= p < 1.0:
                raise ValueError(f"{name} must be in [0, 1)")

    @property
    def message_faults(self) -> bool:
        return bool(self.msg_loss or self.msg_delay or self.msg_dup)

    def delay_scale(self) -> float:
        """Converged-image deadline stretch for the vector engine.

        Loss forces ~1/(1-p) delivery attempts per message; delayed
        messages stretch the mean transit by ``1 + q*delay_factor``.
        Duplicates never slow anything down.
        """
        return (1.0 + self.msg_delay * self.delay_factor) / (1.0 - self.msg_loss)


# --------------------------------------------------------------------------
# data-plane snapshot → edge mask
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DataFaults:
    """Data-plane faults active at one instant.

    ``down_pairs`` holds undirected ``(min, max)`` node-id pairs,
    ``slow_nodes`` the straggling node ids, and ``groups`` the groups
    of the active partition (``None`` when whole).  The data-plane
    mask is always symmetric — if either endpoint cannot complete the
    exchange, the edge is down for both (an asymmetric *control*
    partition still kills data exchange both ways: model exchange is a
    round trip).
    """
    down_pairs: FrozenSet[Tuple[int, int]] = frozenset()
    slow_nodes: FrozenSet[int] = frozenset()
    groups: Optional[Tuple[Tuple[int, ...], ...]] = None

    def __bool__(self) -> bool:
        return bool(self.down_pairs or self.slow_nodes or self.groups)

    def edge_down(self, u: int, v: int) -> bool:
        if u == v:
            return False
        if u in self.slow_nodes or v in self.slow_nodes:
            return True
        if (min(u, v), max(u, v)) in self.down_pairs:
            return True
        if self.groups is not None:
            gu = gv = None
            for gi, g in enumerate(self.groups):
                if u in g:
                    gu = gi
                if v in g:
                    gv = gi
            if gu is not None and gv is not None and gu != gv:
                return True
        return False


def edge_mask_for(sched, slot_nodes: Sequence[Optional[int]],
                  faults: DataFaults) -> np.ndarray:
    """Lower a :class:`DataFaults` snapshot to a ``(C, 2L)`` edge mask.

    ``sched`` is a :class:`repro.core.mixing.PermuteSchedule` (or any
    object with ``(K, C)`` ``perms``) in *slot* space; ``slot_nodes[i]``
    is the node id occupying slot ``i`` (``None`` for empty slots —
    their edges are left at 1, the alive mask already removes them).
    Entry ``[i, k]`` is 0 when the edge between slot ``i`` and its
    k-th incoming slot ``perms[k][i]`` is unreachable.  The mask is
    symmetric by construction because :meth:`DataFaults.edge_down` is.

    Feed the result to the masked mixers' keyword-only ``edge_mask`` —
    a runtime input on the existing weights path, so degraded rounds
    reuse the compiled trace (zero retraces, same MixerCache entry).
    """
    perms = np.asarray(getattr(sched, "perms", sched), dtype=np.int64)
    n = perms.shape[1]
    em = np.ones((n, perms.shape[0]), np.float32)
    if not faults:
        return em
    for i in range(n):
        u = slot_nodes[i]
        if u is None:
            continue
        for k in range(perms.shape[0]):
            v = slot_nodes[int(perms[k, i])]
            if v is None:
                continue
            if faults.edge_down(int(u), int(v)):
                em[i, k] = 0.0
    return em


# --------------------------------------------------------------------------
# chaos engine
# --------------------------------------------------------------------------

def _count(counts: Dict[str, int], name: str, n: int = 1) -> None:
    counts[name] = counts.get(name, 0) + n
    get_telemetry().count(f"faults.{name}", n)


class ChaosEngine:
    """SimulatorProtocol wrapper that executes a :class:`FaultPlan`.

    Wrap either engine::

        sim = ChaosEngine(Simulator(num_spaces=3, seed=0), plan)
        sim = ChaosEngine(VectorSimulator(num_spaces=3), plan)

    and hand the wrapper wherever a plain simulator goes (e.g.
    :class:`repro.overlay.controller.OverlayController`).  Timed plan
    events (partition start/heal, crashes, rejoins) fire in order as
    simulated time passes through them; per-message faults apply via
    the object engine's transport filter, or as a single converged
    delay stretch on the vector engine.  All injections are tallied in
    ``self.counts`` and mirrored as ``faults.*`` bus counters.
    """

    def __init__(self, sim, plan: FaultPlan):
        self.sim = sim
        self.plan = plan
        self.num_spaces = sim.num_spaces
        self.counts: Dict[str, int] = {}
        self._rng = np.random.default_rng(plan.seed)
        self._active: List[Partition] = []
        # (time, seq, kind, payload) — seq keeps same-time events in
        # plan declaration order
        events: List[Tuple[float, int, str, object]] = []
        seq = 0
        for p in plan.partitions:
            events.append((p.start, seq, "partition_start", p)); seq += 1
            events.append((p.end, seq, "partition_heal", p)); seq += 1
        for t, node in plan.crashes:
            events.append((t, seq, "crash", node)); seq += 1
        for t, node, boot in plan.rejoins:
            events.append((t, seq, "rejoin", (node, boot))); seq += 1
        self._events = sorted(events)
        self._next_ev = 0
        self._vector = not hasattr(sim, "set_message_filter")
        if self._vector:
            if plan.message_faults:
                sim.set_delay_scale(plan.delay_scale())
        elif plan.message_faults or plan.partitions:
            sim.set_message_filter(self._filter)

    # ---- protocol surface -------------------------------------------------
    @property
    def now(self) -> float:
        return self.sim.now

    def run_until(self, t: float) -> None:
        while self._next_ev < len(self._events) and self._events[self._next_ev][0] <= t:
            when, _, kind, payload = self._events[self._next_ev]
            self._next_ev += 1
            self.sim.run_until(when)
            self._apply(kind, payload)
        self.sim.run_until(t)

    def advance(self, dt: float) -> None:
        self.run_until(self.sim.now + dt)

    def alive_ids(self):
        return self.sim.alive_ids()

    def alive_addresses(self):
        return self.sim.alive_addresses()

    def neighbor_tables(self):
        return self.sim.neighbor_tables()

    def tables_version(self):
        return self.sim.tables_version()

    def correctness(self) -> float:
        return self.sim.correctness()

    def join(self, node_id: int, bootstrap=None, **kw):
        return self.sim.join(node_id, bootstrap, **kw)

    def leave(self, node_id: int) -> None:
        self.sim.leave(node_id)

    def fail(self, node_id: int) -> None:
        self.sim.fail(node_id)

    def __getattr__(self, name):
        # everything else (seed_network, export_state, heartbeat_period,
        # …) passes straight through to the wrapped engine
        return getattr(self.sim, name)

    # ---- data-plane surface ----------------------------------------------
    def data_faults(self) -> DataFaults:
        """Data-plane faults active at ``sim.now`` (for the edge mask)."""
        t = self.sim.now
        down = frozenset(
            (min(o.a, o.b), max(o.a, o.b))
            for o in self.plan.link_outages if o.start <= t < o.end)
        slow = frozenset(
            s.node for s in self.plan.stragglers if s.start <= t < s.end)
        groups = self._active[-1].groups if self._active else None
        return DataFaults(down_pairs=down, slow_nodes=slow, groups=groups)

    # ---- plan event execution --------------------------------------------
    def _apply(self, kind: str, payload) -> None:
        if kind == "partition_start":
            self._active.append(payload)
            if self._vector:
                self.sim.set_partition([list(g) for g in payload.groups])
            _count(self.counts, "partition_starts")
        elif kind == "partition_heal":
            self._active = [p for p in self._active if p is not payload]
            if self._vector:
                self.sim.heal_partition()
            else:
                self._heal_merge(payload)
            _count(self.counts, "partition_heals")
        elif kind == "crash":
            if payload in set(self.sim.alive_ids()):
                self.sim.fail(payload)
                _count(self.counts, "crashes")
        elif kind == "rejoin":
            node, boot = payload
            if node in set(self.sim.alive_ids()):
                self.sim.rejoin(node, boot)
            else:
                self.sim.join(node, boot)
            _count(self.counts, "rejoins")

    def _heal_merge(self, p: Partition) -> None:
        """Merge the overlays a full partition left behind.

        Failure detection pruned each side down to an internally
        correct but disjoint overlay; probes alone never reconnect
        them.  Re-anchor every alive node of every non-anchor group
        through a bootstrap in the largest surviving group — Theorem 1
        splices each one back at its globally closest coordinates.
        """
        alive = set(self.sim.alive_ids())
        groups = [[u for u in g if u in alive] for g in p.groups]
        groups = [g for g in groups if g]
        if len(groups) < 2:
            return
        anchor = max(groups, key=len)
        boot = min(anchor)
        for g in groups:
            if g is anchor:
                continue
            for u in g:
                self.sim.rejoin(u, boot)
                _count(self.counts, "rejoins")

    # ---- object-engine transport filter ----------------------------------
    def _blocked(self, src: int, dst: int) -> bool:
        for p in self._active:
            gs, gd = p.group_of(src), p.group_of(dst)
            if gs is None or gd is None or gs == gd:
                continue
            if p.symmetric or gs == 0:
                return True
        return False

    def _filter(self, now: float, src: int, dst: int, msg):
        if self._active and self._blocked(src, dst):
            _count(self.counts, "msg_partitioned")
            return (False, 0.0, 0)
        p = self.plan
        if not p.message_faults:
            return None
        u = self._rng.random()
        if u < p.msg_loss:
            _count(self.counts, "msg_dropped")
            return (False, 0.0, 0)
        extra, dups = 0.0, 0
        if p.msg_delay and self._rng.random() < p.msg_delay:
            extra = p.delay_factor * self.sim.latency()
            _count(self.counts, "msg_delayed")
        if p.msg_dup and self._rng.random() < p.msg_dup:
            dups = 1
            _count(self.counts, "msg_duped")
        if extra == 0.0 and dups == 0:
            return None
        return (True, extra, dups)
