"""Graceful-degradation machinery: backoff, health lifecycle, repair policy.

NDMP repair under faults is *bounded, not assumed*: the overlay
controller retries repair waits under a decorrelated-jitter
:class:`BackoffPolicy` at most ``RepairPolicy.max_retries`` times and
then gives up loudly instead of spinning.  Node health moves through a
**versioned** healthy → suspect → evicted (→ healed) lifecycle in
:class:`HealthTracker`; versioning makes a stale heal (one observed
against an older incarnation) a no-op, so an evicted node can never be
resurrected out of order.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, FrozenSet, Optional

import numpy as np

from ..obs import get_telemetry

__all__ = ["BackoffPolicy", "HealthState", "HealthTracker", "RepairPolicy"]


@dataclasses.dataclass
class BackoffPolicy:
    """Decorrelated-jitter backoff (AWS architecture-blog variant).

    Each delay is ``min(cap, uniform(base, prev * 3))`` — jittered so
    concurrent repairers don't thundering-herd the same neighbors,
    growing roughly geometrically, capped at ``cap`` seconds.  Seeded,
    so a fault storm replays bit-identically.
    """
    base: float = 0.5
    cap: float = 8.0
    seed: int = 0

    def __post_init__(self):
        if self.base <= 0 or self.cap < self.base:
            raise ValueError("need 0 < base <= cap")
        self._rng = np.random.default_rng(self.seed)
        self._prev = self.base

    def reset(self) -> None:
        self._prev = self.base
        self._rng = np.random.default_rng(self.seed)

    def next_delay(self) -> float:
        self._prev = min(self.cap,
                         float(self._rng.uniform(self.base, self._prev * 3.0)))
        return self._prev


class HealthState(enum.Enum):
    HEALTHY = "healthy"
    SUSPECT = "suspect"
    EVICTED = "evicted"


@dataclasses.dataclass
class _NodeHealth:
    state: HealthState = HealthState.HEALTHY
    version: int = 0          # bumps on every transition
    since: float = 0.0        # sim time of last transition


class HealthTracker:
    """Versioned suspect → evict → heal lifecycle for data-plane peers.

    ``suspect(node, t)`` marks a node unresponsive; after
    ``suspect_grace`` seconds without a heal it is **evicted** (all its
    data-plane edges masked until it heals).  ``heal(node, version)``
    must quote the version at which the caller observed the node
    suspect/evicted — a stale version is rejected, so a delayed "it's
    fine" from before a newer eviction cannot resurrect the node.
    Transitions land on the bus as ``faults.suspects`` /
    ``faults.evictions`` / ``faults.heals``.
    """

    def __init__(self, suspect_grace: float = 2.0):
        self.suspect_grace = float(suspect_grace)
        self._nodes: Dict[int, _NodeHealth] = {}

    def _get(self, node: int) -> _NodeHealth:
        return self._nodes.setdefault(node, _NodeHealth())

    def state_of(self, node: int) -> HealthState:
        return self._get(node).state

    def version_of(self, node: int) -> int:
        return self._get(node).version

    def suspect(self, node: int, now: float) -> int:
        """Mark ``node`` unresponsive; returns the new version."""
        h = self._get(node)
        if h.state is HealthState.HEALTHY:
            h.state = HealthState.SUSPECT
            h.version += 1
            h.since = now
            get_telemetry().count("faults.suspects")
        return h.version

    def heal(self, node: int, version: int, now: float = 0.0) -> bool:
        """Clear a suspicion/eviction observed at ``version``.

        Returns False (no-op) when ``version`` is stale — a newer
        transition superseded the observation behind this heal.
        """
        h = self._get(node)
        if h.state is HealthState.HEALTHY:
            return False
        if version < h.version:
            return False
        h.state = HealthState.HEALTHY
        h.version += 1
        h.since = now
        get_telemetry().count("faults.heals")
        return True

    def poll(self, now: float) -> None:
        """Advance suspects past their grace window to EVICTED."""
        for h in self._nodes.values():
            if (h.state is HealthState.SUSPECT
                    and now - h.since >= self.suspect_grace):
                h.state = HealthState.EVICTED
                h.version += 1
                h.since = now
                get_telemetry().count("faults.evictions")

    def unhealthy(self) -> FrozenSet[int]:
        """Nodes whose data-plane edges should be masked this round."""
        return frozenset(n for n, h in self._nodes.items()
                         if h.state is not HealthState.HEALTHY)

    def evicted(self) -> FrozenSet[int]:
        return frozenset(n for n, h in self._nodes.items()
                         if h.state is HealthState.EVICTED)


@dataclasses.dataclass
class RepairPolicy:
    """Bounded NDMP-repair retry policy for the overlay controller.

    After each control window the controller checks
    ``sim.correctness()``; below ``correctness_target`` it advances the
    simulator by a backoff delay (giving repair traffic time to land)
    and rechecks, at most ``max_retries`` times.  Recovery increments
    ``faults.repair_recovered``; exhaustion increments
    ``faults.repair_gave_up`` and the round proceeds degraded rather
    than blocking forever.
    """
    correctness_target: float = 1.0
    max_retries: int = 4
    backoff: Optional[BackoffPolicy] = None

    def __post_init__(self):
        if self.backoff is None:
            self.backoff = BackoffPolicy()
