"""repro.faults — deterministic fault injection + graceful degradation.

Every failure the rest of the repo exercises is *clean*: scripted
churn, polite leaves, repair that succeeds on the first try.  This
package is the adversarial counterpart — a seed-reproducible fault
plane over both NDMP engines plus the degradation machinery (edge-mask
degraded mixing, bounded-backoff repair, suspect→evict→heal health
tracking, crash/resume) that survives it.

Failure-model contract
======================

**Fault classes.**  A :class:`~repro.faults.plan.FaultPlan` declares,
once, everything that will go wrong in a run:

* *control-plane message faults* — each NDMP message is independently
  dropped with probability ``msg_loss``, delayed by
  ``delay_factor × latency`` with probability ``msg_delay``, or
  duplicated with probability ``msg_dup``;
* *partitions* — timed :class:`~repro.faults.plan.Partition` windows
  during which cross-group messages are dropped (``symmetric=False``
  drops only traffic *from* ``groups[0]``, the asymmetric/one-way
  outage of unreliable D2D links);
* *crashes* — crash-without-leave at a scheduled time (the node
  vanishes silently; 3T heartbeat silence detects it);
* *rejoins* — a scheduled re-entry: an alive node re-anchors through a
  bootstrap (``rejoin``), a crashed node joins afresh;
* *data-plane faults* — per-edge :class:`~repro.faults.plan.LinkOutage`
  windows and per-node :class:`~repro.faults.plan.Straggler` windows.
  These never touch NDMP; they surface to the mixer as an
  unreachable-edge mask (below).

**Delivery and ordering guarantees.**  The transport under a plan is
*unreliable, unordered, at-least-once*: messages may be lost, delayed
arbitrarily (but never reordered relative to identical send times —
the simulator heap is FIFO per timestamp), or duplicated.  NDMP
tolerates all three by construction: handlers are idempotent, the
``improve_pointer`` rule is monotone (a stale or duplicated message can
never clobber a better pointer), joins retry until every space has
both pointers, and periodic bidirectional self-probes re-converge
concurrent damage.  What loss *cannot* do is corrupt a message or
forge a sender.

**Engine equivalence.**  A plan drives either engine behind the
:class:`repro.core.ndmp.SimulatorProtocol` seam via
:class:`~repro.faults.plan.ChaosEngine`: the object
:class:`~repro.core.ndmp.Simulator` takes faults per message (a
transport filter seeded from the plan), the flat-array
:class:`~repro.scale.ndmp_vec.VectorSimulator` takes their *converged
image* (loss ⇒ deadline stretch ~1/(1-p); partition ⇒ per-group ring
rebuilds; heal ⇒ one re-merge rebuild).  Because converged NDMP tables
are a pure function of visible membership, both engines reach
**table-identical** state once faults heal and the settle time passes
(pinned in ``tests/test_faults.py``).  The vector engine models
partitions symmetrically (the converged approximation); the object
engine reproduces the asymmetric transient exactly.

**Recovery invariants.**

1. *Partition heal merges.*  After a full partition, failure detection
   prunes each side's address books, leaving internally-correct but
   disjoint overlays that probing alone can never reconnect.  The
   chaos engine's heal sweep re-joins every non-anchor side through a
   live cross-side bootstrap (:meth:`repro.core.ndmp.Simulator.rejoin`);
   Theorem 1 splices each rejoiner at its globally closest coordinate
   and correctness returns to 1.0 within a settle window.
2. *Degraded rounds stay exact.*  Unreachable data-plane edges are
   dropped and the surviving weights renormalized via the existing
   runtime-weights path (``edge_mask`` on the masked mixers) —
   equal to the dense renormalized oracle
   (:func:`repro.core.mixing.masked_mixing_matrix`) within 1e-6, with
   **zero retraces** and the same
   :class:`~repro.overlay.controller.MixerCache` entry: a fault storm
   never recompiles anything.
3. *Repair is bounded, not assumed.*  The overlay controller retries
   NDMP repair under a :class:`~repro.faults.degrade.BackoffPolicy`
   (decorrelated jitter) at most ``max_retries`` times, then gives up
   loudly (``faults.repair_gave_up``); the
   :class:`~repro.faults.degrade.HealthTracker` carries each node
   through a **versioned** suspect → evicted → healthy lifecycle so a
   stale heal can never resurrect an evicted node out of order.
4. *Crash/resume is exact.*  :meth:`repro.runtime.loop.SlotTrainLoop.save`
   / ``restore`` round-trip the full slot state (flat rows, optimizer
   state, top-k error-feedback residual, step counter) through
   :mod:`repro.ckpt.checkpoint` bit-exactly; replaying the same seeds
   from a checkpoint is loss-parity ≤ 1e-6 with an uninterrupted run.

**Observability.**  Every injected fault and recovery action lands on
the :mod:`repro.obs` bus as ``faults.*`` counters
(``msg_dropped/msg_delayed/msg_duped/msg_partitioned``, ``crashes``,
``rejoins``, ``partition_starts/partition_heals``,
``repair_retries/repair_recovered/repair_gave_up``,
``suspects/evictions/heals``, ``swap_barrier_aborts``) and as
per-round ``faults_injected`` / ``degraded_edges`` fields on the
:class:`repro.obs.rounds.RoundRecord`, so ledgers show what was
injected vs. what was survived.  ``benchmarks/fault_storm.py`` sweeps
loss-rate × partition × straggler and gates convergence-under-faults
in CI.
"""

from .degrade import BackoffPolicy, HealthState, HealthTracker, RepairPolicy
from .plan import (ChaosEngine, DataFaults, FaultPlan, LinkOutage,
                   Partition, Straggler, edge_mask_for)

__all__ = [
    "BackoffPolicy", "ChaosEngine", "DataFaults", "FaultPlan",
    "HealthState", "HealthTracker", "LinkOutage", "Partition",
    "RepairPolicy", "Straggler", "edge_mask_for",
]
