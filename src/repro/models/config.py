"""Architecture configuration — one dataclass covers all 10 assigned
architecture families (dense GQA, MoE, MLA-MoE, SSM, hybrid, enc-dec
audio, early-fusion VLM) plus the reduced smoke variants.

A config is pure data: the model code in :mod:`repro.models.model`
interprets it.  ``src/repro/configs/<id>.py`` files instantiate the
exact assigned specs and cite their sources.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int            # routed experts
    top_k: int
    d_ff_expert: int            # hidden width of each routed expert
    num_shared: int = 0         # always-on shared experts (DeepSeek-V3: 1)
    router_aux_coef: float = 0.001
    moe_every: int = 1          # apply MoE FFN on layers where i % moe_every == offset
    moe_offset: int = 0
    # capacity factor for the static dispatch; num_experts/top_k ⇒ dropless
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention [arXiv:2412.19437]."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD (state-space duality) [arXiv:2405.21060]."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    chunk: int = 64             # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def nheads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Jamba-style interleave: blocks of ``period`` layers; layer
    ``attn_index`` within a block is attention, the rest Mamba."""

    period: int = 8
    attn_index: int = 0


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None        # default d_model // num_heads
    qk_norm: bool = False
    rope_theta: float = 500_000.0
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    sliding_window: Optional[int] = None  # None = full causal attention
    first_dense_layers: int = 0           # MoE models: leading dense layers
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # encoder-decoder (seamless-m4t): decoder cross-attends into encoder
    # memory. Per the modality carve-out the encoder frontend is a stub:
    # inputs are precomputed frame embeddings of shape (B, enc_len, d_model).
    enc_dec: bool = False
    enc_layers: int = 0
    # DeepSeek-V3 multi-token prediction: extra depth-1 MTP block
    mtp_depth: int = 0
    source: str = ""            # citation for the assigned config

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding tables padded to a multiple of 256 (Megatron-style)
        so the vocab dim shards over any reasonable tensor axis; padded
        logit rows are masked to -inf in the model."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def attn_layer_mask(self) -> Tuple[bool, ...]:
        """Which layers are attention (vs Mamba) layers."""
        if self.family == "ssm":
            return tuple(False for _ in range(self.num_layers))
        if self.hybrid is not None:
            p, a = self.hybrid.period, self.hybrid.attn_index
            return tuple((i % p) == a for i in range(self.num_layers))
        return tuple(True for _ in range(self.num_layers))

    def moe_layer_mask(self) -> Tuple[bool, ...]:
        """Which layers use the MoE FFN (vs dense MLP / none for SSM)."""
        if self.moe is None:
            return tuple(False for _ in range(self.num_layers))
        m = self.moe
        out = []
        for i in range(self.num_layers):
            if i < self.first_dense_layers:
                out.append(False)
            else:
                out.append((i % m.moe_every) == m.moe_offset)
        return tuple(out)

    # ---- parameter counting (exact, for roofline MODEL_FLOPS) ----------
    def param_count(self, active_only: bool = False) -> int:
        """Exact parameter count; ``active_only`` counts top-k routed
        experts instead of all (MoE activated-params for 6·N·D)."""
        d, hd = self.d_model, self.resolved_head_dim
        n_q, n_kv = self.num_heads, self.num_kv_heads
        total = self.vocab_size * d                     # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d                # lm head
        attn_mask = self.attn_layer_mask()
        moe_mask = self.moe_layer_mask()

        def attn_params() -> int:
            if self.mla is not None:
                m = self.mla
                qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
                p = d * m.q_lora_rank + m.q_lora_rank * n_q * qk_dim
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                p += m.kv_lora_rank * n_q * (m.qk_nope_head_dim + m.v_head_dim)
                p += n_q * m.v_head_dim * d
                return p
            p = d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d
            return p

        def mlp_params() -> int:
            return 3 * d * self.d_ff                    # SwiGLU

        def moe_params(active: bool) -> int:
            m = self.moe
            e = m.top_k if active else m.num_experts
            p = 3 * d * m.d_ff_expert * (e + m.num_shared)
            p += d * m.num_experts                      # router
            return p

        def ssm_params() -> int:
            s = self.ssm
            di = s.d_inner(d)
            nh = s.nheads(d)
            p = d * (2 * di + 2 * s.d_state + nh)       # in_proj(x,z,B,C,dt)
            p += s.d_conv * (di + 2 * s.d_state)        # conv over x,B,C
            p += nh * 2                                 # A_log, D
            p += di                                     # norm
            p += di * d                                 # out_proj
            return p

        for i in range(self.num_layers):
            total += d  # pre-norm
            if attn_mask[i]:
                total += attn_params() + d              # + post norm
            else:
                total += ssm_params()
                # mamba layers in pure-ssm models have no separate FFN
            if self.family == "ssm":
                continue
            if moe_mask[i]:
                total += moe_params(active_only)
            else:
                total += mlp_params()
        if self.enc_dec:
            # encoder stack (self-attn + MLP) + decoder cross-attention
            enc = self.enc_layers * (attn_params() + mlp_params() + 2 * d)
            cross = self.num_layers * (attn_params() + d)
            total += enc + cross
        if self.mtp_depth:
            total += self.mtp_depth * (attn_params() + moe_params(active_only)
                                       if self.moe else mlp_params())
        return int(total)


# --------------------------------------------------------------------------
# Input shapes (assigned)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def reduce_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family variant: ≤2 layers, d_model ≤ 512, ≤4 experts."""
    changes = dict(
        name=cfg.name + "-smoke",
        num_layers=min(cfg.num_layers, 2),
        d_model=min(cfg.d_model, 256),
        num_heads=min(cfg.num_heads, 4),
        num_kv_heads=min(cfg.num_kv_heads, 2),
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 512),
        head_dim=64,
        enc_layers=min(cfg.enc_layers, 2),
        first_dense_layers=min(cfg.first_dense_layers, 1),
    )
    if cfg.hybrid is not None:
        # keep the interleave pattern visible in 2 layers: 1 attn + 1 mamba
        changes["hybrid"] = HybridConfig(period=2, attn_index=0)
    if cfg.moe is not None:
        changes["moe"] = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2), d_ff_expert=min(cfg.moe.d_ff_expert, 256))
    if cfg.ssm is not None:
        changes["ssm"] = dataclasses.replace(cfg.ssm, d_state=32, headdim=32, chunk=16)
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                   qk_nope_head_dim=32, qk_rope_head_dim=16,
                                   v_head_dim=32)
    return dataclasses.replace(cfg, **changes)
