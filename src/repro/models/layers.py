"""Shared neural building blocks (pure JAX, functional params-as-pytrees).

Conventions
-----------
* every ``init_*`` returns a dict pytree of ``jnp.ndarray`` leaves;
* every ``apply`` is a pure function of (params, inputs);
* compute dtype follows the input dtype; params are created in the
  dtype passed to init (bf16 for the dry-run, f32 for smoke tests);
* matmuls accumulate in f32 via ``preferred_element_type``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jnp.ndarray:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.uniform(key, (d_in, d_out), jnp.float32,
                               -scale, scale)).astype(dtype)


# Perf knob (§Perf hillclimb): with True, every dot materializes an f32
# output that is converted back to the activation dtype afterwards — on
# a sharded row-parallel matmul XLA then all-reduces the f32 partials
# (2× wire and HBM bytes).  False emits bf16 dot outputs (the MXU still
# accumulates in f32 internally), so partial sums cross the network in
# bf16.  Baseline (paper-faithful numerics) = True.
F32_DOT_OUTPUT = True


def matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """f32-accumulated matmul that keeps the activation dtype."""
    if F32_DOT_OUTPUT:
        return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)
    return jnp.dot(x, w)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32) -> jnp.ndarray:
    return jnp.ones((d,), dtype)


def rmsnorm(g: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * g.astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                       # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp_apply(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(matmul(x, p["w_gate"])) * matmul(x, p["w_up"])
    return matmul(h, p["w_down"])


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int, dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d_model), jnp.float32)
            * 0.02).astype(dtype)


def embed_apply(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0)


def unembed_apply(table: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Logits in f32 (loss-stability); table: (vocab, d)."""
    return jnp.dot(x, table.T, preferred_element_type=jnp.float32)


# --------------------------------------------------------------------------
# Losses
# --------------------------------------------------------------------------

def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean next-token cross-entropy; logits (..., V) f32, labels int."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
