"""Mixture-of-Experts FFN: top-k softmax router, capacity-based static
dispatch (gather → grouped einsum → scatter-add combine), optional
shared (always-on) experts, and the load-balance auxiliary loss.

Dispatch is the GShard/MaxText-style capacity formulation because it is
static-shape, fully differentiable, and the grouped einsum's expert
dimension maps directly onto the mesh ``model`` axis → expert
parallelism with a single all-to-all on each side.  Tokens beyond an
expert's capacity are dropped (weight renormalized) — capacity factor
1.25 keeps drop rates negligible at the assigned top-k/E ratios.

DeepSeek-V3's sigmoid+bias router is simplified to softmax top-k with
the standard aux loss (recorded in DESIGN.md §deviations); the
shared-expert and first-dense-layers structure is kept faithful.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import MoEConfig
from .layers import dense_init, matmul, mlp_apply, mlp_init


def moe_init(key, d_model: int, m: MoEConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    e, f = m.num_experts, m.d_ff_expert
    p = {
        "router": dense_init(ks[0], d_model, e, jnp.float32),  # router in f32
        "w_gate": dense_init(ks[1], d_model, e * f, dtype).reshape(d_model, e, f).transpose(1, 0, 2),
        "w_up": dense_init(ks[2], d_model, e * f, dtype).reshape(d_model, e, f).transpose(1, 0, 2),
        "w_down": dense_init(ks[3], f, e * d_model, dtype).reshape(f, e, d_model).transpose(1, 0, 2),
    }
    if m.num_shared:
        p["shared"] = mlp_init(ks[4], d_model, f * m.num_shared, dtype)
    return p


def router_topk(logits: jnp.ndarray, top_k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(T, E) logits → (T, k) normalized probs + (T, k) expert ids."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_i = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p, top_i


def load_balance_loss(probs_mean: jnp.ndarray, frac_routed: jnp.ndarray) -> jnp.ndarray:
    """Switch/GShard aux loss: E · Σ_e f_e · P_e (1.0 when balanced)."""
    e = probs_mean.shape[-1]
    return e * jnp.sum(frac_routed * probs_mean)


# Perf knob (§Perf hillclimb): the baseline dispatch sorts/buckets over
# the GLOBAL token set (B·S tokens) — the argsort/bincount/scatter are
# unshardable along tokens, so GSPMD all-gathers activations around
# them.  PER_EXAMPLE=True vmaps the dispatch over the batch dimension:
# routing/capacity become per-sequence (capacity C' = k·S/E·cf each),
# every index op stays batch-sharded, and expert compute becomes a
# batched grouped einsum (the all-to-all moves only dispatched tiles).
PER_EXAMPLE = False


def moe_apply(p: dict, x: jnp.ndarray, m: MoEConfig,
              capacity_factor: Optional[float] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) → (out (B, S, D), aux_loss scalar)."""
    if capacity_factor is None:
        capacity_factor = m.capacity_factor
    if PER_EXAMPLE and x.shape[0] > 1:
        out, aux = jax.vmap(
            lambda xb: _moe_apply_flat(p, xb[None], m, capacity_factor))(x)
        return out[:, 0], jnp.mean(aux)
    return _moe_apply_flat(p, x, m, capacity_factor)


def _moe_apply_flat(p: dict, x: jnp.ndarray, m: MoEConfig,
                    capacity_factor: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, D = x.shape
    T = B * S
    E, k = m.num_experts, m.top_k
    xf = x.reshape(T, D)

    logits = jnp.dot(xf.astype(jnp.float32), p["router"])          # (T, E)
    top_p, top_i = router_topk(logits, k)                          # (T, k)

    # aux loss statistics
    probs = jax.nn.softmax(logits, axis=-1)
    routed = jnp.zeros((T, E), jnp.float32).at[
        jnp.arange(T)[:, None], top_i].set(1.0)
    aux = load_balance_loss(probs.mean(0), routed.mean(0) / k)

    # ---- capacity dispatch ------------------------------------------------
    C = max(1, int(math.ceil(k * T / E * capacity_factor)))
    flat_e = top_i.reshape(T * k)                                  # expert of each slot
    flat_p = top_p.reshape(T * k)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)

    order = jnp.argsort(flat_e, stable=True)                       # group by expert
    e_sorted = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_group = jnp.arange(T * k) - starts[e_sorted]            # rank within expert

    keep = pos_in_group < C
    dest_e = jnp.where(keep, e_sorted, E)                          # row E = drop bin
    dest_c = jnp.where(keep, pos_in_group, 0).astype(jnp.int32)

    table_tok = jnp.zeros((E + 1, C), jnp.int32).at[dest_e, dest_c].set(flat_t[order])
    table_w = jnp.zeros((E + 1, C), jnp.float32).at[dest_e, dest_c].set(
        jnp.where(keep, flat_p[order], 0.0))
    table_tok, table_w = table_tok[:E], table_w[:E]                # (E, C)

    # ---- expert compute (grouped einsum; E maps to the mesh model axis) ---
    xg = jnp.take(xf, table_tok.reshape(E * C), axis=0).reshape(E, C, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xg, p["w_gate"],
                               preferred_element_type=jnp.float32)) * \
        jnp.einsum("ecd,edf->ecf", xg, p["w_up"],
                   preferred_element_type=jnp.float32)
    yg = jnp.einsum("ecf,efd->ecd", h.astype(x.dtype), p["w_down"],
                    preferred_element_type=jnp.float32)            # (E, C, D) f32

    # ---- combine: weighted scatter-add back to token order -----------------
    out = jnp.zeros((T, D), jnp.float32).at[table_tok.reshape(E * C)].add(
        (yg * table_w[..., None]).reshape(E * C, D))
    out = out.astype(x.dtype)

    if "shared" in p:
        out = out + mlp_apply(p["shared"], xf)
    return out.reshape(B, S, D), aux * m.router_aux_coef
