"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Train/prefill uses the expanded form (per-head K/V decompressed from the
latent, attention via the blockwise memory-bounded path).  Decode uses
the **absorbed** form: the query is projected into the latent space so
attention runs directly against the cached (kv_lora_rank + rope_dim)
latents — the cache is ``rank+rope`` floats per position instead of
``2·H·hd``, which is the paper's serving-memory win and exactly why the
long-context decode shapes favor MLA.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (NEG_INF, _check_cache_overflow, _positions_vector,
                        blockwise_attention)
from .config import MLAConfig
from .layers import apply_rope, dense_init, matmul, rmsnorm, rmsnorm_init


def mla_init(key, d_model: int, num_heads: int, m: MLAConfig,
             dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": dense_init(ks[0], d_model, m.q_lora_rank, dtype),
        "q_a_norm": rmsnorm_init(m.q_lora_rank, dtype),
        "wq_b": dense_init(ks[1], m.q_lora_rank, num_heads * qk_dim, dtype),
        "wkv_a": dense_init(ks[2], d_model, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_a_norm": rmsnorm_init(m.kv_lora_rank, dtype),
        "wkv_b": dense_init(ks[3], m.kv_lora_rank,
                            num_heads * (m.qk_nope_head_dim + m.v_head_dim), dtype),
        "wo": dense_init(ks[4], num_heads * m.v_head_dim, d_model, dtype),
    }


def _queries(p: dict, x: jnp.ndarray, num_heads: int, m: MLAConfig,
             positions: jnp.ndarray, rope_theta: float, rms_eps: float):
    B, S, _ = x.shape
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    q = matmul(rmsnorm(p["q_a_norm"], matmul(x, p["wq_a"]), rms_eps), p["wq_b"])
    q = q.reshape(B, S, num_heads, qk_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, rope_theta)
    return q_nope, q_rope


def _latents(p: dict, x: jnp.ndarray, m: MLAConfig, positions: jnp.ndarray,
             rope_theta: float, rms_eps: float):
    B, S, _ = x.shape
    kv = matmul(x, p["wkv_a"])
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(p["kv_a_norm"], c_kv, rms_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, rope_theta)  # shared head
    return c_kv, k_rope[:, :, 0, :]


def mla_apply(p: dict, x: jnp.ndarray, *, num_heads: int, m: MLAConfig,
              rope_theta: float, rms_eps: float = 1e-5,
              window: Optional[int] = None,
              positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Train/prefill MLA (expanded form)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    q_nope, q_rope = _queries(p, x, num_heads, m, positions, rope_theta, rms_eps)
    c_kv, k_rope = _latents(p, x, m, positions, rope_theta, rms_eps)
    kv = matmul(c_kv, p["wkv_b"]).reshape(
        B, S, num_heads, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (B, S, num_heads, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    # v_head_dim may differ from qk dim; pad v to qk dim for the shared
    # blockwise path, then slice back (pure-jnp path only).
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    if m.v_head_dim != qk_dim:
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_dim - m.v_head_dim)))
    out = blockwise_attention(q, k, v, window=window)
    out = out[..., :m.v_head_dim].reshape(B, S, num_heads * m.v_head_dim)
    return matmul(out, p["wo"])


# --------------------------------------------------------------------------
# Absorbed decode against the latent cache
# --------------------------------------------------------------------------

def init_mla_cache(batch: int, cache_len: int, m: MLAConfig,
                   dtype=jnp.float32) -> dict:
    return {
        "c_kv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, cache_len, m.qk_rope_head_dim), dtype),
    }


def mla_decode(p: dict, x: jnp.ndarray, cache: dict, pos: jnp.ndarray, *,
               num_heads: int, m: MLAConfig, rope_theta: float,
               rms_eps: float = 1e-5,
               window: Optional[int] = None) -> Tuple[jnp.ndarray, dict]:
    """One-token absorbed-form MLA decode.  x: (B, 1, D).

    ``pos`` is a scalar or per-slot (B,) vector with the same contract
    as :func:`repro.models.attention.gqa_decode`: rows with pos < 0 are
    empty serving slots and return exactly zero; without a window a
    concrete pos >= cache_len raises instead of silently overwriting
    the last latent slot."""
    B = x.shape[0]
    cache_len = cache["c_kv"].shape[1]
    if window is None:
        _check_cache_overflow(pos, cache_len)
    pos = jnp.asarray(pos, jnp.int32)
    pos_vec = _positions_vector(pos, B)
    positions = pos_vec[:, None]
    q_nope, q_rope = _queries(p, x, num_heads, m, positions, rope_theta, rms_eps)
    c_kv, k_rope = _latents(p, x, m, positions, rope_theta, rms_eps)

    cd = c_kv.astype(cache["c_kv"].dtype)
    rd = k_rope.astype(cache["k_rope"].dtype)
    if pos.ndim == 0:
        slot = pos % cache_len if window is not None else pos
        cc = jax.lax.dynamic_update_slice(cache["c_kv"], cd, (0, slot, 0))
        cr = jax.lax.dynamic_update_slice(cache["k_rope"], rd, (0, slot, 0))
    else:
        slot = (pos_vec % cache_len if window is not None
                else jnp.clip(pos_vec, 0, cache_len - 1))
        write = jax.vmap(
            lambda c, u, s: jax.lax.dynamic_update_slice(c, u, (s, 0)))
        cc = write(cache["c_kv"], cd, slot)
        cr = write(cache["k_rope"], rd, slot)

    # absorb W_uk into the query: q_lat[b,h,r] = Σ_d q_nope[b,h,d]·W_uk[r,h,d]
    w_kv = p["wkv_b"].reshape(m.kv_lora_rank, num_heads,
                              m.qk_nope_head_dim + m.v_head_dim)
    w_uk = w_kv[:, :, :m.qk_nope_head_dim]           # (rank, H, nope)
    w_uv = w_kv[:, :, m.qk_nope_head_dim:]           # (rank, H, v)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    s = jnp.einsum("bhr,blr->bhl", q_lat, cc.astype(jnp.float32))
    s += jnp.einsum("bhd,bld->bhl", q_rope[:, 0].astype(jnp.float32),
                    cr.astype(jnp.float32))
    s *= (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    idx = jnp.arange(cache_len, dtype=jnp.int32)
    if window is None:
        valid = idx[None, :] <= pos_vec[:, None]                    # (B, L)
    else:
        valid = ((idx[None, :] <= pos_vec[:, None])
                 | (pos_vec[:, None] + 1 >= cache_len))
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    # empty slots (pos < 0, all-invalid rows) come back exactly zero
    w = jax.nn.softmax(s, axis=-1) * valid[:, None, :]
    ctx_lat = jnp.einsum("bhl,blr->bhr", w, cc.astype(jnp.float32))
    out = jnp.einsum("bhr,rhd->bhd", ctx_lat, w_uv.astype(jnp.float32))
    out = out.reshape(B, 1, num_heads * m.v_head_dim).astype(x.dtype)
    return matmul(out, p["wo"]), {"c_kv": cc, "k_rope": cr}


def mla_prefill(p: dict, x: jnp.ndarray, cache: dict, *, num_heads: int,
                m: MLAConfig, rope_theta: float, rms_eps: float = 1e-5,
                window: Optional[int] = None) -> Tuple[jnp.ndarray, dict]:
    """Whole-prompt MLA prefill: expanded-form attention over x (B, P, D)
    plus one batched write of the prompt's latents into the decode cache
    — replacing P single-token ``mla_decode`` dispatches.  Fresh-cache
    semantics (positions 0..P-1); with a ring shorter than P only the
    last ``cache_len`` latents are written at their ring slots.
    Returns (attn_out (B,P,D), new_cache)."""
    import numpy as np
    B, P, _ = x.shape
    cache_len = cache["c_kv"].shape[1]
    if window is None and P > cache_len:
        raise ValueError(
            f"prompt length {P} overflows the {cache_len}-slot latent cache")
    positions = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32)[None, :],
                                 (B, P))
    c_kv, k_rope = _latents(p, x, m, positions, rope_theta, rms_eps)
    cd = c_kv.astype(cache["c_kv"].dtype)
    rd = k_rope.astype(cache["k_rope"].dtype)
    if P > cache_len:
        order = np.argsort(np.arange(P - cache_len, P) % cache_len)
        cc = cd[:, P - cache_len:][:, order]
        cr = rd[:, P - cache_len:][:, order]
    else:
        cc = jax.lax.dynamic_update_slice(cache["c_kv"], cd, (0, 0, 0))
        cr = jax.lax.dynamic_update_slice(cache["k_rope"], rd, (0, 0, 0))
    out = mla_apply(p, x, num_heads=num_heads, m=m, rope_theta=rope_theta,
                    rms_eps=rms_eps, window=window, positions=positions)
    return out, {"c_kv": cc, "k_rope": cr}
