"""The LanguageModel: config-driven stacks covering all six assigned
architecture families with one code path.

Layer stacks are compiled into **segments**: the layer-kind sequence
(attention/MLA/Mamba mixer × dense/MoE FFN × optional cross-attention)
is factored into the smallest repeating superblock, and each segment is
a single ``lax.scan`` over stacked parameters — so llama3-405b's 126
layers trace once, and jamba's 1:7 mamba/attention interleave with
alternating MoE scans over nine identical 8-layer superblocks.

API
---
* ``init_params(cfg, key, dtype)``
* ``forward(cfg, params, tokens, enc_embeds=None)`` → (logits f32, aux)
* ``train_loss(cfg, params, batch)`` → scalar (+ MoE aux, + MTP term)
* ``init_cache(cfg, params, batch, cache_len, dtype, enc_embeds=None)``
* ``prefill(cfg, params, cache, tokens, lengths=None)`` → (last-token
  logits, cache primed with the whole prompt in one batched pass)
* ``decode_step(cfg, params, cache, token, pos)`` → (logits, new cache)

Serving contract: ``cache["pos"]`` is a scalar for the legacy
whole-batch decode loop, or a per-slot (B,) vector for the continuous
batching serving plane (:mod:`repro.runtime.serving`) — each batch row
sits at its own depth and rows with pos < 0 are empty slots whose
attention output is exactly zero and whose position does not advance.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as attn
from . import mla as mla_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ArchConfig
from .layers import (embed_apply, embed_init, matmul, mlp_apply, mlp_init,
                     rmsnorm, rmsnorm_init, softmax_xent, unembed_apply)

LayerKind = Tuple[str, Optional[str], bool]   # (mixer, ffn, cross)

# When True, every lax.scan in the model is fully unrolled at trace time.
# Used ONLY by the dry-run's depth probes: XLA cost analysis counts a
# while-loop body once, so small-depth probe configs are compiled
# unrolled to obtain true per-layer marginal costs.
SCAN_UNROLL = False


def scan(body, init, xs, length=None):
    n = length
    if n is None:
        n = jax.tree.leaves(xs)[0].shape[0]
    return jax.lax.scan(body, init, xs, unroll=n if SCAN_UNROLL else 1)


def _constrain(x: jnp.ndarray, spec) -> jnp.ndarray:
    """Pin activation sharding (no-op when spec is None / outside jit).

    GSPMD left alone propagates the FSDP *param* sharding into the
    activations (batch replicated, d_model sharded) — catastrophic for
    memory.  One constraint per scan iteration keeps batch on the data
    axes everywhere.
    """
    if x is None or spec is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # no mesh context (e.g. pure eager smoke tests)


# --------------------------------------------------------------------------
# Layer plan → segments
# --------------------------------------------------------------------------

def layer_plan(cfg: ArchConfig) -> List[LayerKind]:
    attn_mask = cfg.attn_layer_mask()
    moe_mask = cfg.moe_layer_mask()
    kinds: List[LayerKind] = []
    for i in range(cfg.num_layers):
        if attn_mask[i]:
            mixer = "mla" if cfg.mla is not None else "attn"
        else:
            mixer = "mamba"
        ffn = None if cfg.family == "ssm" else ("moe" if moe_mask[i] else "dense")
        kinds.append((mixer, ffn, cfg.enc_dec))
    return kinds


def find_segments(kinds: List[LayerKind]) -> List[Tuple[Tuple[LayerKind, ...], int]]:
    """Factor the plan into (superblock pattern, repeats) segments."""
    n = len(kinds)
    for p in range(1, min(16, n) + 1):
        if n % p == 0 and n // p > 1 \
                and all(kinds[i] == kinds[i % p] for i in range(n)):
            return [(tuple(kinds[:p]), n // p)]
    segs: List[Tuple[Tuple[LayerKind, ...], int]] = []
    i = 0
    while i < n:
        j = i
        while j < n and kinds[j] == kinds[i]:
            j += 1
        segs.append(((kinds[i],), j - i))
        i = j
    return segs


# --------------------------------------------------------------------------
# Sublayer init / apply
# --------------------------------------------------------------------------

def _init_sublayer(key, cfg: ArchConfig, kind: LayerKind, dtype) -> dict:
    mixer, ffn, cross = kind
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p: dict = {"norm1": rmsnorm_init(d, dtype)}
    if mixer == "attn":
        p["attn"] = attn.gqa_init(ks[0], d, cfg.num_heads, cfg.num_kv_heads,
                                  hd, cfg.qk_norm, dtype)
    elif mixer == "mla":
        p["mla"] = mla_mod.mla_init(ks[0], d, cfg.num_heads, cfg.mla, dtype)
    else:
        p["mamba"] = ssm_mod.mamba_init(ks[0], d, cfg.ssm, dtype)
    if cross and mixer != "mamba":
        p["norm_c"] = rmsnorm_init(d, dtype)
        p["cross"] = attn.cross_init(ks[1], d, cfg.num_heads,
                                     cfg.num_kv_heads, hd, dtype)
    if ffn is not None:
        p["norm2"] = rmsnorm_init(d, dtype)
        if ffn == "dense":
            p["mlp"] = mlp_init(ks[2], d, cfg.d_ff, dtype)
        else:
            p["moe"] = moe_mod.moe_init(ks[2], d, cfg.moe, dtype)
    return p


def _apply_sublayer(p: dict, cfg: ArchConfig, kind: LayerKind, x: jnp.ndarray,
                    aux: jnp.ndarray, *, window: Optional[int],
                    memory_kv=None, causal: bool = True):
    mixer, ffn, cross = kind
    hd = cfg.resolved_head_dim
    h = rmsnorm(p["norm1"], x, cfg.rms_eps)
    if mixer == "attn":
        h = attn.gqa_apply(p["attn"], h, num_heads=cfg.num_heads,
                           num_kv_heads=cfg.num_kv_heads, head_dim=hd,
                           rope_theta=cfg.rope_theta, rms_eps=cfg.rms_eps,
                           window=window, causal=causal)
    elif mixer == "mla":
        h = mla_mod.mla_apply(p["mla"], h, num_heads=cfg.num_heads,
                              m=cfg.mla, rope_theta=cfg.rope_theta,
                              rms_eps=cfg.rms_eps, window=window)
    else:
        h = ssm_mod.mamba_apply(p["mamba"], h, cfg.ssm, cfg.rms_eps)
    x = x + h
    if cross and mixer != "mamba" and memory_kv is not None:
        h = rmsnorm(p["norm_c"], x, cfg.rms_eps)
        h = attn.cross_apply(p["cross"], h, memory_kv, num_heads=cfg.num_heads,
                             num_kv_heads=cfg.num_kv_heads, head_dim=hd)
        x = x + h
    if ffn is not None:
        h = rmsnorm(p["norm2"], x, cfg.rms_eps)
        if ffn == "dense":
            h = mlp_apply(p["mlp"], h)
        else:
            h, a = moe_mod.moe_apply(p["moe"], h, cfg.moe)
            aux = aux + a
        x = x + h
    return x, aux


# --------------------------------------------------------------------------
# Decode sublayer (cache-carrying)
# --------------------------------------------------------------------------

def _init_sublayer_cache(cfg: ArchConfig, kind: LayerKind, batch: int,
                         cache_len: int, dtype) -> dict:
    mixer, _, cross = kind
    hd = cfg.resolved_head_dim
    c: dict = {}
    if mixer == "attn":
        length = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        c["kv"] = attn.init_kv_cache(batch, length, cfg.num_kv_heads, hd, dtype)
    elif mixer == "mla":
        length = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        c["mla"] = mla_mod.init_mla_cache(batch, length, cfg.mla, dtype)
    else:
        c["ssm"] = ssm_mod.init_ssm_cache(batch, cfg.d_model, cfg.ssm, dtype)
    if cross and mixer != "mamba":
        c["mem_k"] = jnp.zeros((batch, 0, cfg.num_kv_heads, hd), dtype)  # filled by init_cache
    return c


def _apply_sublayer_decode(p: dict, c: dict, cfg: ArchConfig, kind: LayerKind,
                           x: jnp.ndarray, pos):
    mixer, ffn, cross = kind
    hd = cfg.resolved_head_dim
    window = cfg.sliding_window
    h = rmsnorm(p["norm1"], x, cfg.rms_eps)
    new_c = dict(c)
    if mixer == "attn":
        h, kv = attn.gqa_decode(p["attn"], h, c["kv"], pos,
                                num_heads=cfg.num_heads,
                                num_kv_heads=cfg.num_kv_heads, head_dim=hd,
                                rope_theta=cfg.rope_theta, rms_eps=cfg.rms_eps,
                                window=window)
        new_c["kv"] = kv
    elif mixer == "mla":
        h, mc = mla_mod.mla_decode(p["mla"], h, c["mla"], pos,
                                   num_heads=cfg.num_heads, m=cfg.mla,
                                   rope_theta=cfg.rope_theta,
                                   rms_eps=cfg.rms_eps, window=window)
        new_c["mla"] = mc
    else:
        h, sc = ssm_mod.mamba_decode(p["mamba"], h, c["ssm"], cfg.ssm, cfg.rms_eps)
        new_c["ssm"] = sc
    x = x + h
    if cross and mixer != "mamba" and "mem_k" in c:
        h = rmsnorm(p["norm_c"], x, cfg.rms_eps)
        h = attn.cross_apply(p["cross"], h, (c["mem_k"], c["mem_v"]),
                             num_heads=cfg.num_heads,
                             num_kv_heads=cfg.num_kv_heads, head_dim=hd)
        x = x + h
    if ffn is not None:
        h = rmsnorm(p["norm2"], x, cfg.rms_eps)
        if ffn == "dense":
            h = mlp_apply(p["mlp"], h)
        else:
            h, _ = moe_mod.moe_apply(p["moe"], h, cfg.moe)
        x = x + h
    return x, new_c


def _apply_sublayer_prefill(p: dict, c: dict, cfg: ArchConfig,
                            kind: LayerKind, x: jnp.ndarray):
    """Whole-prompt counterpart of ``_apply_sublayer_decode``: one
    batched pass over x (B, P, D) that also primes the sublayer cache."""
    mixer, ffn, cross = kind
    hd = cfg.resolved_head_dim
    window = cfg.sliding_window
    h = rmsnorm(p["norm1"], x, cfg.rms_eps)
    new_c = dict(c)
    if mixer == "attn":
        h, kv = attn.gqa_prefill(p["attn"], h, c["kv"],
                                 num_heads=cfg.num_heads,
                                 num_kv_heads=cfg.num_kv_heads, head_dim=hd,
                                 rope_theta=cfg.rope_theta,
                                 rms_eps=cfg.rms_eps, window=window)
        new_c["kv"] = kv
    elif mixer == "mla":
        h, mc = mla_mod.mla_prefill(p["mla"], h, c["mla"],
                                    num_heads=cfg.num_heads, m=cfg.mla,
                                    rope_theta=cfg.rope_theta,
                                    rms_eps=cfg.rms_eps, window=window)
        new_c["mla"] = mc
    else:
        h, sc = ssm_mod.mamba_prefill(p["mamba"], h, c["ssm"], cfg.ssm,
                                      cfg.rms_eps)
        new_c["ssm"] = sc
    x = x + h
    if cross and mixer != "mamba" and "mem_k" in c:
        h = rmsnorm(p["norm_c"], x, cfg.rms_eps)
        h = attn.cross_apply(p["cross"], h, (c["mem_k"], c["mem_v"]),
                             num_heads=cfg.num_heads,
                             num_kv_heads=cfg.num_kv_heads, head_dim=hd)
        x = x + h
    if ffn is not None:
        h = rmsnorm(p["norm2"], x, cfg.rms_eps)
        if ffn == "dense":
            h = mlp_apply(p["mlp"], h)
        else:
            h, _ = moe_mod.moe_apply(p["moe"], h, cfg.moe)
        x = x + h
    return x, new_c


# --------------------------------------------------------------------------
# Parameter init
# --------------------------------------------------------------------------

def _init_segment(key, cfg: ArchConfig, pattern: Tuple[LayerKind, ...],
                  repeats: int, dtype) -> dict:
    def one(k):
        ks = jax.random.split(k, len(pattern))
        return {f"sub{i}": _init_sublayer(ks[i], cfg, kind, dtype)
                for i, kind in enumerate(pattern)}
    keys = jax.random.split(key, repeats)
    return jax.vmap(one)(keys)


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> dict:
    segs = find_segments(layer_plan(cfg))
    n_aux = 4 + len(segs) + (1 if cfg.mtp_depth else 0)
    ks = jax.random.split(key, n_aux)
    params: dict = {
        "embed": embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = embed_init(ks[1], cfg.padded_vocab, cfg.d_model, dtype)
    for si, (pattern, repeats) in enumerate(segs):
        params[f"seg{si}"] = _init_segment(ks[4 + si], cfg, pattern, repeats, dtype)
    if cfg.enc_dec:
        enc_kind: LayerKind = ("attn", "dense", False)
        params["encoder"] = _init_segment(ks[2], cfg, (enc_kind,),
                                          cfg.enc_layers, dtype)
        params["enc_norm"] = rmsnorm_init(cfg.d_model, dtype)
    if cfg.mtp_depth:
        mtp_kind: LayerKind = layer_plan(cfg)[-1]
        params["mtp_proj"] = jax.random.normal(
            ks[3], (2 * cfg.d_model, cfg.d_model), jnp.float32).astype(dtype) * 0.02
        params["mtp_norm"] = rmsnorm_init(cfg.d_model, dtype)
        params["mtp"] = _init_segment(ks[-1], cfg, (mtp_kind,), cfg.mtp_depth, dtype)
    return params


# --------------------------------------------------------------------------
# Forward / loss
# --------------------------------------------------------------------------

def _run_segment(seg_params: dict, cfg: ArchConfig, pattern, x, aux, *,
                 window, memory_kv=None, causal=True, remat=False,
                 act_spec=None):
    def body(carry, p_slice):
        h, a = carry
        h = _constrain(h, act_spec)
        for i, kind in enumerate(pattern):
            h, a = _apply_sublayer(p_slice[f"sub{i}"], cfg, kind, h, a,
                                   window=window, memory_kv=memory_kv,
                                   causal=causal)
            h = _constrain(h, act_spec)
        return (h, a), None

    leaves = jax.tree.leaves(seg_params)
    repeats = leaves[0].shape[0] if leaves else 0
    if not remat or repeats < 4:
        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux), _ = scan(body, (x, aux), seg_params)
        return x, aux

    # Nested remat: outer scan over ~√R groups of g layers (saves ~√R
    # carries instead of R); an awkward trailing remainder (R % g) runs
    # as a plain-remat scan so no divisibility is required.
    g = max(2, int(repeats ** 0.5))
    main = (repeats // g) * g
    head = jax.tree.map(lambda l: l[:main].reshape((main // g, g) + l.shape[1:]),
                        seg_params)
    inner_body = jax.checkpoint(body, prevent_cse=False)

    def outer(carry, p_group):
        out, _ = scan(inner_body, carry, p_group)
        return out, None

    outer = jax.checkpoint(outer, prevent_cse=False)
    (x, aux), _ = scan(outer, (x, aux), head)
    if main < repeats:
        tail = jax.tree.map(lambda l: l[main:], seg_params)
        (x, aux), _ = scan(inner_body, (x, aux), tail)
    return x, aux


def encode(cfg: ArchConfig, params: dict, enc_embeds: jnp.ndarray,
           remat: bool = False, act_spec=None) -> jnp.ndarray:
    """Encoder stack over precomputed frontend embeddings (B, M, D)."""
    enc_kind: LayerKind = ("attn", "dense", False)
    x, _ = _run_segment(params["encoder"], cfg, (enc_kind,), enc_embeds,
                        jnp.zeros((), jnp.float32), window=None,
                        causal=False, remat=remat, act_spec=act_spec)
    return rmsnorm(params["enc_norm"], x, cfg.rms_eps)


def forward(cfg: ArchConfig, params: dict, tokens: jnp.ndarray,
            enc_embeds: Optional[jnp.ndarray] = None, remat: bool = False,
            return_hidden: bool = False, act_spec=None, logit_spec=None):
    """tokens (B, S) → (logits (B, S, V) f32, aux_loss scalar)."""
    segs = find_segments(layer_plan(cfg))
    x = _constrain(embed_apply(params["embed"], tokens), act_spec)
    aux = jnp.zeros((), jnp.float32)
    memory_kv = None
    if cfg.enc_dec:
        assert enc_embeds is not None, "enc-dec model needs encoder embeddings"
        enc_out = encode(cfg, params, enc_embeds, remat=remat,
                         act_spec=act_spec)
        # each decoder sublayer projects the encoder memory with its own
        # cross weights, recomputed inside its scan body
        memory_kv = enc_out
    for si, (pattern, repeats) in enumerate(segs):
        if cfg.enc_dec:
            x, aux = _run_segment_encdec(params[f"seg{si}"], cfg, pattern, x,
                                         aux, memory=memory_kv,
                                         window=cfg.sliding_window,
                                         remat=remat, act_spec=act_spec)
        else:
            x, aux = _run_segment(params[f"seg{si}"], cfg, pattern, x, aux,
                                  window=cfg.sliding_window, remat=remat,
                                  act_spec=act_spec)
    h = rmsnorm(params["final_norm"], x, cfg.rms_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = _constrain(_mask_pad(unembed_apply(table, h), cfg), logit_spec)
    if return_hidden:
        return logits, aux, h
    return logits, aux


def _mask_pad(logits: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """-inf on the padded vocab rows so they never win softmax/argmax."""
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    valid = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
    return jnp.where(valid, logits, -1e30)


def _run_segment_encdec(seg_params, cfg, pattern, x, aux, *, memory, window,
                        remat=False, act_spec=None):
    """Enc-dec segment: each sublayer projects the encoder memory with its
    own cross weights (recomputed per layer inside the scan)."""
    hd = cfg.resolved_head_dim

    def body(carry, p_slice):
        h, a = carry
        h = _constrain(h, act_spec)
        for i, kind in enumerate(pattern):
            p = p_slice[f"sub{i}"]
            mem_kv = attn.cross_memory(p["cross"], memory,
                                       num_kv_heads=cfg.num_kv_heads,
                                       head_dim=hd) if "cross" in p else None
            h, a = _apply_sublayer(p, cfg, kind, h, a, window=window,
                                   memory_kv=mem_kv)
        return (h, a), None
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    (x, aux), _ = scan(body, (x, aux), seg_params)
    return x, aux


def train_loss(cfg: ArchConfig, params: dict, batch: Dict[str, jnp.ndarray],
               remat: bool = True, act_spec=None, logit_spec=None) -> jnp.ndarray:
    """batch: tokens (B,S) int32, labels (B,S) int32 (+ enc_embeds)."""
    out = forward(cfg, params, batch["tokens"],
                  enc_embeds=batch.get("enc_embeds"), remat=remat,
                  return_hidden=bool(cfg.mtp_depth), act_spec=act_spec,
                  logit_spec=logit_spec)
    if cfg.mtp_depth:
        logits, aux, hidden = out
    else:
        logits, aux = out
    loss = softmax_xent(logits, batch["labels"])
    if cfg.mtp_depth:
        loss = loss + 0.3 * _mtp_loss(cfg, params, hidden, batch,
                                      act_spec=act_spec)
    return loss + aux


def _mtp_loss(cfg: ArchConfig, params: dict, hidden: jnp.ndarray,
              batch: Dict[str, jnp.ndarray], act_spec=None) -> jnp.ndarray:
    """DeepSeek-V3 multi-token prediction (depth 1): combine the trunk
    hidden state at t with the embedding of token t+1, run one extra
    block, predict token t+2 (= labels shifted by one)."""
    tokens, labels = batch["tokens"], batch["labels"]
    nxt_emb = embed_apply(params["embed"], labels)          # token t+1
    h = jnp.concatenate([rmsnorm(params["mtp_norm"], hidden, cfg.rms_eps),
                         nxt_emb], axis=-1)
    h = matmul(h, params["mtp_proj"])
    kind = layer_plan(cfg)[-1]
    h, _ = _run_segment(params["mtp"], cfg, (kind,), h,
                        jnp.zeros((), jnp.float32), window=cfg.sliding_window,
                        act_spec=act_spec)
    h = rmsnorm(params["final_norm"], h, cfg.rms_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = _mask_pad(unembed_apply(table, h[:, :-1]), cfg)
    mtp_labels = labels[:, 1:]
    return softmax_xent(logits, mtp_labels)


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, params: dict, batch: int, cache_len: int,
               dtype=jnp.float32,
               enc_embeds: Optional[jnp.ndarray] = None,
               per_slot_pos: bool = False) -> dict:
    """Build the per-layer decode cache pytree (stacked per segment).

    With ``per_slot_pos`` the cache carries a (batch,) position vector
    initialized to -1 (every slot empty) — the serving-plane layout
    where each row is an independent request slot.  For enc-dec models
    the encoder runs once here and each decoder layer's cross K/V
    memory is precomputed into the cache.
    """
    segs = find_segments(layer_plan(cfg))
    cache: dict = {"pos": (jnp.full((batch,), -1, jnp.int32) if per_slot_pos
                           else jnp.zeros((), jnp.int32))}
    enc_out = None
    if cfg.enc_dec:
        assert enc_embeds is not None
        enc_out = encode(cfg, params, enc_embeds)

    for si, (pattern, repeats) in enumerate(segs):
        def one(p_slice):
            out = {}
            for i, kind in enumerate(pattern):
                c = _init_sublayer_cache(cfg, kind, batch, cache_len, dtype)
                if "mem_k" in c:
                    mk, mv = attn.cross_memory(
                        p_slice[f"sub{i}"]["cross"], enc_out,
                        num_kv_heads=cfg.num_kv_heads,
                        head_dim=cfg.resolved_head_dim)
                    c["mem_k"], c["mem_v"] = mk.astype(dtype), mv.astype(dtype)
                out[f"sub{i}"] = c
            return out
        if cfg.enc_dec:
            cache[f"seg{si}"] = jax.vmap(one)(params[f"seg{si}"])
        else:
            cache[f"seg{si}"] = jax.vmap(lambda _: one(None))(
                jnp.arange(repeats))
    return cache


def prefill(cfg: ArchConfig, params: dict, cache: dict,
            tokens: jnp.ndarray,
            lengths: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, dict]:
    """Batched whole-prompt prefill: one forward pass over tokens
    (B, P) that primes every layer's cache for positions 0..P-1 —
    replacing the O(prompt_len)-dispatch teacher-forced ``decode_step``
    loop.  Returns (logits (B, V) f32 at each row's **last prompt
    token**, new cache positioned for the first generated token).

    ``lengths`` (B,) enables ragged prompts padded to P: row b's real
    prompt is tokens[b, :lengths[b]]; the causal mask keeps padding out
    of real queries' attention, the cache slots past lengths[b] hold
    inert garbage masked by the per-slot pos validity, and the returned
    logits are taken at position lengths[b]-1.  Ragged prompts require
    per-slot positions and are rejected for SSM/hybrid stacks (the
    recurrent state would absorb the padding) and for prompts longer
    than a sliding-window ring (the ring reorder is batch-uniform).

    Exactness: prefill ≡ P stepped ``decode_step`` calls up to float
    error, except through capacity-limited MoE layers — prefill routes
    all B·P prompt tokens against the expert capacity at once while the
    stepped path routes one token per row at a time, so *which* tokens
    a saturated expert drops can differ (inherent to capacity routing,
    not a cache defect: the mixer caches themselves stay step-exact).
    """
    segs = find_segments(layer_plan(cfg))
    B, P = tokens.shape
    kinds = layer_plan(cfg)
    per_slot = jnp.ndim(cache["pos"]) == 1
    if lengths is not None:
        if not per_slot:
            raise ValueError("ragged prefill needs a per-slot pos cache "
                             "(init_cache(..., per_slot_pos=True))")
        if any(k[0] == "mamba" for k in kinds):
            raise ValueError("ragged prefill is not supported for SSM/hybrid "
                             "stacks: the recurrent state would absorb the "
                             "padding tokens")
        ring = min(P, cfg.sliding_window) if cfg.sliding_window else P
        if cfg.sliding_window and P > ring:
            raise ValueError("ragged prefill cannot exceed the sliding-window "
                             "ring; trim prompts to the window")
    x = embed_apply(params["embed"], tokens)
    new_cache: dict = {}
    for si, (pattern, repeats) in enumerate(segs):
        def body(h, slices):
            p_slice, c_slice = slices
            new_c = {}
            for i, kind in enumerate(pattern):
                h, nc = _apply_sublayer_prefill(p_slice[f"sub{i}"],
                                                c_slice[f"sub{i}"], cfg, kind, h)
                new_c[f"sub{i}"] = nc
            return h, new_c
        x, seg_cache = scan(body, x, (params[f"seg{si}"], cache[f"seg{si}"]))
        new_cache[f"seg{si}"] = seg_cache
    h = rmsnorm(params["final_norm"], x, cfg.rms_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = _mask_pad(unembed_apply(table, h), cfg)          # (B, P, V)
    if lengths is None:
        last = logits[:, -1]
        new_cache["pos"] = (jnp.full((B,), P, jnp.int32) if per_slot
                            else jnp.asarray(P, jnp.int32))
    else:
        lengths = jnp.asarray(lengths, jnp.int32)
        last = jnp.take_along_axis(
            logits, (lengths - 1)[:, None, None], axis=1)[:, 0]
        new_cache["pos"] = lengths
    return last, new_cache


def decode_step(cfg: ArchConfig, params: dict, cache: dict,
                token: jnp.ndarray) -> Tuple[jnp.ndarray, dict]:
    """One decode step.  token: (B, 1) int32.  Returns (logits (B, V) f32,
    updated cache with pos advanced).

    ``cache["pos"]`` may be a scalar (whole batch in lockstep) or a
    per-slot (B,) vector; vector rows with pos < 0 are empty serving
    slots — their position does not advance and their logits are
    garbage the caller must mask."""
    segs = find_segments(layer_plan(cfg))
    pos = cache["pos"]
    x = embed_apply(params["embed"], token)
    new_pos = pos + 1 if jnp.ndim(pos) == 0 else jnp.where(pos >= 0, pos + 1, pos)
    new_cache: dict = {"pos": new_pos}
    for si, (pattern, repeats) in enumerate(segs):
        def body(h, slices):
            p_slice, c_slice = slices
            new_c = {}
            for i, kind in enumerate(pattern):
                h, nc = _apply_sublayer_decode(p_slice[f"sub{i}"],
                                               c_slice[f"sub{i}"], cfg, kind,
                                               h, pos)
                new_c[f"sub{i}"] = nc
            return h, new_c
        x, seg_cache = scan(body, x, (params[f"seg{si}"], cache[f"seg{si}"]))
        new_cache[f"seg{si}"] = seg_cache
    h = rmsnorm(params["final_norm"], x, cfg.rms_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = _mask_pad(unembed_apply(table, h), cfg)[:, 0]
    return logits, new_cache
