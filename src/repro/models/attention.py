"""Attention: GQA (full-causal, sliding-window, qk-norm), blockwise
memory-bounded prefill, single-token decode against a KV cache, and
cross-attention for the enc-dec (audio) family.

The train/prefill path is *blockwise* (double ``lax.scan`` over query and
KV chunks with online softmax) so peak activation memory is
O(chunk²·heads) instead of O(seq²·heads) — this is what lets the 32k
prefill dry-run fit a v5e HBM budget without a fused kernel, and it is
the exact algorithm our Pallas ``flash_decode`` kernel implements for the
serving hot path.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import apply_rope, dense_init, matmul, rmsnorm, rmsnorm_init

NEG_INF = -1e30


def _scan(body, init, xs):
    from . import model as _m
    n = jax.tree.leaves(xs)[0].shape[0]
    return jax.lax.scan(body, init, xs, unroll=n if _m.SCAN_UNROLL else 1)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def gqa_init(key, d_model: int, num_heads: int, num_kv_heads: int,
             head_dim: int, qk_norm: bool = False, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, num_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, num_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, num_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], num_heads * head_dim, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = rmsnorm_init(head_dim, dtype)
        p["k_norm"] = rmsnorm_init(head_dim, dtype)
    return p


def _project_qkv(p: dict, x: jnp.ndarray, num_heads: int, num_kv_heads: int,
                 head_dim: int, positions: jnp.ndarray, rope_theta: float,
                 rms_eps: float) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    B, S, _ = x.shape
    q = matmul(x, p["wq"]).reshape(B, S, num_heads, head_dim)
    k = matmul(x, p["wk"]).reshape(B, S, num_kv_heads, head_dim)
    v = matmul(x, p["wv"]).reshape(B, S, num_kv_heads, head_dim)
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q, rms_eps)
        k = rmsnorm(p["k_norm"], k, rms_eps)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


# --------------------------------------------------------------------------
# Blockwise causal attention (train / prefill)
# --------------------------------------------------------------------------

# dry-run depth probes override the block size so fully-unrolled probe
# modules stay a tractable number of blocks (FLOPs are chunk-invariant)
CHUNK_OVERRIDE: Optional[int] = None


def _pick_chunk(seq: int, preferred: int = 1024) -> int:
    c = min(seq, CHUNK_OVERRIDE or preferred)
    while seq % c:
        c //= 2
    return max(c, 1)


def _blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         window: Optional[int] = None,
                         chunk: Optional[int] = None,
                         causal: bool = True) -> jnp.ndarray:
    """Causal (optionally sliding-window) GQA attention.

    q: (B, S, Hq, hd); k, v: (B, S, Hkv, hd) with Hq % Hkv == 0.
    Returns (B, S, Hq, hd).  Peak memory O(B · Hq · chunk²).
    """
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    C = chunk or _pick_chunk(S)
    nq = S // C
    scale = hd ** -0.5

    # (nq, B, C, Hkv, G, hd) chunked views
    qc = q.reshape(B, nq, C, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(B, nq, C, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nq, C, Hkv, hd).transpose(1, 0, 2, 3, 4)
    pos = jnp.arange(S, dtype=jnp.int32).reshape(nq, C)

    def q_block(_, qi):
        qb, qpos, iq = qi                       # (B,C,Hkv,G,hd), (C,), scalar
        m0 = jnp.full((B, C, Hkv, G), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, C, Hkv, G), jnp.float32)
        a0 = jnp.zeros((B, C, Hkv, G, hd), jnp.float32)

        def kv_block(carry, kj):
            m, l, acc = carry
            kb, vb, kpos = kj                   # (B,C,Hkv,hd), (C,)
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            if causal:
                mask = kpos[None, :] <= qpos[:, None]
            else:
                mask = jnp.ones((C, C), bool)
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = _scan(kv_block, (m0, l0, a0), (kc, vc, pos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out

    _, out = _scan(q_block, None,
                          (qc, pos, jnp.arange(nq, dtype=jnp.int32)))
    # (nq, B, C, Hkv, G, hd) -> (B, S, Hq, hd)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, Hq, hd)
    return out.astype(q.dtype)


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        window: Optional[int] = None,
                        chunk: Optional[int] = None,
                        causal: bool = True) -> jnp.ndarray:
    """Flash-attention memory behavior: never save the O(S·chunk) score
    blocks for backward — recompute the blockwise pass from (q, k, v)."""
    import functools
    inner = functools.partial(_blockwise_attention, window=window,
                              chunk=chunk, causal=causal)
    inner = jax.checkpoint(inner, policy=jax.checkpoint_policies.nothing_saveable)
    return inner(q, k, v)


def gqa_apply(p: dict, x: jnp.ndarray, *, num_heads: int, num_kv_heads: int,
              head_dim: int, rope_theta: float, rms_eps: float = 1e-5,
              window: Optional[int] = None, causal: bool = True,
              positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full train/prefill GQA self-attention block body (no residual)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)[None, :].repeat(B, 0)
    q, k, v = _project_qkv(p, x, num_heads, num_kv_heads, head_dim,
                           positions, rope_theta, rms_eps)
    out = blockwise_attention(q, k, v, window=window, causal=causal)
    return matmul(out.reshape(B, S, num_heads * head_dim), p["wo"])


# --------------------------------------------------------------------------
# Decode: one token against a (possibly ring-buffered) KV cache
# --------------------------------------------------------------------------

# When True, gqa_decode dispatches cache attention (window=None path) to
# the Pallas flash_decode kernel instead of the pure-jnp oracle — the
# serving plane's --kernel flag.  Trace-time knob: flip it before the
# decode step is jitted.  Off by default (on CPU the kernel runs in
# interpret mode: correct but slow).
DECODE_KERNEL = False


def init_kv_cache(batch: int, cache_len: int, num_kv_heads: int,
                  head_dim: int, dtype=jnp.float32) -> dict:
    return {
        "k": jnp.zeros((batch, cache_len, num_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, cache_len, num_kv_heads, head_dim), dtype),
    }


def _check_cache_overflow(pos, cache_len: int) -> None:
    """Raise when a concrete prefix-cache position is past the end.

    The old path silently let ``dynamic_update_slice`` clamp the write
    to the last slot, overwriting whatever was there — a wrong-answer
    bug, not an error.  ``pos`` is only checkable when concrete (eager
    decode, host-driven loops); under jit the serving plane guards
    host-side (:class:`repro.runtime.serving.ServeLoop` tracks per-slot
    positions) because a traced value cannot raise.  Ring-buffer reuse
    is the *windowed* path — prefix caches never wrap."""
    if isinstance(pos, jax.core.Tracer):
        return
    p = np.asarray(pos)
    if p.size and int(p.max()) >= cache_len:
        raise ValueError(
            f"decode position {int(p.max())} overflows the {cache_len}-slot "
            f"prefix KV cache; grow cache_len (or use a sliding window — "
            f"ring-buffer reuse is the windowed path)")


def _positions_vector(pos, batch: int) -> jnp.ndarray:
    """Normalize scalar-or-(B,) ``pos`` to a (B,) int32 vector."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim > 1 or (pos.ndim == 1 and pos.shape[0] != batch):
        raise ValueError(
            f"pos must be a scalar or a ({batch},) per-slot vector, got "
            f"shape {pos.shape}")
    return jnp.broadcast_to(pos.reshape(-1), (batch,))


def gqa_decode(p: dict, x: jnp.ndarray, cache: dict, pos: jnp.ndarray, *,
               num_heads: int, num_kv_heads: int, head_dim: int,
               rope_theta: float, rms_eps: float = 1e-5,
               window: Optional[int] = None) -> Tuple[jnp.ndarray, dict]:
    """One-token decode.  x: (B, 1, D); ``pos``: scalar int32 absolute
    position shared by the batch, or a per-slot (B,) vector (continuous
    batching: every request sits at its own depth; rows with pos < 0
    are empty slots — nothing valid, zero attention output, and the
    row's write lands harmlessly inside its own dead cache row).  The
    cache holds ``cache_len`` slots; with a sliding window the cache is
    a ring buffer of exactly ``window`` slots.  Without a window a
    concrete pos >= cache_len raises instead of silently overwriting
    the last slot.  Returns (attn_out (B,1,D), new_cache).
    """
    B = x.shape[0]
    cache_len = cache["k"].shape[1]
    if window is None:
        _check_cache_overflow(pos, cache_len)
    pos = jnp.asarray(pos, jnp.int32)
    pos_vec = _positions_vector(pos, B)
    q, k, v = _project_qkv(p, x, num_heads, num_kv_heads, head_dim,
                           pos_vec[:, None], rope_theta, rms_eps)
    kd, vd = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
    if pos.ndim == 0:
        # legacy whole-batch position: one slice write for all rows
        slot = pos % cache_len if window is not None else pos
        ck = jax.lax.dynamic_update_slice(cache["k"], kd, (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], vd, (0, slot, 0, 0))
    else:
        slot = (pos_vec % cache_len if window is not None
                else jnp.clip(pos_vec, 0, cache_len - 1))
        write = jax.vmap(
            lambda c, u, s: jax.lax.dynamic_update_slice(c, u, (s, 0, 0)))
        ck = write(cache["k"], kd, slot)
        cv = write(cache["v"], vd, slot)
    if DECODE_KERNEL and window is None:
        from ..kernels.flash_decode import flash_decode
        out = flash_decode(q[:, 0], ck, cv, pos)[:, None]
    else:
        out = cache_attention(q, ck, cv, pos, window=window)
    out = matmul(out.reshape(B, 1, num_heads * head_dim), p["wo"])
    return out, {"k": ck, "v": cv}


def cache_attention(q: jnp.ndarray, ck: jnp.ndarray, cv: jnp.ndarray,
                    pos: jnp.ndarray, window: Optional[int] = None) -> jnp.ndarray:
    """q: (B, 1, Hq, hd) vs cache (B, L, Hkv, hd) → (B, 1, Hq, hd).

    ``pos`` is a scalar or per-slot (B,) vector.  Validity: slot i
    holds absolute position i (no window) or is valid iff the ring
    buffer has written it within the last ``window`` steps; rows with
    pos < 0 are empty serving slots and return exactly zero (softmax
    multiplied by the row's validity — matching the kernel's masked
    online softmax).  This is the pure-jnp oracle of the Pallas
    ``flash_decode`` kernel.

    With ``layers.F32_DOT_OUTPUT`` (baseline) the cache is upcast to f32
    before the contractions — faithful to naive serving code, but it
    materializes (and reshards) a 2× copy of the whole cache every
    token.  The bf16c perf knob contracts directly against the bf16
    cache with f32 accumulation — the Pallas kernel's exact dataflow.
    """
    from .layers import F32_DOT_OUTPUT
    B, _, Hq, hd = q.shape
    L, Hkv = ck.shape[1], ck.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    if F32_DOT_OUTPUT:
        s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                       ck.astype(jnp.float32))
    else:
        s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(ck.dtype), ck,
                       preferred_element_type=jnp.float32)
    s = s * (hd ** -0.5)
    pos_vec = _positions_vector(pos, B)
    idx = jnp.arange(L, dtype=jnp.int32)
    if window is None:
        valid = idx[None, :] <= pos_vec[:, None]                     # (B, L)
    else:
        # ring buffer: all slots valid once pos+1 >= L; before that,
        # slots <= pos (empty rows pos < 0 stay all-invalid)
        valid = ((idx[None, :] <= pos_vec[:, None])
                 | (pos_vec[:, None] + 1 >= L))
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1) * valid[:, None, None, :]
    if F32_DOT_OUTPUT:
        out = jnp.einsum("bhgk,bkhd->bhgd", p, cv.astype(jnp.float32))
    else:
        out = jnp.einsum("bhgk,bkhd->bhgd", p.astype(cv.dtype), cv,
                         preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, hd).astype(q.dtype)


def gqa_prefill(p: dict, x: jnp.ndarray, cache: dict, *, num_heads: int,
                num_kv_heads: int, head_dim: int, rope_theta: float,
                rms_eps: float = 1e-5,
                window: Optional[int] = None) -> Tuple[jnp.ndarray, dict]:
    """Whole-prompt prefill: one batched pass over x (B, P, D) that
    writes every position's K/V into the cache and attends causally
    within the prompt — replacing P single-token ``gqa_decode``
    dispatches.  Fresh-cache semantics (positions 0..P-1).  With a
    sliding window whose ring is shorter than P, only the last
    ``cache_len`` positions are written, laid out at their ring slots
    (pos % cache_len) so subsequent ``gqa_decode`` steps continue the
    ring seamlessly.  Returns (attn_out (B,P,D), new_cache)."""
    B, P, _ = x.shape
    cache_len = cache["k"].shape[1]
    if window is None and P > cache_len:
        raise ValueError(
            f"prompt length {P} overflows the {cache_len}-slot prefix KV "
            f"cache")
    positions = jnp.broadcast_to(jnp.arange(P, dtype=jnp.int32)[None, :],
                                 (B, P))
    q, k, v = _project_qkv(p, x, num_heads, num_kv_heads, head_dim,
                           positions, rope_theta, rms_eps)
    kd, vd = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
    if P > cache_len:
        # ring layout of the last cache_len positions: slot s holds the
        # unique position in [P - cache_len, P) with pos % cache_len == s
        order = np.argsort(np.arange(P - cache_len, P) % cache_len)
        ck = kd[:, P - cache_len:][:, order]
        cv = vd[:, P - cache_len:][:, order]
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], kd, (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], vd, (0, 0, 0, 0))
    out = blockwise_attention(q, k, v, window=window, causal=True)
    out = matmul(out.reshape(B, P, num_heads * head_dim), p["wo"])
    return out, {"k": ck, "v": cv}


# --------------------------------------------------------------------------
# Cross-attention (enc-dec audio family)
# --------------------------------------------------------------------------

def cross_init(key, d_model: int, num_heads: int, num_kv_heads: int,
               head_dim: int, dtype=jnp.float32) -> dict:
    return gqa_init(key, d_model, num_heads, num_kv_heads, head_dim,
                    qk_norm=False, dtype=dtype)


def cross_apply(p: dict, x: jnp.ndarray, memory_kv: Tuple[jnp.ndarray, jnp.ndarray],
                *, num_heads: int, num_kv_heads: int, head_dim: int) -> jnp.ndarray:
    """Decoder cross-attention into precomputed encoder memory K/V.

    x: (B, S, D); memory k/v: (B, M, Hkv, hd).  No RoPE across modalities
    (positions are encoder-internal), no causal mask.
    """
    B, S, _ = x.shape
    mk, mv = memory_kv
    Hkv = mk.shape[2]
    G = num_heads // Hkv
    q = matmul(x, p["wq"]).reshape(B, S, Hkv, G, head_dim)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", q.astype(jnp.float32),
                   mk.astype(jnp.float32)) * (head_dim ** -0.5)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", w, mv.astype(jnp.float32))
    out = out.reshape(B, S, num_heads * head_dim).astype(x.dtype)
    return matmul(out, p["wo"])


def cross_memory(p: dict, enc_out: jnp.ndarray, *, num_kv_heads: int,
                 head_dim: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Precompute encoder memory K/V once per sequence (prefill/serve)."""
    B, M, _ = enc_out.shape
    k = matmul(enc_out, p["wk"]).reshape(B, M, num_kv_heads, head_dim)
    v = matmul(enc_out, p["wv"]).reshape(B, M, num_kv_heads, head_dim)
    return k, v
