"""The paper's client-side models (Table II) as DFL ``Task``s: an MLP for
MNIST-like digit classification, a CNN for CIFAR-like images, and an
LSTM for next-character prediction — all pure JAX, exposed through the
flat-parameter ``Task`` protocol the DFL engines drive.

The engines exchange *flat f32 vectors* (exactly what goes over the wire
in the real system), so each task owns a flatten/unflatten pair and
jit'd local-SGD steps.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..data.noniid import Partition
from ..data.synthetic import CharLMData, ClassificationData


def _flatten(tree) -> Tuple[np.ndarray, object]:
    leaves, treedef = jax.tree.flatten(tree)
    flat = np.concatenate([np.asarray(l, np.float32).ravel() for l in leaves])
    shapes = [l.shape for l in leaves]
    return flat, (treedef, shapes)


def _unflatten(flat: np.ndarray, spec) -> object:
    treedef, shapes = spec
    leaves, off = [], 0
    for s in shapes:
        n = int(np.prod(s)) if s else 1
        leaves.append(jnp.asarray(flat[off:off + n], jnp.float32).reshape(s))
        off += n
    return jax.tree.unflatten(treedef, leaves)


class _TaskBase:
    """Shared local-SGD plumbing over a flat parameter vector."""

    def __init__(self, data, partition: Partition, labels: np.ndarray,
                 lr: float, batch: int, local_steps: int):
        self.data = data
        self.partition = partition
        self._labels = np.asarray(labels)
        self.num_clients = len(partition.client_indices)
        self.lr = lr
        self.batch = batch
        self.local_steps = local_steps
        self._spec = None

    # -- Task protocol -----------------------------------------------------
    def init_params(self, seed: int) -> np.ndarray:
        tree = self._init_tree(jax.random.PRNGKey(seed))
        flat, self._spec = _flatten(tree)
        return flat

    def label_histogram(self, client: int) -> np.ndarray:
        return self.partition.label_histogram(self._labels, client)

    def train_cost(self, client: int) -> float:
        return float(len(self.partition.client_indices[client]))

    def local_train(self, params: np.ndarray, client: int, seed: int) -> np.ndarray:
        tree = _unflatten(params, self._spec)
        idx = self.partition.client_indices[client]
        rng = np.random.default_rng(seed)
        for _ in range(self.local_steps):
            take = rng.choice(idx, size=min(self.batch, len(idx)), replace=False)
            tree = self._sgd_step(tree, *self._batch_of(take))
        flat, _ = _flatten(tree)
        return flat

    def evaluate(self, params: np.ndarray) -> float:
        tree = _unflatten(params, self._spec)
        return float(self._accuracy(tree))


# --------------------------------------------------------------------------
# MLP on MNIST-like (paper: 247 KB model)
# --------------------------------------------------------------------------

class MLPTask(_TaskBase):
    def __init__(self, data: ClassificationData, partition: Partition,
                 hidden: int = 64, lr: float = 0.1, batch: int = 32,
                 local_steps: int = 4):
        super().__init__(data, partition, data.y_train, lr, batch, local_steps)
        self.hidden = hidden
        self.d_in = data.x_train.shape[1]
        self.k = data.num_classes
        self._xtr = jnp.asarray(data.x_train)
        self._ytr = jnp.asarray(data.y_train)
        self._xte = jnp.asarray(data.x_test)
        self._yte = jnp.asarray(data.y_test)

        @jax.jit
        def step(tree, x, y):
            def loss(t):
                h = jax.nn.relu(x @ t["w1"] + t["b1"])
                logits = h @ t["w2"] + t["b2"]
                return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(len(y)), y])
            g = jax.grad(loss)(tree)
            return jax.tree.map(lambda p, gg: p - self.lr * gg, tree, g)

        @jax.jit
        def acc(tree):
            h = jax.nn.relu(self._xte @ tree["w1"] + tree["b1"])
            return jnp.mean(jnp.argmax(h @ tree["w2"] + tree["b2"], -1) == self._yte)

        self._step, self._acc = step, acc

    def _init_tree(self, key):
        k1, k2 = jax.random.split(key)
        return {
            "w1": jax.random.normal(k1, (self.d_in, self.hidden)) * (1 / np.sqrt(self.d_in)),
            "b1": jnp.zeros(self.hidden),
            "w2": jax.random.normal(k2, (self.hidden, self.k)) * (1 / np.sqrt(self.hidden)),
            "b2": jnp.zeros(self.k),
        }

    def _batch_of(self, idx):
        return self._xtr[idx], self._ytr[idx]

    def _sgd_step(self, tree, x, y):
        return self._step(tree, x, y)

    def _accuracy(self, tree):
        return self._acc(tree)


# --------------------------------------------------------------------------
# CNN on CIFAR-like
# --------------------------------------------------------------------------

class CNNTask(_TaskBase):
    def __init__(self, data: ClassificationData, partition: Partition,
                 channels: int = 16, lr: float = 0.05, batch: int = 32,
                 local_steps: int = 4):
        super().__init__(data, partition, data.y_train, lr, batch, local_steps)
        self.ch = channels
        self.k = data.num_classes
        h = data.x_train.shape[1]
        self.d_flat = (h // 4) * (h // 4) * (2 * channels)
        self._xtr = jnp.asarray(data.x_train)
        self._ytr = jnp.asarray(data.y_train)
        self._xte = jnp.asarray(data.x_test)
        self._yte = jnp.asarray(data.y_test)

        def fwd(t, x):
            x = jax.lax.conv_general_dilated(
                x, t["c1"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jax.nn.relu(x + t["b1"])
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
            x = jax.lax.conv_general_dilated(
                x, t["c2"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jax.nn.relu(x + t["b2"])
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
            x = x.reshape(x.shape[0], -1)
            return x @ t["w"] + t["b"]

        @jax.jit
        def step(tree, x, y):
            def loss(t):
                logits = fwd(t, x)
                return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(len(y)), y])
            g = jax.grad(loss)(tree)
            return jax.tree.map(lambda p, gg: p - self.lr * gg, tree, g)

        @jax.jit
        def acc(tree):
            return jnp.mean(jnp.argmax(fwd(tree, self._xte), -1) == self._yte)

        self._step, self._acc = step, acc

    def _init_tree(self, key):
        ks = jax.random.split(key, 3)
        c = self.ch
        return {
            "c1": jax.random.normal(ks[0], (3, 3, 3, c)) * 0.1,
            "b1": jnp.zeros(c),
            "c2": jax.random.normal(ks[1], (3, 3, c, 2 * c)) * 0.1,
            "b2": jnp.zeros(2 * c),
            "w": jax.random.normal(ks[2], (self.d_flat, self.k)) * (1 / np.sqrt(self.d_flat)),
            "b": jnp.zeros(self.k),
        }

    def _batch_of(self, idx):
        return self._xtr[idx], self._ytr[idx]

    def _sgd_step(self, tree, x, y):
        return self._step(tree, x, y)

    def _accuracy(self, tree):
        return self._acc(tree)


# --------------------------------------------------------------------------
# LSTM on Shakespeare-like role streams
# --------------------------------------------------------------------------

class LSTMTask(_TaskBase):
    """Next-character prediction; each client = one (or more) role streams."""

    def __init__(self, data: CharLMData, num_clients: int, hidden: int = 64,
                 seq: int = 32, lr: float = 0.5, batch: int = 16,
                 local_steps: int = 4):
        roles = data.role_streams.shape[0]
        assign = [list(range(c, roles, num_clients)) for c in range(num_clients)]
        part = Partition(client_indices=[np.array(a) for a in assign],
                         num_classes=10)
        super().__init__(data, part, data.role_labels, lr, batch, local_steps)
        self.v = data.vocab_size
        self.hd = hidden
        self.seq = seq
        self._streams = jnp.asarray(data.role_streams)
        self._test = jnp.asarray(data.test_stream)

        def fwd_loss(t, x, y):
            emb = t["emb"][x]                       # (b, s, e)
            B = x.shape[0]
            h0 = jnp.zeros((B, self.hd))
            c0 = jnp.zeros((B, self.hd))

            def cell(carry, e_t):
                h, c = carry
                z = e_t @ t["wx"] + h @ t["wh"] + t["b"]
                i, f, g, o = jnp.split(z, 4, axis=-1)
                c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
                h = jax.nn.sigmoid(o) * jnp.tanh(c)
                return (h, c), h

            (_, _), hs = jax.lax.scan(cell, (h0, c0), emb.transpose(1, 0, 2))
            logits = hs.transpose(1, 0, 2) @ t["wo"] + t["bo"]   # (b, s, v)
            logp = jax.nn.log_softmax(logits)
            gold = jnp.take_along_axis(logp, y[..., None], -1)[..., 0]
            return -jnp.mean(gold), logits

        @jax.jit
        def step(tree, x, y):
            g = jax.grad(lambda t: fwd_loss(t, x, y)[0])(tree)
            return jax.tree.map(lambda p, gg: p - self.lr * gg, tree, g)

        @jax.jit
        def acc(tree):
            n = (self._test.shape[0] - 1) // self.seq
            x = self._test[:n * self.seq].reshape(n, self.seq)
            y = self._test[1:n * self.seq + 1].reshape(n, self.seq)
            _, logits = fwd_loss(tree, x, y)
            return jnp.mean(jnp.argmax(logits, -1) == y)

        self._step, self._acc = step, acc

    def _init_tree(self, key):
        ks = jax.random.split(key, 4)
        e = 32
        return {
            "emb": jax.random.normal(ks[0], (self.v, e)) * 0.1,
            "wx": jax.random.normal(ks[1], (e, 4 * self.hd)) * (1 / np.sqrt(e)),
            "wh": jax.random.normal(ks[2], (self.hd, 4 * self.hd)) * (1 / np.sqrt(self.hd)),
            "b": jnp.zeros(4 * self.hd),
            "wo": jax.random.normal(ks[3], (self.hd, self.v)) * (1 / np.sqrt(self.hd)),
            "bo": jnp.zeros(self.v),
        }

    def _batch_of(self, roles):
        rng = np.random.default_rng(int(np.sum(roles)) + 1)
        stream_len = self._streams.shape[1]
        xs, ys = [], []
        for _ in range(self.batch):
            r = int(rng.choice(roles))
            t0 = int(rng.integers(0, stream_len - self.seq - 1))
            xs.append(self._streams[r, t0:t0 + self.seq])
            ys.append(self._streams[r, t0 + 1:t0 + self.seq + 1])
        return jnp.stack(xs), jnp.stack(ys)

    def _sgd_step(self, tree, x, y):
        return self._step(tree, x, y)

    def _accuracy(self, tree):
        return self._acc(tree)
