"""Mamba2 SSD — state-space duality, chunked dual form (arXiv:2405.21060).

Train/prefill uses the chunked SSD algorithm: intra-chunk attention-like
matmuls (MXU-friendly) + an inter-chunk state recurrence carried by
``lax.scan`` — O(S·Q) memory instead of O(S²).  Decode is the O(1)
recurrent step on a (B, H, P, N) state, which is what makes the
``long_500k`` shape native for the SSM and hybrid architectures.

This pure-jnp implementation is also the oracle for the Pallas
``ssd_scan`` kernel.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .config import SSMConfig
from .layers import dense_init, matmul, rmsnorm, rmsnorm_init


def mamba_init(key, d_model: int, s: SSMConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    di = s.d_inner(d_model)
    nh = s.nheads(d_model)
    conv_ch = di + 2 * s.d_state
    return {
        # in_proj → [z (di), x (di), B (N), C (N), dt (nh)]
        "in_proj": dense_init(ks[0], d_model, 2 * di + 2 * s.d_state + nh, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, conv_ch), jnp.float32)
                   * (1.0 / s.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": rmsnorm_init(di, dtype),
        "out_proj": dense_init(ks[3], di, d_model, dtype),
    }


def _split_proj(proj: jnp.ndarray, di: int, n: int, nh: int):
    z = proj[..., :di]
    x = proj[..., di:2 * di]
    Bm = proj[..., 2 * di:2 * di + n]
    Cm = proj[..., 2 * di + n:2 * di + 2 * n]
    dt = proj[..., 2 * di + 2 * n:]
    assert dt.shape[-1] == nh
    return z, x, Bm, Cm, dt


def causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                init: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv over (B, S, Ch) with taps (K, Ch)."""
    K = w.shape[0]
    pad = xbc if init is not None else jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    if init is not None:
        pad = jnp.concatenate([init, xbc], axis=1)
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return jax.nn.silu(out + b[None, None, :])


def _ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                 Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int,
                 init_state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD.

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); A: (H,) negative;
    Bm, Cm: (B, S, N) single-group.  Returns (y (B,S,H,P), final state
    (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q

    dA = dt * A[None, None, :]                       # (B,S,H) log-decay
    xdt = x * dt[..., None]                          # dt-weighted input
    # chunked views
    dAc = dA.reshape(Bsz, nc, Q, H)
    xc = xdt.reshape(Bsz, nc, Q, H, P)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)

    cs = jnp.cumsum(dAc, axis=2)                     # (B,nc,Q,H) inclusive
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]   # (B,nc,Qi,Qj,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    # mask BEFORE exp: upper-triangular entries have large positive
    # exponents whose inf would poison gradients through jnp.where.
    seg = jnp.where(causal[None, None, :, :, None], seg, -jnp.inf)
    L = jnp.exp(seg)

    # intra-chunk: y_ij = (C_i·B_j)·L_ij·xdt_j
    cb = jnp.einsum("bcin,bcjn->bcij", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))
    y_diag = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, L,
                        xc.astype(jnp.float32))

    # per-chunk end state: S_c = Σ_j exp(cs_end - cs_j)·B_j ⊗ xdt_j
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)    # (B,nc,Q,H)
    chunk_state = jnp.einsum("bcjn,bcjh,bcjhp->bchpn",
                             Bc.astype(jnp.float32), decay_to_end,
                             xc.astype(jnp.float32))
    chunk_decay = jnp.exp(cs[:, :, -1, :])           # (B,nc,H) total decay

    s0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(state, inputs):
        st_c, dec_c = inputs                         # (B,H,P,N), (B,H)
        prev = state
        new = prev * dec_c[:, :, None, None] + st_c
        return new, prev

    from . import model as _m
    final, prev_states = jax.lax.scan(
        step, s0, (chunk_state.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)),
        unroll=nc if _m.SCAN_UNROLL else 1)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # inter-chunk: y_i += C_i · prev_state · exp(cs_i)
    y_off = jnp.einsum("bcin,bcih,bchpn->bcihp", Cc.astype(jnp.float32),
                       jnp.exp(cs), prev_states)
    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), final


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Flash-style memory: the O(Q²) intra-chunk decay matrices are
    recomputed in the backward pass, never saved."""
    import functools
    inner = functools.partial(_ssd_chunked, chunk=chunk)
    inner = jax.checkpoint(inner, policy=jax.checkpoint_policies.nothing_saveable)
    if init_state is None:
        return inner(x, dt, A, Bm, Cm)
    return inner(x, dt, A, Bm, Cm, init_state=init_state)


def mamba_apply(p: dict, xin: jnp.ndarray, s: SSMConfig,
                rms_eps: float = 1e-5) -> jnp.ndarray:
    """Full Mamba2 block body (no residual).  xin: (B, S, D)."""
    Bsz, S, D = xin.shape
    di = s.d_inner(D)
    nh = s.nheads(D)
    proj = matmul(xin, p["in_proj"])
    z, x, Bm, Cm, dt = _split_proj(proj, di, s.d_state, nh)
    xbc = causal_conv(jnp.concatenate([x, Bm, Cm], axis=-1),
                      p["conv_w"], p["conv_b"])
    x, Bm, Cm = xbc[..., :di], xbc[..., di:di + s.d_state], xbc[..., di + s.d_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = x.reshape(Bsz, S, nh, s.headdim)
    y, _ = ssd_chunked(xh, dt, A, Bm, Cm, s.chunk)
    y = (y + p["D"][None, None, :, None] * xh).astype(xin.dtype)
    y = y.reshape(Bsz, S, di)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), rms_eps)
    return matmul(y, p["out_proj"])


# --------------------------------------------------------------------------
# O(1) decode step
# --------------------------------------------------------------------------

def init_ssm_cache(batch: int, d_model: int, s: SSMConfig,
                   dtype=jnp.float32) -> dict:
    di = s.d_inner(d_model)
    nh = s.nheads(d_model)
    return {
        "state": jnp.zeros((batch, nh, s.headdim, s.d_state), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, di + 2 * s.d_state), dtype),
    }


def mamba_prefill(p: dict, xin: jnp.ndarray, cache: dict, s: SSMConfig,
                  rms_eps: float = 1e-5) -> Tuple[jnp.ndarray, dict]:
    """Whole-prompt Mamba2 prefill: one chunked-SSD pass over xin
    (B, P, D) that also captures the recurrent state after the last
    token and the conv tail (the last d_conv-1 *pre-activation* conv
    channels) — the exact cache ``mamba_decode`` expects, replacing P
    recurrent single-token dispatches.  Fresh-cache semantics (the
    incoming cache must be zeros).  Returns (out (B,P,D), new_cache)."""
    Bsz, S, D = xin.shape
    di = s.d_inner(D)
    nh = s.nheads(D)
    proj = matmul(xin, p["in_proj"])
    z, x, Bm, Cm, dt = _split_proj(proj, di, s.d_state, nh)
    xbc_raw = jnp.concatenate([x, Bm, Cm], axis=-1)          # (B,S,ch)
    xbc = causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    x, Bm, Cm = xbc[..., :di], xbc[..., di:di + s.d_state], xbc[..., di + s.d_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = x.reshape(Bsz, S, nh, s.headdim)
    y, final = ssd_chunked(xh, dt, A, Bm, Cm, s.chunk)
    y = (y + p["D"][None, None, :, None] * xh).astype(xin.dtype)
    y = y.reshape(Bsz, S, di)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), rms_eps)
    # conv tail: last d_conv-1 raw (pre-silu) rows, zero-padded on the
    # left exactly as the causal conv saw them
    K = p["conv_w"].shape[0]
    padded = jnp.concatenate(
        [jnp.zeros((Bsz, K - 1, xbc_raw.shape[-1]), xbc_raw.dtype), xbc_raw],
        axis=1)
    new_cache = {"state": final, "conv": padded[:, -(K - 1):, :]
                 .astype(cache["conv"].dtype)}
    return matmul(y, p["out_proj"]), new_cache


def mamba_decode(p: dict, xin: jnp.ndarray, cache: dict, s: SSMConfig,
                 rms_eps: float = 1e-5) -> Tuple[jnp.ndarray, dict]:
    """One-token recurrent step.  xin: (B, 1, D)."""
    Bsz, _, D = xin.shape
    di = s.d_inner(D)
    nh = s.nheads(D)
    proj = matmul(xin, p["in_proj"])
    z, x, Bm, Cm, dt = _split_proj(proj, di, s.d_state, nh)
    xbc_new = jnp.concatenate([x, Bm, Cm], axis=-1)      # (B,1,ch)
    conv_win = jnp.concatenate([cache["conv"], xbc_new], axis=1)  # (B,K,ch)
    out = jnp.einsum("bkc,kc->bc", conv_win, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(out)[:, None, :]
    x, Bm, Cm = xbc[..., :di], xbc[..., di:di + s.d_state], xbc[..., di + s.d_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]   # (B,H)
    A = -jnp.exp(p["A_log"])
    xh = x.reshape(Bsz, nh, s.headdim).astype(jnp.float32)
    dA = jnp.exp(dt * A[None, :])                        # (B,H)
    dBx = jnp.einsum("bhp,bn,bh->bhpn", xh, Bm[:, 0].astype(jnp.float32), dt)
    state = cache["state"] * dA[:, :, None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", state, Cm[:, 0].astype(jnp.float32))
    y = (y + p["D"][None, :, None] * xh).astype(xin.dtype)
    y = y.reshape(Bsz, 1, di)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), rms_eps)
    new_cache = {"state": state, "conv": conv_win[:, 1:, :]}
    return matmul(y, p["out_proj"]), new_cache
