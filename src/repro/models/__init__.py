# Model zoo: one config-driven LanguageModel covering all six assigned
# architecture families, plus the paper's own small client models.

from .config import (ArchConfig, HybridConfig, InputShape, INPUT_SHAPES,
                     MLAConfig, MoEConfig, SSMConfig, reduce_for_smoke)
from .model import (decode_step, forward, init_cache, init_params,
                    prefill, train_loss)

__all__ = [
    "ArchConfig", "HybridConfig", "InputShape", "INPUT_SHAPES",
    "MLAConfig", "MoEConfig", "SSMConfig", "reduce_for_smoke",
    "decode_step", "forward", "init_cache", "init_params", "prefill",
    "train_loss",
]
