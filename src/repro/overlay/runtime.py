"""Training under live churn: the controller driving the device data plane.

:class:`ChurnTrainLoop` runs :func:`repro.launch.steps.dfl_train_bundle`
(with ``sync="none"`` — the pure per-client local step, vmapped over the
leading client axis and therefore shape-polymorphic in the number of
clients) and applies the :class:`~repro.overlay.controller
.OverlayController`'s hot-swapped compiled mixer between steps.  The
split is the paper's deployment story: the local step compiles once per
alive-set size, the mixer recompiles only on topology change (and the
schedule-keyed cache makes revisited topologies free).

Membership changes remap state by *node identity*, not device slot:

* survivors carry their parameter/optimizer rows (and their data shard —
  batches are drawn from node-id-keyed streams) to their new slot;
* joiners are initialized from their highest-confidence live neighbor's
  model (:func:`joiner_donors`, the paper's Fig. 18 catch-up mechanism)
  with fresh optimizer state;
* leavers' rows are dropped.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.mixing import PermuteSchedule
from .controller import ControlReport, OverlayController
from .events import ChurnTrace


def joiner_donors(sched: PermuteSchedule, alive: Sequence[int],
                  joiners: Sequence[int],
                  survivors: Sequence[int]) -> Dict[int, Optional[int]]:
    """For each joiner, its highest-confidence *surviving* neighbor under
    the new schedule (paper Fig. 18: new nodes catch up by starting from
    a high-confidence existing model).  None when every neighbor is
    itself a joiner (fresh-init fallback)."""
    slot_of = {u: i for i, u in enumerate(alive)}
    survivor_set = set(survivors)
    out: Dict[int, Optional[int]] = {}
    for j in joiners:
        i = slot_of[j]
        best, best_w = None, 0.0
        for k in range(sched.num_slots):
            src = alive[sched.perms[k][i]]
            w = float(sched.weights[i, k])
            if src in survivor_set and w > best_w:
                best, best_w = src, w
        out[j] = best
    return out


@dataclasses.dataclass
class ChurnStepRecord:
    """One training step under the control plane."""

    step: int
    time: float
    num_alive: int
    loss: float
    swapped: bool
    cache_hit: bool
    joined: Tuple[int, ...]
    left: Tuple[int, ...]


class ChurnTrainLoop:
    """Drive a DFL train bundle under a scripted or stochastic churn trace.

    ``make_params(node_id)`` initializes one client's (unstacked) param
    tree; ``make_batch(node_ids, step)`` draws one stacked batch for the
    current alive set, keyed by node identity so survivors keep their
    shard across slot remaps.  ``local_step`` is the bundle's
    ``sync="none"`` step ``(params, opt_state, batch) -> (params,
    opt_state, metrics)``; the controller's mixer is applied to the
    params afterwards — the hot-swap seam.
    """

    def __init__(self, controller: OverlayController, *,
                 local_step: Callable,
                 make_params: Callable[[int], object],
                 optimizer,
                 make_batch: Callable[[Sequence[int], int], object],
                 step_time: float = 1.0,
                 jit_local_step: bool = True,
                 telemetry=None, ledger=None, trace_count=None):
        """``telemetry`` / ``ledger`` / ``trace_count`` opt into the
        :mod:`repro.obs` plane exactly as on
        :class:`repro.runtime.SlotTrainLoop` — note this loop re-stacks
        state per alive count, so its ledger shows a nonzero
        ``retrace_delta`` at every *new* alive count (the tax the slot
        runtime removes)."""
        import jax
        from ..runtime.loop import TraceCount, counting_jit

        self.controller = controller
        self.optimizer = optimizer
        self.make_params = make_params
        self.make_batch = make_batch
        self.step_time = step_time
        self._telemetry = telemetry
        self._ledger = ledger
        self.trace_count = (trace_count if trace_count is not None
                            else TraceCount())
        self._last_traces = 0
        # closed-form wire/payload bytes memo keyed on (strategy, L, n)
        self._bytes_cache: dict = {}
        if jit_local_step:
            self.local_step, self.trace_count = counting_jit(local_step)
        else:
            self.local_step = local_step
        self._jax = jax

        self.assignment: Tuple[int, ...] = controller.alive
        per_client = [make_params(u) for u in self.assignment]
        self.params = self._stack(per_client)
        self.opt_state = jax.vmap(optimizer.init)(self.params)
        self._row_elems = sum(
            int(np.prod(l.shape[1:], dtype=np.int64))
            for l in jax.tree.leaves(self.params))
        self.records: List[ChurnStepRecord] = []

    # ---- state surgery ---------------------------------------------------
    def _stack(self, trees):
        jnp = self._jax.numpy
        return self._jax.tree.map(lambda *ls: jnp.stack(ls), *trees)

    def _row(self, tree, i: int):
        return self._jax.tree.map(lambda l: l[i], tree)

    def client_params(self, node_id: int):
        """The (unstacked) current model of one live client."""
        return self._row(self.params, self.assignment.index(node_id))

    def _remap(self, report: ControlReport) -> Tuple[Tuple[int, ...],
                                                     Tuple[int, ...]]:
        """Re-stack params/opt rows for the new alive set."""
        jax = self._jax
        old = self.assignment
        new = report.alive
        old_slot = {u: i for i, u in enumerate(old)}
        new_set = set(new)
        survivors = [u for u in new if u in old_slot]
        joiners = [u for u in new if u not in old_slot]
        left = tuple(u for u in old if u not in new_set)
        donors = (joiner_donors(self.controller.schedule, new, joiners,
                                survivors) if joiners else {})

        param_rows, opt_rows = [], []
        for u in new:
            if u in old_slot:
                i = old_slot[u]
                param_rows.append(self._row(self.params, i))
                opt_rows.append(self._row(self.opt_state, i))
            else:
                donor = donors.get(u)
                if donor is not None:
                    p = self._row(self.params, old_slot[donor])
                else:
                    p = self.make_params(u)
                param_rows.append(p)
                opt_rows.append(self.optimizer.init(p))
        self.params = self._stack(param_rows)
        self.opt_state = self._stack(opt_rows)
        self.assignment = new
        return tuple(joiners), left

    # ---- telemetry -------------------------------------------------------
    def _record_round(self, ledger, step: int, report, loss: float,
                      joined, left) -> None:
        from ..dist.sync import sync_bytes_per_client
        ctl = self.controller
        n = len(self.assignment)
        key = (ctl.strategy, ctl.schedule.num_spaces, n)
        cached = self._bytes_cache.get(key)
        if cached is None:
            row_bytes = 4 * self._row_elems
            kwargs = dict(num_spaces=key[1],
                          clients_per_device=ctl.clients_per_device)
            wire = sync_bytes_per_client(ctl.strategy, row_bytes, n,
                                         codec=ctl.codec, **kwargs)
            payload = (sync_bytes_per_client(ctl.strategy, row_bytes, n,
                                             **kwargs)
                       if ctl.codec is not None else wire)
            cached = self._bytes_cache[key] = (wire, payload)
        wire, payload = cached
        traces = self.trace_count.traces
        delta, self._last_traces = traces - self._last_traces, traces
        ledger.record(
            round=step, time=report.time, loop="churn",
            num_alive=n, participating=n, loss=loss,
            wire_bytes_per_client=wire, payload_bytes_per_client=payload,
            retraces=self.trace_count.retraces, retrace_delta=delta,
            swapped=report.swapped, rebuilt=report.rebuilt,
            cache_hit=report.cache_hit, joined=joined, left=left,
            repair_ms=report.rebuild_ms, commit_ms=ctl.last_commit_ms)

    # ---- the loop --------------------------------------------------------
    def run(self, num_steps: int,
            trace: Optional[ChurnTrace] = None) -> List[ChurnStepRecord]:
        """``num_steps`` training steps, one control interval each.

        An explicit ``telemetry=``/``ledger=`` override on the loop is
        installed as the process bus/ledger for the duration of the run,
        so the controller's ``overlay.*`` counters land on the same
        bus."""
        import contextlib

        from ..obs import get_telemetry, telemetry
        from ..obs.rounds import get_round_ledger, round_ledger
        stack = contextlib.ExitStack()
        if self._telemetry is not None:
            stack.enter_context(telemetry(self._telemetry))
        if self._ledger is not None:
            stack.enter_context(round_ledger(self._ledger))
        with stack:
            return self._run(num_steps, trace,
                             get_telemetry, get_round_ledger)

    def _run(self, num_steps, trace,
             get_telemetry, get_round_ledger) -> List[ChurnStepRecord]:
        for step in range(num_steps):
            report = self.controller.step(self.step_time, trace=trace)
            # land any staged swap before touching state (no-op unless
            # the controller is double_buffered) — report.alive and the
            # mixer must describe the same epoch
            self.controller.commit()
            joined, left = ((), ())
            if report.alive != self.assignment:
                joined, left = self._remap(report)
            batch = self.make_batch(self.assignment, step)
            params, opt_state, metrics = self.local_step(
                self.params, self.opt_state, batch)
            # the hot-swap seam: whatever mixer the controller holds now
            self.params = self.controller.mixer(params)
            self.opt_state = opt_state
            loss = float(np.asarray(metrics["loss"]))
            self.records.append(ChurnStepRecord(
                step=step, time=report.time,
                num_alive=len(self.assignment),
                loss=loss,
                swapped=report.swapped, cache_hit=report.cache_hit,
                joined=joined, left=left))
            bus = (self._telemetry if self._telemetry is not None
                   else get_telemetry())
            if bus.enabled:
                bus.count("churn.steps")
                bus.gauge("churn.num_alive", len(self.assignment))
                if joined or left:
                    bus.count("churn.remaps")
            ledger = (self._ledger if self._ledger is not None
                      else get_round_ledger())
            if ledger is not None:
                self._record_round(ledger, step, report, loss, joined, left)
        return self.records
