"""``repro.overlay`` — the live churn control plane (paper §III-B).

Closes the loop the seed left open: :mod:`repro.core.ndmp` converges
neighbor tables host-side, :mod:`repro.dist.sync` compiles a frozen
table into device collectives — this package runs *between* training
steps to keep the two consistent while nodes join, leave, and fail:

* :mod:`repro.overlay.events` — churn traces (scripted / Poisson) and
  epoch-stamped neighbor-table deltas over the NDMP simulator;
* :mod:`repro.overlay.controller` — :class:`OverlayController`: delta →
  :func:`~repro.core.mixing.schedule_from_addresses` rebuild →
  hot-swapped compiled mixer behind a schedule-keyed
  :class:`MixerCache`;
* :mod:`repro.overlay.runtime` — :class:`ChurnTrainLoop`: the bundle's
  local step + the controller's mixer under a churn trace, with
  node-identity shard remapping and Fig.-18 joiner catch-up init.

The re-stack loop retraces the local step once per distinct alive
count; its static-shape sibling lives in :mod:`repro.runtime`
(:class:`~repro.runtime.SlotTrainLoop` over a capacity-mode
``OverlayController(capacity=C)`` — masked dead slots, zero retraces).
"""

from . import controller, events, runtime
from .controller import ControlReport, MixerCache, OverlayController
from .events import ChurnEvent, ChurnTrace, DeltaTracker, TableDelta
from .runtime import ChurnStepRecord, ChurnTrainLoop, joiner_donors

__all__ = [
    "controller", "events", "runtime",
    "ControlReport", "MixerCache", "OverlayController",
    "ChurnEvent", "ChurnTrace", "DeltaTracker", "TableDelta",
    "ChurnStepRecord", "ChurnTrainLoop", "joiner_donors",
]
