"""The live overlay controller: NDMP deltas → recompiled, hot-swapped mixers.

This is the host-side loop that makes the reproduction *practical* DFL
(paper §III-B deployment story): training proceeds on the compiled data
plane while NDMP maintains the overlay under churn; between training
steps the controller

1. advances the discrete-event simulator (and applies any scheduled
   churn events),
2. polls the :class:`~repro.overlay.events.DeltaTracker` for
   neighbor-table deltas,
3. on a delta, rebuilds the :class:`~repro.core.mixing.PermuteSchedule`
   for the current alive set
   (:func:`repro.core.mixing.schedule_from_addresses` over the live
   NDMP coordinates), and
4. hot-swaps the compiled mixer behind a schedule-keyed compile cache —
   an unchanged topology (or a revisited one) never retraces.

Two mixer kinds, matching the two device paths in
:mod:`repro.dist.sync`:

* ``"global"`` (default) — ``jax.jit(global_mixer("fedlay", sched))``,
  a ``params -> params`` program over the leading client axis (what
  :func:`repro.launch.steps.dfl_train_bundle` composes with);
* ``"shard_map"`` — the :func:`repro.dist.sync.make_mixer` shard_map
  body for callers that embed mixing in an explicit shard_map program.
  The cached callable has stable identity per schedule, so the caller's
  enclosing ``jax.jit`` also avoids retracing on cache hits.

**Grouped layout** (``clients_per_device = G > 1``): the client
population is ``G ×`` the device count, laid out block-contiguously
(client slot ``i`` → device ``i // G``, the ``(G, ...)`` per-device
contract of :mod:`repro.dist.sync`).  The group factor threads through
every layer the controller owns: shard_map mixer factories build
grouped programs, capacity mode requires ``capacity % G == 0`` so
padded schedules always map onto whole device groups, and the
:class:`MixerCache` needs no G in its keys — G is fixed per controller,
so the schedule digest alone still uniquely identifies a compiled
program.
"""

from __future__ import annotations

import dataclasses
import time as _time
from collections import OrderedDict
from typing import Callable, Dict, Iterable, Optional, Tuple

import numpy as np

from ..core.coords import NodeAddress
from ..core.mep import ClientProfile
from ..core.mixing import PermuteSchedule, schedule_from_addresses
from ..core.ndmp import SimulatorProtocol
from ..core.topology import Topology, fedlay_topology
from ..obs import get_telemetry
from .events import ChurnEvent, ChurnTrace, DeltaTracker, TableDelta

MIXER_KINDS = ("global", "shard_map")


class MixerCache:
    """Schedule-keyed LRU compile cache for mixers.

    Keys are ``(PermuteSchedule, fuse, codec)`` triples — schedules are
    hashable by perms+weights digest and codecs are frozen dataclasses,
    so two control epochs that converge to the same topology (including
    the common no-op delta) share one compiled program, while the same
    topology compiled for different mixing-round execution modes
    (``fuse=None`` tree walk vs ``fuse="flat"`` Pallas fused,
    :data:`repro.dist.sync.FUSE_MODES`) or different wire codecs
    (:mod:`repro.wire.codec`) never collides.
    ``maxsize`` bounds the pinned jit closures under sustained churn
    (fresh joiner ids mint a new schedule per membership change); the
    fail→rejoin zero-retrace win only needs the recent past.
    """

    def __init__(self, factory: Callable[[PermuteSchedule], Callable],
                 maxsize: int = 64):
        if maxsize < 1:
            raise ValueError("maxsize must be >= 1")
        self._factory = factory
        self._cache: "OrderedDict[Tuple, Callable]" = OrderedDict()
        self.maxsize = maxsize
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, sched: PermuteSchedule,
            fuse: Optional[str] = None,
            codec=None) -> Tuple[Callable, bool]:
        """(mixer, was_hit) for a (schedule, fuse mode, wire codec),
        compiling on first sight."""
        key = (sched, fuse, codec)
        mixer = self._cache.get(key)
        if mixer is not None:
            self.hits += 1
            self._cache.move_to_end(key)
            return mixer, True
        self.misses += 1
        mixer = self._factory(sched)
        self._cache[key] = mixer
        while len(self._cache) > self.maxsize:
            self._cache.popitem(last=False)
            self.evictions += 1
        return mixer, False

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def __len__(self) -> int:
        return len(self._cache)


def _global_mixer_factory(strategy: str = "fedlay", masked: bool = False,
                          fuse: Optional[str] = None, codec=None,
                          flat_io: bool = False):
    import jax
    from ..dist.sync import global_mixer

    def build(sched: PermuteSchedule) -> Callable:
        return jax.jit(global_mixer(strategy, sched, masked=masked,
                                    fuse=fuse, codec=codec,
                                    flat_io=flat_io))
    return build


def _shard_map_mixer_factory(axis_name: str, strategy: str = "fedlay",
                             clients_per_device: int = 1,
                             fuse: Optional[str] = None, codec=None):
    from ..dist.sync import make_mixer

    def build(sched: PermuteSchedule) -> Callable:
        return make_mixer(strategy, sched, axis_name, sched.num_clients,
                          clients_per_device=clients_per_device, fuse=fuse,
                          codec=codec)
    return build


@dataclasses.dataclass(frozen=True)
class _StagedSwap:
    """A fully built (but not yet live) data-plane state: what a control
    step produced, waiting for :meth:`OverlayController.commit` at the
    next step boundary."""

    alive: Tuple[int, ...]
    alive_schedule: PermuteSchedule
    schedule: PermuteSchedule            # == alive_schedule unless capacity
    mixer: Callable
    plan: Optional[object]               # RemapPlan in capacity mode


@dataclasses.dataclass(frozen=True)
class ControlReport:
    """What one control step did."""

    epoch: int                     # delta epoch after this step
    time: float                    # simulator clock after this step
    alive: Tuple[int, ...]         # slot order: sorted live node ids
    delta: TableDelta
    swapped: bool                  # a different mixer is now live
    rebuilt: bool                  # a schedule was (re)compiled host-side
    cache_hit: bool                # the mixer came out of the compile cache
    rebuild_ms: float              # host time spent building the schedule
    correctness: Optional[float] = None


class OverlayController:
    """Closes the loop between an NDMP engine (control plane) and the
    compiled mixer (data plane).

    The engine is anything satisfying
    :class:`repro.core.ndmp.SimulatorProtocol` — the exact discrete-event
    :class:`~repro.core.ndmp.Simulator` or the flat-array
    :class:`repro.scale.ndmp_vec.VectorSimulator`; the controller only
    consumes the delta API (alive_ids / neighbor_tables / tables_version
    / advance) plus the three membership calls.

    ``step(dt)`` advances NDMP by ``dt`` of simulated time, detects
    table deltas, and exposes the current compiled mixer via
    :attr:`mixer` (hot-swapped only when the topology actually changed).
    ``profiles_fn`` supplies MEP confidence profiles for an alive set;
    default: uniform profiles (simple ablation-style weights).  Profiles
    are assumed stable for a given alive set — schedules rebuild on
    membership change, not on profile drift.
    """

    def __init__(self, sim: SimulatorProtocol, *,
                 mixer_kind: str = "global",
                 strategy: str = "fedlay",
                 axis_name: str = "data",
                 alpha_d: float = 0.5, alpha_c: float = 0.5,
                 confidence_weighted: bool = True,
                 profiles_fn: Optional[
                     Callable[[Tuple[int, ...]],
                              Dict[int, ClientProfile]]] = None,
                 mixer_factory: Optional[
                     Callable[[PermuteSchedule], Callable]] = None,
                 cache_size: int = 64,
                 measure_correctness: bool = False,
                 capacity: Optional[int] = None,
                 double_buffered: bool = False,
                 clients_per_device: int = 1,
                 fuse: Optional[str] = None,
                 codec=None,
                 flat_io: bool = False,
                 repair_policy=None,
                 swap_barrier: Optional[Callable[[], None]] = None):
        """``capacity`` switches the controller into fixed-capacity slot
        mode (:mod:`repro.runtime`): it owns a
        :class:`~repro.runtime.slots.SlotMap`, pads every rebuilt
        schedule to ``capacity`` (dead slots self-loop with weight 1),
        and compiles **mask-aware** mixers ``(params, mask) -> params``
        so the data-plane shapes never change under churn.

        ``clients_per_device`` (G) declares the grouped data-plane
        layout: client slot ``i`` lives on device ``i // G``.  shard_map
        mixer factories compile grouped programs for it, and capacity
        mode requires ``capacity`` to be a multiple of G so the padded
        schedule always fills whole device groups (capacity = G × the
        mesh's client-axis size is the intended deployment,
        e.g. via :class:`repro.runtime.SlotTrainLoop`'s ``mesh=``).

        ``double_buffered`` defers the hot swap to the step boundary:
        ``step()`` stages the rebuilt schedule + compiled mixer (and, in
        capacity mode, the slot remap plan) without touching the live
        ones; :meth:`commit` flips the buffers.  This lets a training
        loop overlap the control step with the in-flight training step
        and still swap at a well-defined boundary.

        ``fuse`` selects the mixing-round execution mode for the
        default mixer factories (``"flat"`` = the Pallas flat-buffer
        fused hot path, :mod:`repro.dist.sync` docs); the compile cache
        keys on it alongside the schedule digest, so fused and unfused
        programs for the same topology coexist without collisions.
        Ignored when an explicit ``mixer_factory`` is supplied (the
        factory owns its execution mode) — except that it still
        participates in the cache key.

        ``codec`` (a :mod:`repro.wire.codec` name or instance) makes the
        default factories compile wire-compressed mixers (implies
        ``fuse="flat"``); it keys the compile cache alongside the
        schedule and fuse mode.  For an error-feedback codec the
        compiled mixer signature grows a trailing residual (see
        :func:`repro.dist.sync.global_mixer`) — the slot train loop
        owns that state.  ``flat_io`` compiles mixers that consume and
        produce the raveled (capacity, N) flat buffer directly
        (resident flat params; global kind + fedlay/ring only), skipping
        the per-round ravel/unravel.

        ``repair_policy`` (a :class:`repro.faults.RepairPolicy`) makes
        NDMP repair *bounded instead of assumed*: after each control
        window, while ``sim.correctness()`` is below the policy target
        the controller re-advances the simulator by decorrelated-jitter
        backoff delays (giving repair traffic time to land) up to
        ``max_retries`` times, then proceeds degraded — tallied as
        ``faults.repair_retries`` / ``repair_recovered`` /
        ``repair_gave_up``.

        ``swap_barrier`` is the multi-process-mesh fault hook: a
        callable invoked in :meth:`commit` *before* a staged swap goes
        live (all processes must flip mixers at the same step
        boundary).  If it raises, the swap stays staged for the next
        boundary — the live mixer keeps serving — and
        ``faults.swap_barrier_aborts`` increments.  Single-process
        callers leave it None (no barrier, today's behavior).
        """
        if mixer_kind not in MIXER_KINDS:
            raise ValueError(f"unknown mixer kind {mixer_kind!r}; "
                             f"choose from {MIXER_KINDS}")
        self.sim = sim
        self.tracker = DeltaTracker(sim)
        self.strategy = strategy
        self.alpha_d, self.alpha_c = alpha_d, alpha_c
        self.confidence_weighted = confidence_weighted
        self.profiles_fn = profiles_fn
        self.measure_correctness = measure_correctness
        self.capacity = capacity
        self.double_buffered = double_buffered
        if clients_per_device < 1:
            raise ValueError("clients_per_device must be >= 1")
        if capacity is not None and capacity % clients_per_device:
            raise ValueError(
                f"capacity {capacity} is not a multiple of "
                f"clients_per_device {clients_per_device}")
        from ..dist.sync import resolve_wire
        self.codec, self.fuse = resolve_wire(codec, fuse)
        self.flat_io = bool(flat_io)
        if self.flat_io and (mixer_kind != "global"
                             or self.fuse != "flat"):
            raise ValueError(
                "flat_io mixers need mixer_kind='global' and the flat "
                "fuse mode (fuse='flat' or a codec)")
        self.clients_per_device = clients_per_device
        self.slots = None
        if capacity is not None:
            if mixer_kind != "global" and mixer_factory is None:
                raise ValueError(
                    "capacity mode compiles mask-aware global mixers; "
                    "use mixer_kind='global' or pass a mixer_factory")
            from ..runtime.slots import SlotMap  # lazy: avoids the
            self.slots = SlotMap(capacity)       # runtime<->overlay cycle
        if mixer_factory is None:
            mixer_factory = (_global_mixer_factory(
                strategy, masked=capacity is not None, fuse=self.fuse,
                codec=self.codec, flat_io=self.flat_io)
                if mixer_kind == "global"
                else _shard_map_mixer_factory(axis_name, strategy,
                                              clients_per_device,
                                              fuse=self.fuse,
                                              codec=self.codec))
        self.cache = MixerCache(mixer_factory, maxsize=cache_size)
        self.repair_policy = repair_policy
        self.swap_barrier = swap_barrier
        self.repair_retries = 0
        self.repair_recovered = 0
        self.repair_gave_up = 0
        self.swap_barrier_aborts = 0
        self.rebuilds = 0
        self.swaps = 0
        self.last_commit_ms = 0.0
        self._alive: Tuple[int, ...] = ()
        self._schedule: Optional[PermuteSchedule] = None
        self._alive_schedule: Optional[PermuteSchedule] = None
        self._mixer: Optional[Callable] = None
        self._staged: Optional[_StagedSwap] = None
        self.last_plan = None
        # trace cursor: end of the last processed control window.  Starts
        # at -inf so events scheduled at or before the first window's
        # start (e.g. t=0 mass churn) are applied rather than silently
        # falling outside the half-open (t0, t1] window.
        self._applied_until = float("-inf")
        # initial build for the seed network (not counted as churn-driven
        # rebuild/swap activity; its compile-cache miss is kept).  The
        # initial swap commits immediately even when double-buffered.
        self._refresh(force=True)
        self.commit()
        self.last_plan = None
        self.rebuilds = 0
        self.swaps = 0

    # ---- public state ----------------------------------------------------
    @property
    def alive(self) -> Tuple[int, ...]:
        """Sorted live node ids — slot ``i`` of the schedule hosts
        ``alive[i]``."""
        return self._alive

    @property
    def schedule(self) -> PermuteSchedule:
        """The live schedule — capacity-padded in capacity mode."""
        assert self._schedule is not None
        return self._schedule

    @property
    def alive_schedule(self) -> PermuteSchedule:
        """The live schedule over the alive set only (unpadded) —
        slot ``i`` hosts ``alive[i]``.  Donor selection
        (:func:`~repro.overlay.runtime.joiner_donors`) works in this
        space."""
        assert self._alive_schedule is not None
        return self._alive_schedule

    def alive_mask(self):
        """(capacity,) 0/1 float32 alive mask (capacity mode only)."""
        assert self.slots is not None, "alive_mask needs capacity mode"
        return self.slots.alive_mask()

    @property
    def mixer(self) -> Callable:
        """The currently live compiled mixer."""
        assert self._mixer is not None
        return self._mixer

    @property
    def epoch(self) -> int:
        return self.tracker.epoch

    def topology(self) -> Topology:
        """The ideal FedLay graph over the current alive set (for the
        host-simulation engine and correctness accounting)."""
        return fedlay_topology(self._alive_addresses())

    # ---- the control step ------------------------------------------------
    def step(self, dt: float,
             events: Iterable[ChurnEvent] = (),
             trace: Optional[ChurnTrace] = None) -> ControlReport:
        """One control interval: apply churn scheduled up to ``now+dt``
        and not yet processed (the first window reaches back to -inf, so
        t=0 events fire), advance NDMP to ``now+dt``, then reconcile the
        data plane with the observed tables.

        The schedule is a pure function of the alive set (+ profiles),
        so only *membership* deltas force a rebuild; pointer-only deltas
        (NDMP repair in flight) advance the epoch without paying the
        host-side rebuild for a byte-identical schedule."""
        t_end = self.sim.now + dt
        due = list(events)
        if trace is not None:
            due.extend(trace.between(self._applied_until, t_end))
        self._applied_until = max(self._applied_until, t_end)
        ChurnTrace.apply(self.sim, sorted(due, key=lambda e: e.time))
        self.sim.run_until(t_end)
        if self.repair_policy is not None:
            self._repair_retry()
        delta = self.tracker.poll()
        if self._staged is None:
            self.last_plan = None
        swapped, rebuilt, cache_hit, rebuild_ms, alive = self._refresh(
            force=bool(delta.joined or delta.left))
        bus = get_telemetry()
        if bus.enabled:   # host-side, step-boundary only (repro.obs contract)
            if delta.joined:
                bus.count("overlay.churn_joins", len(delta.joined))
            if delta.left:
                bus.count("overlay.churn_leaves", len(delta.left))
            if rebuilt:
                bus.count("overlay.rebuilds")
                bus.observe("overlay.rebuild_ms", rebuild_ms)
            if swapped:
                bus.count("overlay.swaps")
            bus.count("overlay.cache_hits" if cache_hit
                      else "overlay.cache_misses")
        return ControlReport(
            epoch=self.tracker.epoch, time=self.sim.now,
            alive=alive, delta=delta, swapped=swapped,
            rebuilt=rebuilt, cache_hit=cache_hit, rebuild_ms=rebuild_ms,
            correctness=(self.sim.correctness()
                         if self.measure_correctness else None))

    def commit(self):
        """Apply the staged swap at the step boundary (no-op unless
        ``double_buffered`` staged one).  Returns the
        :class:`~repro.runtime.slots.RemapPlan` of the most recent
        applied membership change (None when membership is unchanged or
        outside capacity mode) so slot train loops can turn it into
        in-place row writes.

        :attr:`last_commit_ms` afterwards holds the host time the swap
        took (0 when nothing was staged) — the per-round commit-latency
        fact the :class:`repro.obs.rounds.RoundLedger` records."""
        if self._staged is not None:
            if self.swap_barrier is not None:
                try:
                    self.swap_barrier()
                except Exception:
                    # a peer missed the boundary: keep serving the live
                    # mixer, leave the swap staged for the next commit
                    self.swap_barrier_aborts += 1
                    get_telemetry().count("faults.swap_barrier_aborts")
                    self.last_commit_ms = 0.0
                    return self.last_plan
            staged, self._staged = self._staged, None
            t0 = _time.perf_counter()
            self._apply(staged)
            self.last_commit_ms = (_time.perf_counter() - t0) * 1e3
            bus = get_telemetry()
            if bus.enabled:
                bus.count("overlay.commits")
                bus.observe("overlay.commit_ms", self.last_commit_ms)
        else:
            self.last_commit_ms = 0.0
        return self.last_plan

    # ---- internals -------------------------------------------------------
    def _repair_retry(self) -> bool:
        """Bounded wait-for-repair: advance the simulator by backoff
        delays until correctness recovers or the retry budget runs out.
        Returns True when the overlay met the target."""
        pol = self.repair_policy
        if self.sim.correctness() >= pol.correctness_target:
            pol.backoff.reset()
            return True
        bus = get_telemetry()
        for _ in range(pol.max_retries):
            self.repair_retries += 1
            bus.count("faults.repair_retries")
            self.sim.run_until(self.sim.now + pol.backoff.next_delay())
            if self.sim.correctness() >= pol.correctness_target:
                self.repair_recovered += 1
                bus.count("faults.repair_recovered")
                pol.backoff.reset()
                return True
        self.repair_gave_up += 1
        bus.count("faults.repair_gave_up")
        return False

    def _alive_addresses(self) -> Tuple[NodeAddress, ...]:
        return tuple(sorted(self.sim.alive_addresses(),
                            key=lambda a: a.node_id))

    def _refresh(self, force: bool) -> Tuple[bool, bool, bool, float,
                                             Tuple[int, ...]]:
        """Reconcile schedule+mixer with the live tables.

        Returns (swapped, rebuilt, cache_hit, rebuild_ms, alive).
        Without ``force`` (empty delta) the current mixer stays live and
        the step counts as a cache hit with no rebuild.  When
        ``double_buffered`` the rebuilt state is staged (``swapped``
        then means "a different mixer is pending") and goes live only at
        :meth:`commit`.
        """
        if not force and self._schedule is not None:
            # quiescent step: same schedule, genuine cache lookup, no
            # host-side rebuild and no retrace
            self._mixer, hit = self.cache.get(self._schedule, self.fuse,
                                              self.codec)
            alive = (self._staged.alive if self._staged is not None
                     else self._alive)
            return False, False, hit, 0.0, alive
        t0 = _time.perf_counter()
        addrs = self._alive_addresses()
        alive = tuple(a.node_id for a in addrs)
        profiles = (self.profiles_fn(alive)
                    if self.profiles_fn is not None else None)
        alive_sched = schedule_from_addresses(
            addrs, profiles=profiles, alpha_d=self.alpha_d,
            alpha_c=self.alpha_c,
            confidence_weighted=self.confidence_weighted)
        plan = None
        sched = alive_sched
        if self.slots is not None:
            from ..core.mixing import pad_schedule
            plan = self.slots.plan(alive)
            slot_of = plan.slot_of
            sched = pad_schedule(alive_sched,
                                 [slot_of[u] for u in alive],
                                 self.capacity)
        rebuild_ms = (_time.perf_counter() - t0) * 1e3
        self.rebuilds += 1
        mixer, hit = self.cache.get(sched, self.fuse, self.codec)
        swapped = sched != self._schedule
        if swapped:
            self.swaps += 1
        staged = _StagedSwap(alive=alive, alive_schedule=alive_sched,
                             schedule=sched, mixer=mixer, plan=plan)
        if self.double_buffered:
            self._staged = staged
        else:
            self._apply(staged)
        return swapped, True, hit, rebuild_ms, alive

    def _apply(self, staged: _StagedSwap) -> None:
        """Make a staged swap live (slot remap, schedule, mixer)."""
        if staged.plan is not None:
            self.slots.apply(staged.plan)
            self.last_plan = staged.plan if staged.plan.changed else None
        self._alive = staged.alive
        self._alive_schedule = staged.alive_schedule
        self._schedule = staged.schedule
        self._mixer = staged.mixer
