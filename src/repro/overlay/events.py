"""Churn event streams and neighbor-table deltas (paper §III-B, Figs 8/18).

Two host-side primitives the control plane is built from:

* :class:`ChurnTrace` — a time-ordered stream of join/leave/fail events,
  either scripted (benchmark reproductions) or stochastic (Poisson
  arrivals/departures, the paper's sustained-churn setting), applied to
  a :class:`repro.core.ndmp.Simulator` as simulated time advances.
* :class:`DeltaTracker` — the neighbor-table delta extractor: it polls
  :meth:`Simulator.neighbor_tables` between control steps (guarded by
  the cheap :meth:`Simulator.tables_version` stamp) and reports what
  changed as an epoch-stamped :class:`TableDelta`.

Neither touches device state; :mod:`repro.overlay.controller` turns the
deltas into recompiled mixers.

Churn-window cursor semantics
-----------------------------
The controller consumes a trace through an **applied-window cursor**:
each ``OverlayController.step(dt, trace=...)`` takes the events in the
half-open window ``(applied_until, now + dt]`` and advances
``applied_until`` to ``now + dt``.  Two consequences worth knowing:

* the cursor starts at ``-inf``, so events stamped at or before the
  first window's start — e.g. a ``t=0`` mass-churn prologue — fire on
  the *first* ``step()`` instead of silently falling outside the
  window;
* the cursor advances **whether or not a trace was passed**, so a trace
  must be supplied on *every* ``step()`` that should observe it.
  Handing the controller a trace after stepping past its event times
  (or only on some steps) silently skips the past-time events — they
  are never retroactively applied.  Benchmarks that need to sample
  state "right after injection" use a ``dt=0`` priming step.
"""

from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.ndmp import SimulatorProtocol


# --------------------------------------------------------------------------
# Churn events
# --------------------------------------------------------------------------

EVENT_KINDS = ("join", "leave", "fail")


@dataclasses.dataclass(frozen=True)
class ChurnEvent:
    """One membership change at simulated time ``time``.

    ``bootstrap`` (joins only) names the existing node the joiner enters
    through; None means "pick any live node at apply time", which is the
    paper's minimum assumption of one live contact.
    """

    time: float
    kind: str                       # "join" | "leave" | "fail"
    node_id: int
    bootstrap: Optional[int] = None

    def __post_init__(self):
        if self.kind not in EVENT_KINDS:
            raise ValueError(
                f"unknown churn event kind {self.kind!r}; "
                f"choose from {EVENT_KINDS}")


@dataclasses.dataclass(frozen=True)
class ChurnTrace:
    """A time-sorted churn schedule, applied against a live simulator."""

    events: Tuple[ChurnEvent, ...]

    def __post_init__(self):
        ordered = tuple(sorted(self.events, key=lambda e: (e.time, e.node_id)))
        object.__setattr__(self, "events", ordered)
        object.__setattr__(self, "_times", [e.time for e in ordered])

    @property
    def horizon(self) -> float:
        return self.events[-1].time if self.events else 0.0

    def between(self, t0: float, t1: float) -> Tuple[ChurnEvent, ...]:
        """Events with time in the half-open window (t0, t1]."""
        lo = bisect.bisect_right(self._times, t0)
        hi = bisect.bisect_right(self._times, t1)
        return self.events[lo:hi]

    @staticmethod
    def apply(sim: SimulatorProtocol, events: Iterable[ChurnEvent]) -> None:
        """Apply ``events`` to ``sim`` at their scheduled times (the
        simulator is advanced to each event's timestamp first, so the
        NDMP message interleaving is exact)."""
        for ev in events:
            sim.run_until(max(sim.now, ev.time))
            if ev.kind == "join":
                boot = ev.bootstrap
                alive = sim.alive_ids()
                if boot is None or boot not in alive:
                    if not alive:
                        raise RuntimeError(
                            f"join of {ev.node_id} at t={ev.time}: "
                            f"no live bootstrap node")
                    boot = alive[ev.node_id % len(alive)]
                sim.join(ev.node_id, bootstrap=boot,
                         seeds=tuple(alive[:3]))
            elif ev.kind == "leave":
                sim.leave(ev.node_id)
            else:
                sim.fail(ev.node_id)

    # ---- constructors ----------------------------------------------------
    @classmethod
    def scripted(cls, events: Sequence[Tuple[float, str, int]]) -> "ChurnTrace":
        """From ``(time, kind, node_id)`` triples (or 4-tuples with a
        bootstrap for joins)."""
        out = []
        for ev in events:
            if len(ev) == 3:
                t, kind, node = ev
                out.append(ChurnEvent(time=float(t), kind=kind,
                                      node_id=int(node)))
            else:
                t, kind, node, boot = ev
                out.append(ChurnEvent(time=float(t), kind=kind,
                                      node_id=int(node),
                                      bootstrap=int(boot)))
        return cls(events=tuple(out))

    @classmethod
    def stochastic(cls, *, horizon: float, join_rate: float = 0.0,
                   fail_rate: float = 0.0, leave_rate: float = 0.0,
                   initial_ids: Sequence[int] = (), first_new_id: int = 10_000,
                   min_alive: int = 2, seed: int = 0) -> "ChurnTrace":
        """Poisson churn: exponential inter-arrival times per event kind,
        departures drawn uniformly from the nodes alive at that instant
        (never dropping below ``min_alive``)."""
        rng = np.random.default_rng(seed)
        proposals: List[Tuple[float, str]] = []
        for kind, rate in (("join", join_rate), ("fail", fail_rate),
                           ("leave", leave_rate)):
            if rate <= 0.0:
                continue
            t = float(rng.exponential(1.0 / rate))
            while t <= horizon:
                proposals.append((t, kind))
                t += float(rng.exponential(1.0 / rate))
        proposals.sort()
        alive = sorted(int(i) for i in initial_ids)
        next_id = first_new_id
        events: List[ChurnEvent] = []
        for t, kind in proposals:
            if kind == "join":
                events.append(ChurnEvent(time=t, kind="join", node_id=next_id))
                alive.append(next_id)
                next_id += 1
            elif len(alive) > min_alive:
                victim = alive.pop(int(rng.integers(len(alive))))
                events.append(ChurnEvent(time=t, kind=kind, node_id=victim))
        return cls(events=tuple(events))


# --------------------------------------------------------------------------
# Neighbor-table deltas
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TableDelta:
    """What changed in the live neighbor tables between two polls.

    ``epoch`` increases by one per poll *that observed a change*;
    quiescent polls return the previous epoch with ``empty`` True.
    ``changed`` maps surviving nodes whose neighbor set differs to their
    (old, new) sets.
    """

    epoch: int
    time: float
    joined: FrozenSet[int]
    left: FrozenSet[int]
    changed: Dict[int, Tuple[FrozenSet[int], FrozenSet[int]]]

    @property
    def empty(self) -> bool:
        return not (self.joined or self.left or self.changed)

    @property
    def num_affected(self) -> int:
        return len(self.joined) + len(self.left) + len(self.changed)


class DeltaTracker:
    """Epoch-stamped neighbor-table diffing on top of a Simulator.

    ``poll()`` is designed to be called once per control step: O(n)
    version check when nothing moved, full table diff otherwise.
    """

    def __init__(self, sim: SimulatorProtocol):
        self.sim = sim
        self.epoch = 0
        self._version = sim.tables_version()
        self._tables = sim.neighbor_tables()

    @property
    def tables(self) -> Dict[int, frozenset]:
        """The table snapshot as of the last poll."""
        return self._tables

    def poll(self) -> TableDelta:
        version = self.sim.tables_version()
        if version == self._version:
            return TableDelta(epoch=self.epoch, time=self.sim.now,
                              joined=frozenset(), left=frozenset(),
                              changed={})
        new = self.sim.neighbor_tables()
        old = self._tables
        joined = frozenset(new) - frozenset(old)
        left = frozenset(old) - frozenset(new)
        changed = {u: (old[u], new[u])
                   for u in frozenset(old) & frozenset(new)
                   if old[u] != new[u]}
        self._version = version
        self._tables = new
        if joined or left or changed:
            self.epoch += 1
        return TableDelta(epoch=self.epoch, time=self.sim.now,
                          joined=joined, left=left, changed=changed)
