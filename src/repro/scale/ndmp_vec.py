"""Vectorized NDMP engine — flat-array overlay maintenance at 10^5–10^6.

See the :mod:`repro.scale` package docstring for the state layout.  The
design point: the object simulator routes every protocol message
individually (exact, O(messages) Python), while this engine observes
that NDMP's *converged outcome* is a pure function of the visible
membership — per space, ring adjacency in coordinate order (Theorems 1
and 2 guarantee join splices and directional repair stop exactly
there).  So membership changes are queued with the protocol's *timing*
(splice / notify / 3T-detect deadlines) and the table update itself is
one vectorized lexsort+roll when each deadline fires.  What is lost is
per-message accounting (hop counts, transient partial tables mid-route);
what is kept is the delta API, the correctness() trajectory shape, and
bit-identical converged tables — which the parity suite in
``tests/test_scale.py`` pins against the object oracle.
"""

from __future__ import annotations

import heapq
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.coords import NodeAddress, coordinates_batch
from ..core.ndmp import SimulatorProtocol  # noqa: F401  (the seam we satisfy)

_NONE = np.int64(-1)
_INF = float("inf")


class VectorSimulator:
    """Flat-array NDMP engine satisfying
    :class:`repro.core.ndmp.SimulatorProtocol`.

    Timing model (constants mirror the object simulator's):

    * ``join``  — the joiner is a member immediately (``alive_ids`` shows
      it, as in the object sim) but splices into the rings after the
      greedy discovery route completes: ``latency · (3 + log2 m)`` for a
      network of m nodes (route ≈ log2 m hops + reply + splice).
    * ``leave`` — ring-adjacent peers splice around the leaver after one
      notify delivery: ``2 · latency``.
    * ``fail``  — neighbors detect after ``3 · heartbeat_period`` of
      silence, then repair-route: ``3T + 2 · latency``.  Until then the
      failed row stays *visible*: survivors' tables still point at it
      (stale entries), exactly the pre-detection state of the object
      simulator, and ``correctness()`` is depressed accordingly.

    Batched churn (``join_batch`` etc.) costs one queued rebuild per
    batch; single-event ``join``/``leave``/``fail`` match the protocol
    signature (``bootstrap``/``seeds`` are accepted and ignored — greedy
    discovery always converges to the same splice point regardless of
    the entry node, Theorem 1).
    """

    def __init__(self, num_spaces: int, latency: float = 0.35,
                 heartbeat_period: float = 1.0, probe_period: float = 2.0,
                 seed: int = 0, salt: str = ""):
        self.num_spaces = num_spaces
        self.heartbeat_period = heartbeat_period
        self.probe_period = probe_period
        self.salt = salt
        self.rng = np.random.default_rng(seed)
        self._latency = float(latency)
        self.now = 0.0
        self.churn_ops = 0
        # fault seams (repro.faults): a message-loss delay multiplier
        # (retransmission under loss-rate p stretches every protocol
        # deadline by ~1/(1-p) in expectation) and an active partition —
        # row groups whose rings rebuild independently until healed.
        self._delay_scale = 1.0
        self._partition: Optional[List[np.ndarray]] = None

        n0 = 0
        self._ids = np.empty((n0,), dtype=np.int64)
        self._coords = np.empty((n0, num_spaces), dtype=np.float64)
        self._alive = np.empty((n0,), dtype=bool)
        self._succ = np.empty((num_spaces, n0), dtype=np.int64)
        self._pred = np.empty((num_spaces, n0), dtype=np.int64)
        self._version = np.empty((n0,), dtype=np.int64)
        self.confidence = np.empty((n0,), dtype=np.float32)
        # visibility window: the span during which a row participates in
        # ring adjacency.  visible_from > now models a join still routing
        # its discovery; visible_to <= now a detected departure.
        self._visible_from = np.empty((n0,), dtype=np.float64)
        self._visible_to = np.empty((n0,), dtype=np.float64)
        self._row_of: Dict[int, int] = {}
        self._used = 0
        # deadlines at which visibility changes => tables need a rebuild
        self._deadlines: List[float] = []
        self._tables_stale = False

    # ---- row storage -----------------------------------------------------
    def _grow(self, extra: int) -> None:
        need = self._used + extra
        cap = len(self._ids)
        if need <= cap:
            return
        new_cap = max(need, 2 * cap, 16)
        pad = new_cap - cap

        def ext(a, fill, dtype=None, axis=0):
            shape = list(a.shape)
            shape[axis] = pad
            return np.concatenate(
                [a, np.full(shape, fill, dtype=dtype or a.dtype)], axis=axis)

        self._ids = ext(self._ids, -1)
        self._coords = ext(self._coords, 0.0)
        self._alive = ext(self._alive, False)
        self._succ = ext(self._succ, _NONE, axis=1)
        self._pred = ext(self._pred, _NONE, axis=1)
        self._version = ext(self._version, 0)
        self.confidence = ext(self.confidence, 1.0)
        self._visible_from = ext(self._visible_from, _INF)
        self._visible_to = ext(self._visible_to, -_INF)

    def _rows_for(self, node_ids: np.ndarray) -> np.ndarray:
        """Rows for ``node_ids``, allocating fresh rows (with hashed
        coordinates) for ids never seen before."""
        rows = np.empty(len(node_ids), dtype=np.int64)
        fresh: List[int] = []
        for i, u in enumerate(node_ids):
            r = self._row_of.get(int(u))
            if r is None:
                fresh.append(i)
                continue
            rows[i] = r
        if fresh:
            self._grow(len(fresh))
            new_ids = node_ids[fresh]
            new_rows = np.arange(self._used, self._used + len(fresh),
                                 dtype=np.int64)
            self._used += len(fresh)
            self._ids[new_rows] = new_ids
            self._coords[new_rows] = coordinates_batch(
                new_ids.tolist(), self.num_spaces, self.salt)
            self.confidence[new_rows] = 1.0
            for r, u in zip(new_rows, new_ids):
                self._row_of[int(u)] = int(r)
            rows[fresh] = new_rows
        return rows

    # ---- deadlines and rebuilds ------------------------------------------
    def _queue_rebuild(self, when: float) -> None:
        heapq.heappush(self._deadlines, when)

    def _visible_rows(self) -> np.ndarray:
        u = self._used
        vis = (self._visible_from[:u] <= self.now) \
            & (self.now < self._visible_to[:u])
        return np.flatnonzero(vis)

    def _rebuild_tables(self) -> None:
        """Vectorized pointer repair: recompute every ring's adjacency
        over the rows visible *now*, in one lexsort+roll per space, and
        bump versions where a pointer actually moved.  Under an active
        partition each group's ring rebuilds independently — the
        converged image of cross-group failure detection + within-group
        repair."""
        u = self._used
        vis = self._visible_rows()
        if self._partition is not None:
            vis_groups = [np.intersect1d(vis, g) for g in self._partition]
        else:
            vis_groups = [vis]
        delta = np.zeros((u,), dtype=np.int64)
        for s in range(self.num_spaces):
            new = np.full((u,), _NONE, dtype=np.int64)
            new_p = np.full((u,), _NONE, dtype=np.int64)
            for grp in vis_groups:
                if len(grp) > 1:
                    order = grp[np.lexsort((self._ids[grp],
                                            self._coords[grp, s]))]
                    new[order] = np.roll(order, -1)
                    new_p[order] = np.roll(order, 1)
            delta += (new != self._succ[s, :u]).astype(np.int64)
            delta += (new_p != self._pred[s, :u]).astype(np.int64)
            self._succ[s, :u] = new
            self._pred[s, :u] = new_p
        self._version[:u] += delta
        self._tables_stale = False

    # ---- clock -----------------------------------------------------------
    def run_until(self, t: float) -> None:
        while self._deadlines and self._deadlines[0] <= t:
            when = heapq.heappop(self._deadlines)
            # coalesce deadlines at the same instant into one rebuild
            while self._deadlines and self._deadlines[0] == when:
                heapq.heappop(self._deadlines)
            self.now = when
            self._rebuild_tables()
        self.now = max(self.now, t)

    def run_for(self, dt: float) -> None:
        self.run_until(self.now + dt)

    def advance(self, dt: float) -> None:
        self.run_for(dt)

    # ---- timing constants (see class docstring) --------------------------
    def _join_delay(self) -> float:
        m = max(int(self._alive[:self._used].sum()), 2)
        return self._latency * (3.0 + math.log2(m)) * self._delay_scale

    def _leave_delay(self) -> float:
        return 2.0 * self._latency * self._delay_scale

    def _fail_delay(self) -> float:
        return (3.0 * self.heartbeat_period
                + 2.0 * self._latency * self._delay_scale)

    # ---- fault seams (repro.faults) --------------------------------------
    def set_delay_scale(self, scale: float) -> None:
        """Stretch every protocol deadline by ``scale`` ≥ 1 — the
        converged-outcome image of message loss: under loss rate p each
        protocol exchange retries ~1/(1-p) times before landing, so
        joins splice, leaves notify, and failures repair later, but the
        converged tables are unchanged (still ring adjacency over the
        visible membership).  The per-message analogue is the object
        simulator's :meth:`repro.core.ndmp.Simulator.set_message_filter`."""
        if scale < 1.0:
            raise ValueError(f"delay scale {scale} < 1")
        self._delay_scale = float(scale)

    def set_partition(self, groups: Sequence[Sequence[int]]) -> None:
        """Partition the overlay into disjoint node-id ``groups``: after
        the failure-detection delay, every ring rebuilds independently
        per group (cross-group entries repaired away), exactly the
        converged state the object simulator reaches when a message
        filter drops all cross-group traffic.  Node ids absent from
        every group form no ring (unreachable from anywhere)."""
        rows = []
        seen: set = set()
        for g in groups:
            grp = np.asarray(sorted({self._row_of[int(u)] for u in g}),
                             dtype=np.int64)
            if seen & set(grp.tolist()):
                raise ValueError("partition groups overlap")
            seen |= set(grp.tolist())
            rows.append(grp)
        self._partition = rows
        self._queue_rebuild(self.now + self._fail_delay())

    def heal_partition(self) -> None:
        """Lift the active partition: after the discovery-route delay
        the rings re-merge over the full visible membership (the
        converged image of the object simulator's cross-side
        :meth:`~repro.core.ndmp.Simulator.rejoin` sweep)."""
        self._partition = None
        self._queue_rebuild(self.now + self._join_delay())

    def rejoin(self, node_id: int, bootstrap: Optional[int] = None) -> None:
        """Protocol-surface twin of the object simulator's ``rejoin``:
        an alive node re-anchoring through ``bootstrap``.  Membership is
        unchanged; tables re-converge after the discovery delay."""
        del bootstrap
        r = self._row_of.get(int(node_id))
        if r is None or not self._alive[r]:
            raise KeyError(f"node {int(node_id)} is not alive")
        self.churn_ops += 1
        self._queue_rebuild(self.now + self._join_delay())

    # ---- batched churn ---------------------------------------------------
    def seed_network(self, node_ids: Sequence[int]) -> None:
        """Instantiate an already-correct FedLay over ``node_ids`` (same
        shortcut as the object simulator's ``seed_network``)."""
        arr = np.asarray(list(node_ids), dtype=np.int64)
        rows = self._rows_for(arr)
        self._alive[rows] = True
        self._visible_from[rows] = self.now
        self._visible_to[rows] = _INF
        self._rebuild_tables()

    def join_batch(self, node_ids: Sequence[int]) -> None:
        """Batched join: all of ``node_ids`` enter now, splice in after
        the discovery-route delay (one rebuild for the whole batch)."""
        arr = np.asarray(list(node_ids), dtype=np.int64)
        if arr.size == 0:
            return
        rows = self._rows_for(arr)
        if self._alive[rows].any():
            dup = self._ids[rows[self._alive[rows]]][0]
            raise ValueError(f"node {int(dup)} is already alive")
        self.churn_ops += int(arr.size)
        when = self.now + self._join_delay()
        self._alive[rows] = True
        self._version[rows] = 0      # fail→rejoin resets, like a fresh NodeState
        self._visible_from[rows] = when
        self._visible_to[rows] = _INF
        self._queue_rebuild(when)

    def _depart_batch(self, node_ids: Sequence[int], delay: float) -> None:
        arr = np.asarray(list(node_ids), dtype=np.int64)
        if arr.size == 0:
            return
        rows = np.empty(arr.size, dtype=np.int64)
        for i, nid in enumerate(arr):
            r = self._row_of.get(int(nid))
            if r is None or not self._alive[r]:
                raise KeyError(f"node {int(nid)} is not alive")
            rows[i] = r
        self.churn_ops += int(arr.size)
        when = self.now + delay
        self._alive[rows] = False
        self._visible_to[rows] = np.minimum(self._visible_to[rows], when)
        self._queue_rebuild(when)

    def leave_batch(self, node_ids: Sequence[int]) -> None:
        self._depart_batch(node_ids, self._leave_delay())

    def fail_batch(self, node_ids: Sequence[int]) -> None:
        self._depart_batch(node_ids, self._fail_delay())

    # ---- single-event protocol surface -----------------------------------
    def join(self, node_id: int, bootstrap: Optional[int] = None,
             seeds: Tuple[int, ...] = ()) -> None:
        del bootstrap, seeds  # Theorem 1: splice point is entry-invariant
        self.join_batch([node_id])

    def leave(self, node_id: int) -> None:
        self.leave_batch([node_id])

    def fail(self, node_id: int) -> None:
        self.fail_batch([node_id])

    # ---- delta API (SimulatorProtocol) -----------------------------------
    def alive_ids(self) -> List[int]:
        rows = np.flatnonzero(self._alive[:self._used])
        return sorted(int(i) for i in self._ids[rows])

    def alive_addresses(self) -> List[NodeAddress]:
        rows = np.flatnonzero(self._alive[:self._used])
        return [NodeAddress(node_id=int(self._ids[r]),
                            coords=tuple(self._coords[r]))
                for r in rows]

    def neighbor_tables(self) -> Dict[int, frozenset]:
        """id → neighbor-id frozenset for live nodes.  O(n·L) Python —
        meant for control-plane populations; population-scale consumers
        should read :meth:`neighbor_rows` instead."""
        rows = np.flatnonzero(self._alive[:self._used])
        out: Dict[int, frozenset] = {}
        for r in rows:
            nbr = set()
            for s in range(self.num_spaces):
                for p in (self._succ[s, r], self._pred[s, r]):
                    if p >= 0 and p != r:
                        nbr.add(int(self._ids[p]))
            out[int(self._ids[r])] = frozenset(nbr)
        return out

    def neighbor_rows(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The flat view: (alive_rows, succ (L, n), pred (L, n)) with
        pointers re-expressed as *positions into alive_rows* (−1 where
        the pointer is unset or points at a non-alive row) — the
        zero-copy-ish currency of population-scale benchmarks."""
        u = self._used
        rows = np.flatnonzero(self._alive[:u])
        pos = np.full((u,), -1, dtype=np.int64)
        pos[rows] = np.arange(len(rows))
        succ = np.full((self.num_spaces, len(rows)), -1, dtype=np.int64)
        pred = np.full((self.num_spaces, len(rows)), -1, dtype=np.int64)
        for s in range(self.num_spaces):
            sp = self._succ[s, rows]
            pp = self._pred[s, rows]
            succ[s] = np.where(sp >= 0, pos[np.maximum(sp, 0)], -1)
            pred[s] = np.where(pp >= 0, pos[np.maximum(pp, 0)], -1)
        return rows, succ, pred

    def tables_version(self) -> Tuple[int, int, int]:
        """Opaque equatable change stamp (same contract as the object
        simulator's): equal stamps ⇒ unchanged live tables."""
        u = self._used
        alive = self._alive[:u]
        return (self.churn_ops, int(alive.sum()),
                int(self._version[:u][alive].sum()))

    def correctness(self) -> float:
        """Definition-1 correctness of the live network, vectorized.

        counts correct entries / (required + stale) exactly like
        :func:`repro.core.topology.correctness`: required entries are
        the ring adjacencies over the *alive* set; a live node's table
        entry pointing at a departed-but-undetected row (or missing a
        freshly required edge) counts against it.
        """
        u = self._used
        alive_rows = np.flatnonzero(self._alive[:u])
        n = len(alive_rows)
        if n <= 1:
            return 1.0
        # the required (Definition-1) undirected edge set over alive rows
        want = set()
        for s in range(self.num_spaces):
            order = alive_rows[np.lexsort((self._ids[alive_rows],
                                           self._coords[alive_rows, s]))]
            nxt = np.roll(order, -1)
            for a, b in zip(order, nxt):
                if a != b:
                    want.add((min(int(a), int(b)), max(int(a), int(b))))
        required: Dict[int, set] = {int(r): set() for r in alive_rows}
        for a, b in want:
            required[a].add(b)
            required[b].add(a)
        total = sum(len(v) for v in required.values())
        got_correct = 0
        extra = 0
        for r in alive_rows:
            have = set()
            for s in range(self.num_spaces):
                for p in (self._succ[s, r], self._pred[s, r]):
                    if p >= 0 and p != r:
                        have.add(int(p))
            w = required[int(r)]
            got_correct += len(have & w)
            extra += len(have - w)
        denom = total + extra
        return got_correct / denom if denom else 1.0

    # ---- bulk state ------------------------------------------------------
    def export_state(self) -> Dict[str, np.ndarray]:
        """Same layout as :meth:`repro.core.ndmp.Simulator.export_state`:
        live rows in sorted-id order, pointers as node ids (−1 unset)."""
        u = self._used
        rows = np.flatnonzero(self._alive[:u])
        rows = rows[np.argsort(self._ids[rows])]
        n, L = len(rows), self.num_spaces
        succ = np.full((L, n), -1, dtype=np.int64)
        pred = np.full((L, n), -1, dtype=np.int64)
        for s in range(L):
            sp = self._succ[s, rows]
            pp = self._pred[s, rows]
            succ[s] = np.where(sp >= 0, self._ids[np.maximum(sp, 0)], -1)
            pred[s] = np.where(pp >= 0, self._ids[np.maximum(pp, 0)], -1)
        return {"ids": self._ids[rows].copy(),
                "coords": self._coords[rows].copy(),
                "succ": succ, "pred": pred,
                "version": self._version[rows].copy()}

    @classmethod
    def from_simulator(cls, sim, **kwargs) -> "VectorSimulator":
        """Seed a vectorized engine from any engine exposing
        ``export_state()`` (typically the object oracle): membership and
        converged tables carry over; in-flight protocol messages do not."""
        state = sim.export_state()
        out = cls(num_spaces=sim.num_spaces,
                  latency=kwargs.pop("latency", getattr(sim, "_latency", 0.35)
                                     if not callable(getattr(sim, "_latency", None))
                                     else 0.35),
                  heartbeat_period=kwargs.pop("heartbeat_period",
                                              sim.heartbeat_period),
                  probe_period=kwargs.pop("probe_period", sim.probe_period),
                  salt=kwargs.pop("salt", sim.salt), **kwargs)
        out.now = sim.now
        ids = state["ids"]
        rows = out._rows_for(ids)
        out._coords[rows] = state["coords"]   # authoritative (same hash anyway)
        out._alive[rows] = True
        out._visible_from[rows] = out.now
        out._visible_to[rows] = _INF
        out._version[rows] = state["version"]
        id_row = out._row_of
        for s in range(out.num_spaces):
            for k, arr in (("succ", out._succ), ("pred", out._pred)):
                src = state[k][s]
                arr[s, rows] = [id_row.get(int(v), -1) if v >= 0 else -1
                                for v in src]
        return out

    # ---- misc ------------------------------------------------------------
    @property
    def num_rows(self) -> int:
        """Total rows ever allocated (alive + departed identities)."""
        return self._used

    def set_confidence(self, node_ids: Sequence[int],
                       values: Sequence[float]) -> None:
        """Install per-node MEP confidences (cohort sampling / donor
        selection weight); ids must have rows already."""
        for u, c in zip(node_ids, values):
            self.confidence[self._row_of[int(u)]] = c
