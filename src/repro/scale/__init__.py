"""Population-scale overlay engine: vectorized NDMP + cohort streaming.

The core reproduction (``repro.core`` / ``repro.overlay`` /
``repro.runtime``) is exact but object-per-node: the discrete-event
:class:`repro.core.ndmp.Simulator` tops out around 10^3 nodes, three
orders of magnitude short of the paper's "millions of users" ambition.
This package closes that gap with two layers behind the same seams the
rest of the stack already consumes.

Flat-array state layout (``ndmp_vec``)
--------------------------------------
:class:`~repro.scale.ndmp_vec.VectorSimulator` re-expresses the NDMP
node population as a struct-of-arrays over **rows** (a row is a node
identity's permanent index, assigned at first join and reused on
fail→rejoin):

* ``ids``       (N,)   int64    node id of each row
* ``coords``    (N, L) float64  virtual coordinates, bit-exact with
  :func:`repro.core.coords.coordinate` via the vectorized FNV-1a batch
  hasher (:func:`repro.core.coords.coordinates_batch`)
* ``alive``     (N,)   bool     current membership (flips at the
  join/leave/fail call, like the object simulator)
* ``succ/pred`` (L, N) int64    ring pointers as **row indices**, −1 =
  unset; exported as node ids through ``neighbor_tables()`` /
  ``export_state()``
* ``version``   (N,)   int64    per-row pointer-rewrite counts (the
  cheap change stamp, same contract as ``NodeState.version``)
* ``confidence``(N,)   float32  per-row MEP confidence used by cohort
  sampling and donor selection

Membership changes are **batched** (``join_batch`` / ``leave_batch`` /
``fail_batch``); pointer repair is **vectorized**: when a repair
deadline fires, every ring's adjacency is recomputed in one
lexsort+roll over the rows visible at that instant, and versions bump
only where a pointer actually changed.  Repair *timing* follows the
object simulator's constants (join splice after the greedy-route
latency, leave splice after one notify round-trip, failure repair after
the 3T silence deadline), so ``correctness()`` dips and recovers on the
same schedule — while the converged tables are exactly the Definition-1
ring adjacency both engines agree on (Theorems 1–2), which is what the
vec-vs-object parity suite pins.

Cohort-weighting contract (``cohort``)
--------------------------------------
The streaming runtime trains a fixed-capacity device mesh against an
arbitrarily large overlay: each round a
:class:`~repro.scale.cohort.CohortSampler` draws K alive nodes, the
:class:`~repro.runtime.slots.SlotMap` turns the cohort delta into an
identity-preserving RemapPlan (stream-in/out as in-place row writes,
Fig-18 donor catch-up for cold slots), and mixing runs on the
**induced subgraph** of the full overlay: cohort member u averages over
``({u} ∪ N(u)) ∩ cohort`` with its schedule weights renormalized over
the present neighbors (absent neighbors' mass redistributed
proportionally, exactly :func:`repro.core.mixing.masked_mixing_matrix`
semantics).  On the device this is the runtime-weight ``gather_mix``
path — cohort composition is data, not code, so any sequence of
cohorts reuses one compiled program (0 retraces) — and with the full
population as the cohort it is provably the dense full-participation
mixing matrix, the small-n oracle the tests pin within 1e-6.
"""

from .cohort import CohortSampler, CohortStreamLoop, cohort_mixing_matrix
from .ndmp_vec import VectorSimulator

__all__ = [
    "CohortSampler",
    "CohortStreamLoop",
    "VectorSimulator",
    "cohort_mixing_matrix",
]
