"""Cohort streaming: a fixed-capacity mesh serving an unbounded overlay.

Production-FL serving shape: the device pool holds C slots but the
overlay holds n ≫ C nodes.  Each round a :class:`CohortSampler` draws a
K ≤ C cohort of alive nodes; the :class:`~repro.runtime.slots.SlotMap`
reconciles it as an identity-preserving
:class:`~repro.runtime.slots.RemapPlan` (stream-out parks a node's
model host-side, stream-in restores it — a node that returns rounds
later continues from its own parameters); the cohort's induced FedLay
schedule comes from :func:`repro.core.mixing.schedule_from_addresses`
over the cohort addresses, capacity-padded so dead slots self-loop; and
the mixing round runs through the :func:`repro.kernels.weighted_mix.gather_mix`
runtime-weight path with the **source table as traced data** — cohort
composition changes are pure data, so every round of every cohort
reuses one compiled program (0 retraces).

The weighting contract (see the package docstring): the padded cohort
schedule's dense image :func:`cohort_mixing_matrix` is row-stochastic,
restricted to the cohort, and with the full population sampled it *is*
the dense full-participation mixing matrix — the small-n oracle
``tests/test_cohort.py`` pins within 1e-6.
"""

from __future__ import annotations

import dataclasses
import time as _time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.coords import NodeAddress, coordinates_batch
from ..core.mep import ClientProfile
from ..core.mixing import (PermuteSchedule, pad_schedule,
                           schedule_from_addresses, schedule_mixing_matrix)
from ..overlay.runtime import joiner_donors
from ..runtime.loop import counting_jit
from ..runtime.slots import RemapPlan, SlotMap


# --------------------------------------------------------------------------
# Schedule → runtime gather tables
# --------------------------------------------------------------------------

def schedule_tables(sched: PermuteSchedule) -> Tuple[np.ndarray, np.ndarray]:
    """A schedule as ``gather_mix`` tables: (C, 2L+1) ``srcs`` int32 and
    ``weights`` float32, column 0 the self edge.  Row-stochastic by
    schedule construction; dead slots of a padded schedule come out as
    pure self-loops.  These are the *runtime inputs* of the cohort
    mixer — same shapes every round, whatever the cohort."""
    C, S = sched.num_clients, sched.num_slots
    srcs = np.empty((C, S + 1), dtype=np.int32)
    weights = np.empty((C, S + 1), dtype=np.float32)
    srcs[:, 0] = np.arange(C)
    weights[:, 0] = sched.self_weight
    for k in range(S):
        srcs[:, k + 1] = sched.perms[k]
        weights[:, k + 1] = sched.weights[:, k]
    return srcs, weights


def cohort_addresses(cohort: Sequence[int], num_spaces: int,
                     salt: str = "") -> List[NodeAddress]:
    """Addresses for a cohort — coordinates are pure functions of the
    node id (the paper's public hash), so no engine round-trip is
    needed; the batch hasher keeps this cheap for large cohorts."""
    ids = list(cohort)
    coords = coordinates_batch(ids, num_spaces, salt)
    return [NodeAddress(node_id=int(u), coords=tuple(coords[i]))
            for i, u in enumerate(ids)]


def cohort_schedule(cohort: Sequence[int], num_spaces: int,
                    slot_of: Dict[int, int], capacity: int, *,
                    salt: str = "",
                    profiles: Optional[Dict[int, ClientProfile]] = None,
                    alpha_d: float = 0.5, alpha_c: float = 0.5,
                    confidence_weighted: bool = True
                    ) -> Tuple[PermuteSchedule, PermuteSchedule]:
    """(cohort-level, capacity-padded) schedules for one round.

    The cohort-level schedule is the induced FedLay over the cohort —
    every member's ring pred/succ *within the cohort* — built by the
    same :func:`schedule_from_addresses` the live controller uses, so
    cohort weighting inherits MEP confidence weighting and duplicate-
    adjacency dedup unchanged.  The padded schedule embeds it into the
    capacity slots per ``slot_of`` (unsampled slots self-loop)."""
    addrs = cohort_addresses(cohort, num_spaces, salt)
    sched = schedule_from_addresses(
        addrs, profiles=profiles, alpha_d=alpha_d, alpha_c=alpha_c,
        confidence_weighted=confidence_weighted)
    padded = pad_schedule(sched, [slot_of[int(u)] for u in cohort], capacity)
    return sched, padded


def cohort_mixing_matrix(cohort: Sequence[int], num_spaces: int,
                         slot_of: Dict[int, int], capacity: int,
                         **kwargs) -> np.ndarray:
    """The dense (capacity, capacity) oracle of one cohort round —
    row-stochastic, identity on unsampled slots.  Test currency: the
    device path must reproduce ``M @ buf`` within float32 tolerance,
    and with ``cohort == alive`` this equals the full-participation
    mixing matrix."""
    _, padded = cohort_schedule(cohort, num_spaces, slot_of, capacity,
                                **kwargs)
    return schedule_mixing_matrix(padded)


# --------------------------------------------------------------------------
# Sampling
# --------------------------------------------------------------------------

class CohortSampler:
    """Draw the round's K-node cohort from an engine's alive set.

    Deterministic per ``(seed, round_index)`` — two runs of the same
    trace sample identical cohorts.  ``weighted=True`` biases the draw
    by per-node MEP confidence when the engine exposes a ``confidence``
    row array (:class:`repro.scale.ndmp_vec.VectorSimulator`); engines
    without one fall back to uniform.  When fewer than K nodes are
    alive the whole population is the cohort."""

    def __init__(self, sim, cohort_size: int, *, seed: int = 0,
                 weighted: bool = False):
        if cohort_size < 1:
            raise ValueError("cohort_size must be >= 1")
        self.sim = sim
        self.cohort_size = cohort_size
        self.seed = seed
        self.weighted = weighted

    def _confidences(self, alive: List[int]) -> Optional[np.ndarray]:
        conf = getattr(self.sim, "confidence", None)
        row_of = getattr(self.sim, "_row_of", None)
        if conf is None or row_of is None:
            return None
        return np.asarray([conf[row_of[u]] for u in alive], dtype=np.float64)

    def sample(self, round_index: int) -> Tuple[int, ...]:
        alive = self.sim.alive_ids()
        if len(alive) <= self.cohort_size:
            return tuple(alive)
        rng = np.random.default_rng([self.seed, round_index])
        p = None
        if self.weighted:
            c = self._confidences(alive)
            if c is not None and c.sum() > 0:
                p = c / c.sum()
        picked = rng.choice(len(alive), size=self.cohort_size,
                            replace=False, p=p)
        return tuple(sorted(alive[i] for i in picked))


# --------------------------------------------------------------------------
# The streaming loop
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CohortRoundRecord:
    """One cohort round: membership motion + data-plane accounting."""

    round: int
    time: float
    cohort_size: int
    streamed_in: int
    streamed_out: int
    restored: int         # stream-ins that resumed a parked model
    donor_seeded: int     # cold slots seeded by Fig-18 donor catch-up
    fresh: int            # cold slots with no surviving donor
    remap_ms: float       # host time for park/restore/schedule rebuild
    retraces: int         # cumulative mixer retraces (must stay 0)
    evicted: int = 0      # LRU park evictions this round


class CohortStreamLoop:
    """Train a (capacity, dim) resident population buffer against an
    arbitrarily large overlay, one sampled cohort per round.

    ``make_params(node_id) -> (dim,)`` initializes one node's flat model
    the first time it is sampled.  ``local_fn`` (optional) is a
    traced-through per-round local update ``(buf, mask) -> buf`` applied
    before mixing (mask = 1 on occupied slots); it is jitted together
    with the mixing round, so the whole round is one compiled program.

    Stream-out **parks** a node's row host-side and stream-in restores
    it — node identity is preserved across arbitrarily long absences.
    By default the park is unbounded (it grows with the number of
    *distinct* nodes ever sampled); ``max_parked`` bounds it with LRU
    eviction — least-recently-parked rows are dropped first, and the
    optional snapshot/restore policy decides what eviction means:

    * ``snapshot_fn(node_id, row)`` is called with every evicted row —
      e.g. spill to disk or object storage.  Without one the row is
      simply discarded (the node re-enters cold, via donor catch-up).
    * ``restore_fn(node_id) -> row | None`` is consulted on stream-in
      when the node is not in the host park — the read side of the
      snapshot policy.  A non-None row counts as ``restored`` exactly
      like a park hit.

    A node sampled for the first time is seeded by Fig-18 donor
    catch-up: the highest-confidence cohort neighbor that is itself a
    survivor/restored member donates its current model; all-cold
    neighborhoods fall back to ``make_params``.
    """

    def __init__(self, sim, *, capacity: int, cohort_size: int,
                 make_params: Callable[[int], np.ndarray],
                 sampler: Optional[CohortSampler] = None,
                 local_fn: Optional[Callable] = None,
                 profiles_fn: Optional[Callable[
                     [Tuple[int, ...]], Dict[int, ClientProfile]]] = None,
                 round_time: float = 1.0, seed: int = 0,
                 max_parked: Optional[int] = None,
                 snapshot_fn: Optional[
                     Callable[[int, np.ndarray], None]] = None,
                 restore_fn: Optional[
                     Callable[[int], Optional[np.ndarray]]] = None):
        import jax
        import jax.numpy as jnp
        from ..kernels.weighted_mix import gather_mix

        if cohort_size > capacity:
            raise ValueError(f"cohort_size {cohort_size} exceeds "
                             f"capacity {capacity}")
        self.sim = sim
        self.capacity = capacity
        self.slots = SlotMap(capacity)
        self.sampler = sampler or CohortSampler(sim, cohort_size, seed=seed)
        self.make_params = make_params
        self.profiles_fn = profiles_fn
        self.round_time = round_time
        self.salt = getattr(sim, "salt", "")
        self.num_spaces = sim.num_spaces
        self._jnp = jnp
        if max_parked is not None and max_parked < 1:
            raise ValueError("max_parked must be >= 1 (or None)")
        self.park: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self.max_parked = max_parked
        self.snapshot_fn = snapshot_fn
        self.restore_fn = restore_fn
        self.evictions = 0
        self.records: List[CohortRoundRecord] = []
        self._round = 0

        probe = self.sim.alive_ids()
        if not probe:
            raise ValueError("engine has no live nodes")
        dim = int(np.asarray(make_params(probe[0])).shape[0])
        self.dim = dim
        self.buf = jnp.zeros((capacity, dim), dtype=jnp.float32)

        def round_fn(buf, srcs, weights, mask):
            if local_fn is not None:
                buf = local_fn(buf, mask)
            return gather_mix(buf, srcs, weights)
        self._round_fn, self.trace_count = counting_jit(round_fn)

    # ---- state access ----------------------------------------------------
    def client_params(self, node_id: int) -> np.ndarray:
        """A node's current model — live slot row if resident, parked
        copy otherwise; evicted nodes fall back to the snapshot policy's
        ``restore_fn`` (identity preservation, testable)."""
        slot = self.slots.slot_of.get(node_id)
        if slot is not None:
            return np.asarray(self.buf[slot])
        row = self.park.get(node_id)
        if row is None and self.restore_fn is not None:
            row = self.restore_fn(node_id)
        if row is None:
            raise KeyError(f"node {node_id} is neither resident, parked, "
                           f"nor restorable")
        return row

    def _park_row(self, node_id: int, row: np.ndarray) -> int:
        """Park one row, LRU-evicting past ``max_parked`` (evicted rows
        go through ``snapshot_fn`` if set).  Returns evictions."""
        self.park[node_id] = row
        self.park.move_to_end(node_id)
        evicted = 0
        while (self.max_parked is not None
               and len(self.park) > self.max_parked):
            victim, vrow = self.park.popitem(last=False)
            if self.snapshot_fn is not None:
                self.snapshot_fn(victim, vrow)
            evicted += 1
        self.evictions += evicted
        return evicted

    def _unpark_row(self, node_id: int) -> Optional[np.ndarray]:
        """Take a row out of the park, falling back to ``restore_fn``
        for snapshot-evicted nodes.  None = genuinely cold."""
        row = self.park.pop(node_id, None)
        if row is None and self.restore_fn is not None:
            row = self.restore_fn(node_id)
        return row

    def _warm(self, node_id: int) -> bool:
        return (node_id in self.park
                or (self.restore_fn is not None
                    and self.restore_fn(node_id) is not None))

    # ---- one round -------------------------------------------------------
    def _reconcile(self, cohort: Tuple[int, ...],
                   sched: PermuteSchedule,
                   plan: RemapPlan) -> Tuple[int, int, int, int]:
        """Stream-out to the park, stream-in from park / snapshot /
        donor / fresh.  Returns (restored, donor_seeded, fresh,
        evicted) counts."""
        jnp = self._jnp
        evicted = 0
        for u, s in plan.leavers:
            evicted += self._park_row(u, np.asarray(self.buf[s]))
        self.slots.apply(plan)
        joiners = tuple(u for u, _ in plan.joiners)
        if not joiners:
            return 0, 0, 0, evicted
        survivors = tuple(u for u, _ in plan.survivors)
        cold = [u for u in joiners if not self._warm(u)]
        # parked members count as warm donors: they resume their own
        # model, so their row is as trustworthy as a survivor's
        donors = joiner_donors(sched, cohort, cold,
                               tuple(set(survivors)
                                     | (set(joiners) - set(cold)))) \
            if cold else {}
        slot_of = self.slots.slot_of
        restored = donor_seeded = fresh = 0
        rows, slots_w = [], []
        for u, s in plan.joiners:
            row = self._unpark_row(u)
            if row is not None:
                rows.append(row)
                restored += 1
            else:
                donor = donors.get(u)
                if donor is not None and donor in slot_of:
                    rows.append(np.asarray(self.buf[slot_of[donor]]))
                    donor_seeded += 1
                else:
                    rows.append(np.asarray(self.make_params(u),
                                           dtype=np.float32))
                    fresh += 1
            slots_w.append(s)
        idx = jnp.asarray(np.asarray(slots_w, dtype=np.int32))
        self.buf = self.buf.at[idx].set(
            jnp.asarray(np.stack(rows), dtype=self.buf.dtype))
        return restored, donor_seeded, fresh, evicted

    def run(self, num_rounds: int) -> List[CohortRoundRecord]:
        jnp = self._jnp
        from ..obs import get_telemetry
        from ..obs.rounds import get_round_ledger
        last_traces = self.trace_count.traces
        for _ in range(num_rounds):
            r = self._round
            self.sim.advance(self.round_time)
            cohort = self.sampler.sample(r)
            t0 = _time.perf_counter()
            plan = self.slots.plan(cohort)
            profiles = (self.profiles_fn(cohort)
                        if self.profiles_fn is not None else None)
            sched, padded = cohort_schedule(
                cohort, self.num_spaces, plan.slot_of, self.capacity,
                salt=self.salt, profiles=profiles)
            restored, donor_seeded, fresh, evicted = self._reconcile(
                cohort, sched, plan)
            srcs, weights = schedule_tables(padded)
            mask = np.zeros((self.capacity,), dtype=np.float32)
            mask[[plan.slot_of[u] for u in cohort]] = 1.0
            remap_ms = (_time.perf_counter() - t0) * 1e3
            self.buf = self._round_fn(self.buf, jnp.asarray(srcs),
                                      jnp.asarray(weights),
                                      jnp.asarray(mask))
            self.records.append(CohortRoundRecord(
                round=r, time=self.sim.now, cohort_size=len(cohort),
                streamed_in=len(plan.joiners),
                streamed_out=len(plan.leavers),
                restored=restored, donor_seeded=donor_seeded, fresh=fresh,
                remap_ms=remap_ms, retraces=self.trace_count.retraces,
                evicted=evicted))
            bus = get_telemetry()
            if bus.enabled:
                bus.count("cohort.rounds")
                bus.count("cohort.streamed_in", len(plan.joiners))
                bus.count("cohort.streamed_out", len(plan.leavers))
                if evicted:
                    bus.count("cohort.park_evictions", evicted)
                bus.gauge("cohort.parked", len(self.park))
                bus.observe("cohort.remap_ms", remap_ms)
            ledger = get_round_ledger()
            if ledger is not None:
                from ..dist.sync import sync_bytes_per_client
                wire = sync_bytes_per_client(
                    "fedlay", 4 * self.dim, self.capacity,
                    num_spaces=self.num_spaces,
                    active_clients=len(cohort))
                traces = self.trace_count.traces
                delta, last_traces = traces - last_traces, traces
                ledger.record(
                    round=r, time=self.sim.now, loop="cohort",
                    num_alive=len(cohort), participating=len(cohort),
                    wire_bytes_per_client=wire,
                    payload_bytes_per_client=wire,
                    retraces=self.trace_count.retraces,
                    retrace_delta=delta,
                    swapped=bool(plan.changed), rebuilt=True,
                    joined=tuple(u for u, _ in plan.joiners),
                    left=tuple(u for u, _ in plan.leavers),
                    repair_ms=remap_ms,
                    restored=restored, donor_seeded=donor_seeded,
                    fresh=fresh, evicted=evicted)
            self._round += 1
        return self.records
