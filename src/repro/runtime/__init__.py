"""``repro.runtime`` — fixed-capacity slot runtime for static-shape
training under churn.

The re-stack loop (:class:`repro.overlay.runtime.ChurnTrainLoop`) pays
one local-step retrace per distinct alive count.  This package removes
that tax: the client axis is a fixed ``capacity`` of slots, dead slots
are masked (self-loop weight 1 in the mixer, ``where``-gated updates in
the local step), and membership changes become in-place row writes —
device shapes are fully static, so the local step compiles **once per
capacity, ever**.

* :mod:`repro.runtime.slots` — :class:`SlotMap`: node id ↔ capacity
  slot with a free heap, alive mask, and identity-preserving
  :class:`RemapPlan`;
* :mod:`repro.runtime.masked` — the mask-aware wrappers (masked local
  step, capacity-padded schedules, masked-mean metrics, on-device
  multirate participation);
* :mod:`repro.runtime.loop` — :class:`SlotTrainLoop`, the static-shape
  sibling of ``ChurnTrainLoop``, plus the :func:`counting_jit` retrace
  instrumentation.
"""

from . import loop, masked, slots
from .loop import SlotStepRecord, SlotTrainLoop, TraceCount, counting_jit
from .masked import (broadcast_mask, masked_local_step, masked_mean,
                     masked_where, pad_to_capacity, participation_mask)
from .serving import Request, ServeLoop
from .slots import RemapPlan, SlotCapacityError, SlotMap

__all__ = [
    "loop", "masked", "serving", "slots",
    "SlotStepRecord", "SlotTrainLoop", "TraceCount", "counting_jit",
    "broadcast_mask", "masked_local_step", "masked_mean", "masked_where",
    "pad_to_capacity", "participation_mask",
    "Request", "ServeLoop",
    "RemapPlan", "SlotCapacityError", "SlotMap",
]
