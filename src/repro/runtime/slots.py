"""Fixed-capacity slot allocation for static-shape training under churn.

The device data plane wants one shape forever: a leading client axis of
size ``capacity`` that never changes.  :class:`SlotMap` owns the mapping
between live NDMP node identities and those capacity slots:

* survivors **never move** — a node keeps its slot for its whole
  lifetime (identity-preserving, so membership changes are in-place row
  writes instead of host re-stacks);
* leavers free their slot (the row goes stale and is masked dead);
* joiners take the lowest free slot (deterministic, so two runs of the
  same churn trace produce the same layout).

:meth:`SlotMap.plan` computes the :class:`RemapPlan` for a new alive set
*without mutating* — the overlay controller stages plans during a
control step and applies them at the step boundary
(:meth:`repro.overlay.controller.OverlayController.commit`), which is
what makes the double-buffered swap race-free.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class SlotCapacityError(RuntimeError):
    """The alive set no longer fits in the fixed capacity."""


@dataclasses.dataclass(frozen=True)
class RemapPlan:
    """One membership reconciliation, expressed as slot operations.

    ``survivors``/``joiners``/``leavers`` are ``(node_id, slot)`` pairs;
    survivors keep the slot they already held, joiners name the slot
    they will be written into, leavers the slot they vacate.  A plan is
    pure data — nothing changes until :meth:`SlotMap.apply`.
    """

    capacity: int
    survivors: Tuple[Tuple[int, int], ...]
    joiners: Tuple[Tuple[int, int], ...]
    leavers: Tuple[Tuple[int, int], ...]

    @property
    def changed(self) -> bool:
        return bool(self.joiners or self.leavers)

    @property
    def slot_of(self) -> Dict[int, int]:
        """node id → slot for the post-plan alive set."""
        out = dict(self.survivors)
        out.update(self.joiners)
        return out


def plan_reset_slots(plan: RemapPlan) -> Tuple[int, ...]:
    """Slots whose per-slot auxiliary state (e.g. the wire codec's
    error-feedback residual, :class:`repro.runtime.loop.SlotTrainLoop`)
    must be zeroed when ``plan`` is applied: every joiner slot (the new
    occupant must not inherit the previous tenant's residual) and every
    leaver slot (a dead row's residual would otherwise be replayed if
    the slot is reused before any intervening join).  Sorted, deduped."""
    return tuple(sorted({s for _, s in plan.joiners}
                        | {s for _, s in plan.leavers}))


class SlotMap:
    """Node-identity → capacity-slot allocator with a free-slot heap."""

    def __init__(self, capacity: int, initial: Sequence[int] = ()):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self._node_at: List[Optional[int]] = [None] * capacity
        self._slot_of: Dict[int, int] = {}
        self._free: List[int] = list(range(capacity))
        heapq.heapify(self._free)
        for u in initial:
            self.alloc(u)

    # ---- queries ---------------------------------------------------------
    @property
    def slot_of(self) -> Dict[int, int]:
        """Live node id → slot (a copy; mutate via alloc/free/apply)."""
        return dict(self._slot_of)

    def node_at(self, slot: int) -> Optional[int]:
        """The node occupying ``slot``, or None if the slot is dead."""
        return self._node_at[slot]

    def nodes(self) -> Tuple[int, ...]:
        """Live node ids in slot order."""
        return tuple(u for u in self._node_at if u is not None)

    @property
    def num_free(self) -> int:
        """Free slots remaining (the serving plane's admission gate)."""
        return len(self._free)

    def alive_mask(self) -> np.ndarray:
        """(capacity,) float32 0/1 mask — 1 where the slot hosts a live
        node.  This is the on-device mask the masked local step and
        mask-aware mixers consume."""
        mask = np.zeros((self.capacity,), dtype=np.float32)
        for slot, node in enumerate(self._node_at):
            if node is not None:
                mask[slot] = 1.0
        return mask

    def __len__(self) -> int:
        return len(self._slot_of)

    def __contains__(self, node_id: int) -> bool:
        return node_id in self._slot_of

    # ---- mutation --------------------------------------------------------
    def alloc(self, node_id: int) -> int:
        """Assign ``node_id`` the lowest free slot."""
        if node_id in self._slot_of:
            raise ValueError(f"node {node_id} already holds slot "
                             f"{self._slot_of[node_id]}")
        if not self._free:
            raise SlotCapacityError(
                f"capacity {self.capacity} exhausted allocating node "
                f"{node_id}")
        slot = heapq.heappop(self._free)
        self._slot_of[node_id] = slot
        self._node_at[slot] = node_id
        return slot

    def free(self, node_id: int) -> int:
        """Release ``node_id``'s slot back to the free heap."""
        slot = self._slot_of.pop(node_id, None)
        if slot is None:
            raise KeyError(f"node {node_id} holds no slot")
        self._node_at[slot] = None
        heapq.heappush(self._free, slot)
        return slot

    # ---- remap planning --------------------------------------------------
    def plan(self, new_alive: Sequence[int]) -> RemapPlan:
        """The identity-preserving :class:`RemapPlan` taking the current
        occupancy to ``new_alive``.  Pure: the map is unchanged until
        :meth:`apply`.  Joiners are assigned lowest-slot-first in the
        order they appear in ``new_alive``."""
        new_ids = list(new_alive)
        new_set = set(new_ids)
        if len(new_set) != len(new_ids):
            raise ValueError("duplicate node ids in new alive set")
        survivors = tuple((u, s) for u, s in sorted(self._slot_of.items())
                          if u in new_set)
        leavers = tuple((u, s) for u, s in sorted(self._slot_of.items())
                        if u not in new_set)
        pool = sorted(self._free + [s for _, s in leavers])
        joiners: List[Tuple[int, int]] = []
        for u in new_ids:
            if u in self._slot_of:
                continue
            if not pool:
                raise SlotCapacityError(
                    f"capacity {self.capacity} cannot hold "
                    f"{len(new_ids)} alive nodes")
            joiners.append((u, pool.pop(0)))
        return RemapPlan(capacity=self.capacity, survivors=survivors,
                         joiners=tuple(joiners), leavers=leavers)

    def apply(self, plan: RemapPlan) -> None:
        """Mutate the map per ``plan`` (leavers freed, joiners placed)."""
        if plan.capacity != self.capacity:
            raise ValueError(
                f"plan is for capacity {plan.capacity}, map has "
                f"{self.capacity}")
        for u, s in plan.survivors:
            if self._slot_of.get(u) != s:
                raise ValueError(
                    f"stale plan: survivor {u} expected in slot {s}")
        for u, _ in plan.leavers:
            self.free(u)
        for u, s in plan.joiners:
            if self._node_at[s] is not None:
                raise ValueError(
                    f"stale plan: joiner slot {s} occupied by "
                    f"{self._node_at[s]}")
            self._free.remove(s)
            heapq.heapify(self._free)
            self._slot_of[u] = s
            self._node_at[s] = u

    def remap(self, new_alive: Sequence[int]) -> RemapPlan:
        """plan + apply in one call."""
        plan = self.plan(new_alive)
        self.apply(plan)
        return plan
