"""Mask-aware wrappers: everything the slot runtime computes per step
against a static-capacity client axis with some slots dead.

Three mask consumers, one convention — a (capacity,) 0/1 float32 vector,
1 = live and participating:

* the **local step**: :func:`masked_local_step` gates parameter and
  optimizer updates with ``where`` (dead rows stay frozen bit-for-bit;
  a NaN loss on a dead slot's garbage row cannot leak into live state
  or metrics) and reduces per-client metrics with :func:`masked_mean`;
* the **mixer**: :func:`repro.dist.sync.global_mixer` with
  ``masked=True`` (compiled over a :func:`pad_to_capacity` schedule
  whose dead slots self-loop with weight 1) takes the mask as a runtime
  input, so participation can change every step with zero retrace;
* **multirate participation** (the async open item):
  :func:`participation_mask` evaluates t % k_u == 0 on device from the
  host-static :func:`repro.core.mixing.participation_mults`, so slow
  clients skip mixing collectives without leaving the compiled program.
"""

from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.mixing import (PermuteSchedule, pad_schedule,
                           participation_mults)


def broadcast_mask(mask: jnp.ndarray, leaf: jnp.ndarray) -> jnp.ndarray:
    """Reshape a (C,) mask to broadcast against a (C, ...) leaf."""
    return mask.reshape((leaf.shape[0],) + (1,) * (leaf.ndim - 1))


def masked_where(mask: jnp.ndarray, new, old):
    """Per-row select: new where mask > 0, old elsewhere (tree-mapped)."""
    return jax.tree.map(
        lambda n, o: jnp.where(broadcast_mask(mask, n) > 0, n, o), new, old)


def masked_mean(values: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Mean of ``values`` over live rows only.  Dead rows are zeroed
    with ``where`` before the sum, so a NaN on a dead slot cannot
    poison the reduction."""
    m = mask.astype(jnp.float32)
    mm = broadcast_mask(m, values)
    v = jnp.where(mm > 0, values.astype(jnp.float32), 0.0)
    return jnp.sum(v) / jnp.maximum(jnp.sum(mm) * (values.size // m.size), 1.0)


def masked_local_step(step: Callable) -> Callable:
    """Wrap a stacked local step ``(params, opt_state, batch) ->
    (params, opt_state, metrics)`` — per-client metrics leaves carry the
    leading client dim — into its mask-aware sibling ``(params,
    opt_state, batch, mask) -> ...``.

    Dead slots still *compute* (the shapes are static; that is the whole
    point) but their updates are discarded: params and optimizer rows
    are ``where``-gated back to their previous values, and metrics
    leaves whose leading dim matches the mask are masked-mean reduced.
    """

    def masked_step(params, opt_state, batch, mask):
        new_params, new_opt, metrics = step(params, opt_state, batch)
        new_params = masked_where(mask, new_params, params)
        new_opt = masked_where(mask, new_opt, opt_state)
        n = mask.shape[0]
        metrics = jax.tree.map(
            lambda v: (masked_mean(v, mask)
                       if getattr(v, "ndim", 0) >= 1 and v.shape[0] == n
                       else v), metrics)
        return new_params, new_opt, metrics
    return masked_step


def pad_to_capacity(sched: PermuteSchedule, slots) -> PermuteSchedule:
    """Pad an alive-set schedule to a :class:`~repro.runtime.slots
    .SlotMap`'s capacity.  ``sched`` slot order must be the map's live
    nodes in **sorted id order** (the overlay controller's convention).
    Dead capacity slots self-loop with weight 1."""
    alive_sorted = sorted(slots.slot_of)
    if len(alive_sorted) != sched.num_clients:
        raise ValueError(
            f"schedule is for {sched.num_clients} clients, slot map "
            f"holds {len(alive_sorted)}")
    assignment = [slots.slot_of[u] for u in alive_sorted]
    return pad_schedule(sched, assignment, slots.capacity)


def participation_mask(mults: Sequence[int], step) -> jnp.ndarray:
    """On-device multirate participation: 1 where ``step % k_u == 0``.

    ``mults`` is the host-static :func:`repro.core.mixing
    .participation_mults` vector; ``step`` may be a traced scalar, so
    the mask lives inside the compiled program — slow clients skip the
    mixing collective with zero retrace."""
    k = jnp.asarray(np.asarray(mults, dtype=np.int64))
    return (jnp.asarray(step) % k == 0).astype(jnp.float32)
