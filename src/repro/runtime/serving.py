"""Continuous-batching serving plane over a fixed-capacity request
:class:`~repro.runtime.slots.SlotMap`.

The serving analogue of the churn-proof training runtime: the device
data plane keeps **one shape forever** — a (capacity,) request axis, a
slotted per-layer KV cache, and a per-slot position vector — and request
churn (a prompt arriving, a generation finishing) is an in-place row
write, never a re-stack or a retrace.  Prompt arrival = join (lowest
free slot, one batched prefill into a fresh B=1 cache, one row insert),
completion = leave (the slot's position is set to -1, which the whole
decode stack — :func:`repro.models.model.decode_step`,
:func:`repro.models.attention.cache_attention`, the Pallas
``flash_decode`` kernel — treats as an *empty slot*: zero attention
output, position frozen, row ready for the next tenant).

Slot lifecycle
--------------
::

    pending ──admit──► slot s: prefill(prompt) ─► pos[s] = len(prompt)
                         │ decode ticks: pos[s] += 1, token appended
                         ▼
    retire (max_new reached, or pos[s] would overflow cache_len)
                         │
                         ▼  pos[s] = -1  (empty; SlotMap frees s)

Admission policy is the whole continuous-vs-static story in one knob:
``policy="continuous"`` admits whenever a slot is free (requests join a
running batch mid-flight); ``policy="static"`` only admits into an
*empty* batch and then drains it completely — the classic static-batch
baseline ``benchmarks/serve_load.py`` measures against.

Zero-retrace contract: the prefill, insert, decode, and retire steps
are jitted once each via :func:`repro.runtime.loop.counting_jit`; slot
indices, positions, and tokens are traced device values, so occupancy
changes never retrace.  :attr:`ServeLoop.retraces` exposes the live
count (pinned to 0 after warmup by ``tests/test_serve.py``).

Position overflow is guarded host-side (a traced position cannot
``raise``): the loop tracks a host mirror of every slot's position and
force-retires a row before its next write would pass ``cache_len`` —
the eager/concrete decode path raises instead
(:func:`repro.models.attention.gqa_decode`).

Hot model reload: :meth:`ServeLoop.reload` swaps the parameter tree
between ticks (same treedef/shapes → no retrace);
:meth:`ServeLoop.reload_from_flat` lifts one client's row straight out
of the training loop's :class:`repro.dist.flat.FlatSpec` flat buffer
(``spec.unravel_row``) — the training→serving seam with no host
round-trip.

Telemetry: ``serve.*`` counters (admitted/completed/ticks/reloads),
occupancy/queue gauges, a ``serve.tick.ms`` span histogram, and one
:class:`repro.obs.rounds.RoundRecord` per batching tick on the ambient
round ledger, so the JSONL/summary plumbing is reused unchanged.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import decode_step, init_cache, prefill
from ..obs.events import get_telemetry
from ..obs.rounds import get_round_ledger
from .loop import counting_jit
from .slots import SlotMap

_CLOCK = time.perf_counter


@dataclasses.dataclass
class Request:
    """One generation request moving through the serving plane.

    ``prompt`` is the token prefix; ``max_new`` the number of tokens to
    generate (the token sampled from the prefill logits is the first).
    The loop fills ``tokens`` and the latency stamps: ``t_arrival``
    when the request became eligible (entered the queue), ``t_first``
    at its first sampled token, ``t_done`` at completion — all
    ``perf_counter`` seconds.

    Deadlines (straggler timeout): ``max_ticks`` bounds how many
    batching ticks the request may occupy a slot after admission;
    ``deadline_s`` is a wall-clock bound measured from ``t_arrival``.
    A request over either bound is force-retired with ``evicted=True``
    (and a ``serve.evictions`` counter) so a stuck generation can never
    occupy capacity forever."""

    rid: int
    prompt: np.ndarray
    max_new: int = 16
    arrival_tick: int = 0
    max_ticks: Optional[int] = None
    deadline_s: Optional[float] = None
    admit_tick: int = -1
    evicted: bool = False
    tokens: List[int] = dataclasses.field(default_factory=list)
    t_arrival: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def done(self) -> bool:
        return self.t_done > 0.0


class ServeLoop:
    """Fixed-capacity continuous-batching decode loop (see module doc).

    Parameters
    ----------
    cfg, params : the model (``cfg.enc_dec`` is rejected — the serving
        plane is decoder-only).
    capacity : request slots (the static batch axis).
    cache_len : per-slot KV slots; every request's prompt+generation
        must fit (longer generations are force-retired).
    prompt_len : the static padded prompt width every admission is
        padded to (one prefill trace for all prompt lengths ≤ it).
    policy : ``"continuous"`` (admit into any free slot) or
        ``"static"`` (admit only into an empty batch, then drain).
    """

    def __init__(self, cfg, params, *, capacity: int, cache_len: int,
                 prompt_len: int, policy: str = "continuous"):
        if cfg.enc_dec:
            raise ValueError("ServeLoop is decoder-only")
        if policy not in ("continuous", "static"):
            raise ValueError(f"unknown policy {policy!r}")
        if prompt_len > cache_len:
            raise ValueError(f"prompt_len {prompt_len} > cache_len {cache_len}")
        if cfg.sliding_window and prompt_len > cfg.sliding_window:
            raise ValueError("padded prompts longer than the sliding window "
                             "are not servable (ragged ring prefill)")
        from ..models.model import layer_plan
        if any(k[0] == "mamba" for k in layer_plan(cfg)):
            raise ValueError("ServeLoop pads ragged prompts, which SSM "
                             "stacks cannot prefill; serve attention "
                             "models here")
        self.cfg = cfg
        self.params = params
        self.capacity = capacity
        self.cache_len = cache_len
        self.prompt_len = prompt_len
        self.policy = policy

        self.slots = SlotMap(capacity)
        self.cache = init_cache(cfg, params, capacity, cache_len,
                                per_slot_pos=True)
        self._tok = jnp.zeros((capacity, 1), jnp.int32)
        self._pos_host = np.full((capacity,), -1, np.int64)
        self.pending: Deque[Request] = deque()
        self.active: Dict[int, Request] = {}
        self.completed: List[Request] = []
        self.tick_index = 0
        self._next_rid = 0

        cfg_ = cfg

        def _prefill_fn(params, tokens, lengths):
            c0 = init_cache(cfg_, params, 1, cache_len, per_slot_pos=True)
            logits, c1 = prefill(cfg_, params, c0, tokens, lengths=lengths)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            return tok, c1

        def _insert_fn(cache, row, slot, tok, tokbuf):
            new = {"pos": jax.lax.dynamic_update_slice(
                cache["pos"], row["pos"].astype(cache["pos"].dtype), (slot,))}
            for key in cache:
                if key == "pos":
                    continue
                new[key] = jax.tree.map(
                    lambda d, s: jax.lax.dynamic_update_slice_in_dim(
                        d, s.astype(d.dtype), slot, axis=1),
                    cache[key], row[key])
            tokbuf = jax.lax.dynamic_update_slice(tokbuf, tok, (slot, 0))
            return new, tokbuf

        def _decode_fn(params, cache, tokbuf):
            logits, new_cache = decode_step(cfg_, params, cache, tokbuf)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            return tok, new_cache

        def _retire_fn(cache, slot):
            new = dict(cache)
            new["pos"] = jax.lax.dynamic_update_slice(
                cache["pos"], jnp.full((1,), -1, cache["pos"].dtype), (slot,))
            return new

        self._prefill_j, self._tc_prefill = counting_jit(_prefill_fn)
        self._insert_j, self._tc_insert = counting_jit(_insert_fn)
        self._decode_j, self._tc_decode = counting_jit(_decode_fn)
        self._retire_j, self._tc_retire = counting_jit(_retire_fn)

    # ---- request intake --------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new: int = 16,
               arrival_tick: int = 0, max_ticks: Optional[int] = None,
               deadline_s: Optional[float] = None) -> Request:
        """Queue one request; returns its :class:`Request` handle.
        ``max_ticks`` / ``deadline_s`` set its eviction deadlines (see
        :class:`Request`)."""
        prompt = np.asarray(prompt, np.int32)
        if prompt.ndim != 1 or prompt.size < 1:
            raise ValueError("prompt must be a non-empty 1-D token list")
        if prompt.size > self.prompt_len:
            raise ValueError(f"prompt length {prompt.size} > static "
                             f"prompt_len {self.prompt_len}")
        if max_ticks is not None and max_ticks < 1:
            raise ValueError("max_ticks must be >= 1")
        req = Request(rid=self._next_rid, prompt=prompt, max_new=max_new,
                      arrival_tick=arrival_tick, max_ticks=max_ticks,
                      deadline_s=deadline_s, t_arrival=_CLOCK())
        self._next_rid += 1
        self.pending.append(req)
        get_telemetry().count("serve.submitted")
        return req

    # ---- internals -------------------------------------------------------
    @property
    def retraces(self) -> int:
        """Fresh traces beyond each step's first — 0 after warmup is the
        zero-retrace-across-churn guarantee, observed live."""
        return (self._tc_prefill.retraces + self._tc_insert.retraces
                + self._tc_decode.retraces + self._tc_retire.retraces)

    @property
    def traces(self) -> int:
        return (self._tc_prefill.traces + self._tc_insert.traces
                + self._tc_decode.traces + self._tc_retire.traces)

    def _admit_one(self, req: Request) -> None:
        bus = get_telemetry()
        slot = self.slots.alloc(req.rid)
        P = self.prompt_len
        padded = np.zeros((1, P), np.int32)
        padded[0, :req.prompt.size] = req.prompt
        lengths = jnp.asarray([req.prompt.size], jnp.int32)
        tok, row = self._prefill_j(self.params, jnp.asarray(padded), lengths)
        self.cache, self._tok = self._insert_j(
            self.cache, row, jnp.asarray(slot, jnp.int32), tok, self._tok)
        self._pos_host[slot] = req.prompt.size
        req.admit_tick = self.tick_index
        req.t_first = _CLOCK()
        req.tokens.append(int(tok[0, 0]))
        self.active[slot] = req
        bus.count("serve.admitted")
        if req.max_new <= 1:
            self._retire(slot, req)

    def _retire(self, slot: int, req: Request) -> None:
        req.t_done = _CLOCK()
        self.slots.free(req.rid)
        self.cache = self._retire_j(self.cache,
                                    jnp.asarray(slot, jnp.int32))
        self._pos_host[slot] = -1
        del self.active[slot]
        self.completed.append(req)
        get_telemetry().count("serve.completed")

    # ---- the batching tick -----------------------------------------------
    def tick(self) -> int:
        """One batching tick: admissions, then one decode step for the
        whole slot axis.  Returns the number of live requests after the
        tick.  Emits one round-ledger record."""
        bus = get_telemetry()
        completed_before = len(self.completed)
        n_admit = 0
        n_evict = self._evict_overdue()
        # static batching = the one-line policy difference: only an
        # EMPTY batch may admit, and then it drains completely
        allow = self.policy == "continuous" or len(self.slots) == 0
        with bus.span("serve.tick"):
            while allow and self.pending and self.slots.num_free > 0:
                self._admit_one(self.pending.popleft())
                n_admit += 1
            if self.active:
                tok, self.cache = self._decode_j(self.params, self.cache,
                                                 self._tok)
                self._tok = tok
                toks = np.asarray(tok[:, 0])
                self._pos_host[self._pos_host >= 0] += 1
                for slot, req in list(self.active.items()):
                    req.tokens.append(int(toks[slot]))
                    # host-side overflow guard: the *next* decode would
                    # write at pos == cache_len → retire now
                    if (len(req.tokens) >= req.max_new
                            or self._pos_host[slot] >= self.cache_len):
                        self._retire(slot, req)
        self.tick_index += 1
        bus.count("serve.ticks")
        bus.gauge("serve.occupancy", len(self.slots))
        bus.gauge("serve.queue_depth", len(self.pending))
        ledger = get_round_ledger()
        if ledger is not None:
            ledger.record(round=self.tick_index, loop="serve",
                          num_alive=len(self.slots),
                          participating=len(self.slots),
                          retraces=self.retraces,
                          admitted=n_admit,
                          completed=len(self.completed) - completed_before,
                          evicted=n_evict,
                          queue_depth=len(self.pending))
        return len(self.active)

    def _evict_overdue(self) -> int:
        """Force-retire active requests past their deadlines (straggler
        timeout): the slot frees before this tick's admissions, so a
        stuck generation yields capacity the moment it expires."""
        bus = get_telemetry()
        n = 0
        now = _CLOCK()
        for slot, req in list(self.active.items()):
            over_ticks = (req.max_ticks is not None
                          and self.tick_index - req.admit_tick
                          >= req.max_ticks)
            over_wall = (req.deadline_s is not None
                         and now - req.t_arrival >= req.deadline_s)
            if over_ticks or over_wall:
                req.evicted = True
                self._retire(slot, req)
                bus.count("serve.evictions")
                n += 1
        return n

    def run(self, max_ticks: int = 100_000) -> List[Request]:
        """Tick until every submitted request has completed (or
        ``max_ticks``).  Returns the completed requests."""
        t = 0
        while (self.pending or self.active) and t < max_ticks:
            self.tick()
            t += 1
        if self.pending or self.active:
            raise RuntimeError(f"serving did not drain in {max_ticks} ticks")
        return self.completed

    # ---- hot model reload ------------------------------------------------
    def reload(self, params) -> None:
        """Swap the serving parameters between ticks.  Same
        treedef/shapes/dtypes → the jitted steps are cache hits (no
        retrace); in-flight requests continue on the new weights."""
        self.params = params
        get_telemetry().count("serve.reloads")

    def reload_from_flat(self, buf: jnp.ndarray, spec, row: int = 0) -> None:
        """Hot-reload from the training loop's (B, N) flat buffer: lift
        client ``row`` via ``spec.unravel_row`` and serve it."""
        self.reload(spec.unravel_row(buf[row]))
