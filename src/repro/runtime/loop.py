"""The static-shape sibling of :class:`repro.overlay.runtime.ChurnTrainLoop`.

:class:`SlotTrainLoop` trains against a **fixed-capacity** client axis:
the jitted local step sees (capacity, ...) shapes on every step of the
run, no matter how membership churns — one trace ever per capacity,
versus the re-stack loop's one trace per distinct alive count.  The
moving parts:

* the :class:`~repro.overlay.controller.OverlayController` runs in
  capacity mode (it owns the :class:`~repro.runtime.slots.SlotMap`,
  pads rebuilt schedules so dead slots self-loop with weight 1, and
  compiles mask-aware mixers ``(params, mask) -> params``);
* membership changes become **in-place row writes** at the step
  boundary: joiners are written into their assigned slot (donor copy
  from the highest-confidence surviving neighbor — the paper's Fig. 18
  catch-up — or fresh init for all-joiner cohorts), leavers simply go
  dead in the mask;
* the local step is mask-aware (``(params, opt_state, batch, mask)``,
  e.g. :func:`repro.runtime.masked.masked_local_step` or
  :func:`repro.launch.steps.dfl_train_bundle` with ``masked=True``):
  dead slots compute but their updates are discarded;
* multirate participation (``periods``) rides the same mask: a slow
  client trains locally every step but only joins the mixing collective
  when ``step % k_u == 0`` — the mask is a runtime input, so this costs
  zero retraces.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.mixing import multirate_participation
from ..faults.plan import DataFaults, edge_mask_for
from ..overlay.controller import OverlayController
from ..overlay.events import ChurnTrace
from ..overlay.runtime import joiner_donors
from .slots import RemapPlan, plan_reset_slots


@dataclasses.dataclass
class TraceCount:
    """Counts Python re-executions of a jitted function's body — i.e.
    XLA traces.  ``retraces`` excludes the unavoidable first trace."""

    traces: int = 0

    @property
    def retraces(self) -> int:
        return max(0, self.traces - 1)


def counting_jit(fn: Callable, **jit_kwargs) -> Tuple[Callable, TraceCount]:
    """``jax.jit(fn, **jit_kwargs)`` plus a :class:`TraceCount` that
    ticks once per trace (compiled executions skip the Python body, so
    they don't count).  The retrace-tax instrumentation used by
    ``benchmarks/slot_runtime``, ``benchmarks/cohort_stream``, and the
    per-round ``retrace_delta`` of :class:`repro.obs.rounds.RoundLedger`.

    ``jit_kwargs`` pass straight through to ``jax.jit``
    (``donate_argnums``, ``static_argnums``, ...).  The counter ticks
    per trace of the *wrapped* body: calling the result from inside
    another jitted function counts that one (inlined) trace, and
    distinct static-arg values or donated-buffer shapes each count
    their own trace, exactly like jax's own cache."""
    import jax

    counter = TraceCount()

    def counted(*args, **kwargs):
        counter.traces += 1
        return fn(*args, **kwargs)
    return jax.jit(counted, **jit_kwargs), counter


# ---- capacity-row surgery (shared by SlotTrainLoop and the cohort
# streaming runtime, repro.scale.cohort) ----------------------------------

def stack_rows(trees):
    """Stack per-client trees into one capacity-stacked tree."""
    import jax
    return jax.tree.map(lambda *ls: jax.numpy.stack(ls), *trees)


def tree_row(tree, i: int):
    """Row ``i`` of every leaf (one client's unstacked state)."""
    import jax
    return jax.tree.map(lambda l: l[i], tree)


def set_tree_row(tree, i: int, row):
    """Functionally write ``row`` into leaf row ``i`` (dtype-cast to the
    destination — the in-place membership write of the slot runtimes)."""
    import jax
    return jax.tree.map(
        lambda l, r: l.at[i].set(r.astype(l.dtype)), tree, row)


@dataclasses.dataclass
class SlotStepRecord:
    """One training step of the slot runtime."""

    step: int
    time: float
    num_alive: int
    participating: int
    loss: float
    swapped: bool
    cache_hit: bool
    joined: Tuple[int, ...]
    left: Tuple[int, ...]


class SlotTrainLoop:
    """Drive a mask-aware local step under churn with static shapes.

    Same host contract as :class:`~repro.overlay.runtime.ChurnTrainLoop`
    — ``make_params(node_id)`` one client's unstacked param tree,
    ``make_batch(node_ids, step)`` a stacked batch for the given alive
    set keyed by node identity — so the two loops are drop-in
    comparable on the same churn trace (the ``benchmarks/slot_runtime``
    parity check).  ``local_step`` is the mask-aware step ``(params,
    opt_state, batch, mask) -> (params, opt_state, metrics)``.

    ``periods`` (optional, node id → MEP period) enables multirate
    participation: the mixing mask at step t is ``alive & (t % k_u ==
    0)``; the local-step mask stays pure aliveness (slow clients keep
    training locally, per the paper's asynchrony model).

    With a wire codec on the controller (``OverlayController(codec=...)``)
    the loop matches the compiled mixer's signature automatically; for an
    **error-feedback** codec it also owns the residual leaf of the slot
    runtime state — a (capacity, N) f32 buffer threaded through every
    mixing round (``mixed, residual = mixer(params, mask, residual)``)
    and zeroed at joiner *and* leaver slots when a remap plan lands
    (:func:`repro.runtime.slots.plan_reset_slots`), so no slot ever
    inherits a previous tenant's compression error.

    With ``OverlayController(flat_io=True)`` the loop keeps the
    parameters **resident in flat form**: ``self.params`` is the raveled
    (capacity, N) buffer across steps, the mixer consumes and produces
    it directly, and the tree view exists only transiently inside the
    jitted local step (unravel → step → ravel in one program) and in
    host-side row surgery — the steady-state round never pays a
    host-visible ravel/unravel.

    ``mesh`` (optional) places the capacity axis on a real device mesh:
    every capacity-stacked row tree (params, optimizer state, batches,
    masks) is sharded over ``client_axis``, so with ``capacity = G ×
    devices`` each device hosts a block-contiguous group of G client
    slots — the grouped layout of :mod:`repro.dist.sync` — and the
    controller must declare the same factor
    (``OverlayController(clients_per_device=G)``).  After each step the
    loop re-pins params/opt state to that canonical row sharding, so
    the jitted local step sees identical shardings every step and the
    zero-retrace guarantee survives whatever layout GSPMD picks for the
    mixer output.

    The step counter persists across :meth:`run` calls, so churn traces
    and participation phases stay consistent when driven incrementally.
    """

    def __init__(self, controller: OverlayController, *,
                 local_step: Callable,
                 make_params: Callable[[int], object],
                 optimizer,
                 make_batch: Callable[[Sequence[int], int], object],
                 periods: Optional[Dict[int, float]] = None,
                 step_time: float = 1.0,
                 jit_local_step: bool = True,
                 mesh=None, client_axis: str = "data",
                 telemetry=None, ledger=None, trace_count=None,
                 health=None):
        """``telemetry`` / ``ledger`` opt into the :mod:`repro.obs`
        plane: an explicit bus / :class:`~repro.obs.rounds.RoundLedger`
        to report into (default: the process globals, which are the
        no-op bus / no ledger until enabled).  With ``jit_local_step``
        the step is jitted through :func:`counting_jit` and
        :attr:`trace_count` tracks its traces; callers that jit their
        own step (``jit_local_step=False``) may pass the matching
        ``trace_count`` so per-round retrace deltas stay observable.

        When the controller's simulator is a
        :class:`repro.faults.ChaosEngine` (it exposes ``data_faults()``)
        the loop runs **degraded rounds**: every step it lowers the
        active link outages / stragglers / partition to the (capacity,
        2L) unreachable-edge mask and passes it to the masked mixer's
        keyword-only ``edge_mask`` — a runtime input, so fault storms
        cost zero retraces.  ``health`` (a
        :class:`repro.faults.HealthTracker`) folds locally-observed
        suspect/evicted peers into the same mask through the versioned
        suspect → evict → heal lifecycle."""
        import jax

        if controller.slots is None:
            raise ValueError(
                "SlotTrainLoop needs a capacity-mode controller "
                "(OverlayController(..., capacity=C))")
        self.controller = controller
        self.capacity = controller.capacity
        self.mesh = mesh
        self.client_axis = client_axis
        if mesh is not None:
            devices = mesh.shape[client_axis]
            expect = controller.clients_per_device * devices
            if self.capacity != expect:
                raise ValueError(
                    f"capacity {self.capacity} != clients_per_device "
                    f"{controller.clients_per_device} × {devices} "
                    f"devices on axis {client_axis!r}")
        self.optimizer = optimizer
        self.make_params = make_params
        self.make_batch = make_batch
        self.periods = periods
        self.step_time = step_time
        self._jax = jax
        self._step = 0
        self._telemetry = telemetry
        self._ledger = ledger
        self.health = health
        # degraded-round plumbing: a ChaosEngine (or anything exposing
        # data_faults()) wrapped around the controller's simulator
        self._chaos_engine = (controller.sim
                              if hasattr(controller.sim, "data_faults")
                              else None)
        self._faults_on = self._chaos_engine is not None or health is not None
        self._last_fault_count = 0
        self.trace_count = (trace_count if trace_count is not None
                            else TraceCount())
        self._last_traces = 0
        # closed-form wire/payload bytes memo keyed on (strategy, L,
        # participating) — _record_round runs every step on the host
        self._bytes_cache: Dict[tuple, tuple] = {}

        # capacity-stacked state: live slots get their node's init, dead
        # slots zeros (their rows are masked and mixed as self-loops)
        template = None
        rows = []
        for slot in range(self.capacity):
            node = controller.slots.node_at(slot)
            if node is not None:
                row = make_params(node)
                template = template if template is not None else row
                rows.append(row)
            else:
                rows.append(None)
        if template is None:
            raise ValueError("controller has no live nodes")
        dead = jax.tree.map(lambda l: jax.numpy.zeros_like(l), template)
        rows = [r if r is not None else dead for r in rows]
        stacked = self._stack(rows)
        self.opt_state = self._shard_rows(jax.vmap(optimizer.init)(stacked))

        self.codec = controller.codec
        self.ef = self.codec is not None and self.codec.error_feedback
        self.flat_io = controller.flat_io
        self._spec = self._row_spec = None
        if self.flat_io or self.ef:
            from ..dist.flat import FlatSpec
            self._spec = FlatSpec.for_tree(stacked)
            self._row_spec = FlatSpec.for_tree(
                jax.tree.map(lambda l: l[:1], stacked))
        if self.flat_io:
            # params live raveled; the tree view exists only inside the
            # jitted step and in host-side row surgery
            self.params = self._shard_rows(self._spec.ravel(stacked))
            spec = self._spec

            def flat_step(buf, opt_state, batch, mask):
                p, o, m = local_step(spec.unravel(buf), opt_state,
                                     batch, mask)
                return spec.ravel(p), o, m
            if jit_local_step:
                self.local_step, self.trace_count = counting_jit(flat_step)
            else:
                self.local_step = flat_step
        else:
            self.params = self._shard_rows(stacked)
            if jit_local_step:
                self.local_step, self.trace_count = counting_jit(local_step)
            else:
                self.local_step = local_step
        # per-client flat-row element count, for the ledger's closed-form
        # wire accounting (lane-padded when a FlatSpec exists — that is
        # what a codec actually ships)
        self._row_elems = (self._spec.size if self._spec is not None
                           else sum(int(np.prod(l.shape[1:], dtype=np.int64))
                                    for l in jax.tree.leaves(stacked)))
        self.residual = (self._shard_rows(jax.numpy.zeros(
            (self.capacity, self._spec.size), jax.numpy.float32))
            if self.ef else None)
        self.records: List[SlotStepRecord] = []

    # ---- state surgery ---------------------------------------------------
    def _stack(self, trees):
        return stack_rows(trees)

    def _shard_rows(self, tree):
        """Pin capacity-stacked leaves to the canonical row sharding
        over ``mesh``'s client axis (no-op without a mesh; leaves
        without the leading capacity dim are replicated)."""
        if self.mesh is None:
            return tree
        from jax.sharding import NamedSharding, PartitionSpec as P

        def put(l):
            if getattr(l, "ndim", 0) >= 1 and l.shape[0] == self.capacity:
                spec = P(self.client_axis, *([None] * (l.ndim - 1)))
            else:
                spec = P()
            return self._jax.device_put(l, NamedSharding(self.mesh, spec))
        return self._jax.tree.map(put, tree)

    def _row(self, tree, i: int):
        return tree_row(tree, i)

    def _set_row(self, tree, i: int, row):
        return set_tree_row(tree, i, row)

    def _tree_of_row(self, slot: int):
        """The (unstacked) param tree held at ``slot`` — a direct row
        read, or an unravel of one flat row in resident-flat mode."""
        if self.flat_io:
            return tree_row(
                self._row_spec.unravel(self.params[slot][None]), 0)
        return self._row(self.params, slot)

    def client_params(self, node_id: int):
        """The (unstacked) current model of one live client."""
        return self._tree_of_row(self.controller.slots.slot_of[node_id])

    def _apply_plan(self, plan: RemapPlan) -> Tuple[Tuple[int, ...],
                                                    Tuple[int, ...]]:
        """Membership change as in-place row writes: joiners get a donor
        copy (Fig. 18 catch-up from the highest-confidence surviving
        neighbor) or a fresh init when every neighbor is itself a
        joiner; leavers' rows just go dead in the mask.  Error-feedback
        residual rows at joiner and leaver slots are zeroed."""
        ctl = self.controller
        joiners = tuple(u for u, _ in plan.joiners)
        survivors = tuple(u for u, _ in plan.survivors)
        donors = (joiner_donors(ctl.alive_schedule, ctl.alive, joiners,
                                survivors) if joiners else {})
        for node, slot in plan.joiners:
            donor = donors.get(node)
            if donor is not None:
                row = self._tree_of_row(ctl.slots.slot_of[donor])
            else:
                row = self.make_params(node)
            if self.flat_io:
                flat = self._row_spec.ravel(
                    self._jax.tree.map(lambda l: l[None], row))[0]
                self.params = self.params.at[slot].set(flat)
            else:
                self.params = self._set_row(self.params, slot, row)
            self.opt_state = self._jax.tree.map(
                lambda l, r: l.at[slot].set(r.astype(l.dtype)),
                self.opt_state, self.optimizer.init(row))
        if joiners:
            self.params = self._shard_rows(self.params)
            self.opt_state = self._shard_rows(self.opt_state)
        if self.ef:
            reset = plan_reset_slots(plan)
            if reset:
                self.residual = self._shard_rows(
                    self.residual.at[np.asarray(reset)].set(0.0))
        return joiners, tuple(u for u, _ in plan.leavers)

    # ---- per-step masks and batches --------------------------------------
    def _mix_mask(self, alive: Tuple[int, ...],
                  alive_mask: np.ndarray, step: int) -> np.ndarray:
        if self.periods is None:
            return alive_mask
        part = multirate_participation(
            [self.periods.get(u, 1.0) for u in alive], step)
        mask = alive_mask.copy()
        slot_of = self.controller.slots.slot_of
        for i, u in enumerate(alive):
            mask[slot_of[u]] *= part[i]
        return mask

    def _edge_mask(self, now: float) -> Tuple[Optional[np.ndarray], int]:
        """The round's (capacity, 2L) unreachable-edge mask, or (None,
        0) when no fault plumbing is configured.  Chaos-engine
        data-plane faults and HealthTracker verdicts are unioned; the
        mask is host-built numpy, consumed as a runtime input."""
        if not self._faults_on:
            return None, 0
        df = (self._chaos_engine.data_faults()
              if self._chaos_engine is not None else DataFaults())
        if self.health is not None:
            self.health.poll(now)
            bad = self.health.unhealthy()
            if bad:
                df = DataFaults(down_pairs=df.down_pairs,
                                slow_nodes=df.slow_nodes | bad,
                                groups=df.groups)
        ctl = self.controller
        slot_nodes = [ctl.slots.node_at(s) for s in range(self.capacity)]
        em = edge_mask_for(ctl.schedule, slot_nodes, df)
        return em, int((em == 0.0).sum())

    def _faults_injected(self) -> int:
        """Chaos-engine injections since the previous round."""
        if self._chaos_engine is None or not hasattr(self._chaos_engine,
                                                     "counts"):
            return 0
        total = sum(self._chaos_engine.counts.values())
        delta, self._last_fault_count = (total - self._last_fault_count,
                                         total)
        return delta

    def _capacity_batch(self, alive: Tuple[int, ...], step: int):
        """Scatter the alive-set batch onto capacity rows (dead slots
        replay row 0's data; their compute is discarded by the mask)."""
        jnp = self._jax.numpy
        batch = self.make_batch(alive, step)
        pos = {u: i for i, u in enumerate(alive)}
        idx = np.zeros((self.capacity,), dtype=np.int32)
        for slot in range(self.capacity):
            node = self.controller.slots.node_at(slot)
            if node is not None:
                idx[slot] = pos[node]
        gather = jnp.asarray(idx)
        return self._jax.tree.map(
            lambda l: jnp.take(l, gather, axis=0), batch)

    # ---- crash/resume ----------------------------------------------------
    def state_dict(self) -> Dict[str, object]:
        """The full slot-runtime training state: the capacity-stacked
        params (flat (capacity, N) buffer in resident-flat mode), the
        optimizer state, and — for an error-feedback codec — the
        residual leaf.  Everything else (schedules, mixers, slot map)
        is a pure function of the controller's simulator, which the
        resume path reconstructs by replaying the control plane."""
        state = {"params": self.params, "opt_state": self.opt_state}
        if self.ef:
            state["residual"] = self.residual
        return state

    def save(self, path: str) -> None:
        """Checkpoint the training state + step counter + slot
        occupancy to ``path`` (:mod:`repro.ckpt.checkpoint` npz).

        The state is saved as its flattened leaf list (optimizer states
        are often NamedTuples/dataclasses the checkpoint treedef spec
        doesn't cover); :meth:`restore` unflattens against the live
        loop's own structure, so a resume must build the loop the same
        way (same capacity, codec, flat_io, optimizer)."""
        from ..ckpt.checkpoint import save as ckpt_save
        state = self.state_dict()
        leaves = [np.asarray(l) for l in self._jax.tree.leaves(state)]
        occupancy = [(-1 if self.controller.slots.node_at(s) is None
                      else int(self.controller.slots.node_at(s)))
                     for s in range(self.capacity)]
        ckpt_save(path, {"leaves": leaves},
                  metadata={"step": int(self._step), "slots": occupancy,
                            "ef": bool(self.ef),
                            "flat_io": bool(self.flat_io)})

    def restore(self, path: str) -> dict:
        """Exact resume from :meth:`save`: restores params / optimizer
        state / EF residual bit-for-bit and the step counter, after
        validating that this loop's slot occupancy matches the
        checkpoint's (the caller replays the control plane — same
        simulator seed and control windows — before restoring, see
        ``tests/test_faults.py``).  Returns the checkpoint metadata."""
        from ..ckpt.checkpoint import load as ckpt_load
        tree, meta = ckpt_load(path)
        if bool(meta.get("ef")) != self.ef or \
                bool(meta.get("flat_io")) != self.flat_io:
            raise ValueError(
                "checkpoint was written by a loop with a different "
                f"wire configuration (ef={meta.get('ef')}, "
                f"flat_io={meta.get('flat_io')})")
        occupancy = [(-1 if self.controller.slots.node_at(s) is None
                      else int(self.controller.slots.node_at(s)))
                     for s in range(self.capacity)]
        if list(meta.get("slots", ())) != occupancy:
            raise ValueError(
                "slot occupancy mismatch: replay the control plane to "
                f"the checkpoint step first (ckpt {meta.get('slots')} "
                f"vs live {occupancy})")
        template = self.state_dict()
        treedef = self._jax.tree.structure(template)
        want = self._jax.tree.leaves(template)
        leaves = tree["leaves"]
        if len(leaves) != len(want):
            raise ValueError(f"checkpoint has {len(leaves)} leaves, "
                             f"this loop expects {len(want)}")
        jnp = self._jax.numpy
        restored = []
        for have, exp in zip(leaves, want):
            arr = jnp.asarray(have)
            if arr.shape != exp.shape or arr.dtype != exp.dtype:
                raise ValueError(
                    f"leaf mismatch: checkpoint {arr.shape}/{arr.dtype} "
                    f"vs live {exp.shape}/{exp.dtype}")
            restored.append(arr)
        state = self._jax.tree.unflatten(treedef, restored)
        self.params = self._shard_rows(state["params"])
        self.opt_state = self._shard_rows(state["opt_state"])
        if self.ef:
            self.residual = self._shard_rows(state["residual"])
        self._step = int(meta["step"])
        # retrace accounting restarts at the live counter: the resumed
        # process pays its own (unavoidable) first traces
        self._last_traces = self.trace_count.traces
        self._last_fault_count = (
            sum(self._chaos_engine.counts.values())
            if self._chaos_engine is not None
            and hasattr(self._chaos_engine, "counts") else 0)
        return meta

    # ---- telemetry -------------------------------------------------------
    def _record_round(self, ledger, step: int, report, participating: int,
                      loss: float, joined, left, faults_injected: int = 0,
                      degraded_edges: int = 0) -> None:
        """One :class:`repro.obs.rounds.RoundRecord`: the closed-form
        wire/payload bytes for this round's participation, the retrace
        delta, and the control-plane latencies (repair = the schedule
        rebuild NDMP churn forced, commit = the staged-swap flip)."""
        from ..dist.sync import sync_bytes_per_client
        ctl = self.controller
        key = (ctl.strategy, ctl.schedule.num_spaces,
               max(int(participating), 1))
        cached = self._bytes_cache.get(key)
        if cached is None:
            row_bytes = 4 * self._row_elems
            kwargs = dict(num_spaces=key[1],
                          clients_per_device=ctl.clients_per_device,
                          active_clients=key[2])
            wire = sync_bytes_per_client(ctl.strategy, row_bytes,
                                         self.capacity, codec=ctl.codec,
                                         **kwargs)
            payload = (sync_bytes_per_client(ctl.strategy, row_bytes,
                                             self.capacity, **kwargs)
                       if ctl.codec is not None else wire)
            cached = self._bytes_cache[key] = (wire, payload)
        wire, payload = cached
        traces = self.trace_count.traces
        delta, self._last_traces = traces - self._last_traces, traces
        ledger.record(
            round=step, time=report.time, loop="slot",
            num_alive=len(report.alive), participating=int(participating),
            loss=loss, wire_bytes_per_client=wire,
            payload_bytes_per_client=payload,
            retraces=self.trace_count.retraces, retrace_delta=delta,
            swapped=report.swapped, rebuilt=report.rebuilt,
            cache_hit=report.cache_hit, joined=joined, left=left,
            repair_ms=report.rebuild_ms, commit_ms=ctl.last_commit_ms,
            faults_injected=faults_injected, degraded_edges=degraded_edges)

    # ---- the loop --------------------------------------------------------
    def run(self, num_steps: int,
            trace: Optional[ChurnTrace] = None) -> List[SlotStepRecord]:
        """``num_steps`` training steps, one control interval each.

        An explicit ``telemetry=``/``ledger=`` override on the loop is
        installed as the process bus/ledger for the duration of the run,
        so the whole stack underneath (controller ``overlay.*``
        counters, codec trace ticks) reports to the same place."""
        import contextlib

        jnp = self._jax.numpy
        ctl = self.controller
        from ..obs import get_telemetry, telemetry
        from ..obs.rounds import get_round_ledger, round_ledger
        stack = contextlib.ExitStack()
        if self._telemetry is not None:
            stack.enter_context(telemetry(self._telemetry))
        if self._ledger is not None:
            stack.enter_context(round_ledger(self._ledger))
        with stack:
            return self._run(num_steps, trace, jnp, ctl,
                             get_telemetry, get_round_ledger)

    def _run(self, num_steps, trace, jnp, ctl,
             get_telemetry, get_round_ledger) -> List[SlotStepRecord]:
        for _ in range(num_steps):
            step = self._step
            report = ctl.step(self.step_time, trace=trace)
            plan = ctl.commit()          # swap lands at the step boundary
            joined, left = ((), ())
            if plan is not None and plan.changed:
                joined, left = self._apply_plan(plan)
            alive = ctl.alive
            alive_mask = ctl.alive_mask()
            mask = self._shard_rows(jnp.asarray(alive_mask))
            mix_mask = self._shard_rows(
                jnp.asarray(self._mix_mask(alive, alive_mask, step)))
            batch = self._shard_rows(self._capacity_batch(alive, step))
            em_np, degraded = self._edge_mask(report.time)
            params, opt_state, metrics = self.local_step(
                self.params, self.opt_state, batch, mask)
            # the hot-swap seam: the controller's mask-aware mixer; slow
            # or dead slots pass through untouched.  EF codecs thread
            # the residual leaf through the round.  Under a fault plane
            # the edge mask is passed every round (even all-ones, so the
            # arity — and thus the trace — never changes mid-run).
            mkw = ({} if em_np is None
                   else {"edge_mask": self._shard_rows(jnp.asarray(em_np))})
            if self.ef:
                mixed, res = ctl.mixer(params, mix_mask, self.residual,
                                       **mkw)
                self.residual = self._shard_rows(res)
            else:
                mixed = ctl.mixer(params, mix_mask, **mkw)
            self.params = self._shard_rows(mixed)
            self.opt_state = self._shard_rows(opt_state)
            part = int(np.asarray(mix_mask).sum())
            loss = float(np.asarray(metrics["loss"]))
            self.records.append(SlotStepRecord(
                step=step, time=report.time, num_alive=len(alive),
                participating=part, loss=loss,
                swapped=report.swapped, cache_hit=report.cache_hit,
                joined=joined, left=left))
            bus = (self._telemetry if self._telemetry is not None
                   else get_telemetry())
            if bus.enabled:
                bus.count("slot.steps")
                bus.gauge("slot.num_alive", len(alive))
                bus.gauge("slot.participating", part)
            ledger = (self._ledger if self._ledger is not None
                      else get_round_ledger())
            if ledger is not None:
                self._record_round(ledger, step, report, part, loss,
                                   joined, left,
                                   faults_injected=self._faults_injected(),
                                   degraded_edges=degraded)
            self._step += 1
        return self.records
