"""Shared benchmark plumbing: CSV emission (optionally mirrored into a
JSON row capture for ``benchmarks.run --json``) + the paper's ML tasks
in synthetic form (offline container)."""

from __future__ import annotations

import sys
import time
from contextlib import contextmanager
from typing import Dict, List, Optional

from repro.data.noniid import shard_partition
from repro.data.synthetic import char_lm, cifar_like, mnist_like
from repro.models.small import CNNTask, LSTMTask, MLPTask

#: When not None, every emit() row is also appended here as a dict —
#: the machine-readable path behind ``benchmarks.run --json``.
_JSON_ROWS: Optional[List[Dict]] = None


def start_json_capture() -> None:
    """Begin mirroring emit() rows into an in-memory JSON row list."""
    global _JSON_ROWS
    _JSON_ROWS = []


def end_json_capture() -> List[Dict]:
    """Stop capturing and return the rows collected since start."""
    global _JSON_ROWS
    rows, _JSON_ROWS = _JSON_ROWS or [], None
    return rows


def _jsonable(v):
    """np scalars → python scalars so json.dumps accepts every row."""
    return v.item() if hasattr(v, "item") else v


def emit(table: str, **fields) -> None:
    """One CSV row: table,key=value,..."""
    if _JSON_ROWS is not None:
        _JSON_ROWS.append({"table": table,
                           **{k: _jsonable(v) for k, v in fields.items()}})
    kv = ",".join(f"{k}={v}" for k, v in fields.items())
    print(f"{table},{kv}")
    sys.stdout.flush()


@contextmanager
def timed(table: str, **fields):
    t0 = time.time()
    yield
    emit(table, seconds=round(time.time() - t0, 2), **fields)


def mnist_task(n_clients=12, shards=3, seed=0, **kw):
    data = mnist_like(n_train=1200, n_test=400, seed=seed)
    part = shard_partition(data.y_train, n_clients, shards, seed=seed)
    return MLPTask(data, part, hidden=32, local_steps=2, batch=32, **kw)


def cifar_task(n_clients=10, shards=3, seed=0):
    data = cifar_like(n_train=800, n_test=300, image=8, seed=seed)
    part = shard_partition(data.y_train, n_clients, shards, seed=seed)
    return CNNTask(data, part, channels=8, local_steps=2, batch=32)


def shakespeare_task(n_clients=8, seed=0):
    data = char_lm(num_roles=24, stream_len=512, test_len=2048, seed=seed)
    return LSTMTask(data, n_clients, hidden=32, seq=24, local_steps=2,
                    batch=8)
