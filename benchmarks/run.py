"""Benchmark harness front door — one module per paper table/figure plus
the roofline and the beyond-paper collective comparison.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig3,fig8]
                                          [--json] [--baseline]

Default is quick mode (CPU-friendly); --full reproduces the paper-scale
settings.  Output: CSV rows ``table,key=value,...``.  With ``--json``
each benchmark additionally writes a machine-readable
``BENCH_<name>.json`` at the repo root (rows + wall time + mode + the
run's :mod:`repro.obs` telemetry block) and appends a slim record to
the ``BENCH_history.jsonl`` append-log (tracked in git, so the perf
trajectory accumulates across commits; render it with
``python -m benchmarks.report --history``).  Every benchmark runs
under a scoped telemetry bus + round ledger, so any instrumented loop
it drives lands its counters in the JSON for free.
``--baseline`` (implies ``--json``) compares
against the committed ``git HEAD`` copy of each ``BENCH_<name>.json``
(falling back to the artifact on disk when untracked) and exits nonzero
when any perf field regresses by more than 25% (lower-is-better
fields: ``seconds`` / ``*_ms``; higher-is-better: ``*_per_s`` /
``speedup``; rows are matched by their non-perf identity fields).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
import traceback
from typing import Dict, List, Optional, Tuple

from repro import obs

from . import (churn_swap, cohort_stream, common, crosspod, fault_storm,
               fig3_topology, fig8_churn, fig11_noniid, fig12_async,
               fig13_locality, fig15_compute_cost, fig16_confidence,
               fig18_churn_accuracy, fig20_scalability, mix_fusion,
               roofline, serve_load, slot_runtime, sync_collectives,
               table3_accuracy)

MODULES = {
    "fig3": fig3_topology,
    "fig8": fig8_churn,
    "table3": table3_accuracy,
    "fig11": fig11_noniid,
    "fig12": fig12_async,
    "fig13": fig13_locality,
    "fig15": fig15_compute_cost,
    "fig16": fig16_confidence,
    "fig18": fig18_churn_accuracy,
    "fig20": fig20_scalability,
    "roofline": roofline,
    "sync_collectives": sync_collectives,
    "crosspod": crosspod,
    "churn_swap": churn_swap,
    "slot_runtime": slot_runtime,
    "mix_fusion": mix_fusion,
    "cohort_stream": cohort_stream,
    "serve_load": serve_load,
    "fault_storm": fault_storm,
}

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
HISTORY = os.path.join(REPO_ROOT, "BENCH_history.jsonl")

#: Regression gate for --baseline: new must stay within 25% of committed.
REGRESSION_TOLERANCE = 0.25


def _write_json(name: str, *, quick: bool, seconds: float, failed: bool,
                rows, telemetry: Optional[Dict] = None) -> str:
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    payload = {"benchmark": name, "quick": quick,
               "seconds": round(seconds, 2), "failed": failed, "rows": rows}
    if telemetry:
        payload["telemetry"] = telemetry
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path


def _git_sha() -> Optional[str]:
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=REPO_ROOT, capture_output=True, text=True,
                             timeout=10)
        return out.stdout.strip() or None if out.returncode == 0 else None
    except Exception:
        return None


def _append_history(name: str, *, quick: bool, seconds: float, failed: bool,
                    rows) -> None:
    """One line per benchmark run: the perf trajectory across commits."""
    record = {"ts": round(time.time(), 1), "git_sha": _git_sha(),
              "benchmark": name, "quick": quick,
              "seconds": round(seconds, 2), "failed": failed,
              "rows": rows}
    with open(HISTORY, "a") as f:
        f.write(json.dumps(record) + "\n")


# --------------------------------------------------------------------------
# --baseline: compare perf fields against the committed BENCH artifacts
# --------------------------------------------------------------------------

def perf_direction(key: str) -> Optional[int]:
    """+1: higher is better; -1: lower is better; None: not a perf
    field (identity or accuracy data, never gated).  Bytes-on-the-wire
    fields (``*_bytes``, ``*_mb``) are lower-is-better; compression
    ratios (``*_reduction``) higher-is-better."""
    if (key == "seconds" or key.endswith("_ms") or key.endswith("_bytes")
            or key.endswith("_mb")):
        return -1
    if (key == "speedup" or key.endswith("_per_s")
            or key.endswith("_reduction")):
        return +1
    return None


def _row_identity(row: Dict) -> Tuple:
    """A row's match key: its table plus every non-perf str/bool/int
    field (floats are measurements, not identity)."""
    return tuple(sorted(
        (k, v) for k, v in row.items()
        if perf_direction(k) is None and isinstance(v, (str, bool, int))))


def compare_rows(baseline_rows: List[Dict], new_rows: List[Dict],
                 tolerance: float = REGRESSION_TOLERANCE) -> List[str]:
    """Regression messages for every matched row whose perf field got
    more than ``tolerance`` worse than the baseline.  Unmatched rows
    (new tables, changed identities) are never regressions."""
    by_id: Dict[Tuple, Dict] = {}
    for row in baseline_rows:
        by_id.setdefault(_row_identity(row), row)
    out = []
    for row in new_rows:
        base = by_id.get(_row_identity(row))
        if base is None:
            continue
        for key, new in row.items():
            direction = perf_direction(key)
            base_v = base.get(key)
            if (direction is None or not isinstance(new, (int, float))
                    or not isinstance(base_v, (int, float))
                    or base_v <= 0 or new <= 0):
                continue
            ratio = new / base_v
            worse = ratio > 1 + tolerance if direction < 0 \
                else ratio < 1 / (1 + tolerance)
            if worse:
                ident = ",".join(f"{k}={v}" for k, v in _row_identity(row))
                out.append(f"{ident}: {key} {base_v} -> {new} "
                           f"({ratio:.2f}x, tolerance {tolerance:.0%})")
    return out


def _baseline_warn(name: str, reason: str) -> None:
    print(f"# WARNING baseline {name}: {reason}; skipping comparison",
          file=sys.stderr, flush=True)


def _load_baseline(name: str, quick: bool) -> Optional[List[Dict]]:
    """The committed (git HEAD) BENCH_<name>.json rows, falling back to
    the artifact currently on disk (e.g. a CI-downloaded baseline) when
    the file is not tracked; None unless comparable (same mode, not a
    failed run).

    A missing artifact is a clean None (there is simply no baseline
    yet); an *unreadable or malformed* one — truncated JSON, a non-dict
    document, rows that aren't objects — warns and returns None so one
    bad artifact degrades to "no comparison" instead of crashing the
    whole ``--baseline`` gate."""
    data = None
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD:BENCH_{name}.json"], cwd=REPO_ROOT,
            capture_output=True, text=True, timeout=10)
    except Exception:
        out = None
    if out is not None and out.returncode == 0:
        try:
            data = json.loads(out.stdout)
        except ValueError:
            _baseline_warn(name, "committed artifact is not valid JSON")
            return None
    if data is None:
        path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
        if not os.path.exists(path):
            return None
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError) as exc:
            _baseline_warn(name, f"unreadable artifact on disk ({exc})")
            return None
    if not isinstance(data, dict):
        _baseline_warn(
            name, f"malformed artifact (expected a JSON object, "
            f"got {type(data).__name__})")
        return None
    if data.get("failed") or data.get("quick") != quick:
        return None
    rows = data.get("rows")
    if rows is None:
        return None
    if (not isinstance(rows, list)
            or not all(isinstance(r, dict) for r in rows)):
        _baseline_warn(name, "malformed rows (expected a list of objects)")
        return None
    return rows or None


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmarks")
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_<name>.json at the repo root "
                         "and append to BENCH_history.jsonl")
    ap.add_argument("--baseline", action="store_true",
                    help="compare against the committed BENCH_<name>.json "
                         "and exit nonzero on >25%% perf regression "
                         "(implies --json)")
    args = ap.parse_args()
    if args.baseline:
        args.json = True

    names = list(MODULES) if not args.only else args.only.split(",")
    unknown = [n for n in names if n not in MODULES]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; "
                 f"choose from {', '.join(MODULES)}")
    failures = []
    regressions: List[str] = []
    for name in names:
        mod = MODULES[name]
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        baseline = (_load_baseline(name, quick=not args.full)
                    if args.baseline else None)
        if args.json:
            common.start_json_capture()
        bus = obs.Telemetry()
        ledger = obs.RoundLedger(bus=bus)
        try:
            with obs.telemetry(bus), obs.round_ledger(ledger):
                mod.run(quick=not args.full)
        except Exception:  # noqa: BLE001 — keep the harness going
            failures.append(name)
            traceback.print_exc()
        finally:
            if args.json:
                rows = common.end_json_capture()
                seconds = time.time() - t0
                telem: Optional[Dict] = {}
                counters = bus.summary()
                if counters.get("counters") or counters.get("gauges"):
                    telem["bus"] = counters
                if len(ledger):
                    telem["rounds"] = ledger.summary()
                path = _write_json(name, quick=not args.full,
                                   seconds=seconds,
                                   failed=name in failures, rows=rows,
                                   telemetry=telem or None)
                _append_history(name, quick=not args.full, seconds=seconds,
                                failed=name in failures, rows=rows)
                print(f"# wrote {os.path.relpath(path, REPO_ROOT)} "
                      f"(+ BENCH_history.jsonl)", flush=True)
                if baseline is not None and name not in failures:
                    found = compare_rows(baseline, rows)
                    for msg in found:
                        print(f"# REGRESSION {name}: {msg}",
                              file=sys.stderr, flush=True)
                    regressions.extend(f"{name}: {m}" for m in found)
                elif args.baseline and baseline is None:
                    print(f"# no comparable committed baseline for {name}",
                          flush=True)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        return 1
    if regressions:
        print(f"# {len(regressions)} perf regression(s) vs committed "
              f"baseline", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
