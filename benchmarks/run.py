"""Benchmark harness front door — one module per paper table/figure plus
the roofline and the beyond-paper collective comparison.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig3,fig8] [--json]

Default is quick mode (CPU-friendly); --full reproduces the paper-scale
settings.  Output: CSV rows ``table,key=value,...``.  With ``--json``
each benchmark additionally writes a machine-readable
``BENCH_<name>.json`` at the repo root (rows + wall time + mode) so the
perf trajectory accumulates across commits.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

from . import (churn_swap, common, crosspod, fig3_topology, fig8_churn,
               fig11_noniid, fig12_async, fig13_locality, fig15_compute_cost,
               fig16_confidence, fig18_churn_accuracy, fig20_scalability,
               roofline, slot_runtime, sync_collectives, table3_accuracy)

MODULES = {
    "fig3": fig3_topology,
    "fig8": fig8_churn,
    "table3": table3_accuracy,
    "fig11": fig11_noniid,
    "fig12": fig12_async,
    "fig13": fig13_locality,
    "fig15": fig15_compute_cost,
    "fig16": fig16_confidence,
    "fig18": fig18_churn_accuracy,
    "fig20": fig20_scalability,
    "roofline": roofline,
    "sync_collectives": sync_collectives,
    "crosspod": crosspod,
    "churn_swap": churn_swap,
    "slot_runtime": slot_runtime,
}

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _write_json(name: str, *, quick: bool, seconds: float, failed: bool,
                rows) -> str:
    path = os.path.join(REPO_ROOT, f"BENCH_{name}.json")
    payload = {"benchmark": name, "quick": quick,
               "seconds": round(seconds, 2), "failed": failed, "rows": rows}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale settings (slow)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmarks")
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_<name>.json at the repo root")
    args = ap.parse_args()

    names = list(MODULES) if not args.only else args.only.split(",")
    unknown = [n for n in names if n not in MODULES]
    if unknown:
        ap.error(f"unknown benchmark(s) {unknown}; "
                 f"choose from {', '.join(MODULES)}")
    failures = []
    for name in names:
        mod = MODULES[name]
        t0 = time.time()
        print(f"# === {name} ===", flush=True)
        if args.json:
            common.start_json_capture()
        try:
            mod.run(quick=not args.full)
        except Exception:  # noqa: BLE001 — keep the harness going
            failures.append(name)
            traceback.print_exc()
        finally:
            if args.json:
                path = _write_json(
                    name, quick=not args.full, seconds=time.time() - t0,
                    failed=name in failures,
                    rows=common.end_json_capture())
                print(f"# wrote {os.path.relpath(path, REPO_ROOT)}",
                      flush=True)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
