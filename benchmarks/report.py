"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSONL files — or, with ``--history``, render the tracked
``BENCH_history.jsonl`` perf trajectory as a markdown table (the CI
job-summary step).

  PYTHONPATH=src python -m benchmarks.report > results/tables.md
  PYTHONPATH=src python -m benchmarks.report --history >> "$GITHUB_STEP_SUMMARY"
"""

from __future__ import annotations

import argparse
import datetime
import json
import os
import sys

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
HISTORY_PATH = os.path.join(REPO_ROOT, "BENCH_history.jsonl")


def _load(path):
    if not os.path.exists(path):
        return []
    rows = [json.loads(l) for l in open(path) if l.strip()]
    # keep the last row per (arch, shape, opts) — reruns override
    out = {}
    for r in rows:
        out[(r["arch"], r["shape"], r.get("opts", "baseline"))] = r
    return sorted(out.values(),
                  key=lambda r: (r["arch"], ORDER.index(r["shape"])))


def fmt(x, nd=2):
    if x == 0:
        return "0"
    if abs(x) < 0.01:
        return f"{x:.1e}"
    return f"{x:,.{nd}f}"


def dryrun_table(rows, title):
    print(f"\n### {title}\n")
    print("| arch | shape | attn | compile s | args GiB/dev | temp GiB/dev "
          "| HLO GFLOP/dev | HBM GB/dev | wire GB/dev | collectives |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("opts", "baseline") != "baseline":
            continue
        cc = "+".join(f"{k}:{v}" for k, v in
                      sorted(r["collective_counts"].items()))
        print(f"| {r['arch']} | {r['shape']} | {r['attn']} "
              f"| {r['compile_s']} | {fmt(r['mem_args_gib'])} "
              f"| {fmt(r['mem_temp_gib'])} "
              f"| {fmt(r['flops_per_dev']/1e9, 0)} "
              f"| {fmt(r['hbm_bytes_per_dev']/1e9, 1)} "
              f"| {fmt(r['wire_bytes_per_dev']/1e9, 1)} | {cc} |")


def roofline_table(rows):
    print("\n### Roofline terms (single pod, per step, seconds)\n")
    print("| arch | shape | t_compute | t_memory | t_collective | dominant "
          "| dom. frac | MODEL/HLO flops |")
    print("|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("opts", "baseline") != "baseline":
            continue
        terms = {"compute": r["t_compute_s"], "memory": r["t_memory_s"],
                 "collective": r["t_collective_s"]}
        dom = max(terms, key=terms.get)
        frac = terms[dom] / max(sum(terms.values()), 1e-12)
        print(f"| {r['arch']} | {r['shape']} | {fmt(terms['compute'], 3)} "
              f"| {fmt(terms['memory'], 3)} | {fmt(terms['collective'], 3)} "
              f"| **{dom}** | {frac:.2f} "
              f"| {r['useful_flops_ratio']:.3f} |")


def perf_table(paths):
    print("\n### Perf variants\n")
    print("| arch | shape | opts | t_compute | t_memory | t_collective "
          "| temp GiB |")
    print("|---|---|---|---|---|---|---|")
    for path in paths:
        for r in _load(path):
            print(f"| {r['arch']} | {r['shape']} | {r.get('opts','baseline')} "
                  f"| {fmt(r['t_compute_s'], 3)} | {fmt(r['t_memory_s'], 3)} "
                  f"| {fmt(r['t_collective_s'], 3)} "
                  f"| {fmt(r['mem_temp_gib'])} |")


def _history_records(path):
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except ValueError:
                continue  # a truncated append never breaks the report
    return out


def _headline_perf(rows, limit=3):
    """Up to ``limit`` ``key=value`` perf highlights from a history
    record's rows (first occurrence of each distinct perf field)."""
    from .run import perf_direction
    seen = {}
    for row in rows or []:
        for key, val in row.items():
            if (key not in seen and isinstance(val, (int, float))
                    and not isinstance(val, bool)
                    and perf_direction(key) is not None):
                seen[key] = val
        if len(seen) >= limit:
            break
    pairs = list(seen.items())[:limit]
    return ", ".join(f"{k}={fmt(float(v), 3)}" for k, v in pairs)


def history_table(path=HISTORY_PATH, last=30):
    """The tracked perf trajectory, newest last, as one markdown table
    (capped at the most recent ``last`` records)."""
    records = _history_records(path)
    print("\n### Benchmark history (BENCH_history.jsonl)\n")
    if not records:
        print(f"_no history at {os.path.relpath(path, REPO_ROOT)}_")
        return
    shown = records[-last:]
    if len(records) > len(shown):
        print(f"_{len(records) - len(shown)} earlier records elided_\n")
    print("| date | sha | benchmark | mode | seconds | rows | status "
          "| headline |")
    print("|---|---|---|---|---|---|---|---|")
    for rec in shown:
        ts = rec.get("ts")
        date = (datetime.datetime.fromtimestamp(ts).strftime("%Y-%m-%d")
                if isinstance(ts, (int, float)) else "?")
        rows = rec.get("rows") or []
        print(f"| {date} | {rec.get('git_sha') or '?'} "
              f"| {rec.get('benchmark', '?')} "
              f"| {'quick' if rec.get('quick') else 'full'} "
              f"| {fmt(float(rec.get('seconds', 0)))} | {len(rows)} "
              f"| {'FAILED' if rec.get('failed') else 'ok'} "
              f"| {_headline_perf(rows)} |")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--history", nargs="?", const=HISTORY_PATH,
                    default=None, metavar="PATH",
                    help="render BENCH_history.jsonl (or PATH) as a "
                         "markdown table instead of the dry-run tables")
    ap.add_argument("--last", type=int, default=30,
                    help="history records shown (newest last)")
    args = ap.parse_args()
    if args.history is not None:
        history_table(args.history, last=args.last)
        return
    single = _load("results/dryrun_single.jsonl")
    multi = _load("results/dryrun_multi.jsonl")
    dryrun_table(single, "Dry-run — single pod 16x16 (256 chips), "
                 "depth-probed costs")
    dryrun_table(multi, "Dry-run — multi-pod 2x16x16 (512 chips), "
                 "compile proof (rolled costs)")
    roofline_table(single)
    perf_table(["results/perf_llama.jsonl", "results/perf_deepseek.jsonl",
                "results/perf_decode.jsonl"])


if __name__ == "__main__":
    main()
