"""Beyond-paper table: FedLay-as-gradient-sync vs all-reduce on the TPU
path — compiled wire bytes of one DFL round at several client counts,
measured from the HLO of the actual shard_map programs (8 host devices,
subprocess so the parent jax stays single-device)."""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import emit

_PROBE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.mixing import build_permute_schedule
    from repro.dist.compat import make_client_mesh, shard_map
    from repro.dist.sync import make_mixer
    from repro.launch.hlo_stats import collective_stats

    n, dim = 8, 1_000_000
    mesh = make_client_mesh(n, "data")
    out = {}
    for strategy in ("fedlay", "allreduce", "ring"):
        sched = build_permute_schedule(n, 3)
        mixer = make_mixer(strategy, sched, "data", n)

        def body(x, w, s):
            return mixer({"m": x}, w, s)["m"]

        f = jax.jit(shard_map(body, mesh=mesh,
                              in_specs=(P("data"), P("data"), P("data")),
                              out_specs=P("data"), check_vma=False))
        lowered = f.lower(
            jax.ShapeDtypeStruct((n, dim), jnp.float32),
            jax.ShapeDtypeStruct((n, 6), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32))
        hlo = lowered.compile().as_text()
        st = collective_stats(hlo)
        out[strategy] = {"wire_bytes_per_dev": st.wire_bytes_per_device,
                         "counts": st.counts}
    print(json.dumps(out))
""")


def run(quick: bool = False) -> None:
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _PROBE], env=env,
                         capture_output=True, text=True, timeout=600)
    if res.returncode != 0:
        emit("sync_collectives", error=res.stderr[-300:].replace(",", ";")
             .replace("\n", " "))
        return
    data = json.loads(res.stdout.strip().splitlines()[-1])
    for strategy, row in data.items():
        emit("sync_collectives", strategy=strategy, clients=8,
             model_mb=4.0,
             wire_mb_per_dev=round(row["wire_bytes_per_dev"] / 1e6, 2),
             ops="+".join(f"{k}:{v}" for k, v in row["counts"].items()))


if __name__ == "__main__":
    run()
