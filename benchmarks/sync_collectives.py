"""Beyond-paper table: FedLay-as-gradient-sync vs all-reduce on the TPU
path — compiled wire bytes of one DFL round, measured from the HLO of
the actual shard_map programs (8 host devices, subprocess so the parent
jax stays at its own device count).

ISSUE 4 adds the ``--clients-per-device`` axis: with G > 1 local
clients per device (``num_clients = 8·G``), intra-device mixing edges
never reach the wire, so measured collective-permute bytes drop below
the flat-layout 2L·model bound.  Each row carries the analytic
prediction (``sync_bytes_per_client`` grouped accounting) next to the
HLO-measured bytes so the model and the compiler stay reconciled.

ISSUE 7 adds the wire-codec axis (``sync_collectives_codec`` rows):
one fedlay ``fuse="flat"`` round per :mod:`repro.wire.codec` codec,
pinning the codec-aware ``sync_bytes_per_client(..., codec=)`` closed
form against the HLO-measured collective-permute bytes (the small
residual gap is the FlatSpec 128-lane padding, which the closed form
prices at the unpadded element count).

  PYTHONPATH=src python -m benchmarks.sync_collectives \
      [--clients-per-device 1,2,4] [--quick]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap
from typing import Sequence

from .common import emit

_PROBE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core.mixing import build_permute_schedule, grouped_routing
    from repro.dist.compat import make_client_mesh, shard_map
    from repro.dist.sync import make_mixer, sync_bytes_per_client
    from repro.launch.hlo_stats import collective_stats

    cfg = json.loads(sys.argv[1])
    dim, spaces, groups = cfg["dim"], cfg["spaces"], cfg["groups"]
    devices = 8
    mesh = make_client_mesh(devices, "data")
    out = []
    for G in groups:
        n = devices * G
        sched = build_permute_schedule(n, spaces)
        for strategy in ("fedlay", "allreduce", "ring"):
            mixer = make_mixer(strategy, sched, "data", n,
                               clients_per_device=G)

            def body(x, w, s):
                return mixer({"m": x}, w, s)["m"]

            f = jax.jit(shard_map(body, mesh=mesh,
                                  in_specs=(P("data"), P("data"),
                                            P("data")),
                                  out_specs=P("data"), check_vma=False))
            lowered = f.lower(
                jax.ShapeDtypeStruct((n, dim), jnp.float32),
                jax.ShapeDtypeStruct((n, 2 * spaces), jnp.float32),
                jax.ShapeDtypeStruct((n,), jnp.float32))
            hlo = lowered.compile().as_text()
            st = collective_stats(hlo)
            model_bytes = 4 * dim
            row = {"strategy": strategy, "clients_per_device": G,
                   "clients": n,
                   "wire_bytes_per_dev": st.wire_bytes_per_device,
                   "model_bytes_per_client": sync_bytes_per_client(
                       strategy, model_bytes, n, spaces,
                       clients_per_device=G),
                   "counts": st.counts}
            if strategy == "fedlay":
                rt = grouped_routing(sched, G)
                row["cross_edges"] = rt.cross_edges
                row["ppermute_rounds_max"] = rt.max_rounds
            out.append(row)

    # wire-codec axis: fedlay flat round per codec, G = 1
    from repro.dist.flat import FlatSpec
    from repro.wire.codec import get_codec
    codec_rows = []
    n = devices
    sched = build_permute_schedule(n, spaces)
    nflat = FlatSpec.for_tree(
        {"m": jax.ShapeDtypeStruct((1, dim), jnp.float32)}).size
    w_sds = jax.ShapeDtypeStruct((n, 2 * spaces), jnp.float32)
    s_sds = jax.ShapeDtypeStruct((n,), jnp.float32)
    x_sds = jax.ShapeDtypeStruct((n, dim), jnp.float32)
    for name in cfg.get("codecs", []):
        codec = get_codec(name)
        ef = codec is not None and codec.error_feedback
        mixer = make_mixer("fedlay", sched, "data", n, fuse="flat",
                           codec=name)
        if ef:
            def body_ef(x, w, s, r, mixer=mixer):
                out_t, r = mixer({"m": x}, w, s, r)
                return out_t["m"], r
            f = jax.jit(shard_map(
                body_ef, mesh=mesh,
                in_specs=(P("data"), P("data"), P("data"),
                          P("data", None)),
                out_specs=(P("data"), P("data", None)), check_vma=False))
            lowered = f.lower(x_sds, w_sds, s_sds,
                              jax.ShapeDtypeStruct((n, nflat),
                                                   jnp.float32))
        else:
            def body_c(x, w, s, mixer=mixer):
                return mixer({"m": x}, w, s)["m"]
            f = jax.jit(shard_map(
                body_c, mesh=mesh,
                in_specs=(P("data"), P("data"), P("data")),
                out_specs=P("data"), check_vma=False))
            lowered = f.lower(x_sds, w_sds, s_sds)
        st = collective_stats(lowered.compile().as_text())
        codec_rows.append({
            "codec": name if name is not None else "uncompressed",
            "wire_bytes_per_dev": st.wire_bytes_per_device,
            "predicted_bytes_per_client": sync_bytes_per_client(
                "fedlay", 4 * dim, n, spaces, codec=name),
            "counts": st.counts})
    print(json.dumps({"rows": out, "codec_rows": codec_rows}))
""")


def run(quick: bool = False,
        clients_per_device: Sequence[int] = ()) -> None:
    groups = list(clients_per_device) or ([1, 2] if quick else [1, 2, 4])
    cfg = {"dim": 250_000 if quick else 1_000_000,
           "spaces": 3, "groups": groups,
           "codecs": [None, "bf16", "int8-block", "int4-block", "topk"]}
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-c", _PROBE, json.dumps(cfg)], env=env,
        capture_output=True, text=True, timeout=600)
    if res.returncode != 0:
        emit("sync_collectives", error=res.stderr[-300:].replace(",", ";")
             .replace("\n", " "))
        return
    data = json.loads(res.stdout.strip().splitlines()[-1])
    codec_rows = data["codec_rows"]
    for row in data["rows"]:
        extra = {}
        if "cross_edges" in row:
            # exact per-client wire bytes for this schedule: one model
            # row per weight>0 cross-device edge.  (The HLO column is a
            # per-device ring-model upper bound — every ppermute op is
            # costed at full operand bytes even on devices its partial
            # perm leaves idle.)
            extra = {"cross_edges": row["cross_edges"],
                     "exact_mb_per_client": round(
                         row["cross_edges"] * 4 * cfg["dim"]
                         / row["clients"] / 1e6, 2),
                     "ppermute_rounds_max": row["ppermute_rounds_max"]}
        emit("sync_collectives", strategy=row["strategy"],
             clients=row["clients"],
             clients_per_device=row["clients_per_device"],
             model_mb=round(4 * cfg["dim"] / 1e6, 2),
             wire_mb_per_dev=round(row["wire_bytes_per_dev"] / 1e6, 2),
             predicted_mb_per_client=round(
                 row["model_bytes_per_client"] / 1e6, 2),
             ops="+".join(f"{k}:{v}" for k, v in row["counts"].items()),
             **extra)
    base = next(r for r in codec_rows if r["codec"] == "uncompressed")
    for row in codec_rows:
        emit("sync_collectives_codec", strategy="fedlay",
             clients=8, codec=row["codec"],
             wire_mb_per_dev=round(row["wire_bytes_per_dev"] / 1e6, 3),
             predicted_mb_per_client=round(
                 row["predicted_bytes_per_client"] / 1e6, 3),
             wire_reduction=round(base["wire_bytes_per_dev"]
                                  / row["wire_bytes_per_dev"], 2)
             if row["wire_bytes_per_dev"] > 0 else -1,
             ops="+".join(f"{k}:{v}" for k, v in row["counts"].items()))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--clients-per-device", default=None,
                    help="comma-separated G values, e.g. 1,2,4")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized table (smaller dim, G in {1,2}); the "
                         "bare invocation reproduces the full table, "
                         "matching the other benchmark modules")
    args = ap.parse_args()
    groups = ([int(g) for g in args.clients_per_device.split(",")]
              if args.clients_per_device else ())
    run(quick=args.quick, clients_per_device=groups)


if __name__ == "__main__":
    main()
