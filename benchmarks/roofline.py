"""Roofline report (deliverable g): reads the dry-run JSONL and emits the
three-term roofline per (arch × shape) — compute / memory / collective
seconds, dominant bottleneck, MODEL_FLOPS/HLO_FLOPs, and a one-line
what-would-move-it-down note per dominant term."""

from __future__ import annotations

import json
import os

from .common import emit

NOTES = {
    "compute": "raise arithmetic intensity: larger per-device batch or "
               "fewer remat recomputes",
    "memory": "cut HBM traffic: fuse attention/SSD blocks (Pallas kernels "
              "keep tiles in VMEM), bf16 intermediates instead of f32",
    "collective": "cut wire bytes: bf16 collectives, reduce-scatter + "
                  "sequence-parallel instead of per-layer all-reduce, or "
                  "FedLay 2L-permute sync instead of global all-reduce",
}


def run(path: str = "results/dryrun_single.jsonl", quick: bool = False) -> None:
    if not os.path.exists(path):
        emit("roofline", error=f"missing {path} (run repro.launch.dryrun)")
        return
    rows = [json.loads(l) for l in open(path) if l.strip()]
    for r in rows:
        terms = {"compute": r["t_compute_s"], "memory": r["t_memory_s"],
                 "collective": r["t_collective_s"]}
        dom = max(terms, key=terms.get)
        bound_frac = terms[dom] / max(sum(terms.values()), 1e-12)
        emit("roofline", arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
             attn=r["attn"],
             t_compute_s=round(terms["compute"], 4),
             t_memory_s=round(terms["memory"], 4),
             t_collective_s=round(terms["collective"], 4),
             dominant=dom,
             dominant_frac=round(bound_frac, 3),
             useful_flops_ratio=round(r["useful_flops_ratio"], 3),
             mem_temp_gib=r["mem_temp_gib"],
             note=NOTES[dom].replace(",", ";"))


if __name__ == "__main__":
    run()
