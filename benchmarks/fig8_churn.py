"""Paper Fig. 8: (a) mass join correctness-vs-time, (b) mass failure
recovery, (c) construction messages per client vs network size.

(a)/(b) run through the live control plane
(:class:`repro.overlay.OverlayController`): each 1 s control step
advances NDMP, extracts the neighbor-table delta, and hot-swaps the
compiled mixer — so the rows also report what the data plane did
(schedule swaps, compile-cache hits) while the overlay converged.
"""

from __future__ import annotations

import numpy as np

from repro.core.ndmp import Simulator
from repro.overlay import ChurnTrace, OverlayController

from .common import emit


def _controller(n, L=3, seed=0):
    sim = Simulator(num_spaces=L, latency=0.35, heartbeat_period=1.0,
                    probe_period=2.0, seed=seed)
    sim.seed_network(list(range(n)))
    return OverlayController(sim, measure_correctness=True)


def mass_join(n0: int = 400, joins: int = 100, degree: int = 6) -> None:
    ctl = _controller(n0, L=degree // 2)
    trace = ChurnTrace.scripted(
        [(0.0, "join", j, int(j % n0))
         for j in range(10_000, 10_000 + joins)])
    # dt=0 priming step: inject the mass join and sample the t=0 dip
    r = ctl.step(0.0, trace=trace)
    emit("fig8a", n0=n0, joins=joins, degree=degree, t=0.0,
         correctness=round(r.correctness, 4), epoch=r.epoch,
         swapped=int(r.swapped), cache_hit=int(r.cache_hit))
    for step in range(20):
        r = ctl.step(1.0)
        emit("fig8a", n0=n0, joins=joins, degree=degree, t=round(r.time, 2),
             correctness=round(r.correctness, 4), epoch=r.epoch,
             swapped=int(r.swapped), cache_hit=int(r.cache_hit))
        if r.correctness == 1.0 and step > 2:
            break
    emit("fig8a_swap", n0=n0, joins=joins, rebuilds=ctl.rebuilds,
         swaps=ctl.swaps, cache_hit_rate=round(ctl.cache.hit_rate, 3))


def mass_failure(n0: int = 400, failures: int = 100, degree: int = 6) -> None:
    ctl = _controller(n0, L=degree // 2)
    trace = ChurnTrace.scripted(
        [(0.0, "fail", f) for f in range(failures)])
    r = ctl.step(0.0, trace=trace)
    emit("fig8b", n0=n0, failures=failures, degree=degree, t=0.0,
         correctness=round(r.correctness, 4), epoch=r.epoch,
         swapped=int(r.swapped), cache_hit=int(r.cache_hit))
    for step in range(40):
        r = ctl.step(1.0)
        emit("fig8b", n0=n0, failures=failures, degree=degree,
             t=round(r.time, 2), correctness=round(r.correctness, 4),
             epoch=r.epoch, swapped=int(r.swapped),
             cache_hit=int(r.cache_hit))
        if r.correctness == 1.0 and step > 2:
            break
    emit("fig8b_swap", n0=n0, failures=failures, rebuilds=ctl.rebuilds,
         swaps=ctl.swaps, cache_hit_rate=round(ctl.cache.hit_rate, 3))


def construction_cost(sizes=(100, 200, 500)) -> None:
    # join-phase traffic is tagged separately, so maintenance can stay on
    # (it is what converges racing near-simultaneous joins)
    for n in sizes:
        sim = Simulator(num_spaces=3, latency=0.05, heartbeat_period=2.0,
                        probe_period=3.0, seed=1)
        sim.seed_network(list(range(10)))
        for j in range(10, n):
            sim.join(j, bootstrap=int(j % 10))
            sim.run_for(0.8)
        sim.run_for(30.0)
        joins = [s.join_messages for i, s in sim.nodes.items() if i >= 10]
        emit("fig8c", n=n, msgs_per_client=round(float(np.mean(joins)), 1),
             correctness=round(sim.correctness(), 4))


def run(quick: bool = False) -> None:
    if quick:
        mass_join(n0=100, joins=25)
        mass_failure(n0=100, failures=25)
        construction_cost(sizes=(50, 150))
    else:
        mass_join()
        mass_failure()
        construction_cost()


if __name__ == "__main__":
    run()
