"""Paper Fig. 8: (a) mass join correctness-vs-time, (b) mass failure
recovery, (c) construction messages per client vs network size."""

from __future__ import annotations

import numpy as np

from repro.core.ndmp import Simulator

from .common import emit


def _sim(n, L=3, seed=0):
    sim = Simulator(num_spaces=L, latency=0.35, heartbeat_period=1.0,
                    probe_period=2.0, seed=seed)
    sim.seed_network(list(range(n)))
    return sim


def mass_join(n0: int = 400, joins: int = 100, degree: int = 6) -> None:
    sim = _sim(n0, L=degree // 2)
    for j in range(10_000, 10_000 + joins):
        sim.join(j, bootstrap=int(j % n0))
    t = 0.0
    for step in range(20):
        sim.run_until(t)
        emit("fig8a", n0=n0, joins=joins, degree=degree, t=round(t, 2),
             correctness=round(sim.correctness(), 4))
        if sim.correctness() == 1.0 and step > 2:
            break
        t += 1.0


def mass_failure(n0: int = 400, failures: int = 100, degree: int = 6) -> None:
    sim = _sim(n0, L=degree // 2)
    for f in range(failures):
        sim.fail(f)
    t = 0.0
    for step in range(40):
        sim.run_until(t)
        emit("fig8b", n0=n0, failures=failures, degree=degree, t=round(t, 2),
             correctness=round(sim.correctness(), 4))
        if sim.correctness() == 1.0 and step > 2:
            break
        t += 1.0


def construction_cost(sizes=(100, 200, 500)) -> None:
    # join-phase traffic is tagged separately, so maintenance can stay on
    # (it is what converges racing near-simultaneous joins)
    for n in sizes:
        sim = Simulator(num_spaces=3, latency=0.05, heartbeat_period=2.0,
                        probe_period=3.0, seed=1)
        sim.seed_network(list(range(10)))
        for j in range(10, n):
            sim.join(j, bootstrap=int(j % 10))
            sim.run_for(0.8)
        sim.run_for(30.0)
        joins = [s.join_messages for i, s in sim.nodes.items() if i >= 10]
        emit("fig8c", n=n, msgs_per_client=round(float(np.mean(joins)), 1),
             correctness=round(sim.correctness(), 4))


def run(quick: bool = False) -> None:
    if quick:
        mass_join(n0=100, joins=25)
        mass_failure(n0=100, failures=25)
        construction_cost(sizes=(50, 150))
    else:
        mass_join()
        mass_failure()
        construction_cost()


if __name__ == "__main__":
    run()
