"""Paper Figs. 18/19: model accuracy under extreme churn — 50 new
clients join a 50-client FedLay mid-training; the new nodes' accuracy
catches up via high-confidence models from existing nodes."""

from __future__ import annotations

import numpy as np

from repro.core.baselines import TOPOLOGY_REGISTRY
from repro.core.dfl import Engine, MethodSpec, capacity_periods

from .common import emit, mnist_task


def run(quick: bool = False) -> None:
    n_old = 8 if quick else 16
    n_total = 2 * n_old
    t_join = 10.0
    total = 30.0 if quick else 60.0
    task = mnist_task(n_clients=n_total, shards=3)
    periods = capacity_periods(n_total, 1.0, seed=0)

    # phase 1: only the first half trains — the not-yet-joined clients
    # are edgeless and dormant (period beyond the horizon)
    from repro.core.topology import Topology
    engine = Engine()
    topo_old = TOPOLOGY_REGISTRY["fedlay"](n_old, 3)
    topo_p1 = Topology(nodes=tuple(range(n_total)), edges=topo_old.edges)
    periods_p1 = np.concatenate([periods[:n_old],
                                 np.full(n_old, 10 * t_join)])
    res1 = engine.run(task, MethodSpec(name="phase1", topology=topo_p1),
                      total_time=t_join, model_bytes=4096, seed=0,
                      periods=periods_p1)
    # phase 2: full network; new nodes start from init, old keep params
    topo_new = TOPOLOGY_REGISTRY["fedlay"](n_total, 3)
    res2 = engine.run(task, MethodSpec(name="phase2", topology=topo_new),
                      total_time=total - t_join, model_bytes=4096, seed=1,
                      periods=periods,
                      init_params=res1.final_params[:n_old]
                      + [task.init_params(0)] * n_old)
    for row in res2.trace:
        accs = row.accs
        if accs is None:
            continue
        emit("fig18", t=round(t_join + row.time, 1),
             old_nodes_acc=round(float(np.mean(accs[:n_old])), 4),
             new_nodes_acc=round(float(np.mean(accs[n_old:])), 4))


if __name__ == "__main__":
    run()
